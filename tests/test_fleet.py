"""Fleet (capacity-bucketed engine pools) and async sharded saver tests.

The fleet's exactness contract: a tenant served through the fleet —
including bucket migrations, lane reuse after retirement, and sharded
pools — produces the SAME p-value stream and read-path results as a
dedicated single-lane engine fed the same observations, because
repadding to a larger capacity only appends inert fill (capacity
padding is p-value-invariant, the same property the engines' ``grow``
relies on).
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import AsyncShardedSaver, Fleet, ServingEngine
from repro.serving.fleet import pow2_buckets
from repro.serving.snapshot import SessionStore
from repro.telemetry import MetricsRegistry
from repro.telemetry.costmodel import CostModel

D, K = 3, 3


def test_pow2_buckets():
    assert pow2_buckets(8, 64) == [8, 16, 32, 64]
    assert pow2_buckets(8, 8) == [8]
    assert pow2_buckets(8, 100) == [8, 16, 32, 64, 100]


def _streams(rng, tids, T, mode):
    out = {}
    for t in tids:
        x = rng.normal(size=(T, D)).astype(np.float32)
        if mode == "classification":
            y = rng.integers(0, 3, size=T).astype(np.int32)
        else:
            y = rng.normal(size=T).astype(np.float32)
        out[t] = (x, y, rng.uniform(size=T).astype(np.float32))
    return out


def _ref_engine(mode):
    if mode == "classification":
        return ServingEngine(n_sessions=1, capacity=8, dim=D, k=K,
                             n_labels=3, window=None)
    from repro.regression.engine import RegressionServingEngine
    return RegressionServingEngine(n_sessions=1, capacity=8, dim=D, k=K,
                                   window=None)


@pytest.mark.parametrize("mode", ["classification", "regression"])
def test_fleet_matches_dedicated_engines(mode):
    """Fleet p-values == dedicated 1-lane engines across migrations
    and ragged per-tenant activity; reads match too."""
    rng = np.random.default_rng(1)
    tids = [f"t{i}" for i in range(4)]
    T = 28  # crosses cap_min=8 twice for the always-active tenant
    metrics = MetricsRegistry()
    fleet = Fleet(dim=D, k=K, n_labels=3, mode=mode, cap_min=8,
                  cap_max=64, pool_sessions=4, metrics=metrics)
    for t in tids:
        fleet.admit(t)
    refs = {t: _ref_engine(mode) for t in tids}
    ref_state = {t: refs[t].init_state() for t in tids}
    streams = _streams(rng, tids, T, mode)

    for step in range(T):
        items = {}
        for i, t in enumerate(tids):
            if step % (i + 1) == 0:  # tenant i active every i+1 steps
                x, y, tau = streams[t]
                n = fleet.occupancy(t)
                items[t] = (x[n], y[n], tau[n])
        ps = fleet.observe(items)
        for t, (xx, yy, tt) in items.items():
            ref_state[t], pref = refs[t].observe(
                ref_state[t], jnp.asarray(xx)[None], jnp.asarray([yy]),
                jnp.asarray([tt]))
            np.testing.assert_array_equal(
                np.asarray(ps[t]), np.asarray(pref[0]), err_msg=t)

    Xq = jnp.asarray(rng.normal(size=(2, D)).astype(np.float32))
    for t in tids:
        if mode == "classification":
            a = fleet.predict(t, Xq)
            b = refs[t].predict(ref_state[t], Xq)[0]
        else:
            a = fleet.intervals(t, Xq, 0.1)
            b = refs[t].intervals(ref_state[t], Xq, 0.1)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=t)

    # the always-active tenant crossed 8 -> 16 -> 32: migrations fired
    assert metrics.counter("fleet_migrations_total", mode=mode).value >= 2
    assert fleet.occupancy(tids[0]) == T


def test_fleet_retire_readmit_reuses_lane_fresh():
    rng = np.random.default_rng(2)
    fleet = Fleet(dim=D, k=K, n_labels=3, cap_min=8, cap_max=32,
                  pool_sessions=2)  # one pool, 2 lanes: reuse is forced
    fleet.admit("a")
    fleet.admit("b")
    (x, y, tau), = _streams(rng, ["a"], 6, "classification").values()
    for i in range(6):
        fleet.observe({"a": (x[i], y[i], tau[i])})
    fleet.retire("a")
    with pytest.raises(KeyError):
        fleet.occupancy("a")
    fleet.admit("c")  # lands on a's recycled lane
    assert fleet.occupancy("c") == 0
    ref = _ref_engine("classification")
    rst, rp = ref.observe(ref.init_state(), jnp.asarray(x[0])[None],
                          jnp.asarray(y[:1]), jnp.asarray(tau[:1]))
    p = fleet.observe({"c": (x[0], y[0], tau[0])})
    np.testing.assert_array_equal(np.asarray(p["c"]), np.asarray(rp[0]))


def test_fleet_admit_twice_raises():
    fleet = Fleet(dim=D, k=K, cap_min=8, cap_max=16)
    fleet.admit("a")
    with pytest.raises(KeyError):
        fleet.admit("a")


def test_fleet_buckets_from_cost_model():
    """suggest_buckets drives the pool boundaries; pow2 is the
    no-model fallback and the linear-cost special case."""
    lin = CostModel({("classification", "observe_many", c):
                     {"a": 1e-4, "b": 1e-6 * c, "n": 8.0}
                     for c in (64, 256, 1024)})
    quad = CostModel({("classification", "observe_many", c):
                      {"a": 1e-4, "b": 1e-9 * c * c, "n": 8.0}
                      for c in (64, 256, 1024)})
    f_lin = Fleet(dim=D, k=K, cap_min=8, cap_max=64, cost_model=lin)
    assert f_lin.buckets == lin.suggest_buckets(cap_min=8, cap_max=64)
    assert f_lin.buckets == pow2_buckets(8, 64)  # alpha=1 => pow2
    f_quad = Fleet(dim=D, k=K, cap_min=8, cap_max=64, cost_model=quad)
    assert f_quad.buckets == quad.suggest_buckets(cap_min=8, cap_max=64)
    # quadratic cost => denser (sqrt2-spaced) boundaries than pow2
    assert len(f_quad.buckets) > len(f_lin.buckets)
    f_none = Fleet(dim=D, k=K, cap_min=8, cap_max=64)
    assert f_none.buckets == pow2_buckets(8, 64)


def test_async_sharded_saver_matches_blocking_save(tmp_path):
    rng = np.random.default_rng(3)
    eng = ServingEngine(n_sessions=8, capacity=16, dim=D, k=K,
                        n_labels=3, window=8)
    xs = jnp.asarray(rng.normal(size=(6, 8, D)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 3, size=(6, 8)), jnp.int32)
    ts = jnp.asarray(rng.uniform(size=(6, 8)), jnp.float32)
    state, _ = eng.observe_many(eng.init_state(), xs, ys, ts)

    sync_store = SessionStore(str(tmp_path / "sync"))
    sync_store.save(6, state, meta=eng.meta(), blocking=True)
    async_store = SessionStore(str(tmp_path / "async"))
    saver = AsyncShardedSaver(async_store, shards=4)
    saver.save(6, state, meta=eng.meta())
    saver.close()

    eng_a, st_a, step_a = sync_store.restore_engine()
    eng_b, st_b, step_b = async_store.restore_engine()
    assert step_a == step_b == 6
    assert eng_a.meta() == eng_b.meta()
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(st_a),
                      jax.tree_util.tree_leaves(st_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # the saver's copies were real: donating-style mutation of the
    # original state after save() must not corrupt what was written
    assert async_store.latest_step() == 6


def test_async_saver_surfaces_worker_errors(tmp_path):
    class Boom(SessionStore):
        def save(self, *a, **kw):
            raise RuntimeError("disk on fire")

    eng = ServingEngine(n_sessions=4, capacity=8, dim=D, k=K,
                        n_labels=2, window=None)
    saver = AsyncShardedSaver(Boom(str(tmp_path)), shards=2)
    saver.save(1, eng.init_state(), meta=eng.meta())
    with pytest.raises(RuntimeError, match="async snapshot save failed"):
        saver.close()


_SHARDED_FLEET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    from repro.serving import Fleet
    rng = np.random.default_rng(7)
    x = rng.normal(size=(20, 3, 3)).astype(np.float32)
    y = rng.integers(0, 3, size=(20, 3)).astype(np.int32)
    tau = rng.uniform(size=(20, 3)).astype(np.float32)
    ref = None
    for shards in (1, 4):
        fleet = Fleet(dim=3, k=3, n_labels=3, cap_min=8, cap_max=32,
                      pool_sessions=8, shards=shards)
        for t in ("a", "b", "c"):
            fleet.admit(t)
        ps_all = []
        for step in range(20):
            ps = fleet.observe({t: (x[step, i], y[step, i], tau[step, i])
                                for i, t in enumerate(("a", "b", "c"))})
            ps_all.append([float(np.asarray(ps[t]))
                           for t in ("a", "b", "c")])
        if ref is None:
            ref = ps_all
        else:
            assert ps_all == ref, "sharded fleet diverged"
    print("FLEET_SHARDED_OK")
""")


def test_sharded_fleet_matches_unsharded():
    r = subprocess.run([sys.executable, "-c", _SHARDED_FLEET],
                       capture_output=True, text=True, timeout=600)
    assert "FLEET_SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_merge_bench_rows_ownership(tmp_path):
    """bench_kind-prefix row ownership: each bench module replaces only
    its own row family; "" owns exactly the un-kinded rows."""
    import importlib.util
    import json
    import os

    spec = importlib.util.spec_from_file_location(
        "bench_common", os.path.join(os.path.dirname(__file__), os.pardir,
                                     "benchmarks", "common.py"))
    common = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(common)
    out = str(tmp_path / "bench.json")

    common.merge_bench_rows(out, [{"sessions": 8}], owned_prefixes=("",))
    common.merge_bench_rows(
        out, [{"bench_kind": "replay", "workload": "steady"},
              {"bench_kind": "replay_autotune"}],
        owned_prefixes=("replay",))
    common.merge_bench_rows(
        out, [{"bench_kind": "fleet_scaling", "tenants": 64}],
        owned_prefixes=("fleet",))
    rows = json.load(open(out))["results"]
    assert len(rows) == 4

    # fleet rewrite replaces fleet* rows, keeps replay* and un-kinded
    common.merge_bench_rows(
        out, [{"bench_kind": "fleet_scaling", "tenants": 128},
              {"bench_kind": "fleet_lifecycle"}],
        owned_prefixes=("fleet",))
    rows = json.load(open(out))["results"]
    kinds = sorted(str(r.get("bench_kind", "")) for r in rows)
    assert kinds == ["", "fleet_lifecycle", "fleet_scaling", "replay",
                     "replay_autotune"]
    fleet = [r for r in rows if r.get("bench_kind") == "fleet_scaling"]
    assert fleet == [{"bench_kind": "fleet_scaling", "tenants": 128}]

    # "" owns only un-kinded rows: serve_bench-style rewrite keeps both
    # other families
    common.merge_bench_rows(
        out, [{"sessions": 32}, {"bench_kind": "sliding_full_window"}],
        owned_prefixes=("", "sliding_full_window"))
    rows = json.load(open(out))["results"]
    assert {str(r.get("bench_kind", "")) for r in rows} == {
        "", "sliding_full_window", "fleet_scaling", "fleet_lifecycle",
        "replay", "replay_autotune"}
    unkinded = [r for r in rows if "bench_kind" not in r]
    assert unkinded == [{"sessions": 32}]
