"""k-NN CP regression (paper Section 8.1): optimized == standard; interval
sweep == brute-force grid evaluation; ICP regression covers.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import regression as reg
from repro.data.synthetic import make_regression


def _data(n, seed):
    X, y = make_regression(n_samples=n, n_features=5, seed=seed)
    return X.astype(np.float32), y.astype(np.float32)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 7))
def test_regression_optimized_equals_standard(seed, k):
    X, y = _data(50, seed)
    Xt, _ = _data(6, seed + 1)
    # irrational-ish offset: grid points must not coincide with
    # critical points (measure-zero f32 ties; see below)
    tq = jnp.linspace(float(y.min()) - 5, float(y.max()) + 5, 21) \
        + 0.0137039
    p_std = np.asarray(reg.pvalues_standard(X, y, Xt, tq, k=k))
    st_ = reg.fit(X, y, k=k)
    p_opt = np.asarray(reg.pvalues_optimized(st_, Xt, tq, k=k))
    # both paths are exact; the only permitted discrepancy is a query point
    # landing within f32 epsilon of a critical point (measure-zero tie),
    # where the rank count may flip by a unit or two
    d = np.abs(p_std - p_opt)
    n = X.shape[0]
    assert (d > 1e-6).mean() <= 0.05, d.max()
    assert d.max() <= 3.5 / (n + 1), d.max()


def test_interval_matches_grid_bruteforce():
    """Sweep-derived interval == hull of {t on a fine grid : p(t) > eps}."""
    X, y = _data(60, 0)
    Xt, _ = _data(4, 1)
    k, eps = 5, 0.15
    st_ = reg.fit(X, y, k=k)
    iv = np.asarray(reg.intervals_optimized(st_, Xt, k=k, epsilon=eps))
    grid = jnp.linspace(float(y.min()) - 50, float(y.max()) + 50, 4001)
    pg = np.asarray(reg.pvalues_optimized(st_, Xt, grid, k=k))
    g = np.asarray(grid)
    for i in range(Xt.shape[0]):
        ok = g[pg[i] > eps]
        assert ok.size, "grid found empty set but sweep nonempty?"
        lo, hi = ok.min(), ok.max()
        step = g[1] - g[0]
        assert abs(iv[i, 0] - lo) <= 2 * step, (iv[i], lo, hi)
        assert abs(iv[i, 1] - hi) <= 2 * step, (iv[i], lo, hi)


def test_interval_coverage():
    """Intervals cover the true label >= 1 - eps of the time."""
    hits, total = 0, 0
    for seed in range(4):
        X, y = _data(120, seed)
        st_ = reg.fit(X[:90], y[:90], k=7)
        iv = np.asarray(reg.intervals_optimized(
            st_, X[90:120], k=7, epsilon=0.2))
        yt = y[90:120]
        hits += int(np.sum((yt >= iv[:, 0]) & (yt <= iv[:, 1])))
        total += 30
    assert hits / total >= 0.8 - 0.08, hits / total


def test_icp_regression_coverage():
    X, y = _data(200, 5)
    iv = np.asarray(reg.icp_intervals(
        jnp.asarray(X[:160]), jnp.asarray(y[:160]), jnp.asarray(X[160:]),
        k=7, t=100, epsilon=0.2))
    yt = y[160:]
    cov = np.mean((yt >= iv[:, 0]) & (yt <= iv[:, 1]))
    assert cov >= 0.8 - 0.12, cov


def test_pvalue_at_boundary_cases():
    """b_i = -1/k with k = 1 exercises the |b_i| = |b| linear branch.

    The query grid is offset by an irrational-ish epsilon: a grid point
    landing exactly ON a critical point is a measure-zero tie where f32
    rounding legitimately differs between the two (exact) paths."""
    X, y = _data(30, 2)
    Xt, _ = _data(3, 3)
    tq = jnp.linspace(-100.0, 100.0, 41) + 0.0137039
    p_std = np.asarray(reg.pvalues_standard(X, y, Xt, tq, k=1))
    st_ = reg.fit(X, y, k=1)
    p_opt = np.asarray(reg.pvalues_optimized(st_, Xt, tq, k=1))
    d = np.abs(p_std - p_opt)
    assert (d > 1e-6).mean() <= 0.02, d.max()
    assert d.max() <= 2.5 / (X.shape[0] + 1), d.max()
