"""Replay harness, load generators, cost model (repro.telemetry).

The acceptance-critical properties:
* every loadgen workload emits schema-valid, strictly-ordered,
  byte-deterministic traces interchangeable with recorded ones;
* replaying the same trace twice (fixed seed, speedup=inf) leaves the
  engine in a bit-identical final state with identical step counts,
  and ``chunk`` coalescing does not change that state (it rides the
  engines' observe_many == observe x T property);
* the cost model recovers planted affine coefficients, its JSON
  round-trip is bitwise, and ``suggest_chunk`` / ``suggest_buckets``
  invert the model as documented;
* ``launch/serve.py --replay`` runs end-to-end for both engines.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.telemetry import (CostModel, MetricsRegistry, calibrate_engine,
                             iter_trace, loadgen, replay, validate_record,
                             write_trace)

GEO = dict(ops=48, tenants=3, capacity=16)
ENG = dict(dim=4, k=3)

# ---------------------------------------------------------------- loadgen


def test_loadgen_all_workloads_schema_valid():
    for w in loadgen.WORKLOADS:
        recs = loadgen.generate(w, **GEO, seed=3, slo_s=0.05,
                                predict_every=8)
        assert len(recs) == GEO["ops"]
        for r in recs:
            validate_record(r)
            assert r["workload"] == w and r["seed"] == 3
            assert r["slo_s"] == 0.05
        ts = [r["t"] for r in recs]
        assert all(b > a for a, b in zip(ts, ts[1:]))
        ops = [r["op"] for r in recs]
        assert "observe" in ops and "predict" in ops
        # one read per predict_every observes, never back-to-back reads
        assert ops.count("predict") == GEO["ops"] // 9


def test_loadgen_deterministic_in_seed():
    a = loadgen.generate("bursty", **GEO, seed=7)
    b = loadgen.generate("bursty", **GEO, seed=7)
    c = loadgen.generate("bursty", **GEO, seed=8)
    assert a == b
    assert a != c


def test_loadgen_zipf_active_subsets_are_skewed():
    recs = loadgen.generate("zipf", ops=256, tenants=8, capacity=16,
                            seed=0, predict_every=0)
    counts = np.zeros(8)
    for r in recs:
        assert len(r["active"]) == 4  # zipf_active_frac=0.5 of 8
        assert r["active"] == sorted(set(r["active"]))
        counts[r["active"]] += 1
    # Zipf(1.2) weights: rank 0 must dominate rank 7 by a wide margin
    assert counts[0] > 2 * counts[7]


def test_loadgen_regression_trace_reads_intervals():
    recs = loadgen.generate("steady", **GEO, engine="regression", seed=0)
    assert {r["op"] for r in recs} == {"observe", "intervals"}


def test_loadgen_rejects_unknown_workload():
    with pytest.raises(ValueError):
        loadgen.generate("tsunami", **GEO)


# ------------------------------------------------- trace streaming I/O


def test_write_then_iter_trace_roundtrip(tmp_path):
    recs = loadgen.generate("diurnal", **GEO, seed=1)
    p = str(tmp_path / "t.jsonl")
    assert write_trace(p, recs) == len(recs)
    assert list(iter_trace(p)) == recs


def test_iter_trace_rejects_non_monotone_seq(tmp_path):
    recs = loadgen.generate("steady", ops=4, tenants=1, capacity=8)
    recs[2]["seq"] = recs[1]["seq"]
    p = str(tmp_path / "t.jsonl")
    with open(p, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    with pytest.raises(ValueError, match="monotone"):
        list(iter_trace(p))
    # validation off: the stream passes through
    assert len(list(iter_trace(p, validate=False))) == 4


def test_iter_trace_rejects_invalid_record(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with open(p, "w") as f:
        f.write(json.dumps({"schema": 2, "seq": 0, "t": 0.0}) + "\n")
    with pytest.raises(ValueError):
        list(iter_trace(p))


# ----------------------------------------------------------------- replay


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


@pytest.fixture(scope="module")
def bursty_trace():
    return loadgen.generate("bursty", **GEO, seed=5, predict_every=8)


@pytest.fixture(scope="module")
def bursty_replayed(bursty_trace):
    return replay(bursty_trace, **ENG, seed=0)


def test_replay_twice_is_bit_identical(bursty_trace, bursty_replayed):
    again = replay(bursty_trace, **ENG, seed=0)
    assert _leaves_equal(bursty_replayed.state, again.state)
    for key in ("ops_replayed", "ticks", "session_steps", "tenants",
                "capacity"):
        assert bursty_replayed.report[key] == again.report[key]


def test_replay_chunk_coalescing_is_bit_neutral(bursty_trace,
                                                bursty_replayed):
    chunked = replay(bursty_trace, **ENG, seed=0, chunk=8)
    assert _leaves_equal(bursty_replayed.state, chunked.state)
    assert chunked.report["ticks"] == bursty_replayed.report["ticks"]


def test_replay_seed_changes_traffic(bursty_trace, bursty_replayed):
    other = replay(bursty_trace, **ENG, seed=1)
    assert not _leaves_equal(bursty_replayed.state, other.state)


def test_replay_report_and_metrics(bursty_trace, bursty_replayed):
    rep = bursty_replayed.report
    n_obs = sum(r["op"] == "observe" for r in bursty_trace)
    assert rep["ops_replayed"] == len(bursty_trace)
    assert rep["ticks"] == n_obs
    assert rep["session_steps"] == n_obs * GEO["tenants"]
    assert rep["steps_per_s"] > 0
    assert set(rep["per_op"]) == {"observe", "predict"}
    for d in rep["per_op"].values():
        assert 0 < d["p50_s"] <= d["p99_s"]
        assert d["sojourn_p99_s"] > 0
    names = {m["name"]
             for m in bursty_replayed.metrics.to_dict()["metrics"]}
    assert {"replay_sojourn_s", "replay_queue_depth",
            "replay_steps_per_s", "replay_slo_violation_frac",
            "replay_ops_total"} <= names


def test_replay_slo_accounting(bursty_trace):
    # speedup=inf: sojourn == service time, strictly positive on CPU
    tight = replay(bursty_trace, **ENG, seed=0, slo_s=1e-12).report
    loose = replay(bursty_trace, **ENG, seed=0, slo_s=1e3).report
    assert tight["slo_violation_frac"] == 1.0
    assert loose["slo_violation_frac"] == 0.0
    # no SLO anywhere: the fraction is undefined, not zero
    assert math.isnan(replay(bursty_trace, **ENG,
                             seed=0).report["slo_violation_frac"])


def test_replay_zipf_masks_drive_step_counts():
    recs = loadgen.generate("zipf", **GEO, seed=2, predict_every=0)
    rep = replay(recs, **ENG, seed=0).report
    assert rep["session_steps"] == sum(
        len(r["active"]) for r in recs if r["op"] == "observe")


def test_replay_regression_engine(bursty_trace):
    recs = loadgen.generate("steady", **GEO, engine="regression", seed=4,
                            predict_every=12)
    res = replay(recs, engine="regression", **ENG, seed=0)
    assert res.report["engine"] == "regression"
    assert set(res.report["per_op"]) == {"intervals", "observe"}
    assert res.report["ticks"] > 0


def test_replay_skips_unreplayable_ops(bursty_trace):
    recs = list(bursty_trace) + [{
        "schema": 2, "seq": bursty_trace[-1]["seq"] + 1,
        "t": bursty_trace[-1]["t"] + 1.0, "op": "snapshot_save",
        "wall_s": 0.0}]
    rep = replay(recs, **ENG, seed=0).report
    assert rep["ops_skipped"] == 1
    assert rep["ops_replayed"] == len(bursty_trace)


def test_replay_rejects_empty_and_bad_speedup(bursty_trace):
    with pytest.raises(ValueError):
        replay([], **ENG)
    with pytest.raises(ValueError):
        replay(bursty_trace, **ENG, speedup=0.0)


# -------------------------------------------------------------- costmodel


def _synth_records(a, b, *, ticks=(1, 4, 16, 64), reps=3, bucket=32,
                   engine="classification"):
    recs = []
    for i, t in enumerate(ticks):
        for r in range(reps):
            recs.append({"seq": i * reps + r, "op": "observe_many",
                         "ticks": t, "wall_s": a + b * t,
                         "cap_bucket": bucket, "engine": engine})
    return recs


def test_costmodel_fit_recovers_planted_affine():
    a, b = 2e-4, 5e-5
    m = CostModel.fit(_synth_records(a, b))
    e = m.entries[("classification", "observe_many", 32)]
    assert e["a"] == pytest.approx(a, rel=1e-6)
    assert e["b"] == pytest.approx(b, rel=1e-6)
    assert m.predict("observe_many", ticks=10,
                     cap_bucket=32) == pytest.approx(a + 10 * b, rel=1e-6)


def test_costmodel_excludes_compile_and_zero_wall():
    recs = _synth_records(1e-4, 1e-5)
    recs[0]["compile"] = True
    recs[0]["wall_s"] = 50.0  # would wreck the fit if included
    recs.append({"seq": 99, "op": "observe_many", "ticks": 1,
                 "wall_s": 0.0, "cap_bucket": 32,
                 "engine": "classification"})
    e = CostModel.fit(recs).entries[("classification", "observe_many", 32)]
    assert e["a"] == pytest.approx(1e-4, rel=1e-6)


def test_costmodel_suggest_chunk_inverts_model():
    a, b = 3e-4, 2e-5
    m = CostModel.fit(_synth_records(a, b))
    f = 0.05
    want = math.ceil(a * (1 - f) / (b * f))
    assert m.suggest_chunk(cap_bucket=32, overhead_frac=f) == want
    # amortized overhead share at the suggested chunk is at most f
    t = m.suggest_chunk(cap_bucket=32, overhead_frac=f)
    assert a / (a + b * t) <= f * 1.01
    # unresolvable marginal cost: chunk as much as allowed
    flat = CostModel({("classification", "observe_many", 32):
                      {"a": 1e-3, "b": 0.0, "n": 4.0}})
    assert flat.suggest_chunk(cap_bucket=32, max_chunk=256) == 256
    with pytest.raises(ValueError):
        m.suggest_chunk(cap_bucket=32, overhead_frac=1.5)
    with pytest.raises(KeyError):
        m.suggest_chunk("nonexistent_op", cap_bucket=32)


def test_costmodel_roundtrip_is_bitwise(tmp_path):
    # awkward floats on purpose: shortest-repr JSON must round-trip them
    m = CostModel({
        ("classification", "observe_many", 32):
            {"a": 1 / 3, "b": 2.2250738585072014e-308, "n": 7.0},
        ("regression", "intervals", 128):
            {"a": 0.1 + 0.2, "b": 0.0, "n": 3.0},
    }, meta={"source": "test"})
    p = str(tmp_path / "cm.json")
    m.save(p)
    back = CostModel.load(p)
    assert back.entries == m.entries  # dict == is exact float equality
    assert back.meta == m.meta
    assert CostModel.from_json(m.to_json()).entries == m.entries


def test_costmodel_version_gate():
    with pytest.raises(ValueError):
        CostModel.from_json({"version": 999, "entries": []})


def test_costmodel_suggest_buckets_linear_cost_doubles():
    # b scales linearly with bucket => alpha == 1 => growth == cost_ratio
    entries = {("", "observe_many", c): {"a": 0.0, "b": 1e-6 * c, "n": 3.0}
               for c in (32, 64, 128, 256)}
    m = CostModel(entries)
    _, alpha = m.fit_capacity_scaling()
    assert alpha == pytest.approx(1.0, abs=1e-9)
    assert m.suggest_buckets(cap_min=32, cap_max=256) == [32, 64, 128, 256]
    with pytest.raises(ValueError):
        m.suggest_buckets(cap_min=0, cap_max=8)
    with pytest.raises(ValueError):
        m.suggest_buckets(cap_min=8, cap_max=64, cost_ratio=1.0)


def test_calibrate_engine_yields_fittable_records():
    recs = calibrate_engine("classification", tenants=2, capacity=16,
                            dim=4, k=3, chunks=(1, 8), reps=2, seed=0)
    for r in recs:
        validate_record(r)
    m = CostModel.fit(recs, source="test")
    key = ("classification", "observe_many", 16)
    assert key in m.entries and m.entries[key]["b"] >= 0.0
    assert 1 <= m.suggest_chunk(cap_bucket=16) <= 1024


# ---------------------------------------------------- serve.py --replay


def test_serve_replay_cli_classification(tmp_path, capsys):
    from repro.launch import serve

    mpath = str(tmp_path / "m.json")
    rc = serve.main(["--replay", "loadgen:bursty", "--steps", "32",
                     "--sessions", "3", "--dim", "4", "--k", "3",
                     "--capacity", "16", "--slo-ms", "1000",
                     "--metrics-out", mpath])
    assert rc == 0
    out = capsys.readouterr().out
    assert "replay loadgen:bursty -> classification engine" in out
    assert "SLO 1000ms" in out
    names = {m["name"] for m in json.load(open(mpath))["metrics"]}
    assert "replay_steps_per_s" in names


def test_serve_replay_cli_regression_from_file(tmp_path, capsys):
    from repro.launch import serve

    recs = loadgen.generate("zipf", ops=24, tenants=3, capacity=16,
                            engine="regression", seed=6)
    tpath = str(tmp_path / "t.jsonl")
    write_trace(tpath, recs)
    rc = serve.main(["--replay", tpath, "--regression", "--dim", "4",
                     "--k", "3", "--speedup", "500"])
    assert rc == 0
    assert "-> regression engine" in capsys.readouterr().out


# --------------------------------------------------------- sharded replay


def test_replay_sharded_state_bit_identical(bursty_trace,
                                            bursty_replayed):
    """Partitioning tenants across per-shard engines must not change
    the final state: vmap lane independence makes each tenant's stream
    batch-width-invariant."""
    sharded = replay(bursty_trace, **ENG, seed=0, shards=2)
    assert _leaves_equal(bursty_replayed.state, sharded.state)
    rep = sharded.report
    assert rep["shards"] == 2
    assert rep["session_steps"] == bursty_replayed.report["session_steps"]
    assert rep["ops_replayed"] == bursty_replayed.report["ops_replayed"]


def test_replay_sharded_per_shard_report(bursty_trace):
    rep = replay(bursty_trace, **ENG, seed=0, shards=3).report
    per = rep["per_shard"]
    assert [s["shard"] for s in per] == [0, 1, 2]
    assert sum(s["tenants"] for s in per) == rep["tenants"]
    assert all(s["tenants"] >= 1 for s in per)
    assert sum(s["session_steps"] for s in per) == rep["session_steps"]
    for s in per:
        assert s["occupancy_max"] <= GEO["capacity"]


def test_replay_sharded_metrics_merge_matches_unsharded(bursty_trace):
    m1, m2 = MetricsRegistry(), MetricsRegistry()
    replay(bursty_trace, **ENG, seed=0, metrics=m1)
    replay(bursty_trace, **ENG, seed=0, metrics=m2, shards=2)
    # counters aggregate across shards to the unsharded totals
    for op in ("observe", "predict"):
        assert m2.counter("replay_ops_total", op=op).value == \
            m1.counter("replay_ops_total", op=op).value
    assert m2.counter("engine_ticks_total",
                      engine="classification").value == \
        m1.counter("engine_ticks_total", engine="classification").value


def test_replay_sharded_regression(bursty_trace):
    recs = loadgen.generate("bursty", ops=48, tenants=4, capacity=16,
                            engine="regression", seed=5, predict_every=8)
    ref = replay(recs, engine="regression", **ENG, seed=0)
    sh = replay(recs, engine="regression", **ENG, seed=0, shards=2)
    assert _leaves_equal(ref.state, sh.state)


def test_replay_rejects_bad_shards(bursty_trace):
    with pytest.raises(ValueError, match="shards"):
        replay(bursty_trace, **ENG, shards=0)
    with pytest.raises(ValueError, match="shards"):
        replay(bursty_trace, **ENG, shards=99)  # > tenants
