"""repro.serving: exactness + engine + snapshot + registry.

The acceptance-critical properties:
* decremental eviction (+ incremental re-add) is BIT-exact against
  fit-from-scratch on the same window;
* N vmapped engine sessions produce BIT-identical p-values to N
  sequential ``core.online.run_stream`` calls.
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import online
from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m
from repro.data.synthetic import make_classification
from repro.serving import (ConformalPredictor, ServingEngine, SessionStore,
                           registry)
from repro.serving import session as sm

K, DIM = 5, 6


def _stream(T, seed, dim=DIM):
    X, y = make_classification(n_samples=T, n_features=dim, seed=seed)
    taus = jax.random.uniform(jax.random.PRNGKey(seed), (T,),
                              dtype=jnp.float32)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32), taus


def _fill(sess, X, y, taus, lo=0, hi=None):
    ps = []
    for t in range(lo, hi if hi is not None else X.shape[0]):
        sess, p = sm.observe(sess, X[t], y[t], taus[t], k=K)
        ps.append(float(p))
    return sess, ps


# ---------------------------------------------------------------------------
# session exactness
# ---------------------------------------------------------------------------


def test_session_observe_matches_run_stream_bitwise():
    T, cap = 40, 64
    X, y, taus = _stream(T, seed=0)
    want, _ = online.run_stream(X, y, k=K, key=jax.random.PRNGKey(0),
                                capacity=cap)
    _, got = _fill(sm.init(cap, DIM, K), X, y, taus)
    np.testing.assert_array_equal(np.asarray(want),
                                  np.array(got, np.float32))


def _assert_linear_equal(a, b):
    """Leaf-for-leaf bitwise equality after ring normalization."""
    for la, lb in zip(jax.tree_util.tree_leaves(sm.to_linear(a)),
                      jax.tree_util.tree_leaves(sm.to_linear(b))):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("seed,evictions", [(1, 1), (2, 9), (3, 17)])
def test_evict_plus_readd_equals_fit_from_scratch(seed, evictions):
    """Eviction then incremental re-add == fresh fit on the same window
    (leaf-for-leaf through the ring normalization, D and aid included)."""
    T, cap = 36, 64
    X, y, taus = _stream(T, seed=seed)
    sess, _ = _fill(sm.init(cap, DIM, K), X, y, taus, hi=T - 5)
    for _ in range(evictions):
        sess = sm.evict_oldest(sess, k=K)
    sess, _ = _fill(sess, X, y, taus, lo=T - 5)  # incremental re-add

    scratch, _ = _fill(sm.init(cap, DIM, K), X, y, taus, lo=evictions)
    n = int(sess.knn.n)
    assert n == T - evictions == int(scratch.knn.n)
    _assert_linear_equal(sess, scratch)
    # and the *next* smoothed p-value agrees bitwise
    xq, yq, tq = X[0], y[0], jnp.float32(0.37)
    _, pa = sm.observe(sess, xq, yq, tq, k=K)
    _, pb = sm.observe(scratch, xq, yq, tq, k=K)
    assert float(pa) == float(pb)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_evict_oldest_tie_heavy_bit_exact(seed):
    """Binary-grid features force many exactly-equal distances: the
    O(k)-surgery evict_oldest must match fit-from-scratch bitwise."""
    T = 22
    rng = np.random.RandomState(seed)
    X = jnp.asarray(rng.randint(0, 2, size=(T, DIM)), jnp.float32)
    y = jnp.asarray(rng.randint(0, 2, size=T), jnp.int32)
    taus = jnp.full((T,), 0.5, jnp.float32)
    sess, _ = _fill(sm.init(32, DIM, K), X, y, taus)
    for e in range(T - K - 1):
        sess = sm.evict_oldest(sess, k=K)
        scratch, _ = _fill(sm.init(32, DIM, K), X, y, taus, lo=e + 1)
        _assert_linear_equal(sess, scratch)


def test_sliding_window_equals_refit_each_window():
    T, cap, w = 40, 64, 12
    X, y, taus = _stream(T, seed=4)
    sl = sm.init(cap, DIM, K)
    for t in range(T):
        sl, _ = sm.observe_sliding(sl, X[t], y[t], taus[t], jnp.int32(w),
                                   k=K)
    ref, _ = _fill(sm.init(cap, DIM, K), X, y, taus, lo=T - w)
    assert int(sl.knn.n) == w
    assert int(sl.head) == T - w  # eviction = head advance, no shift
    _assert_linear_equal(sl, ref)


def test_grow_preserves_state_bitwise():
    T, cap = 20, 32
    X, y, taus = _stream(T, seed=5)
    sess, _ = _fill(sm.init(cap, DIM, K), X, y, taus)
    g = sm.grow(sess)
    assert g.capacity == 2 * cap and int(g.knn.n) == T
    _, pa = sm.observe(g, X[0], y[0], jnp.float32(0.5), k=K)
    _, pb = sm.observe(sess, X[0], y[0], jnp.float32(0.5), k=K)
    assert float(pa) == float(pb)


def test_predict_pvalues_matches_optimized_knn():
    T, cap = 40, 64
    X, y, taus = _stream(T, seed=6)
    sess, _ = _fill(sm.init(cap, DIM, K), X, y, taus)
    Xt, _, _ = _stream(8, seed=60)
    got = sm.predict_pvalues(sess, Xt, k=K, n_labels=2)
    st = knn_m.fit(X, y, k=K)
    want = knn_m.pvalues_optimized(st, Xt, k=K, simplified=True, n_labels=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("rare_count", [K - 2, K, K + 1])
def test_predict_pvalues_exact_with_rare_label(rare_count):
    """Labels rarer than (or equal to) k: the BIG-padded neighbour lists
    must not go through the kernel's cancellation-prone update."""
    T, cap = 24, 32
    X, _, taus = _stream(T, seed=11)
    y = jnp.asarray([1 if t < rare_count else 0 for t in range(T)],
                    jnp.int32)
    sess = sm.init(cap, DIM, K)
    for t in range(T):
        sess, _ = sm.observe(sess, X[t], y[t], taus[t], k=K)
    Xt, _, _ = _stream(6, seed=12)
    got = sm.predict_pvalues(sess, Xt, k=K, n_labels=2)
    st = knn_m.fit(X, y, k=K)
    want = knn_m.pvalues_optimized(st, Xt, k=K, simplified=True, n_labels=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_engine_vmapped_equals_sequential_run_stream_bitwise():
    """N concurrent engine sessions == N independent run_stream calls."""
    S, T = 4, 30
    streams = [_stream(T, seed=100 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=8, dim=DIM, k=K, n_labels=2)
    state = eng.init_state()  # grow mode: auto-doubles 8 -> 32
    got = np.zeros((S, T), np.float32)
    for t in range(T):
        state, p = eng.observe(
            state,
            jnp.stack([st[0][t] for st in streams]),
            jnp.stack([st[1][t] for st in streams]),
            jnp.stack([st[2][t] for st in streams]))
        got[:, t] = np.asarray(p)
    assert state.capacity == 32  # capacity-doubling happened
    for s, (X, y, _) in enumerate(streams):
        want, _ = online.run_stream(X, y, k=K,
                                    key=jax.random.PRNGKey(100 + s),
                                    capacity=T)
        np.testing.assert_array_equal(np.asarray(want), got[s])


def test_engine_sliding_equals_sequential_sessions_bitwise():
    S, T, cap, w = 3, 25, 32, 10
    streams = [_stream(T, seed=200 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=cap, dim=DIM, k=K,
                        n_labels=2, window=w)
    state = eng.init_state()
    got = np.zeros((S, T), np.float32)
    for t in range(T):
        state, p = eng.observe(
            state,
            jnp.stack([st[0][t] for st in streams]),
            jnp.stack([st[1][t] for st in streams]),
            jnp.stack([st[2][t] for st in streams]))
        got[:, t] = np.asarray(p)
    for s, (X, y, taus) in enumerate(streams):
        sl = sm.init(cap, DIM, K)
        for t in range(T):
            sl, p = sm.observe_sliding(sl, X[t], y[t], taus[t],
                                       jnp.int32(w), k=K)
            assert float(p) == got[s, t]


def test_engine_active_masking_freezes_inactive_slots():
    S = 4
    streams = [_stream(3, seed=300 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=16, dim=DIM, k=K, n_labels=2)
    state = eng.init_state()
    active = jnp.array([True, False, True, False])
    state, p = eng.observe(
        state,
        jnp.stack([st[0][0] for st in streams]),
        jnp.stack([st[1][0] for st in streams]),
        jnp.stack([st[2][0] for st in streams]),
        active=active)
    p = np.asarray(p)
    assert not np.isnan(p[0]) and np.isnan(p[1])
    assert list(np.asarray(state.knn.n)) == [1, 0, 1, 0]


def test_engine_predict_shapes_and_window_rejection():
    eng = ServingEngine(n_sessions=2, capacity=16, dim=DIM, k=K, n_labels=3,
                        window=16)
    state = eng.init_state()
    X, y, taus = _stream(8, seed=7)
    for t in range(8):
        state, _ = eng.observe(state, jnp.stack([X[t], X[t]]),
                               jnp.stack([y[t], y[t]]),
                               jnp.stack([taus[t], taus[t]]))
    p = eng.predict(state, X[:5])  # (m, dim) broadcast across sessions
    assert p.shape == (2, 5, 3)
    np.testing.assert_array_equal(np.asarray(p[0]), np.asarray(p[1]))
    with pytest.raises(ValueError):
        ServingEngine(n_sessions=1, capacity=8, dim=DIM, k=K, window=9)


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_and_engine_restore():
    S, T = 3, 12
    streams = [_stream(T, seed=400 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=16, dim=DIM, k=K,
                        n_labels=2, window=8)
    state = eng.init_state()
    for t in range(T):
        state, _ = eng.observe(
            state,
            jnp.stack([st[0][t] for st in streams]),
            jnp.stack([st[1][t] for st in streams]),
            jnp.stack([st[2][t] for st in streams]))
    with tempfile.TemporaryDirectory() as d:
        SessionStore(d).save(T, state, meta=eng.meta(), blocking=True)
        eng2, state2, step = SessionStore(d).restore_engine()
        assert step == T
        assert (eng2.k, eng2.window, eng2.capacity) == (K, 8, 16)
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored engine continues bit-identically
        x = jnp.stack([st[0][0] for st in streams])
        y = jnp.stack([st[1][0] for st in streams])
        tau = jnp.stack([st[2][0] for st in streams])
        _, pa = eng.observe(state, x, y, tau)
        _, pb = eng2.observe(state2, x, y, tau)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_restore_engine_without_meta_raises_clearly():
    eng = ServingEngine(n_sessions=2, capacity=8, dim=DIM, k=K)
    with tempfile.TemporaryDirectory() as d:
        SessionStore(d).save(1, eng.init_state(), blocking=True)  # no meta
        store = SessionStore(d)
        state, step, meta = store.restore()  # plain restore still works
        assert step == 1 and meta == {}
        with pytest.raises(ValueError, match="no engine meta"):
            store.restore_engine()


# ---------------------------------------------------------------------------
# measure registry + decremental measures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("i", [0, 7, 34, -1])
def test_knn_decremental_remove_exact(i):
    X, y = make_classification(n_samples=35, n_features=DIM, n_classes=3,
                               seed=2)
    X, y = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32)
    got = knn_m.decremental_remove(knn_m.fit(X, y, k=K), i, k=K)
    want = knn_m.fit(jnp.delete(X, i, axis=0), jnp.delete(y, i, axis=0),
                     k=K)
    np.testing.assert_array_equal(np.asarray(got.best_same),
                                  np.asarray(want.best_same))
    np.testing.assert_array_equal(np.asarray(got.best_diff),
                                  np.asarray(want.best_diff))


def test_kde_decremental_remove_matches_refit():
    X, y = make_classification(n_samples=30, n_features=DIM, n_classes=3,
                               seed=3)
    X, y = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32)
    st = kde_m.fit(X, y, h=1.1, n_labels=3)
    got = kde_m.decremental_remove(st, 3, h=1.1)
    want = kde_m.fit(jnp.delete(X, 3, axis=0), jnp.delete(y, 3, axis=0),
                     h=1.1, n_labels=3)
    np.testing.assert_allclose(np.asarray(got.prelim),
                               np.asarray(want.prelim), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.class_counts),
                                  np.asarray(want.class_counts))


def test_kde_incremental_add_matches_refit():
    X, y = make_classification(n_samples=25, n_features=DIM, seed=8)
    X, y = jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32)
    st = kde_m.fit(X[:24], y[:24], h=0.9, n_labels=2)
    got = kde_m.incremental_add(st, X[24], y[24], h=0.9)
    want = kde_m.fit(X, y, h=0.9, n_labels=2)
    np.testing.assert_allclose(np.asarray(got.prelim),
                               np.asarray(want.prelim), atol=1e-5)


def test_lssvm_decremental_remove_matches_refit_and_roundtrip():
    X, y = make_classification(n_samples=30, n_features=DIM, seed=9)
    X = jnp.asarray(X, jnp.float32)
    Y = jnp.asarray(2.0 * y - 1.0, jnp.float32)
    st = lssvm_m.fit(X, Y, 1.0)
    got = lssvm_m.decremental_remove(st, 4)
    want = lssvm_m.fit(jnp.delete(X, 4, axis=0), jnp.delete(Y, 4, axis=0),
                       1.0)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(want.w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.C), np.asarray(want.C),
                               atol=1e-4)
    up = lssvm_m.incremental_add(st, X[0] * 0.5 + 1.0, jnp.float32(1.0))
    back = lssvm_m.decremental_remove(up, 30)
    np.testing.assert_allclose(np.asarray(back.w), np.asarray(st.w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(back.C), np.asarray(st.C),
                               atol=1e-4)


@pytest.mark.parametrize("measure", ["knn", "simplified_knn", "kde",
                                     "lssvm"])
def test_conformal_predictor_fit_observe_evict_pvalues(measure):
    X, y = make_classification(n_samples=40, n_features=DIM, seed=1)
    cp = ConformalPredictor(measure).fit(X[:30], y[:30])
    cp.observe(jnp.asarray(X[30], jnp.float32), int(y[30]))
    assert cp.n == 31
    cp.evict(0)
    assert cp.n == 30
    p = cp.pvalues(jnp.asarray(X[31:35], jnp.float32))
    assert p.shape == (4, 2)
    assert float(jnp.min(p)) > 0.0 and float(jnp.max(p)) <= 1.0
    sets = cp.predict_set(jnp.asarray(X[31:35], jnp.float32), eps=0.05)
    assert sets.dtype == bool


def test_lssvm_measure_rejects_multiclass():
    X, y = make_classification(n_samples=20, n_features=DIM, n_classes=3,
                               seed=4)
    with pytest.raises(ValueError, match="binary"):
        ConformalPredictor("lssvm", n_labels=3).fit(X, y)
    with pytest.raises(ValueError, match="labels in \\{0, 1\\}"):
        ConformalPredictor("lssvm").fit(X, y)  # labels {0,1,2}, n_labels=2
    cp = ConformalPredictor("lssvm").fit(X[:10], np.asarray(y[:10]) % 2)
    with pytest.raises(ValueError, match="labels in \\{0, 1\\}"):
        cp.observe(jnp.asarray(X[10], jnp.float32), 2)


def test_engine_grow_keeps_meta_capacity_in_sync():
    eng = ServingEngine(n_sessions=2, capacity=8, dim=DIM, k=K, n_labels=2)
    state = eng.init_state()
    X, y, taus = _stream(20, seed=13)
    for t in range(20):  # forces auto-growth past capacity 8
        state, _ = eng.observe(state, jnp.stack([X[t], X[t]]),
                               jnp.stack([y[t], y[t]]),
                               jnp.stack([taus[t], taus[t]]))
    assert state.capacity > 8
    assert eng.meta()["capacity"] == state.capacity
    assert eng.init_state().capacity == state.capacity
    with pytest.raises(ValueError, match="capacity"):
        ServingEngine(n_sessions=1, capacity=K - 1, dim=DIM, k=K)


# ---------------------------------------------------------------------------
# observe_many chunking + buffer donation
# ---------------------------------------------------------------------------


def _batched_stream(S, T, base_seed):
    streams = [_stream(T, seed=base_seed + s) for s in range(S)]
    xs = jnp.stack([jnp.stack([st[0][t] for st in streams])
                    for t in range(T)])  # (T, S, dim)
    ys = jnp.stack([jnp.stack([st[1][t] for st in streams])
                    for t in range(T)])
    taus = jnp.stack([jnp.stack([st[2][t] for st in streams])
                      for t in range(T)])
    return streams, xs, ys, taus


@pytest.mark.parametrize("chunks", [(24,), (1,) * 24, (5, 18, 1),
                                    (2, 22)])
def test_observe_many_bit_identical_to_per_tick(chunks):
    """Any chunking of the tick stream == the per-tick path, bitwise."""
    S, T, cap, w = 3, 24, 32, 10
    assert sum(chunks) == T
    streams, xs, ys, taus = _batched_stream(S, T, base_seed=600)
    kw = dict(n_sessions=S, capacity=cap, dim=DIM, k=K, n_labels=2,
              window=w)
    ref_eng = ServingEngine(**kw, donate=False)
    st_ref = ref_eng.init_state()
    want = np.zeros((T, S), np.float32)
    for t in range(T):
        st_ref, p = ref_eng.observe(st_ref, xs[t], ys[t], taus[t])
        want[t] = np.asarray(p)

    eng = ServingEngine(**kw)  # donate=True default
    st = eng.init_state()
    got = []
    off = 0
    for c in chunks:
        st, p = eng.observe_many(st, xs[off:off + c], ys[off:off + c],
                                 taus[off:off + c])
        got.append(np.asarray(p))
        off += c
    np.testing.assert_array_equal(np.concatenate(got, axis=0), want)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(st_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_observe_many_grow_mode_provisions_whole_chunk():
    """Grow mode doubles capacity up front so one dispatch covers T."""
    S, T = 2, 20
    streams, xs, ys, taus = _batched_stream(S, T, base_seed=620)
    eng = ServingEngine(n_sessions=S, capacity=8, dim=DIM, k=K, n_labels=2)
    state, pvals = eng.observe_many(eng.init_state(), xs, ys, taus)
    assert state.capacity == 32  # 8 -> 16 -> 32 before the scan
    assert eng.capacity == 32
    for s, (X, y, _) in enumerate(streams):
        want, _ = online.run_stream(X, y, k=K,
                                    key=jax.random.PRNGKey(620 + s),
                                    capacity=T)
        np.testing.assert_array_equal(np.asarray(want),
                                      np.asarray(pvals)[:, s])


def test_observe_many_active_mask_per_tick():
    S, T = 2, 4
    _, xs, ys, taus = _batched_stream(S, T, base_seed=640)
    eng = ServingEngine(n_sessions=S, capacity=16, dim=DIM, k=K,
                        n_labels=2, window=16)
    active = jnp.asarray([[True, False]] * 2 + [[True, True]] * 2)
    state, p = eng.observe_many(eng.init_state(), xs, ys, taus,
                                active=active)
    p = np.asarray(p)
    assert np.isnan(p[:2, 1]).all() and not np.isnan(p[:, 0]).any()
    assert not np.isnan(p[2:, 1]).any()
    assert list(np.asarray(state.knn.n)) == [4, 2]


def test_donated_observe_matches_undonated_and_consumes_input():
    """Donation is numerically free, and the donated input is dead:
    reusing a pre-donation state raises instead of silently aliasing."""
    S, T, cap, w = 2, 10, 16, 8
    _, xs, ys, taus = _batched_stream(S, T, base_seed=660)
    eng_d = ServingEngine(n_sessions=S, capacity=cap, dim=DIM, k=K,
                          n_labels=2, window=w, donate=True)
    eng_u = ServingEngine(n_sessions=S, capacity=cap, dim=DIM, k=K,
                          n_labels=2, window=w, donate=False)
    st_d, st_u = eng_d.init_state(), eng_u.init_state()
    for t in range(T):
        prev_d = st_d
        st_d, pd = eng_d.observe(st_d, xs[t], ys[t], taus[t])
        st_u, pu = eng_u.observe(st_u, xs[t], ys[t], taus[t])
        np.testing.assert_array_equal(np.asarray(pd), np.asarray(pu))
    for a, b in zip(jax.tree_util.tree_leaves(st_d),
                    jax.tree_util.tree_leaves(st_u)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # undonated inputs stay alive ...
    assert np.asarray(st_u.D).shape == (S, cap, cap)
    # ... donated inputs are deleted; both direct reads and a second
    # observe on the stale state fail loudly
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(prev_d.D)
    with pytest.raises((RuntimeError, ValueError), match="deleted"):
        eng_d.observe(prev_d, xs[0], ys[0], taus[0])


def test_session_donated_step_matches_and_consumes():
    T = 12
    X, y, taus = _stream(T, seed=680)
    a = sm.init(32, DIM, K)
    b = sm.init(32, DIM, K)
    for t in range(T):
        prev = a
        a, pa = sm.observe_sliding_donated(a, X[t], y[t], taus[t],
                                           jnp.int32(8), k=K)
        b, pb = sm.observe_sliding(b, X[t], y[t], taus[t],
                                   jnp.int32(8), k=K)
        assert float(pa) == float(pb)
    np.testing.assert_array_equal(np.asarray(a.knn.best),
                                  np.asarray(b.knn.best))
    np.testing.assert_array_equal(np.asarray(a.D), np.asarray(b.D))
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(prev.D)


# ---------------------------------------------------------------------------
# dtype stability across grow (post-grow re-jit audit)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_engine_dtype_stable_across_grow(dtype):
    """Every state leaf, the p-values and ``taus`` keep the engine dtype
    through grow-mode capacity doubling (sub-f32 dtypes used to drift to
    f32 through the p-value's int promotion, breaking the masked cond)."""
    S = 2
    eng = ServingEngine(n_sessions=S, capacity=8, dim=DIM, k=K,
                        n_labels=2, dtype=dtype)
    tau = eng.taus(jax.random.PRNGKey(0))
    assert tau.dtype == dtype
    state = eng.init_state()
    X, y, _ = _stream(20, seed=700)
    for t in range(20):  # forces 8 -> 16 -> 32 growth
        state, p = eng.observe(
            state, jnp.stack([X[t]] * S).astype(dtype),
            jnp.stack([y[t]] * S), eng.taus(jax.random.PRNGKey(t)))
    assert state.capacity > 8
    assert p.dtype == dtype
    assert state.knn.X.dtype == dtype
    assert state.knn.best.dtype == dtype
    assert state.D.dtype == dtype
    assert state.knn.y.dtype == jnp.int32
    assert eng.taus(jax.random.PRNGKey(9)).dtype == dtype


def test_registry_custom_measure_plugs_in():
    spec = registry.MeasureSpec(
        name="_test_mean_dist",
        fit=lambda X, y, hp: ((X, y), None),
        observe=lambda st, ctx, x, y, hp: (
            jnp.concatenate([st[0], x[None]]),
            jnp.concatenate([st[1], jnp.asarray([y], st[1].dtype)])),
        evict=lambda st, ctx, i, hp: (jnp.delete(st[0], i, axis=0),
                                      jnp.delete(st[1], i, axis=0)),
        pvalues=lambda st, ctx, Xt, hp: jnp.full(
            (Xt.shape[0], hp["n_labels"]), 0.5),
        defaults={"n_labels": 2},
    )
    registry.register(spec)
    try:
        assert "_test_mean_dist" in registry.available()
        cp = ConformalPredictor("_test_mean_dist")
        X, y = make_classification(n_samples=10, n_features=DIM, seed=0)
        cp.fit(X, y)
        cp.observe(jnp.asarray(X[0], jnp.float32), int(y[0]))
        cp.evict(0)
        assert cp.pvalues(jnp.asarray(X[:3], jnp.float32)).shape == (3, 2)
        with pytest.raises(TypeError):
            ConformalPredictor("_test_mean_dist", bogus=1)
    finally:
        registry._REGISTRY.pop("_test_mean_dist", None)
