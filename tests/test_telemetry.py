"""repro.telemetry: metrics math, trace schema, device-stat exactness.

The acceptance-critical properties:
* instrumented engines are BIT-identical to uninstrumented ones
  (state leaf-for-leaf + p-values, both engine families, sliding and
  grow modes) — the device tick stats only read integer bookkeeping;
* the device tick counters equal an offline recomputation from the
  traffic (closed form == per-tick simulation);
* the rolling coverage monitor matches an exact offline recomputation,
  and the drift monitor matches ``core.online``'s mixture martingale;
* ``launch/serve.py --trace-out`` produces a schema-valid trace.
"""
import io
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.telemetry import (CoverageMonitor, DriftMonitor, EngineTelemetry,
                             MetricsRegistry, Tracer, UniformityMonitor,
                             capacity_bucket, validate_record,
                             validate_trace_file)
from repro.telemetry.device import STAT_KEYS
from repro.telemetry.metrics import Histogram

# ---------------------------------------------------------------- metrics


def test_counter_gauge_identity_and_labels():
    reg = MetricsRegistry()
    reg.counter("ops_total", op="observe").inc()
    reg.counter("ops_total", op="observe").inc(2)
    reg.counter("ops_total", op="predict").inc()
    assert reg.counter("ops_total", op="observe").value == 3
    assert reg.counter("ops_total", op="predict").value == 1
    with pytest.raises(ValueError):
        reg.counter("ops_total", op="observe").inc(-1)
    reg.gauge("occ").set(7)
    reg.gauge("occ").set(5)
    assert reg.gauge("occ").value == 5


def test_histogram_bucket_math_exact_quantiles():
    h = Histogram("h", (), bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(6.5)
    assert h.min == 0.5 and h.max == 3.0
    # rank 2 of 4 lands on the (1, 2] bucket: lo + (hi-lo) * frac with
    # cum=1, c=2, rank=2 -> frac=1/2 -> 1.5 exactly
    assert h.quantile(0.5) == pytest.approx(1.5)
    # estimates are clamped into [min, max] of the true observations
    assert h.quantile(1.0) <= h.max
    assert h.quantile(0.0) >= h.min


def test_histogram_overflow_is_lower_bound():
    h = Histogram("h", (), bounds=(1.0,))
    h.observe(100.0)
    # overflow estimate: max(last finite edge, observed min) — a lower
    # bound on the true quantile, and flagged as such
    assert h.quantile(0.99) == pytest.approx(100.0)
    assert h.quantile_is_lower_bound(0.99)
    h2 = Histogram("h2", (), bounds=(1.0,))
    h2.observe(0.5)
    assert not h2.quantile_is_lower_bound(0.99)


def test_histogram_rejects_bad_bounds_and_quantiles():
    with pytest.raises(ValueError):
        Histogram("h", (), bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", (), bounds=())
    h = Histogram("h", (), bounds=(1.0,))
    assert math.isnan(h.quantile(0.5))  # empty
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_empty_histogram_flagged_in_snapshot_and_text():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    s = h.snapshot()
    assert s["empty"] is True
    assert math.isnan(h.quantile(0.5))
    assert "empty=1" in reg.to_text()
    h.observe(1.0)
    assert h.snapshot()["empty"] is False
    assert "empty=1" not in reg.to_text()


def test_label_values_escaped_in_exposition_format():
    reg = MetricsRegistry()
    reg.counter("c_total", path='a"b\\c\nd').inc()
    text = reg.to_text()
    # backslash, quote and newline escape per the exposition format —
    # and the snapshot stays one-line-per-series parseable
    assert r'path="a\"b\\c\nd"' in text
    assert len(text.splitlines()) == 1


def test_histogram_emits_sum_count_series():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", op="observe")
    h.observe(1.0)
    h.observe(3.0)
    text = reg.to_text()
    assert 'lat_s_count{op="observe"} 2' in text
    assert 'lat_s_sum{op="observe"} 4' in text


def test_registry_export_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total", engine="classification").inc(4)
    reg.gauge("b").set(1.25)
    reg.histogram("c_s").observe(0.01)
    text = reg.to_text()
    assert 'a_total{engine="classification"} 4' in text
    assert "c_s count=1" in text
    path = str(tmp_path / "m.json")
    reg.dump(path)
    d = json.load(open(path))
    by_name = {m["name"]: m for m in d["metrics"]}
    assert by_name["a_total"]["value"] == 4
    assert by_name["a_total"]["labels"] == {"engine": "classification"}
    assert by_name["c_s"]["count"] == 1


# ----------------------------------------------------------------- tracer


def test_trace_schema_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = Tracer(path)
    tr.record("observe", 0.001, tenants=4, ticks=1, capacity=100,
              engine="classification")
    with tr.op("observe_many", signature=(64, 256), tenants=8) as ctx:
        ctx.late["ticks"] = 64
    with tr.op("observe_many", signature=(64, 256)):
        pass
    tr.close()
    recs = validate_trace_file(path)
    assert [r["op"] for r in recs] == ["observe", "observe_many",
                                      "observe_many"]
    assert recs[0]["capacity"] == 100 and recs[0]["cap_bucket"] == 128
    assert recs[1]["compile"] is True and recs[1]["ticks"] == 64
    assert recs[2]["compile"] is False  # same (op, signature): steady


def test_trace_validation_rejects_bad_records():
    with pytest.raises(ValueError):
        validate_record({"schema": 1, "seq": 0, "t": 0.0,
                         "op": "not_an_op", "wall_s": 0.0})
    with pytest.raises(ValueError):
        validate_record({"schema": 1, "seq": 0, "t": 0.0, "op": "observe"})
    with pytest.raises(ValueError):  # bool is not an int
        validate_record({"schema": 1, "seq": True, "t": 0.0,
                         "op": "observe", "wall_s": 0.0})
    f = io.StringIO()
    tr = Tracer(f)
    with pytest.raises(ValueError):
        tr.record("nope", 0.0)


def test_capacity_bucket():
    assert [capacity_bucket(c) for c in (1, 2, 3, 128, 129)] == \
        [1, 2, 4, 128, 256]


# --------------------------------------------- engine bit-exactness (CP!)


def _class_traffic(S, T, dim, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky, kt = jax.random.split(key, 3)
    return (jax.random.normal(kx, (T, S, dim), jnp.float32),
            jax.random.bernoulli(ky, 0.5, (T, S)).astype(jnp.int32),
            jax.random.uniform(kt, (T, S), dtype=jnp.float32))


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb))


@pytest.mark.parametrize("window", [None, 10])
def test_instrumented_serving_engine_bit_identical(window):
    from repro.serving import ServingEngine

    S, T, dim, cap = 3, 26, 5, 32
    xs, ys, taus = _class_traffic(S, T, dim)
    kw = dict(n_sessions=S, capacity=cap, dim=dim, k=5, n_labels=2,
              window=window)
    plain = ServingEngine(**kw)
    inst = ServingEngine(**kw, instrument=True, metrics=MetricsRegistry())
    s1, s2 = plain.init_state(), inst.init_state()
    s1, p1 = plain.observe_many(s1, xs, ys, taus)
    s2, p2 = inst.observe_many(s2, xs, ys, taus)
    assert np.asarray(p1).tobytes() == np.asarray(p2).tobytes()
    # per-tick path on top of the chunked one
    s1, q1 = plain.observe(s1, xs[0], ys[0], taus[0])
    s2, q2 = inst.observe(s2, xs[0], ys[0], taus[0])
    assert np.asarray(q1).tobytes() == np.asarray(q2).tobytes()
    assert _leaves_equal(s1, s2)
    r1 = plain.predict(s1, xs[:2].transpose(1, 0, 2))
    r2 = inst.predict(s2, xs[:2].transpose(1, 0, 2))
    assert np.asarray(r1).tobytes() == np.asarray(r2).tobytes()


@pytest.mark.parametrize("window", [None, 12])
def test_instrumented_regression_engine_bit_identical(window):
    from repro.regression import RegressionServingEngine

    S, T, dim, cap = 3, 30, 4, 32
    key = jax.random.PRNGKey(5)
    kx, ky, kt = jax.random.split(key, 3)
    xs = jax.random.normal(kx, (T, S, dim), jnp.float32)
    ys = jax.random.normal(ky, (T, S), jnp.float32)
    taus = jax.random.uniform(kt, (T, S), dtype=jnp.float32)
    kw = dict(n_sessions=S, capacity=cap, dim=dim, k=5, window=window)
    plain = RegressionServingEngine(**kw)
    inst = RegressionServingEngine(**kw, instrument=True,
                                   metrics=MetricsRegistry())
    s1, s2 = plain.init_state(), inst.init_state()
    s1, p1 = plain.observe_many(s1, xs, ys, taus)
    s2, p2 = inst.observe_many(s2, xs, ys, taus)
    assert np.asarray(p1).tobytes() == np.asarray(p2).tobytes()
    s1, q1 = plain.observe(s1, xs[0], ys[0], taus[0])
    s2, q2 = inst.observe(s2, xs[0], ys[0], taus[0])
    assert np.asarray(q1).tobytes() == np.asarray(q2).tobytes()
    assert _leaves_equal(s1, s2)
    Xq = jax.random.normal(kx, (3, dim), jnp.float32)
    iv1 = plain.intervals(s1, Xq, 0.2)
    iv2 = inst.intervals(s2, Xq, 0.2)
    assert np.asarray(iv1).tobytes() == np.asarray(iv2).tobytes()


def test_instrumented_compact_layout_bit_identical():
    from repro.serving import ServingEngine

    S, T, dim, cap = 2, 20, 4, 16
    xs, ys, taus = _class_traffic(S, T, dim, seed=3)
    kw = dict(n_sessions=S, capacity=cap, dim=dim, k=3, n_labels=2,
              window=8, layout="compact")
    plain = ServingEngine(**kw)
    inst = ServingEngine(**kw, instrument=True, metrics=MetricsRegistry())
    s1, p1 = plain.observe_many(plain.init_state(), xs, ys, taus)
    s2, p2 = inst.observe_many(inst.init_state(), xs, ys, taus)
    assert np.asarray(p1).tobytes() == np.asarray(p2).tobytes()
    assert _leaves_equal(s1, s2)


# ------------------------------------------------------ device tick stats


def _simulate_stats(n0, head0, wrap, windows, actives):
    """Per-tick reference simulation of the closed-form chunk stats."""
    n, head = n0.copy(), head0.copy()
    tot = {k: 0 for k in STAT_KEYS}
    tot["occupancy_max"] = 0
    for act in actives:
        ev = act & (n >= windows)
        tot["ticks"] += int(act.sum())
        tot["evictions"] += int(ev.sum())
        tot["ring_wraps"] += int((ev & (head == wrap - 1)).sum())
        tot["backfills"] += int(ev.sum())
        head = np.where(ev, (head + 1) % wrap, head)
        n = np.where(act, np.minimum(n + 1, windows), n)
        tot["occupancy_sum"] += int(n.sum())
        tot["occupancy_max"] = max(tot["occupancy_max"], int(n.max()))
    return tot


def test_device_tick_stats_match_offline_simulation():
    from repro.serving import ServingEngine

    S, dim, cap, w = 4, 4, 16, 6
    reg = MetricsRegistry()
    eng = ServingEngine(n_sessions=S, capacity=cap, dim=dim, k=3,
                        n_labels=2, window=w, instrument=True, metrics=reg)
    state = eng.init_state()
    rng = np.random.default_rng(0)
    total = {k: 0 for k in STAT_KEYS}
    for chunk in (7, 9, 13):  # several chunks, ragged active masks
        xs, ys, taus = _class_traffic(S, chunk, dim, seed=chunk)
        active = jnp.asarray(rng.random((chunk, S)) < 0.8)
        ref = _simulate_stats(
            np.asarray(state.knn.n), np.asarray(state.head),
            np.asarray(state.wrap), np.full(S, w, np.int64),
            np.asarray(active))
        state, _ = eng.observe_many(state, xs, ys, taus, active=active)
        for k in STAT_KEYS:
            if k == "occupancy_max":
                total[k] = max(total[k], ref[k])
            else:
                total[k] += ref[k]
    got = eng.telemetry.drain()
    assert got == total
    # published under engine_* with the run totals
    assert reg.counter("engine_ticks_total",
                       engine="classification").value == total["ticks"]
    assert reg.gauge("engine_occupancy_max",
                     engine="classification").value == \
        total["occupancy_max"]
    # drained: a second drain is empty and totals persist
    assert eng.telemetry.drain() == {k: 0 for k in STAT_KEYS}
    assert eng.telemetry.ticks.totals["evictions"] == total["evictions"]


def test_engine_telemetry_without_accessors_is_timing_only():
    tele = EngineTelemetry(engine="registry", metrics=MetricsRegistry())
    assert tele.stats_fn is None
    with tele.timed("fit", signature="knn", tenants=1):
        pass
    assert tele.drain() == {}
    assert tele.metrics.counter("engine_ops_total", op="fit",
                                engine="registry").value == 1


# ------------------------------------------------------ validity monitors


def test_coverage_monitor_matches_offline_recomputation():
    rng = np.random.default_rng(1)
    S, T, w, eps = 5, 40, 16, 0.2
    p = rng.random((T, S))
    p[rng.random((T, S)) < 0.25] = np.nan  # ragged tenant clocks
    mon = CoverageMonitor(eps, S, window=w)
    for t in range(T):
        mon.update(p[t])
    cov = mon.coverage()
    for s in range(S):
        hist = p[:, s][np.isfinite(p[:, s])]
        kept = hist[-w:]  # the rolling window keeps the suffix
        if kept.size == 0:
            assert math.isnan(cov[s])
        else:
            assert cov[s] == pytest.approx(np.mean(kept > eps))
    assert np.array_equal(
        mon.counts(), [min(np.isfinite(p[:, s]).sum(), w)
                       for s in range(S)])


def test_uniformity_monitor_ks_matches_offline():
    rng = np.random.default_rng(2)
    S, T, w = 3, 30, 30
    p = rng.random((T, S))
    mon = UniformityMonitor(S, window=w)
    mon.update(p)  # (T, S) block form
    ks = mon.ks()
    for s in range(S):
        u = np.sort(p[:, s])
        i = np.arange(1, T + 1)
        ref = max(np.max(i / T - u), np.max(u - (i - 1) / T))
        assert ks[s] == pytest.approx(ref)


def test_drift_monitor_matches_core_martingale():
    from repro.core.online import simple_mixture_log_martingale

    rng = np.random.default_rng(3)
    S, T = 4, 60
    p = rng.random((T, S)).astype(np.float32)
    # tenant 3 drifts: p-values collapse toward 0 halfway through
    p[T // 2:, 3] *= 0.02
    # threshold high enough that exchangeable tenants stay under it
    # (Ville: P(max log M > 6) <= e^-6), low enough that the drifted
    # tenant (log M ~ +40 here) is far past it
    mon = DriftMonitor(S, threshold=6.0)
    running_max = np.full(S, -np.inf)
    for t in range(T):
        mon.update(p[t])
        running_max = np.maximum(running_max, mon.log_m())
    for s in range(S):
        ref = float(simple_mixture_log_martingale(jnp.asarray(p[:, s]))[-1])
        assert mon.log_m()[s] == pytest.approx(ref, rel=1e-4, abs=1e-4)
    assert np.allclose(mon.max_log_m, running_max)
    assert mon.flagged(use_max=True)[3]
    assert not mon.flagged(use_max=True)[:3].any()
    assert mon.log_m()[0] != 0.0 or mon.ticks[0] == 0


def test_drift_monitor_export_has_no_infinities():
    mon = DriftMonitor(2)
    reg = MetricsRegistry()
    mon.export(reg, engine="classification")
    assert reg.gauge("drift_log_m_max", engine="classification").value == 0
    json.dumps(reg.to_dict())  # -inf would not serialize


# ------------------------------------------------------- snapshot timing


def test_snapshot_store_records_timing(tmp_path):
    from repro.serving import ServingEngine, SessionStore

    reg = MetricsRegistry()
    tracef = io.StringIO()
    tr = Tracer(tracef)
    eng = ServingEngine(n_sessions=2, capacity=8, dim=3, k=3, n_labels=2)
    state = eng.init_state()
    store = SessionStore(str(tmp_path / "snap"), metrics=reg, tracer=tr)
    store.save(1, state, meta=eng.meta(), blocking=True)
    _, step, _ = store.restore()
    assert step == 1
    assert reg.histogram("snapshot_save_s").count == 1
    assert reg.histogram("snapshot_restore_s").count == 1
    ops = [json.loads(line)["op"]
           for line in tracef.getvalue().splitlines()]
    assert ops == ["snapshot_save", "snapshot_restore"]


# -------------------------------------------------------- serve.py e2e


def test_serve_classification_e2e_trace_and_metrics(tmp_path):
    from repro.launch import serve

    trace = str(tmp_path / "trace.jsonl")
    mout = str(tmp_path / "metrics.json")
    rc = serve.main([
        "--sessions", "3", "--steps", "16", "--window", "6",
        "--capacity", "16", "--dim", "3", "--k", "3",
        "--snapshot-dir", str(tmp_path / "snap"),
        "--trace-out", trace, "--metrics-out", mout])
    assert rc == 0
    recs = validate_trace_file(trace)
    ops = {r["op"] for r in recs}
    assert {"observe", "snapshot_save", "snapshot_restore"} <= ops
    compiles = [r for r in recs if r["op"] == "observe" and r["compile"]]
    assert len(compiles) == 1  # one signature -> one compile record
    d = json.load(open(mout))
    names = {m["name"] for m in d["metrics"]}
    assert {"engine_ticks_total", "engine_evictions_total",
            "validity_coverage_mean", "drift_log_m_max",
            "serve_session_steps_per_s"} <= names


def test_serve_regression_e2e(tmp_path):
    from repro.launch import serve

    trace = str(tmp_path / "trace.jsonl")
    rc = serve.main([
        "--sessions", "2", "--regression", "--steps", "20",
        "--window", "8", "--capacity", "16", "--dim", "2", "--k", "3",
        "--trace-out", trace])
    assert rc == 0
    recs = validate_trace_file(trace)
    assert {"observe", "intervals"} <= {r["op"] for r in recs}
    assert all(r["engine"] == "regression" for r in recs
               if r["op"] == "observe")


def test_serve_registry_e2e(tmp_path):
    from repro.launch import serve

    trace = str(tmp_path / "trace.jsonl")
    mout = str(tmp_path / "metrics.json")
    rc = serve.main([
        "--sessions", "2", "--measure", "knn", "--steps", "24",
        "--window", "8", "--dim", "3", "--k", "3",
        "--trace-out", trace, "--metrics-out", mout])
    assert rc == 0
    recs = validate_trace_file(trace)
    assert {"fit", "observe", "pvalues", "evict"} <= \
        {r["op"] for r in recs}
    d = json.load(open(mout))
    names = {m["name"] for m in d["metrics"]}
    assert "validity_coverage_mean" in names


# ----------------------------------------------------- registry merging


def test_counter_and_histogram_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("ops_total", op="observe").inc(3)
    b.counter("ops_total", op="observe").inc(4)
    a.counter("ops_total", op="observe").merge(
        b.counter("ops_total", op="observe"))
    assert a.counter("ops_total", op="observe").value == 7

    ha, hb = Histogram("h", (), bounds=(1.0, 2.0)), \
        Histogram("h", (), bounds=(1.0, 2.0))
    for v in (0.5, 1.5):
        ha.observe(v)
    for v in (1.5, 5.0):
        hb.observe(v)
    ha.merge(hb)
    assert ha.count == 4 and ha.counts == [1, 2, 1]
    assert ha.min == 0.5 and ha.max == 5.0 and ha.sum == 8.5


def test_histogram_merge_mismatched_bounds_raises():
    ha = Histogram("h", (), bounds=(1.0, 2.0))
    hb = Histogram("h", (), bounds=(1.0, 4.0, 8.0))
    with pytest.raises(ValueError, match="mismatched bucket"):
        ha.merge(hb)


def test_gauge_merge_policies():
    from repro.telemetry.metrics import Gauge

    def pair(x, y):
        ga, gb = Gauge("g", ()), Gauge("g", ())
        ga.set(x)
        gb.set(y)
        return ga, gb

    for policy, want in (("max", 5.0), ("min", 2.0), ("sum", 7.0),
                         ("last", 2.0)):
        ga, gb = pair(5.0, 2.0)
        ga.merge(gb, policy=policy)
        assert ga.value == want, policy
    # NaN (unset) never clobbers a set value, in either direction
    ga, gb = Gauge("g", ()), Gauge("g", ())
    gb.set(3.0)
    ga.merge(gb)
    assert ga.value == 3.0
    gb.merge(Gauge("g", ()), policy="last")
    assert gb.value == 3.0
    with pytest.raises(ValueError, match="policy"):
        ga.merge(gb, policy="median")


def _populated_registry(seed: int) -> MetricsRegistry:
    r = MetricsRegistry()
    r.counter("ticks_total", engine="c").inc(10 + seed)
    r.gauge("occupancy_max", engine="c").set(4.0 * (seed + 1))
    h = r.histogram("wall_s", op="observe")
    for v in (1e-4 * (seed + 1), 2e-3):
        h.observe(v)
    # a series only this shard owns
    r.counter(f"only_{seed}_total").inc(seed + 1)
    return r


def test_registry_merge_identity_and_commutativity():
    # identity: merging an empty registry changes nothing
    a = _populated_registry(0)
    before = a.to_text()
    a.merge(MetricsRegistry())
    assert a.to_text() == before
    # ... and merging INTO an empty registry copies everything
    e = MetricsRegistry()
    e.merge(_populated_registry(0))
    assert e.to_text() == before

    # commutativity (sum/max/bucket-add are all symmetric)
    ab = _populated_registry(0).merge(_populated_registry(1))
    ba = _populated_registry(1).merge(_populated_registry(0))
    assert ab.to_text() == ba.to_text()
    assert ab.counter("ticks_total", engine="c").value == 21
    assert ab.gauge("occupancy_max", engine="c").value == 8.0
    assert ab.histogram("wall_s", op="observe").count == 4
    assert ab.counter("only_0_total").value == 1
    assert ab.counter("only_1_total").value == 2


def test_registry_merge_gauge_policy_forwarded():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.gauge("occ").set(3.0)
    b.gauge("occ").set(2.0)
    a.merge(b, gauge_policy="sum")
    assert a.gauge("occ").value == 5.0
