"""The compiled-artifact invariant auditor (``repro.analysis.audit``).

Four layers:

* the source lint is clean on the shipped tree, and each rule fires on
  a purpose-built bad fixture (with the ``# audit: allow`` escape);
* a quick in-process audit run over the single-shard matrix reports
  zero failures (the CI gate in miniature);
* deliberately broken invariants are CAUGHT with the offending HLO op
  named: a dropped donation, a per-tick dense materialization, a
  smuggled collective, a blown retrace budget;
* bit-neutrality: auditing an engine (tracing/lowering + checkers)
  never perturbs its served results — ticks and reads stay
  leaf-for-leaf identical to an unaudited twin (both engines, both
  layouts).
"""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit as audit_m
from repro.analysis import lint as lint_m
from repro.regression.engine import RegressionServingEngine
from repro.serving.engine import ServingEngine

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# source lint
# ---------------------------------------------------------------------------


def test_lint_clean_on_shipped_tree():
    vs = lint_m.lint_tree(os.path.join(_SRC, "repro"))
    assert vs == [], [v.as_dict() for v in vs]


def _write(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))
    return str(p)


def test_lint_unkeyed_randomness(tmp_path):
    p = _write(tmp_path, "mod.py", """
        import numpy as np
        import random
        a = np.random.rand(3)
        b = random.random()
        rng = np.random.default_rng(0)
        ok = rng.normal(size=3)
        allowed = np.random.rand(2)  # audit: allow
    """)
    vs = lint_m.lint_paths([p])
    assert [v.line for v in vs] == [4, 5]
    assert all(v.rule == "unkeyed-randomness" for v in vs)


def test_lint_host_sync_in_jit(tmp_path):
    p = _write(tmp_path, "mod.py", """
        import time
        import numpy as np
        import jax

        def helper(x):
            time.time()
            return x.item()

        @jax.jit
        def step(x):
            np.asarray(x)
            return helper(x)

        def host_only(x):  # NOT jit-reachable: no violation
            time.time()
            return np.asarray(x)
    """)
    vs = lint_m.lint_paths([p])
    assert {v.rule for v in vs} == {"host-sync-in-jit"}
    assert [v.line for v in vs] == [7, 8, 12]  # helper is reachable


def test_lint_tenant_loop_only_in_engine_modules(tmp_path):
    body = """
        def tick(self, n_sessions):
            for s in range(n_sessions):
                pass
    """
    eng = _write(tmp_path, "serving/engine.py", body)
    other = _write(tmp_path, "serving/other.py", body)
    vs = lint_m.lint_paths([eng, other])
    assert len(vs) == 1 and vs[0].rule == "tenant-python-loop"
    assert vs[0].path == eng


def test_lint_donate_contract(tmp_path):
    p = _write(tmp_path, "repro/serving/mod.py", """
        import jax

        def _obs(s, x):
            return s

        observe = jax.jit(_obs)
        observe_donated = jax.jit(_obs, donate_argnums=(0,))
        orphan_donated = jax.jit(_obs, donate_argnums=(0,))

        def build(donate):
            return jax.jit(_obs,
                           donate_argnums=(0,) if donate else ())

        def sneaky():
            return jax.jit(_obs, donate_argnums=(0,))
    """)
    vs = lint_m.lint_paths([p])
    assert all(v.rule == "donate-inconsistent" for v in vs)
    # orphan (no plain twin) + the unconditioned nested jit
    assert len(vs) == 2, [v.as_dict() for v in vs]


# ---------------------------------------------------------------------------
# the gate is green on the current tree (single-shard quick matrix; CI
# runs the full sharded matrix via `python -m repro.analysis.audit`)
# ---------------------------------------------------------------------------


def test_quick_audit_reports_zero_failures():
    rep = audit_m.run_audit(max_shards=1, quick=True)
    assert rep["ok"], audit_m.format_summary(rep)
    assert rep["summary"]["fail"] == 0
    assert rep["summary"]["pass"] > 0
    # every engine-matrix multiplicity came from exact trip metadata
    assert rep["summary"]["trip_fallbacks"] == 0
    checks = {(r["check"], r["target"]): r["status"]
              for r in rep["checks"]}
    assert checks[("source-lint", "src")] == "pass"
    # the compact-sliding budget is a waiver, not a silent pass
    waived = [k for k, s in checks.items() if s == "waived"]
    assert any("sliding-compact" in t for _, t in waived)
    assert rep["route"]["backend"] == jax.default_backend()


# ---------------------------------------------------------------------------
# deliberate violations are caught, offending op named
# ---------------------------------------------------------------------------


def test_dropped_donation_is_caught():
    t = audit_m.AuditTarget(name="sab-donate", kind="engine",
                            family="classification", mode="sliding",
                            layout="ring", shards=1)
    art = audit_m.Artifact(t)
    art._engine = art.build_engine(donate=False)  # the sabotage
    r = audit_m.CHECKERS["donation-alias"](t, art)
    assert r["status"] == "fail"
    assert "donated state leaves" in r["violations"][0]["line"]


def test_per_tick_dense_materialization_is_caught():
    # the compact sliding layout WITHOUT its waiver is exactly the
    # "shift the ring with a copy" regression
    t = audit_m.AuditTarget(name="sab-dense", kind="engine",
                            family="classification", mode="sliding",
                            layout="compact", shards=1)
    r = audit_m.CHECKERS["dense-budget"](t, audit_m.Artifact(t))
    assert r["status"] == "fail"
    v = r["violations"][0]
    assert v["mult"] > 1 and v["bytes"] >= t.n_sessions * 32 * 32 * 4
    assert v["line"]  # the offending HLO op, verbatim


_PSUM_FIX = """\
HloModule sabotage

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  ROOT %ar = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %p0), replica_groups={}, to_apply=%sum
}
"""


def test_smuggled_collective_is_caught():
    vs = audit_m.collective_violations(_PSUM_FIX)
    assert len(vs) == 1
    assert vs[0]["kind"] == "all-reduce" and vs[0]["name"] == "%ar"
    assert "all-reduce" in vs[0]["line"]


def test_blown_retrace_budget_is_caught():
    t = audit_m.AuditTarget(name="sab-retrace", kind="engine",
                            family="classification", mode="sliding",
                            layout="ring", shards=1,
                            retrace_budget={"step": 0, "read": 0})
    r = audit_m.CHECKERS["retrace"](t, audit_m.Artifact(t))
    assert r["status"] == "fail"
    assert {v["kind"] for v in r["violations"]} == {"retrace-budget"}


def test_format_summary_names_failures():
    rep = {"summary": {"pass": 1, "fail": 1, "waived": 0, "skipped": 0,
                       "trip_fallbacks": 2},
           "matrix": {"engine_targets": 1, "measure_targets": 0,
                      "max_shards": 1},
           "elapsed_s": 0.1,
           "checks": [{"check": "collective-freedom", "target": "x",
                       "status": "fail",
                       "violations": [{"line": "%ar = all-reduce(...)"}]}]}
    text = audit_m.format_summary(rep)
    assert "FAIL collective-freedom @ x" in text
    assert "%ar = all-reduce(...)" in text
    assert "known_trip_count" in text  # the fallback warning


# ---------------------------------------------------------------------------
# bit-neutrality: auditing never perturbs served results
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["classification", "regression"])
@pytest.mark.parametrize("layout", ["ring", "compact"])
def test_audit_is_bit_neutral(family, layout):
    S, T, cap, dim, k = 3, 6, 16, 4, 3
    rng = np.random.default_rng(42)
    xs = jnp.asarray(rng.normal(size=(T, S, dim)), jnp.float32)
    taus = jnp.asarray(rng.uniform(size=(T, S)), jnp.float32)
    kw = dict(n_sessions=S, capacity=cap, dim=dim, k=k, window=cap,
              layout=layout)
    if family == "classification":
        ys = jnp.asarray(rng.integers(0, 2, (T, S)), jnp.int32)
        mk = lambda: ServingEngine(n_labels=2, **kw)
    else:
        ys = jnp.asarray(rng.normal(size=(T, S)), jnp.float32)
        mk = lambda: RegressionServingEngine(**kw)
    audited, plain = mk(), mk()

    # run the full static battery against the audited engine first
    t = audit_m.AuditTarget(
        name="bitneutral", kind="engine", family=family, mode="sliding",
        layout=layout, shards=1, n_sessions=S, capacity=cap, dim=dim,
        k=k, window=cap,
        dense_waiver="compact oracle" if layout == "compact" else "",
        copy_waiver="compact oracle" if layout == "compact" else "")
    art = audit_m.Artifact(t)
    art._engine = audited
    for name in ("donation-alias", "collective-freedom", "dense-budget"):
        r = audit_m.CHECKERS[name](t, art)
        assert r["status"] in ("pass", "waived"), r

    sa, pa = audited.observe_many(audited.init_state(), xs, ys, taus)
    sb, pb = plain.observe_many(plain.init_state(), xs, ys, taus)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    for la, lb in zip(jax.tree_util.tree_leaves(sa),
                      jax.tree_util.tree_leaves(sb)):
        assert np.array_equal(np.asarray(la), np.asarray(lb),
                              equal_nan=True)
    xq = xs[0]
    if family == "classification":
        ra, rb = audited.predict(sa, xq), plain.predict(sb, xq)
    else:
        ra = audited.intervals(sa, xq, epsilon=0.1)
        rb = plain.intervals(sb, xq, epsilon=0.1)
    assert np.array_equal(np.asarray(ra), np.asarray(rb),
                          equal_nan=True)
