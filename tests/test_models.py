"""Per-architecture smoke tests (reduced configs): forward/train/decode on
CPU; output shapes; finite values; decode-vs-full parity for cache paths.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as cfgs
from repro.models import lm

ARCHS = list(cfgs.names())


def _batch(cfg, B=2, S=16, key=jax.random.PRNGKey(1)):
    ks = jax.random.split(key, 3)
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(
                ks[0], (B, cfg.n_frontend_tokens, cfg.d_model),
                jnp.float32) * 0.1,
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        s_txt = S - cfg.n_frontend_tokens
        return {
            "tokens": jax.random.randint(ks[1], (B, s_txt), 0,
                                         cfg.vocab_size, jnp.int32),
            "patch_embeds": jax.random.normal(
                ks[0], (B, cfg.n_frontend_tokens, cfg.d_model),
                jnp.float32) * 0.1,
            "labels": jax.random.randint(ks[2], (B, s_txt), 0,
                                         cfg.vocab_size, jnp.int32),
        }
    return {
        "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size,
                                     jnp.int32),
        "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size,
                                     jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = cfgs.get(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm.train_step_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = cfgs.get(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    batch = _batch(cfg, B=B)
    cache = lm.init_cache(cfg, B, 24)
    if cfg.is_encoder_decoder:
        cache["cross"] = lm.prefill_cross_cache(params, cfg,
                                                batch["frames"])
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = lm.decode_step(params, cfg, tok, cache, 0)
    assert logits.shape == (B, 1, cfg.padded_vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "gemma3_1b",
                                  "recurrentgemma_9b", "xlstm_125m",
                                  "mixtral_8x22b", "deepseek_v2_236b"])
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode through the cache == full-sequence forward.

    The strongest cache-correctness check: covers KV caches, MLA latent
    caches, RG-LRU/conv states, m/sLSTM states.
    """
    cfg = cfgs.get(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    batch = _batch(cfg, B=B, S=S)
    full_logits, _, _ = lm.forward(params, cfg, batch)

    cache = lm.init_cache(cfg, B, S)
    toks = batch["tokens"]
    if cfg.frontend == "vision_stub":
        pytest.skip("decode parity for vlm covered via text-only archs")
    outs = []
    for i in range(toks.shape[1]):
        lg, cache = lm.decode_step(params, cfg, toks[:, i:i + 1], cache, i)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), atol=2e-2, rtol=2e-2)


def test_moe_aux_loss_and_dispatch():
    cfg = cfgs.get("mixtral_8x22b").reduced()
    from repro.models import mlp as mlp_m
    p = mlp_m.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out, aux = mlp_m.moe(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 0.0
    # capacity semantics: doubling capacity never changes routed tokens'
    # outputs for the kept slots (equal weights); just check determinism
    out2, _ = mlp_m.moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


def test_n_params_analytic_close_to_actual():
    for arch in ("qwen2_1_5b", "granite_34b"):
        cfg = cfgs.get(arch).reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        est = cfg.n_params()
        assert 0.5 < est / actual < 2.0, (arch, est, actual)
