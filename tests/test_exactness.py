"""Exactness: the paper's optimized measures == naive full CP, bit-for-bit
on the p-value counts (the paper's central 'exact optimization' claim).
Property-based via hypothesis over data geometry, k, labels.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m
from repro.data.synthetic import make_classification


def _data(n, p, n_labels, seed):
    X, y = make_classification(n_samples=n, n_features=p,
                               n_classes=n_labels, seed=seed)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 9),
       n_labels=st.integers(2, 4),
       simplified=st.booleans())
def test_knn_optimized_equals_standard(seed, k, n_labels, simplified):
    X, y = _data(40, 5, n_labels, seed)
    Xt, _ = _data(6, 5, n_labels, seed + 1)
    p_std = knn_m.pvalues_standard(X, y, Xt, k=k, simplified=simplified,
                                   n_labels=n_labels)
    st_ = knn_m.fit(X, y, k=k)
    p_opt = knn_m.pvalues_optimized(st_, Xt, k=k, simplified=simplified,
                                    n_labels=n_labels)
    np.testing.assert_allclose(np.asarray(p_std), np.asarray(p_opt),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), h=st.floats(0.5, 3.0),
       n_labels=st.integers(2, 3))
def test_kde_optimized_equals_standard(seed, h, n_labels):
    X, y = _data(35, 4, n_labels, seed)
    Xt, _ = _data(5, 4, n_labels, seed + 1)
    p_std = kde_m.pvalues_standard(X, y, Xt, h=h, p_dim=4,
                                   n_labels=n_labels)
    st_ = kde_m.fit(X, y, h=h, n_labels=n_labels)
    p_opt = kde_m.pvalues_optimized(st_, Xt, h=h, p_dim=4,
                                    n_labels=n_labels)
    np.testing.assert_allclose(np.asarray(p_std), np.asarray(p_opt),
                               atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), rho=st.floats(0.5, 4.0))
def test_lssvm_optimized_equals_standard(seed, rho):
    X, y = _data(25, 4, 2, seed)
    Xt, _ = _data(4, 4, 2, seed + 1)
    Y = 2.0 * jnp.asarray(y, jnp.float32) - 1.0
    p_std = lssvm_m.pvalues_standard(X, Y, Xt, rho=rho)
    st_ = lssvm_m.fit(X, Y, rho)
    p_opt = lssvm_m.pvalues_optimized(st_, Xt)
    np.testing.assert_allclose(np.asarray(p_std), np.asarray(p_opt),
                               atol=1e-4)


def test_lssvm_incremental_matches_refit():
    """Lee et al. (2019) update == training from scratch."""
    X, y = _data(30, 5, 2, 0)
    Y = 2.0 * jnp.asarray(y, jnp.float32) - 1.0
    st_ = lssvm_m.fit(X[:-1], Y[:-1], 1.0)
    st_inc = lssvm_m.incremental_add(st_, X[-1], Y[-1])
    st_full = lssvm_m.fit(X, Y, 1.0)
    np.testing.assert_allclose(np.asarray(st_inc.w), np.asarray(st_full.w),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_inc.C), np.asarray(st_full.C),
                               atol=2e-5)


def test_lssvm_loo_scores_match_per_point_downdate():
    """Vectorized LOO (3 GEMMs) == n separate decremental removals."""
    X, y = _data(20, 4, 2, 1)
    Y = 2.0 * jnp.asarray(y, jnp.float32) - 1.0
    st_ = lssvm_m.fit(X, Y, 1.0)
    fast = np.asarray(lssvm_m.loo_scores(st_))
    for i in range(X.shape[0]):
        mask = jnp.arange(X.shape[0]) != i
        st_i = lssvm_m.fit(X[mask], Y[mask], 1.0)
        slow = -Y[i] * (X[i] @ st_i.w)
        assert abs(fast[i] - float(slow)) < 5e-4, i


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 6))
def test_knn_incremental_add_matches_refit(seed, k):
    """Online learning (paper Section 9): learn-one == refit."""
    X, y = _data(30, 4, 2, seed)
    st_inc = knn_m.fit(X[:-1], y[:-1], k=k)
    st_inc = knn_m.incremental_add(st_inc, X[-1], y[-1], k=k)
    st_full = knn_m.fit(X, y, k=k)
    np.testing.assert_allclose(np.asarray(st_inc.best_same),
                               np.asarray(st_full.best_same), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_inc.best_diff),
                               np.asarray(st_full.best_diff), atol=1e-5)
