"""Ring-buffer sliding-window layout: exactness + O(cap) eviction.

The acceptance-critical properties of the circular-indexing tentpole:

* any observe/evict interleaving on the ring layout — wrap-around, tie
  runs across the ring seam, inactive lanes, window-confined blocks —
  is BIT-identical (p-values and every normalized state leaf) to the
  historic positional-compaction layout (``_sliding_step_compact``) and
  therefore, transitively through the pre-existing suites, to
  fit-from-scratch on the surviving window;
* the jitted ring sliding step materializes NO (cap, cap)-sized buffer:
  the distance matrix is only read (backfill reductions) and written in
  place at one row + one column (asserted on the optimized HLO via
  ``analysis.hlo.dense_materializations`` — the compact layout is the
  positive control);
* wrapped rings survive ``grow`` and snapshot save/restore, and legacy
  pre-ring (5/6-leaf linear) snapshots still restore and serve.
"""
import functools
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAS_HYPOTHESIS = False

from repro.core import regression as reg
from repro.data.synthetic import make_classification, make_regression
from repro.regression import RegressionServingEngine
from repro.regression import session as rsess
from repro.regression import stream as rstream
from repro.serving import ServingEngine, SessionStore
from repro.serving import session as sm

DIM = 5
_STAT = ("k", "evictable", "wmax")
_cstep_ring = functools.partial(jax.jit, static_argnames=_STAT)(
    sm._sliding_step)
_cstep_compact = functools.partial(jax.jit, static_argnames=_STAT)(
    sm._sliding_step_compact)
_rstep_ring = functools.partial(jax.jit, static_argnames=_STAT)(
    rsess._sliding_step)
_rstep_compact = functools.partial(jax.jit, static_argnames=_STAT)(
    rsess._sliding_step_compact)


def _class_stream(T, seed):
    X, y = make_classification(n_samples=T, n_features=DIM, seed=seed)
    taus = jax.random.uniform(jax.random.PRNGKey(seed), (T,), jnp.float32)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.int32), taus


def _reg_stream(T, seed):
    X, y = make_regression(n_samples=T, n_features=DIM, seed=seed)
    taus = jax.random.uniform(jax.random.PRNGKey(seed), (T,), jnp.float32)
    return (jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
            taus)


def _tie_stream(T, seed, classes=2):
    """Integer grids force exactly-equal distances across the ring seam."""
    rng = np.random.RandomState(seed)
    X = jnp.asarray(rng.randint(0, 2, size=(T, DIM)), jnp.float32)
    y = rng.randint(0, classes, size=T)
    taus = jnp.full((T,), 0.5, jnp.float32)
    return X, y, taus


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _run_pair(kind, X, y, taus, *, k, cap, window, wmax, actmod):
    """Drive ring and compact steps over the same stream; p-values must
    agree per tick and the normalized final states leaf-for-leaf."""
    if kind == "class":
        init, ring, compact, lin = (sm.init, _cstep_ring, _cstep_compact,
                                    sm.to_linear)
        cast = lambda v: jnp.asarray(v, jnp.int32)
    else:
        init, ring, compact, lin = (rsess.init, _rstep_ring,
                                    _rstep_compact, rstream.to_linear)
        cast = lambda v: jnp.asarray(v, jnp.float32)
    wm = wmax if wmax is None else max(min(window, cap), k)
    wr = cap if wmax is None else wm
    a = init(cap, DIM, k, wrap=wr)
    b = init(cap, DIM, k, wrap=wr)
    for t in range(X.shape[0]):
        act = jnp.asarray(actmod == 0 or (t % actmod != 0))
        a, pa = ring(a, X[t], cast(y[t]), taus[t], jnp.int32(window), act,
                     k=k, evictable=True, wmax=wm)
        b, pb = compact(b, X[t], cast(y[t]), taus[t], jnp.int32(window),
                        act, k=k, evictable=True, wmax=wm)
        assert (float(pa) == float(pb)
                or (np.isnan(float(pa)) and np.isnan(float(pb)))), t
    _assert_trees_equal(lin(a), lin(b))
    return a


# ---------------------------------------------------------------------------
# ring == compact, property-tested across wrap-around
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    _ring_cases = lambda f: settings(max_examples=10, deadline=None)(
        given(seed=st.integers(0, 10_000), k=st.integers(1, 6),
              window=st.integers(1, 14), confined=st.booleans(),
              actmod=st.integers(0, 4), ties=st.booleans())(f))
else:  # deterministic fallback grid (hypothesis not installed)
    _ring_cases = pytest.mark.parametrize(
        "seed,k,window,confined,actmod,ties",
        [(0, 5, 12, True, 3, False), (1, 3, 10, False, 0, False),
         (2, 1, 7, True, 0, True), (3, 4, 3, True, 4, False),
         (4, 2, 2, False, 0, True), (5, 6, 13, True, 2, False)])


@pytest.mark.parametrize("kind", ["class", "reg"])
@_ring_cases
def test_ring_equals_compact_any_interleaving(kind, seed, k, window,
                                              confined, actmod, ties):
    """The tentpole exactness property: ring ticks (wrap-around, ties at
    the seam, gated lanes, window-confined blocks) are bit-identical to
    the positional-compaction oracle."""
    T, cap = 40, 32
    if ties:
        X, y, taus = _tie_stream(T, seed, classes=2)
    elif kind == "class":
        X, y, taus = _class_stream(T, seed)
    else:
        X, y, taus = _reg_stream(T, seed)
    window = max(min(window, cap), 1)
    _run_pair(kind, X, y, taus, k=k, cap=cap, window=window,
              wmax=(window if confined else None), actmod=actmod)


def test_ring_wraps_and_matches_refit_classification():
    """A visibly wrapped ring (head > 0, several laps) still equals an
    incremental fit on the surviving window, D and arrival ids included."""
    T, cap, w, k = 50, 16, 16, 5
    X, y, taus = _class_stream(T, seed=7)
    sess = sm.init(cap, DIM, k)
    for t in range(T):
        sess, _ = sm.observe_sliding(sess, X[t], y[t], taus[t],
                                     jnp.int32(w), k=k)
    assert int(sess.head) == (T - w) % cap  # wrapped 2+ laps
    scratch = sm.init(cap, DIM, k)
    for t in range(T - w, T):
        scratch, _ = sm.observe(scratch, X[t], y[t], taus[t], k=k)
    a, b = sm.to_linear(sess), sm.to_linear(scratch)
    np.testing.assert_array_equal(np.asarray(a.knn.best),
                                  np.asarray(b.knn.best))
    np.testing.assert_array_equal(np.asarray(a.D), np.asarray(b.D))
    # predict on the wrapped ring == predict on the fresh state
    pa = sm.predict_pvalues(sess, X[:6], k=k, n_labels=2)
    pb = sm.predict_pvalues(scratch, X[:6], k=k, n_labels=2)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


@pytest.mark.parametrize("i_kind", ["head", "mid", "last"])
def test_reg_evict_index_on_wrapped_ring(i_kind):
    """evict(i) (arrival rank) on a wrapped ring: evict-at-head equals
    evict_oldest's window; mid/last exercise the general recompute."""
    T, cap, k = 26, 32, 4
    X, y, _ = _reg_stream(T, seed=3)
    stt = rstream.init(cap, DIM, k)
    for t in range(T):
        stt, _ = rstream.observe(stt, X[t], y[t], k=k)
    for _ in range(5):  # wrap: free 5 slots, refill them
        stt = rstream.evict_oldest(stt, k=k)
    for t in range(5):
        stt, _ = rstream.observe(stt, X[t], y[t], k=k)
    order = np.concatenate([np.arange(5, T), np.arange(5)])
    i = {"head": 0, "mid": T // 2, "last": T - 1}[i_kind]
    stt = rstream.evict(stt, jnp.int32(i), k=k)
    keep = np.delete(order, i)
    fit = reg.fit(X[keep], y[keep], k=k)
    view = rstream.state_view(stt, k=k)
    n = int(stt.n)
    np.testing.assert_array_equal(np.asarray(view.X)[:n],
                                  np.asarray(X)[keep])
    np.testing.assert_array_equal(np.asarray(view.a_prime)[:n],
                                  np.asarray(fit.a_prime))
    np.testing.assert_array_equal(np.asarray(view.kth_label)[:n],
                                  np.asarray(fit.kth_label))


@pytest.mark.parametrize("kind", ["class", "reg"])
def test_grow_while_wrapped(kind):
    """grow() on a wrapped ring normalizes and keeps serving exactly."""
    T, cap, w, k = 30, 16, 10, 4
    if kind == "class":
        X, y, taus = _class_stream(T, seed=11)
        a = _run_pair(kind, X, y, taus, k=k, cap=cap, window=w, wmax=w,
                      actmod=0)
        g = sm.grow(a)
        assert g.capacity == 2 * cap
        assert int(g.head) == 0 and int(g.wrap) == 2 * cap
        scratch = sm.init(2 * cap, DIM, k)
        for t in range(T - w, T):
            scratch, _ = sm.observe(scratch, X[t], y[t], taus[t], k=k)
        _, pg = sm.observe(g, X[0], y[0], jnp.float32(0.5), k=k)
        _, ps = sm.observe(scratch, X[0], y[0], jnp.float32(0.5), k=k)
        assert float(pg) == float(ps)
    else:
        X, y, taus = _reg_stream(T, seed=12)
        a = _run_pair(kind, X, y, taus, k=k, cap=cap, window=w, wmax=w,
                      actmod=0)
        g = rsess.grow(a)
        assert g.capacity == 2 * cap
        assert int(g.head) == 0 and int(g.wrap) == 2 * cap
        fit = reg.fit(X[T - w:], y[T - w:], k=k)
        view = rstream.state_view(g, k=k)
        np.testing.assert_array_equal(np.asarray(view.a_prime)[:w],
                                      np.asarray(fit.a_prime))


# ---------------------------------------------------------------------------
# engines: compact layout plugs in, wrapped snapshots round-trip
# ---------------------------------------------------------------------------


def _drive(eng, state, xs, ys, taus):
    ps = []
    for t in range(xs.shape[0]):
        state, p = eng.observe(state, xs[t], ys[t], taus[t])
        ps.append(np.asarray(p))
    return state, np.stack(ps)


def test_engine_layouts_bit_identical_classification():
    S, T, cap, w, k = 2, 30, 16, 8, 3
    streams = [_class_stream(T, seed=500 + s) for s in range(S)]
    xs = jnp.stack([jnp.stack([st_[0][t] for st_ in streams])
                    for t in range(T)])
    ys = jnp.stack([jnp.stack([st_[1][t] for st_ in streams])
                    for t in range(T)])
    taus = jnp.stack([jnp.stack([st_[2][t] for st_ in streams])
                      for t in range(T)])
    kw = dict(n_sessions=S, capacity=cap, dim=DIM, k=k, n_labels=2,
              window=w)
    er = ServingEngine(**kw, layout="ring", donate=False)
    ec = ServingEngine(**kw, layout="compact", donate=False)
    sr, pr = _drive(er, er.init_state(), xs, ys, taus)
    sc, pc = _drive(ec, ec.init_state(), xs, ys, taus)
    np.testing.assert_array_equal(pr, pc)
    assert int(jnp.max(sr.head)) > 0  # the ring engines actually wrapped
    assert int(jnp.max(sc.head)) == 0  # the compact ones never move rows
    q = er.predict(sr, xs[0])
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(ec.predict(sc, xs[0])))
    with pytest.raises(ValueError, match="layout"):
        ServingEngine(**kw, layout="spiral")


def test_wrapped_ring_snapshot_roundtrip_both_engines():
    S, T, k, w, cap = 2, 26, 3, 8, 16
    # classification
    streams = [_class_stream(T, seed=600 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=cap, dim=DIM, k=k,
                        n_labels=2, window=w)
    state = eng.init_state()
    for t in range(T):
        state, _ = eng.observe(
            state, jnp.stack([st_[0][t] for st_ in streams]),
            jnp.stack([st_[1][t] for st_ in streams]),
            jnp.stack([st_[2][t] for st_ in streams]))
    assert int(jnp.max(state.head)) > 0  # wrapped before snapshotting
    with tempfile.TemporaryDirectory() as d:
        SessionStore(d).save(T, state, meta=eng.meta(), blocking=True)
        eng2, state2, step = SessionStore(d).restore_engine()
        assert step == T
        _assert_trees_equal(state, state2)
        x = jnp.stack([st_[0][0] for st_ in streams])
        y = jnp.stack([st_[1][0] for st_ in streams])
        tau = jnp.stack([st_[2][0] for st_ in streams])
        _, pa = eng.observe(state, x, y, tau)
        _, pb = eng2.observe(state2, x, y, tau)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
    # regression
    rstreams = [_reg_stream(T, seed=650 + s) for s in range(S)]
    reng = RegressionServingEngine(n_sessions=S, capacity=cap, dim=DIM,
                                  k=k, window=w)
    rstate = reng.init_state()
    for t in range(T):
        rstate, _ = reng.observe(
            rstate, jnp.stack([st_[0][t] for st_ in rstreams]),
            jnp.stack([st_[1][t] for st_ in rstreams]),
            jnp.stack([st_[2][t] for st_ in rstreams]))
    assert int(jnp.max(rstate.head)) > 0
    with tempfile.TemporaryDirectory() as d:
        SessionStore(d).save(T, rstate, meta=reng.meta(), blocking=True)
        reng2, rstate2, _ = SessionStore(d).restore_engine()
        assert isinstance(reng2, RegressionServingEngine)
        _assert_trees_equal(rstate, rstate2)
        iv = reng.intervals(rstate, rstreams[0][0][:3], epsilon=0.157)
        iv2 = reng2.intervals(rstate2, rstreams[0][0][:3], epsilon=0.157)
        np.testing.assert_array_equal(np.asarray(iv), np.asarray(iv2))


def test_legacy_linear_snapshot_restores_and_serves():
    """Pre-ring snapshots (5-leaf classification / 6-leaf regression
    linear layouts) restore into ring states and keep serving."""
    from repro.checkpoint.store import CheckpointStore

    S, T, cap, w, k = 2, 12, 16, 8, 3
    streams = [_class_stream(T, seed=700 + s) for s in range(S)]
    eng = ServingEngine(n_sessions=S, capacity=cap, dim=DIM, k=k,
                        n_labels=2, window=w)
    state = eng.init_state()
    for t in range(T):
        state, _ = eng.observe(
            state, jnp.stack([st_[0][t] for st_ in streams]),
            jnp.stack([st_[1][t] for st_ in streams]),
            jnp.stack([st_[2][t] for st_ in streams]))
    # fabricate the legacy 5-leaf layout from the normalized state
    lin = jax.vmap(sm.to_linear)(state)
    legacy = [lin.knn.X, lin.knn.y, lin.knn.best, lin.knn.n, lin.D]
    with tempfile.TemporaryDirectory() as d:
        CheckpointStore(d).save(T, legacy, blocking=True,
                                extra=eng.meta())
        eng2, state2, step = SessionStore(d).restore_engine()
        assert step == T and eng2.window == w
        assert int(jnp.max(state2.head)) == 0
        assert int(jnp.min(state2.wrap)) == eng2._wmax  # re-pinned
        x = jnp.stack([st_[0][0] for st_ in streams])
        y = jnp.stack([st_[1][0] for st_ in streams])
        tau = jnp.stack([st_[2][0] for st_ in streams])
        _, pa = eng2.observe(state2, x, y, tau)  # serves without error
        assert np.isfinite(np.asarray(pa)).all()

    # regression legacy (6-leaf): nbr_a is reconstructed from D
    X, y, taus = _reg_stream(T, seed=710)
    stt = rstream.init(cap, DIM, k)
    for t in range(T):
        stt, _ = rstream.observe(stt, X[t], y[t], k=k)
    legacy = [stt.X, stt.y, stt.D, stt.nbr_d, stt.nbr_y, stt.n]
    meta = RegressionServingEngine(
        n_sessions=1, capacity=cap, dim=DIM, k=k).meta()
    with tempfile.TemporaryDirectory() as d:
        CheckpointStore(d).save(T, legacy, blocking=True, extra=meta)
        store = SessionStore(d)
        state2, _, _ = store.restore()
        assert isinstance(state2, rstream.RegStreamState)
        np.testing.assert_array_equal(np.asarray(state2.nbr_a),
                                      np.asarray(stt.nbr_a))
        # and the restored state keeps evicting exactly
        a = rstream.evict_oldest(state2, k=k)
        b = rstream.evict_oldest(stt, k=k)
        _assert_trees_equal(a, b)


def test_engine_rejects_mismatched_ring_modulus():
    eng = ServingEngine(n_sessions=1, capacity=16, dim=DIM, k=3,
                        n_labels=2, window=8)
    bad = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (1,) + a.shape),
        sm.init(16, DIM, 3))  # wrap == capacity != window block
    X, y, taus = _class_stream(1, seed=13)
    with pytest.raises(ValueError, match="ring modulus"):
        eng.observe(bad, X[:1], y[:1], taus[:1])
    # the reverse handoff — a window-confined ring into a GROW engine —
    # must be rejected too: the grow engine would keep inserting past
    # the state's smaller modulus and overwrite live slots
    grow_eng = ServingEngine(n_sessions=1, capacity=16, dim=DIM, k=3,
                             n_labels=2)
    confined = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (1,) + a.shape),
        sm.init(16, DIM, 3, wrap=8))
    with pytest.raises(ValueError, match="grow-mode engine's capacity"):
        grow_eng.observe(confined, X[:1], y[:1], taus[:1])


def test_arrival_id_wraparound_is_harmless():
    """The int32 arrival counters may overflow on a long-lived stream;
    every id comparison is a wraparound difference from the oldest live
    id, so a state whose ids straddle INT32_MAX must evict and observe
    exactly like its unshifted twin (tie-heavy data so the id-based
    tie-breaks actually fire)."""
    T, cap, k = 24, 32, 4
    X, y, _ = _tie_stream(T, seed=5, classes=4)
    y = jnp.asarray(y, jnp.float32)
    a = rstream.init(cap, DIM, k)
    for t in range(T):
        a, _ = rstream.observe(a, X[t], y[t], k=k)
    # shift every id (slot counters and neighbour lists) near the wrap
    # point: after ~40 more inserts the raw counters overflow
    off = jnp.int32(2**31 - 40)
    live = np.asarray(rstream.ring_live(cap, a.head, a.n, a.wrap))
    b = rstream.RegStreamState(
        a.X, a.y, a.D, a.nbr_d, a.nbr_y, a.n, a.head,
        jnp.where(jnp.asarray(live), a.aid + off, a.aid), a.wrap,
        jnp.where(a.nbr_d < 1e29, a.nbr_a + off, a.nbr_a))
    for t in range(T):  # interleave evicts with re-adds across the wrap
        a = rstream.evict_oldest(a, k=k)
        b = rstream.evict_oldest(b, k=k)
        a, _ = rstream.observe(a, X[t], y[t], k=k)
        b, _ = rstream.observe(b, X[t], y[t], k=k)
        for nm in ("nbr_d", "nbr_y", "n", "head"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, nm)), np.asarray(getattr(b, nm)),
                err_msg=f"{nm} diverged at tick {t}")
    # the shifted twin's raw counters really did wrap negative
    newest = np.asarray(b.aid)[int(rstream.ring_slots(
        cap, b.head, b.wrap)[int(b.n) - 1])]
    assert newest < 0
    fit = reg.fit(X, y, k=k)
    view = rstream.state_view(b, k=k)
    np.testing.assert_array_equal(np.asarray(view.kth_label)[:T],
                                  np.asarray(fit.kth_label))


# ---------------------------------------------------------------------------
# the O(cap) eviction claim, on the optimized HLO (via the auditor —
# repro.analysis.audit owns the single definition of this invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["class", "reg"])
def test_ring_sliding_step_never_materializes_cap_sq(kind):
    """No (cap, cap) shift/copy/rebuild per tick in the jitted sliding
    step: the distance matrix may only appear as a parameter, inside
    reductions, and as in-place dynamic-update-slice writes. The compact
    layout is the positive control — its per-tick compaction trips the
    same detector. Asserted through ``audit.dense_tick_violations``,
    the same predicate the CI audit gate runs over the whole matrix."""
    from repro.analysis import audit as audit_m

    S, cap, dim, k, chunk = 2, 64, 8, 5, 4
    min_bytes = S * cap * cap * 4  # a full f32 (S, cap, cap) result
    kw = dict(n_sessions=S, capacity=cap, dim=dim, k=k, window=cap)
    if kind == "class":
        mk = lambda layout: ServingEngine(**kw, n_labels=2, layout=layout)
    else:
        mk = lambda layout: RegressionServingEngine(**kw, layout=layout)
    ring_hlo = mk("ring").lower_tick(chunk).compile().as_text()
    per_tick = audit_m.dense_tick_violations(ring_hlo, min_bytes)
    assert not per_tick, per_tick
    compact_hlo = mk("compact").lower_tick(chunk).compile().as_text()
    assert audit_m.dense_tick_violations(compact_hlo, min_bytes), (
        "positive control: the compaction layout should materialize "
        "(cap, cap) buffers per tick")
    # and the ring tick keeps its donated buffers aliased (no leak)
    assert not audit_m.alias_violations(
        ring_hlo, len(jax.tree_util.tree_leaves(mk("ring").init_state())))
