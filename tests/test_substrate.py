"""Substrate tests: optimizer, checkpoint store, trainer restart, data
pipeline determinism, flops/HLO accounting.
"""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as cfgs
from repro.analysis.flops import flops_of
from repro.checkpoint import CheckpointStore
from repro.data.lm_pipeline import TokenStream
from repro.optim import (OptimizerConfig, apply_updates, init_opt_state,
                         lr_schedule)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(peak_lr=0.1, end_lr=0.01, warmup_steps=5,
                          total_steps=200, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    opt = init_opt_state(params, cfg)
    tgt = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - tgt)}
        params, opt, _ = apply_updates(params, g, opt, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(tgt),
                               atol=2e-2)


def test_adamw_matches_reference_step():
    """One step vs a hand-rolled AdamW reference."""
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                          b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                          clip_norm=1e9)
    w0 = np.array([[1.0, -2.0], [0.5, 3.0]], np.float32)
    g = np.array([[0.1, 0.2], [-0.3, 0.4]], np.float32)
    params = {"w": jnp.asarray(w0)}
    opt = init_opt_state(params, cfg)
    params, opt, stats = apply_updates(params, {"w": jnp.asarray(g)}, opt,
                                       cfg)
    lr = float(lr_schedule(cfg, jnp.asarray(1)))
    m = 0.1 * g
    v = 0.01 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    ref = w0 - lr * (mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * w0)
    np.testing.assert_allclose(np.asarray(params["w"]), ref, atol=1e-6)


def test_factored_moments_memory_shape():
    cfg = OptimizerConfig(factored=True)
    params = {"big": jnp.zeros((64, 32)), "small": jnp.zeros((7,))}
    opt = init_opt_state(params, cfg)
    assert opt["nu"]["big"]["row"].shape == (64,)
    assert opt["nu"]["big"]["col"].shape == (32,)
    assert opt["nu"]["small"]["full"].shape == (7,)
    # one step still descends
    g = {"big": jnp.ones((64, 32)), "small": jnp.ones((7,))}
    p2, _, _ = apply_updates(params, g, opt, cfg)
    assert float(jnp.sum(p2["big"])) < 0.0


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------


@pytest.fixture
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(ckpt_dir):
    store = CheckpointStore(ckpt_dir)
    t = _tree(0)
    store.save(10, t, blocking=True)
    restored, step = store.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_uncommitted_ignored(ckpt_dir):
    store = CheckpointStore(ckpt_dir)
    store.save(5, _tree(0), blocking=True)
    # simulate a crashed writer: step dir without COMMITTED
    bad = os.path.join(ckpt_dir, "step_000000009")
    os.makedirs(bad)
    with open(os.path.join(bad, "manifest.json"), "w") as f:
        f.write("{}")
    assert store.latest_step() == 5
    assert not os.path.exists(bad)  # garbage collected


def test_checkpoint_gc_keeps_last(ckpt_dir):
    store = CheckpointStore(ckpt_dir, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s), blocking=True)
    assert store.committed_steps() == [3, 4]


def test_checkpoint_checksum_detects_corruption(ckpt_dir):
    store = CheckpointStore(ckpt_dir)
    store.save(7, _tree(0), blocking=True)
    shard = os.path.join(ckpt_dir, "step_000000007", "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        store.restore(jax.tree.map(jnp.zeros_like, _tree(0)), 7)


def test_checkpoint_async_then_wait(ckpt_dir):
    store = CheckpointStore(ckpt_dir)
    store.save(3, _tree(1), blocking=False)
    store.wait()
    assert store.latest_step() == 3


# ---------------------------------------------------------------------------
# trainer restart (end-to-end)
# ---------------------------------------------------------------------------


def test_trainer_restart_continues(tmp_path):
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import Trainer, TrainerConfig

    cfg = cfgs.get("xlstm_125m").reduced()
    d = str(tmp_path / "tr")
    mesh = make_host_mesh(1, 1)
    t1 = Trainer(cfg, TrainerConfig(steps=4, ckpt_every=2, ckpt_dir=d,
                                    log_every=10, batch=2, seq_len=32),
                 mesh)
    out1 = t1.run()
    assert out1["stop_step"] == 4
    t2 = Trainer(cfg, TrainerConfig(steps=6, ckpt_every=2, ckpt_dir=d,
                                    log_every=10, batch=2, seq_len=32),
                 mesh)
    out2 = t2.run()
    assert out2["stop_step"] == 6
    assert len(out2["losses"]) == 2  # resumed at 4, ran 4..5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_stream_deterministic_and_host_sharded():
    cfg = cfgs.get("qwen2_1_5b").reduced()
    s1 = TokenStream(cfg, 8, 32, seed=3)
    s2 = TokenStream(cfg, 8, 32, seed=3)
    np.testing.assert_array_equal(s1.batch_at(5)["tokens"],
                                  s2.batch_at(5)["tokens"])
    # host sharding partitions the global batch
    h0 = TokenStream(cfg, 8, 32, seed=3, host_id=0, num_hosts=2)
    h1 = TokenStream(cfg, 8, 32, seed=3, host_id=1, num_hosts=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


# ---------------------------------------------------------------------------
# flops / HLO accounting
# ---------------------------------------------------------------------------


def test_flops_counter_exact_matmul_and_scan():
    D = 128
    def f(w, x):
        def body(c, wi):
            return c @ wi, None
        c, _ = jax.lax.scan(body, x, w)
        return jnp.sum(c)

    w = jax.ShapeDtypeStruct((5, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((16, D), jnp.float32)
    got = flops_of(f, w, x)["flops"]
    want = 5 * 2 * 16 * D * D + 16 * D  # dots + final reduce
    assert abs(got - want) / want < 0.01, (got, want)


def test_hlo_while_trip_and_collectives():
    from repro.analysis.hlo import collective_bytes, \
        computation_multiplicities
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
        jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)).compile()
    txt = comp.as_text()
    info = computation_multiplicities(txt)
    assert 9.0 in info["mult"].values(), info["mult"]
    assert collective_bytes(txt) == {}  # single device: no collectives
