"""repro.regression: streaming exactness + engine + kernel + registry.

The acceptance-critical properties:
* after ANY interleaving of observe/evict, the streaming state's
  per-point statistics are BIT-exact vs ``regression.fit`` refit-from-
  scratch on the live window;
* session- and engine-served prediction intervals are BIT-identical to
  ``regression.intervals_optimized`` on that window;
* the Pallas ``interval_sweep`` kernel matches its ``ref.py`` oracle.
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property-test widely with hypothesis; else a fixed grid
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAS_HYPOTHESIS = False

from repro.core import regression as reg
from repro.data.synthetic import make_regression
from repro.regression import RegressionServingEngine
from repro.regression import session as rsess
from repro.regression import stream as rstream
from repro.serving import ConformalPredictor, SessionStore

DIM = 5
EPS = 0.157  # irrational-ish: eps (n+1) never lands on a rank boundary


def _data(n, seed, dim=DIM):
    X, y = make_regression(n_samples=n, n_features=dim, seed=seed)
    return X.astype(np.float32), y.astype(np.float32)


def _fill(state, X, y, k, lo=0, hi=None):
    for t in range(lo, hi if hi is not None else X.shape[0]):
        state, _ = rstream.observe(state, jnp.asarray(X[t]),
                                   jnp.asarray(y[t]), k=k)
    return state


def _assert_state_matches_fit(state, Xw, yw, k):
    """Streaming statistics == regression.fit bits on the live window.

    ``state_view`` gathers the ring into arrival order, so the checks
    below are layout-independent (wrapped rings included)."""
    n = int(state.n)
    assert n == Xw.shape[0]
    fit = reg.fit(jnp.asarray(Xw), jnp.asarray(yw), k=k)
    view = rstream.state_view(state, k=k)
    np.testing.assert_array_equal(np.asarray(view.X)[:n], np.asarray(Xw))
    np.testing.assert_array_equal(
        np.asarray(view.a_prime)[:n], np.asarray(fit.a_prime))
    np.testing.assert_array_equal(
        np.asarray(view.kth_dist)[:n], np.asarray(fit.kth_dist))
    np.testing.assert_array_equal(
        np.asarray(view.kth_label)[:n], np.asarray(fit.kth_label))
    return fit


# ---------------------------------------------------------------------------
# ordering guarantees the streaming machinery (and fit) rest on
# ---------------------------------------------------------------------------


def test_topk_negation_is_ascending():
    """-top_k(-d, k) is ascending with ties toward the lower index — the
    ordering ``regression.fit`` and ``distributed._global_k_best`` assume
    (this is their assertion-backed 'ascending?' resolution)."""
    key = jax.random.PRNGKey(0)
    for n, k in [(30, 5), (12, 12), (50, 1), (9, 4)]:
        key, sub = jax.random.split(key)
        # quantized values force plenty of ties; BIG exercises the padding
        d = jnp.round(jax.random.uniform(sub, (n,)) * 8.0) / 8.0
        d = d.at[: n // 3].set(d[n // 3: 2 * (n // 3)][: n // 3])
        neg, idx = jax.lax.top_k(-d, k)
        asc = -neg
        assert bool(jnp.all(asc[1:] >= asc[:-1])), (n, k)
        # matches a stable numpy argsort (ties by index)
        order = np.argsort(np.asarray(d), kind="stable")[:k]
        np.testing.assert_array_equal(np.asarray(idx), order)
        np.testing.assert_array_equal(np.asarray(asc),
                                      np.asarray(d)[order])


def test_fit_kth_stats_are_the_kth_ascending_neighbour():
    X, y = _data(40, 0)
    k = 5
    fit = reg.fit(jnp.asarray(X), jnp.asarray(y), k=k)
    D = np.sqrt(np.maximum(
        ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1), 0.0))
    np.fill_diagonal(D, np.inf)
    order = np.argsort(D, axis=1, kind="stable")
    np.testing.assert_allclose(
        np.asarray(fit.kth_dist), np.take_along_axis(
            D, order, 1)[:, k - 1], rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(fit.kth_label), y[order[:, k - 1]])


# ---------------------------------------------------------------------------
# streaming exactness (the paper's incremental/decremental updates)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    _interleave_cases = lambda f: settings(max_examples=12, deadline=None)(
        given(seed=st.integers(0, 10_000), k=st.integers(1, 7),
              n_evict=st.integers(0, 10))(f))
    _evict_cases = lambda f: settings(max_examples=8, deadline=None)(
        given(seed=st.integers(0, 10_000), k=st.integers(1, 6),
              i=st.integers(0, 20))(f))
else:  # deterministic fallback grid (hypothesis not installed)
    _interleave_cases = pytest.mark.parametrize(
        "seed,k,n_evict",
        [(0, 5, 3), (1, 1, 0), (2, 7, 10), (3, 3, 6), (4, 2, 1),
         (5, 6, 8)])
    _evict_cases = pytest.mark.parametrize(
        "seed,k,i", [(0, 5, 0), (1, 1, 12), (2, 6, 25), (3, 3, 7)])


@_interleave_cases
def test_observe_evict_interleaving_bit_exact_vs_refit(seed, k, n_evict):
    """Observe/evict in arbitrary interleavings == fit on the window."""
    T = 34
    X, y = _data(T, seed)
    state = rstream.init(64, DIM, k)
    state = _fill(state, X, y, k, hi=T - 8)
    for _ in range(n_evict):
        state = rstream.evict_oldest(state, k=k)
    state = _fill(state, X, y, k, lo=T - 8)
    Xw, yw = X[n_evict:], y[n_evict:]
    fit = _assert_state_matches_fit(state, Xw, yw, k)

    Xt, _ = _data(5, seed + 1)
    Xt = jnp.asarray(Xt)
    got = np.asarray(rsess.intervals(state, Xt, k=k, epsilon=EPS))
    want = np.asarray(reg.intervals_optimized(fit, Xt, k=k, epsilon=EPS))
    assert got.tobytes() == want.tobytes(), np.abs(got - want).max()


@_evict_cases
def test_evict_arbitrary_index_bit_exact_vs_refit(seed, k, i):
    T = 26
    X, y = _data(T, seed)
    state = _fill(rstream.init(32, DIM, k), X, y, k)
    state = rstream.evict(state, i % T, k=k)
    keep = np.arange(T) != (i % T)
    _assert_state_matches_fit(state, X[keep], y[keep], k)


@pytest.mark.parametrize("seed,k", [(0, 3), (1, 1), (2, 5)])
def test_evict_oldest_tie_heavy_bit_exact(seed, k):
    """Integer-grid features force many exactly-equal distances: the
    O(k)-surgery evict_oldest must reproduce fit's ties-toward-lower-
    index order (distances AND labels) bit-for-bit."""
    T = 24
    rng = np.random.RandomState(seed)
    X = rng.randint(0, 3, size=(T, DIM)).astype(np.float32)
    y = rng.randint(0, 4, size=T).astype(np.float32)
    state = _fill(rstream.init(32, DIM, k), X, y, k)
    for e in range(T - k - 1):
        state = rstream.evict_oldest(state, k=k)
        _assert_state_matches_fit(state, X[e + 1:], y[e + 1:], k)


def test_sliding_window_equals_refit_each_window():
    T, cap, w, k = 40, 64, 12, 5
    X, y = _data(T, seed=4)
    state = rstream.init(cap, DIM, k)
    for t in range(T):
        state, _ = rsess.observe_sliding(
            state, jnp.asarray(X[t]), jnp.asarray(y[t]),
            jnp.float32(0.5), jnp.int32(w), k=k)
    _assert_state_matches_fit(state, X[T - w:], y[T - w:], k)


def test_grow_preserves_exactness():
    T, k = 20, 5
    X, y = _data(T, seed=5)
    state = _fill(rstream.init(16, DIM, k), X, y, k, hi=15)
    state = rsess.grow(state)
    assert state.capacity == 32
    state = _fill(state, X, y, k, lo=15)
    _assert_state_matches_fit(state, X, y, k)


def test_pvalues_match_optimized_counts():
    """Served p-values carry fit's exact rank counts; the final division
    may differ by 1 ulp (traced vs constant divisor)."""
    T, k = 30, 5
    X, y = _data(T, seed=6)
    state = _fill(rstream.init(32, DIM, k), X, y, k)
    fit = reg.fit(jnp.asarray(X), jnp.asarray(y), k=k)
    Xt = jnp.asarray(_data(4, 7)[0])
    tq = jnp.linspace(float(y.min()) - 5, float(y.max()) + 5, 15) + 0.0137
    got = np.asarray(rsess.pvalues(state, Xt, tq, k=k))
    want = np.asarray(reg.pvalues_optimized(fit, Xt, tq, k=k))
    np.testing.assert_array_equal(
        np.round(got * (T + 1)), np.round(want * (T + 1)))
    np.testing.assert_allclose(got, want, atol=1e-7)


def test_observe_pvalue_is_valid_and_smoothed():
    """Online p-values of exchangeable labels are ~uniform (validity)."""
    T, k = 200, 5
    X, y = _data(T, seed=8)
    key = jax.random.PRNGKey(0)
    state = rstream.init(256, DIM, k)
    ps = []
    for t in range(T):
        key, sub = jax.random.split(key)
        state, p = rsess.observe(
            state, jnp.asarray(X[t]), jnp.asarray(y[t]),
            jax.random.uniform(sub, dtype=jnp.float32), k=k)
        ps.append(float(p))
    ps = np.asarray(ps[20:])  # skip the k-NN warmup
    assert ((ps > 0) & (ps <= 1)).all()
    assert 0.35 < ps.mean() < 0.65
    assert (ps < 0.25).mean() < 0.45


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def _run_engine(eng, streams, T):
    state = eng.init_state()
    key = jax.random.PRNGKey(1)
    pvals = np.zeros((len(streams), T), np.float32)
    for t in range(T):
        key, sub = jax.random.split(key)
        state, p = eng.observe(
            state,
            jnp.stack([jnp.asarray(s[0][t]) for s in streams]),
            jnp.stack([jnp.asarray(s[1][t]) for s in streams]),
            eng.taus(sub))
        pvals[:, t] = np.asarray(p)
    return state, pvals


def test_engine_served_intervals_bit_identical_to_optimized():
    S, T, k, w = 4, 36, 5, 24
    streams = [_data(T, seed=100 + s) for s in range(S)]
    eng = RegressionServingEngine(n_sessions=S, capacity=32, dim=DIM,
                                  k=k, window=w)
    state, _ = _run_engine(eng, streams, T)
    Xt = jnp.asarray(_data(5, 999)[0])
    iv = np.asarray(eng.intervals(state, Xt, epsilon=EPS))
    tq = jnp.linspace(-30.0, 30.0, 9) + 0.0137
    pv = np.asarray(eng.pvalues(state, Xt, tq))
    for s in range(S):
        X, y = streams[s]
        fit = reg.fit(jnp.asarray(X[T - w:]), jnp.asarray(y[T - w:]), k=k)
        want = np.asarray(reg.intervals_optimized(fit, Xt, k=k,
                                                  epsilon=EPS))
        assert iv[s].tobytes() == want.tobytes()
        want_p = np.asarray(reg.pvalues_optimized(fit, Xt, tq, k=k))
        np.testing.assert_allclose(pv[s], want_p, atol=1e-7)


def test_engine_vmapped_step_equals_sequential_sessions_bitwise():
    S, T, k, w = 3, 25, 4, 10
    streams = [_data(T, seed=200 + s) for s in range(S)]
    eng = RegressionServingEngine(n_sessions=S, capacity=32, dim=DIM,
                                  k=k, window=w)
    state, pvals = _run_engine(eng, streams, T)
    key = jax.random.PRNGKey(1)
    taus = []
    for t in range(T):
        key, sub = jax.random.split(key)
        taus.append(np.asarray(eng.taus(sub)))
    for s, (X, y) in enumerate(streams):
        sl = rstream.init(32, DIM, k)
        for t in range(T):
            sl, p = rsess.observe_sliding(
                sl, jnp.asarray(X[t]), jnp.asarray(y[t]),
                jnp.float32(taus[t][s]), jnp.int32(w), k=k)
            assert float(p) == pvals[s, t]
        # the engine ring is confined to the [:window] block while the
        # standalone session rings over the full capacity — identical
        # windows, different slot layouts, so compare normalized
        lane = jax.tree_util.tree_map(lambda a: a[s], state)
        np.testing.assert_array_equal(
            np.asarray(rstream.to_linear(sl).nbr_d),
            np.asarray(rstream.to_linear(lane).nbr_d))


def test_engine_grow_mode_doubles_and_stays_exact():
    S, T, k = 2, 20, 5
    streams = [_data(T, seed=300 + s) for s in range(S)]
    eng = RegressionServingEngine(n_sessions=S, capacity=8, dim=DIM, k=k)
    state, pvals = _run_engine(eng, streams, T)
    assert state.capacity == 32  # 8 -> 16 -> 32
    assert eng.meta()["capacity"] == 32
    assert np.isfinite(pvals[:, 1:]).all()
    Xt = jnp.asarray(_data(3, 998)[0])
    iv = np.asarray(eng.intervals(state, Xt, epsilon=EPS))
    for s, (X, y) in enumerate(streams):
        fit = reg.fit(jnp.asarray(X), jnp.asarray(y), k=k)
        want = np.asarray(reg.intervals_optimized(fit, Xt, k=k,
                                                  epsilon=EPS))
        assert iv[s].tobytes() == want.tobytes()


if HAS_HYPOTHESIS:
    _chunk_cases = lambda f: settings(max_examples=8, deadline=None)(
        given(seed=st.integers(0, 10_000), k=st.integers(1, 6),
              cut=st.integers(0, 24))(f))
else:
    _chunk_cases = pytest.mark.parametrize(
        "seed,k,cut", [(0, 4, 0), (1, 1, 24), (2, 6, 7), (3, 3, 13)])


@_chunk_cases
def test_observe_many_chunking_bit_identical_to_per_tick(seed, k, cut):
    """Any split of the tick stream into observe_many chunks (donated)
    == the per-tick undonated path, bitwise, states included."""
    S, T, cap, w = 3, 24, 32, 10
    streams = [_data(T, seed + 31 * s) for s in range(S)]
    xs = jnp.stack([jnp.stack([jnp.asarray(st_[0][t]) for st_ in streams])
                    for t in range(T)])
    ys = jnp.stack([jnp.stack([jnp.asarray(st_[1][t]) for st_ in streams])
                    for t in range(T)])
    taus = jax.random.uniform(jax.random.PRNGKey(seed), (T, S),
                              dtype=jnp.float32)
    kw = dict(n_sessions=S, capacity=cap, dim=DIM, k=k, window=w)
    ref_eng = RegressionServingEngine(**kw, donate=False)
    st_ref = ref_eng.init_state()
    want = np.zeros((T, S), np.float32)
    for t in range(T):
        st_ref, p = ref_eng.observe(st_ref, xs[t], ys[t], taus[t])
        want[t] = np.asarray(p)

    eng = RegressionServingEngine(**kw)  # donate=True default
    state = eng.init_state()
    got = []
    for lo, hi in [(0, cut), (cut, T)]:
        if hi > lo:
            state, p = eng.observe_many(state, xs[lo:hi], ys[lo:hi],
                                        taus[lo:hi])
            got.append(np.asarray(p))
    np.testing.assert_array_equal(np.concatenate(got, axis=0), want)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(st_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_observe_many_grow_mode_provisions_whole_chunk():
    S, T, k = 2, 20, 5
    streams = [_data(T, seed=310 + s) for s in range(S)]
    xs = jnp.stack([jnp.stack([jnp.asarray(st_[0][t]) for st_ in streams])
                    for t in range(T)])
    ys = jnp.stack([jnp.stack([jnp.asarray(st_[1][t]) for st_ in streams])
                    for t in range(T)])
    taus = jax.random.uniform(jax.random.PRNGKey(7), (T, S), jnp.float32)
    eng = RegressionServingEngine(n_sessions=S, capacity=8, dim=DIM, k=k)
    state, pvals = eng.observe_many(eng.init_state(), xs, ys, taus)
    assert state.capacity == 32  # provisioned for all 20 ticks up front
    assert eng.capacity == 32
    assert np.isfinite(np.asarray(pvals)).all()
    Xt = jnp.asarray(_data(3, 997)[0])
    iv = np.asarray(eng.intervals(state, Xt, epsilon=EPS))
    for s, (X, y) in enumerate(streams):
        fit = reg.fit(jnp.asarray(X), jnp.asarray(y), k=k)
        want = np.asarray(reg.intervals_optimized(fit, Xt, k=k,
                                                  epsilon=EPS))
        assert iv[s].tobytes() == want.tobytes()


def test_donated_stream_step_matches_undonated_and_consumes():
    """stream.observe_donated / evict_donated: same bits as the
    undonated forms; the pre-donation state is dead afterwards."""
    T, k = 20, 4
    X, y = _data(T, seed=11)
    a = rstream.init(32, DIM, k)
    b = rstream.init(32, DIM, k)
    for t in range(T):
        prev = a
        a, da = rstream.observe_donated(
            a, jnp.asarray(X[t]), jnp.asarray(y[t]), k=k)
        b, db = rstream.observe(
            b, jnp.asarray(X[t]), jnp.asarray(y[t]), k=k)
        np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
    a = rstream.evict_donated(a, 3, k=k)
    b = rstream.evict(b, 3, k=k)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    _assert_state_matches_fit(
        a, np.delete(X, 3, axis=0), np.delete(y, 3, axis=0), k)
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(prev.D)


def test_regression_engine_dtype_stable_across_grow():
    S, k, dtype = 2, 3, jnp.bfloat16
    eng = RegressionServingEngine(n_sessions=S, capacity=8, dim=DIM, k=k,
                                  dtype=dtype)
    assert eng.taus(jax.random.PRNGKey(0)).dtype == dtype
    state = eng.init_state()
    X, y = _data(20, seed=13)
    for t in range(20):  # forces growth past capacity 8
        state, p = eng.observe(
            state, jnp.stack([jnp.asarray(X[t])] * S).astype(dtype),
            jnp.stack([jnp.asarray(y[t])] * S).astype(dtype),
            eng.taus(jax.random.PRNGKey(t)))
    assert state.capacity > 8
    assert p.dtype == dtype
    for leaf in (state.X, state.y, state.D, state.nbr_d, state.nbr_y):
        assert leaf.dtype == dtype
    assert eng.taus(jax.random.PRNGKey(9)).dtype == dtype


def test_engine_active_masking_freezes_inactive_slots():
    S, k = 4, 3
    streams = [_data(3, seed=400 + s) for s in range(S)]
    eng = RegressionServingEngine(n_sessions=S, capacity=16, dim=DIM, k=k,
                                  window=8)
    state = eng.init_state()
    active = jnp.array([True, False, True, False])
    state, p = eng.observe(
        state,
        jnp.stack([jnp.asarray(s[0][0]) for s in streams]),
        jnp.stack([jnp.asarray(s[1][0]) for s in streams]),
        eng.taus(jax.random.PRNGKey(0)), active=active)
    p = np.asarray(p)
    assert not np.isnan(p[0]) and np.isnan(p[1])
    assert list(np.asarray(state.n)) == [1, 0, 1, 0]


def test_engine_constructor_validation():
    with pytest.raises(ValueError, match="window"):
        RegressionServingEngine(n_sessions=1, capacity=8, dim=DIM, k=3,
                                window=9)
    with pytest.raises(ValueError, match="capacity"):
        RegressionServingEngine(n_sessions=1, capacity=2, dim=DIM, k=3)


# ---------------------------------------------------------------------------
# snapshot
# ---------------------------------------------------------------------------


def test_regression_snapshot_roundtrip_and_engine_restore():
    S, T, k, w = 3, 14, 4, 8
    streams = [_data(T, seed=500 + s) for s in range(S)]
    eng = RegressionServingEngine(n_sessions=S, capacity=16, dim=DIM,
                                  k=k, window=w)
    state, _ = _run_engine(eng, streams, T)
    with tempfile.TemporaryDirectory() as d:
        SessionStore(d).save(T, state, meta=eng.meta(), blocking=True)
        eng2, state2, step = SessionStore(d).restore_engine()
        assert step == T
        assert isinstance(eng2, RegressionServingEngine)
        assert (eng2.k, eng2.window, eng2.capacity) == (k, w, 16)
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(state2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored engine continues bit-identically
        x = jnp.stack([jnp.asarray(s[0][0]) for s in streams])
        y = jnp.stack([jnp.asarray(s[1][0]) for s in streams])
        tau = eng.taus(jax.random.PRNGKey(7))
        _, pa = eng.observe(state, x, y, tau)
        _, pb = eng2.observe(state2, x, y, tau)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# ---------------------------------------------------------------------------
# registry measure
# ---------------------------------------------------------------------------


def test_registry_knn_regression_measure_exact():
    k = 5
    X, y = _data(40, seed=9)
    cp = ConformalPredictor("knn_regression", k=k).fit(X[:30], y[:30])
    cp.observe(jnp.asarray(X[30]), float(y[30]))
    assert cp.n == 31
    cp.evict(3)
    assert cp.n == 30
    keep = np.concatenate([np.arange(3), np.arange(4, 31)])
    fit = reg.fit(jnp.asarray(X[keep]), jnp.asarray(y[keep]), k=k)
    Xt = jnp.asarray(X[31:35])
    got = np.asarray(cp.intervals(Xt, eps=EPS))
    want = np.asarray(reg.intervals_optimized(fit, Xt, k=k, epsilon=EPS))
    assert got.tobytes() == want.tobytes()
    with pytest.raises(ValueError, match="t_query"):
        cp.pvalues(Xt)
    cp.hp["t_query"] = np.linspace(-20, 20, 7) + 0.0137
    p = cp.pvalues(Xt)
    assert p.shape == (4, 7)


def test_registry_classification_measures_have_no_intervals():
    X, y = make_regression(n_samples=20, n_features=DIM, seed=1)
    cls_y = (y > np.median(y)).astype(np.int32)
    cp = ConformalPredictor("simplified_knn", k=3).fit(
        X.astype(np.float32), cls_y)
    with pytest.raises(NotImplementedError, match="interval"):
        cp.intervals(jnp.asarray(X[:2], jnp.float32), eps=0.1)
