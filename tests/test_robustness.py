"""Fault-tolerance tests: keyed fault plans, injector semantics, saver
retry / uncommit, restore fallback, guarded ticks + quarantine, fault-
stamped traces, and the chaos property test.

The chaos property is the acceptance contract of the robustness PR:
under ANY injected fault plan (I/O + traffic + timing + state poison),
the surviving tenants' p-values and final state are BIT-identical to a
fault-free run on the same surviving stream, every quarantine / retry /
rejection is counted in metrics, and the guard adds zero new engine
retraces.
"""
import os
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.regression.engine import RegressionServingEngine
from repro.robustness import (VALUE_FAULTS, Fault, FaultInjector, FaultPlan,
                              PermanentWriteError, TickGuard,
                              TransientWriteError, backoff_schedule,
                              corrupt_traffic, flip_byte, poison_state)
from repro.serving import AsyncShardedSaver, ServingEngine, SessionStore
from repro.telemetry import MetricsRegistry
from repro.telemetry.loadgen import generate
from repro.telemetry.replay import replay
from repro.telemetry.tracer import validate_record, validate_trace_file, \
    write_trace

S, CAP, DIM, K, WIN = 6, 32, 4, 3, 16


def _mk(mode):
    if mode == "classification":
        return ServingEngine(n_sessions=S, capacity=CAP, dim=DIM, k=K,
                             n_labels=2, window=WIN)
    return RegressionServingEngine(n_sessions=S, capacity=CAP, dim=DIM,
                                   k=K, window=WIN)


def _traffic(mode, T, seed=5):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(T, S, DIM)).astype(np.float32)
    if mode == "classification":
        y = rng.integers(0, 2, size=(T, S)).astype(np.int64)
    else:
        y = rng.normal(size=(T, S)).astype(np.float32)
    taus = rng.uniform(size=(T, S)).astype(np.float32)
    return X, y, taus


def _leaves_equal(a, b, rows=None):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if rows is not None:
            x, y = x[rows], y[rows]
        if not np.array_equal(x, y, equal_nan=True):
            return False
    return True


def _metric_sum(metrics, name):
    return sum(m["value"] for m in metrics.to_dict()["metrics"]
               if m["name"] == name)


# --------------------------------------------------------------------------
# fault plans: keyed determinism
# --------------------------------------------------------------------------

def test_fault_plan_keyed_and_deterministic():
    a = FaultPlan.random(9, steps=64, tenants=4, rate=0.2)
    b = FaultPlan.random(9, steps=64, tenants=4, rate=0.2)
    assert a.faults() == b.faults()
    assert len(a) > 0
    # per-cell keying: the decision at step s does not depend on how
    # many steps the plan covers
    wide = FaultPlan.random(9, steps=256, tenants=4, rate=0.2)
    assert [f for f in wide.faults() if f.step < 64] == a.faults()
    # a different seed draws a different schedule
    c = FaultPlan.random(10, steps=64, tenants=4, rate=0.2)
    assert a.faults() != c.faults()


def test_fault_kind_validated():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("traffic", 0, "meteor_strike")


def test_plan_lookup_is_positional():
    plan = FaultPlan(0, (Fault("traffic", 3, "nan_feature", tenant=1),))
    assert plan.at("traffic", 3)[0].kind == "nan_feature"
    assert plan.at("traffic", 4) == ()
    assert plan.at("store.write", 3) == ()


# --------------------------------------------------------------------------
# injector: transient vs permanent, attempt counting
# --------------------------------------------------------------------------

def test_injector_transient_clears_after_times():
    metrics = MetricsRegistry()
    plan = FaultPlan(1, (Fault("store.write", 5, "write_fail", times=2),))
    inj = FaultInjector(plan, metrics=metrics)
    for _ in range(2):
        with pytest.raises(TransientWriteError):
            inj.enter("store.write", 5)
    inj.enter("store.write", 5)  # third attempt succeeds
    inj.enter("store.write", 6)  # other steps unaffected
    assert _metric_sum(metrics, "faults_injected_total") == 2


def test_injector_permanent_never_clears():
    plan = FaultPlan(1, (Fault("store.write", 2, "write_fail", times=-1),))
    inj = FaultInjector(plan)
    for _ in range(4):
        with pytest.raises(PermanentWriteError):
            inj.enter("store.write", 2)


def test_backoff_schedule_keyed_and_increasing():
    a = backoff_schedule(3, 7, 4, 0.05)
    assert a == backoff_schedule(3, 7, 4, 0.05)
    assert a != backoff_schedule(3, 8, 4, 0.05)
    assert all(y > x for x, y in zip(a, a[1:]))
    assert all(0.05 * 2 ** i <= d <= 0.05 * 2 ** i * 1.25
               for i, d in enumerate(a))


def test_flip_byte_is_an_involution(tmp_path):
    p = tmp_path / "blob.bin"
    p.write_bytes(bytes(range(64)))
    off = flip_byte(str(p), seed=4)
    assert p.read_bytes() != bytes(range(64))
    flip_byte(str(p), offset=off)
    assert p.read_bytes() == bytes(range(64))


def test_corrupt_traffic_reports_oracle_mask():
    X, y, taus = _traffic("classification", 16)
    plan = FaultPlan(2, (Fault("traffic", 3, "nan_feature", tenant=2),
                         Fault("traffic", 5, "label_out_of_range",
                               tenant=1),
                         Fault("traffic", 9, "tau_out_of_range",
                               tenant=0)))
    hits = corrupt_traffic(plan, X, y, taus, mode="classification",
                           n_labels=2, time_axis=0)
    assert hits == {(3, 2), (5, 1), (9, 0)}
    assert np.isnan(X[3, 2, 0])
    assert y[5, 1] >= 2
    assert taus[9, 0] > 1.0
    # launcher layout: tenant-major with time_axis=1
    Xl = np.transpose(X, (1, 0, 2)).copy()
    yl, tl = y.T.copy(), taus.T.copy()
    hits_l = corrupt_traffic(plan, Xl, yl, tl, mode="classification",
                             n_labels=2, time_axis=1)
    assert hits_l == hits
    assert np.isnan(Xl[2, 3, 0])


# --------------------------------------------------------------------------
# store: restore fallback on corruption (satellite a)
# --------------------------------------------------------------------------

def test_restore_falls_back_to_previous_committed_step(tmp_path):
    metrics = MetricsRegistry()
    eng = _mk("classification")
    state1 = eng.init_state()
    X, y, taus = _traffic("classification", 8)
    state1, _ = eng.observe_many(eng.init_state(), jnp.asarray(X),
                                 jnp.asarray(y), jnp.asarray(taus))
    store = SessionStore(str(tmp_path), metrics=metrics)
    store.save(1, state1, meta=eng.meta(), blocking=True)
    state1 = jax.device_get(state1)  # observe_many donates its input
    state2, _ = eng.observe_many(
        jax.tree_util.tree_map(jnp.asarray, state1), jnp.asarray(X),
        jnp.asarray(y), jnp.asarray(taus))
    store.save(2, state2, meta=eng.meta(), blocking=True)
    step_dir = os.path.join(str(tmp_path), f"step_{2:09d}")
    shard = next(os.path.join(step_dir, f)
                 for f in sorted(os.listdir(step_dir))
                 if f.endswith(".npz"))
    flip_byte(shard, seed=0)

    got, got_step, _meta = store.restore()
    assert got_step == 1
    assert _leaves_equal(got, state1)
    assert _metric_sum(metrics, "restore_fallback_total") >= 1
    # an explicitly requested corrupt step still raises — fallback is
    # only for "give me the latest good one"
    with pytest.raises(Exception):
        store.restore(step=2)


# --------------------------------------------------------------------------
# async saver: retry on transient faults, uncommit on exhaustion
# (satellite b)
# --------------------------------------------------------------------------

def test_saver_retries_transient_write_faults(tmp_path):
    metrics = MetricsRegistry()
    eng = _mk("classification")
    state = eng.init_state()
    plan = FaultPlan(4, (Fault("store.write", 7, "write_fail", times=2),))
    store = SessionStore(str(tmp_path), metrics=metrics,
                         injector=FaultInjector(plan, metrics=metrics))
    saver = AsyncShardedSaver(store, 2, metrics=metrics, retries=3,
                              retry_base_s=0.01, seed=4)
    saver.save(7, state, meta=eng.meta())
    saver.close()
    assert store.latest_step() == 7
    assert _metric_sum(metrics, "snapshot_retries_total") == 2
    got, got_step, _ = store.restore()
    assert got_step == 7 and _leaves_equal(got, state)


def test_saver_uncommits_failed_step(tmp_path):
    metrics = MetricsRegistry()
    eng = _mk("classification")
    state = eng.init_state()
    store = SessionStore(str(tmp_path), metrics=metrics)
    store.save(1, state, meta=eng.meta(), blocking=True)
    plan = FaultPlan(4, (Fault("store.write", 2, "write_fail", times=9),))
    store2 = SessionStore(str(tmp_path), metrics=metrics,
                          injector=FaultInjector(plan))
    saver = AsyncShardedSaver(store2, 1, metrics=metrics, retries=2,
                              retry_base_s=0.01, seed=4)
    saver.save(2, state, meta=eng.meta())
    with pytest.raises(RuntimeError, match="async snapshot save failed"):
        saver.close()
    # the failed step was discarded: latest never points at the
    # half-written snapshot, and restore serves the previous commit
    assert store2.latest_step() == 1
    assert _metric_sum(metrics, "snapshot_failed_steps_total") == 1
    _got, got_step, _ = store2.restore()
    assert got_step == 1


# --------------------------------------------------------------------------
# guard: bit-neutral when clean, admission == oracle mask, quarantine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["classification", "regression"])
def test_guard_bit_identical_on_clean_traffic(mode):
    X, y, taus = _traffic(mode, 48)
    plain, guarded = _mk(mode), TickGuard(_mk(mode), check_every=1)
    sp, sg = plain.init_state(), guarded.init_state()
    for c in range(3):
        sl = slice(c * 16, (c + 1) * 16)
        args = (jnp.asarray(X[sl]), jnp.asarray(y[sl]),
                jnp.asarray(taus[sl]))
        sp, pp = plain.observe_many(sp, *args)
        sg, pg = guarded.observe_many(sg, *args)
        assert np.array_equal(np.asarray(pp), np.asarray(pg),
                              equal_nan=True)
    sg = guarded.finalize(sg)
    assert _leaves_equal(sp, sg)
    rep = guarded.drain()
    assert sum(rep["rejected"].values()) == 0
    assert rep["quarantines"] == 0 and rep["quarantined_lanes"] == []
    # the guarded path dispatches the same compiled engine step: one
    # cache entry each, zero new retraces
    assert guarded.engine._step_many._cache_size() == 1
    assert plain._step_many._cache_size() == 1


@pytest.mark.parametrize("mode", ["classification", "regression"])
def test_guard_admission_matches_oracle_mask(mode):
    T = 32
    X, y, taus = _traffic(mode, T)
    Xc, yc, tc = X.copy(), y.copy(), taus.copy()
    plan = FaultPlan.random(17, steps=T, tenants=S, rate=0.15,
                            kinds=VALUE_FAULTS)
    hits = corrupt_traffic(plan, X, y, taus, mode=mode, n_labels=2,
                           time_axis=0)
    assert hits, "seed 17 must draw at least one traffic fault"
    mask = np.ones((T, S), dtype=bool)
    for t, lane in hits:
        mask[t, lane] = False

    metrics = MetricsRegistry()
    guarded = TickGuard(_mk(mode), metrics=metrics)
    sg, pg = guarded.observe_many(guarded.init_state(), jnp.asarray(X),
                                  jnp.asarray(y), jnp.asarray(taus))
    sg = guarded.finalize(sg)
    oracle = _mk(mode)
    so, po = oracle.observe_many(oracle.init_state(), jnp.asarray(Xc),
                                 jnp.asarray(yc), jnp.asarray(tc),
                                 active=jnp.asarray(mask))
    # every faulted lane-tick was rejected (NaN p) and the surviving
    # stream is bit-identical to the fault-free masked run
    for t, lane in hits:
        assert np.isnan(np.asarray(pg)[t, lane])
    assert np.array_equal(np.asarray(pg), np.asarray(po), equal_nan=True)
    assert _leaves_equal(sg, so)
    rep = guarded.drain()
    assert sum(rep["rejected"].values()) == len(hits)
    assert _metric_sum(metrics, "guard_rejected_inputs_total") == len(hits)


def test_guard_freezes_poisoned_lane_without_store():
    mode, lane = "classification", 2
    X, y, taus = _traffic(mode, 32)
    guard = TickGuard(_mk(mode), check_every=1)
    state = guard.init_state()
    state, _ = guard.observe_many(state, jnp.asarray(X[:16]),
                                  jnp.asarray(y[:16]),
                                  jnp.asarray(taus[:16]))
    state = poison_state(state, lane)
    state = guard.finalize(state)
    rep_mid = dict(guard.drain())
    assert rep_mid["quarantines"] == 1 and rep_mid["restores"] == 0
    assert rep_mid["quarantined_lanes"] == [lane]
    # the frozen lane is masked out of every subsequent tick: NaN
    # p-values, state bitwise frozen
    before = jax.tree_util.tree_map(
        lambda L: np.asarray(L)[lane].copy(), state)
    state, p = guard.observe_many(state, jnp.asarray(X[16:]),
                                  jnp.asarray(y[16:]),
                                  jnp.asarray(taus[16:]))
    assert np.all(np.isnan(np.asarray(p)[:, lane]))
    after = jax.tree_util.tree_map(
        lambda L: np.asarray(L)[lane], state)
    assert _leaves_equal(before, after)


@pytest.mark.parametrize("mode", ["classification", "regression"])
def test_guard_restores_quarantined_lane_from_snapshot(tmp_path, mode):
    lane = 3
    X, y, taus = _traffic(mode, 32)
    metrics = MetricsRegistry()
    store = SessionStore(str(tmp_path), metrics=metrics)
    eng = _mk(mode)
    guard = TickGuard(eng, store=store, metrics=metrics, check_every=1)
    state = eng.init_state()
    store.save(0, state, meta=eng.meta(), blocking=True)
    snap_lane = jax.tree_util.tree_map(
        lambda L: np.asarray(L)[lane].copy(), state)
    state, _ = guard.observe_many(state, jnp.asarray(X[:16]),
                                  jnp.asarray(y[:16]),
                                  jnp.asarray(taus[:16]))
    state = poison_state(state, lane)
    state = guard.finalize(state)
    rep = guard.drain()
    assert rep["quarantines"] == 1 and rep["restores"] == 1
    assert rep["quarantined_lanes"] == []  # restored, back in service
    got_lane = jax.tree_util.tree_map(
        lambda L: np.asarray(L)[lane], state)
    assert _leaves_equal(snap_lane, got_lane)
    assert _metric_sum(metrics, "guard_restores_total") == 1
    # the restored lane serves again: finite p-values resume
    state, p = guard.observe_many(state, jnp.asarray(X[16:]),
                                  jnp.asarray(y[16:]),
                                  jnp.asarray(taus[16:]))
    assert np.isfinite(np.asarray(p)[:, lane]).any()


# --------------------------------------------------------------------------
# fault-stamped traces (tracer schema v3) + replay dedup / shed
# --------------------------------------------------------------------------

def test_loadgen_stamps_fault_schedule(tmp_path):
    plan = FaultPlan.random(
        13, steps=128, tenants=4, rate=0.2,
        kinds=VALUE_FAULTS + ("duplicate_arrival", "delay"), param=0.002)
    clean = generate("steady", ops=128, tenants=4, capacity=32, seed=1)
    recs = generate("steady", ops=128, tenants=4, capacity=32, seed=1,
                    faults=plan)
    stamped = [r for r in recs if "fault" in r or "delay_s" in r]
    assert stamped, "seed 13 must stamp at least one fault"
    assert any(r.get("fault", {}).get("kind") in VALUE_FAULTS
               for r in recs)
    dups = [r for r in recs
            if r.get("fault", {}).get("kind") == "duplicate_arrival"]
    for d in dups:
        assert d["fault"]["of_seq"] < d["seq"]
    # the base trace is unchanged by the plan: only the stamped fields
    # differ from the fault-free twin
    for a, b in zip(clean, recs):
        sa = {k: v for k, v in b.items() if k not in ("fault", "delay_s")}
        assert a == sa
    # round-trips through the schema validator
    path = str(tmp_path / "faulted.jsonl")
    write_trace(path, recs)
    assert len(validate_trace_file(path)) == 128


def test_trace_schema_v2_still_valid_and_bad_fault_rejected():
    v2 = {"schema": 2, "seq": 0, "t": 0.0, "op": "observe",
          "wall_s": 0.0, "workload": "steady", "seed": 1}
    validate_record(v2)
    bad = {"schema": 3, "seq": 0, "t": 0.0, "op": "observe",
           "wall_s": 0.0, "fault": {"kind": 42}}
    with pytest.raises(ValueError, match="fault"):
        validate_record(bad)
    bad2 = {"schema": 3, "seq": 0, "t": 0.0, "op": "observe",
            "wall_s": 0.0, "delay_s": "soon"}
    with pytest.raises(ValueError, match="delay_s"):
        validate_record(bad2)


def test_replay_drops_duplicate_arrivals():
    plan = FaultPlan(
        21, tuple(Fault("traffic", s, "duplicate_arrival", tenant=0)
                  for s in (20, 40, 60)))
    recs = generate("steady", ops=96, tenants=4, capacity=32, seed=3,
                    faults=plan)
    res = replay(recs, dim=DIM, k=K, capacity=CAP, window=WIN, seed=3)
    assert res.report["duplicates_dropped"] == 3
    # dedup removes the re-delivered events from the driven stream
    clean = [r for r in recs
             if r.get("fault", {}).get("kind") != "duplicate_arrival"]
    oracle = replay(clean, dim=DIM, k=K, capacity=CAP, window=WIN, seed=3)
    assert _leaves_equal(res.state, oracle.state)


def test_replay_shed_defers_but_never_drops_observes():
    recs = generate("steady", ops=128, tenants=4, capacity=32, seed=9)
    base = replay(recs, dim=DIM, k=K, capacity=CAP, window=WIN, seed=9)
    shed = replay(recs, dim=DIM, k=K, capacity=CAP, window=WIN, seed=9,
                  shed_depth=1, defer_flush=8)
    # reads are shed first; observes only defer, and the deferred
    # flush preserves order — the final state is bit-identical
    assert _leaves_equal(base.state, shed.state)
    assert shed.report["shed_depth"] == 1
    assert shed.report["session_steps"] == base.report["session_steps"]


# --------------------------------------------------------------------------
# lint rule: swallowed exceptions in durability layers (satellite e)
# --------------------------------------------------------------------------

def _lint_fixture(tmp_path, rel, src):
    from repro.analysis.lint import lint_paths
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return [v for v in lint_paths([str(p)])
            if v.rule == "swallowed-exception"]


def test_lint_flags_swallowed_exceptions_in_scope(tmp_path):
    vs = _lint_fixture(tmp_path, "repro/serving/bad.py", """
        def f():
            try:
                g()
            except:
                pass
            try:
                g()
            except OSError:
                continue_ = 1
            try:
                g()
            except ValueError:
                pass
    """)
    assert [v.line for v in vs] == [5, 13]


def test_lint_pragma_and_scope_escapes(tmp_path):
    ok = _lint_fixture(tmp_path, "repro/serving/ok.py", """
        def f():
            try:
                g()
            except ValueError:  # audit: allow
                pass
    """)
    assert ok == []
    out_of_scope = _lint_fixture(tmp_path, "repro/models/other.py", """
        def f():
            try:
                g()
            except:
                pass
    """)
    assert out_of_scope == []


def test_lint_clean_over_src_tree():
    from repro.analysis.lint import lint_tree
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    assert [v for v in lint_tree(root)
            if v.rule == "swallowed-exception"] == []


# --------------------------------------------------------------------------
# the chaos property test
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["classification", "regression"])
def test_chaos_surviving_tenants_bit_identical(tmp_path, mode):
    """Randomized keyed fault plan (traffic value faults + I/O write
    faults + a timing delay + an in-memory lane poison) over >= 200
    ticks: unaffected tenants must be bit-identical to a fault-free run
    on the same surviving stream; every rejection / quarantine /
    restore / retry is counted; zero new engine retraces."""
    SEED, T, CH = 23, 224, 8
    # chunk 7 starts at ring head 56 % WIN == 8, so the poisoned slot 0
    # survives the chunk and the deferred sweep's flags catch it before
    # the following chunk's ring pass overwrites the NaN
    POISON_LANE, POISON_CHUNK = 4, 7
    nchunks = T // CH
    assert T >= 200

    X, y, taus = _traffic(mode, T)
    Xc, yc, tc = X.copy(), y.copy(), taus.copy()
    plan = FaultPlan.random(SEED, steps=T, tenants=S, rate=0.06,
                            kinds=VALUE_FAULTS)
    hits = corrupt_traffic(plan, X, y, taus, mode=mode, n_labels=2,
                           time_axis=0)
    assert len(hits) >= 5, "seed 23 must draw a handful of value faults"
    mask = np.ones((T, S), dtype=bool)
    for t, lane in hits:
        mask[t, lane] = False

    metrics = MetricsRegistry()
    io_plan = FaultPlan(SEED, (
        Fault("store.write", 3, "write_fail", times=1),
        Fault("store.commit", 3, "delay", param=0.001),
    ))
    store = SessionStore(str(tmp_path), metrics=metrics,
                         injector=FaultInjector(io_plan, metrics=metrics))
    saver = AsyncShardedSaver(store, 1, metrics=metrics,
                              retry_base_s=0.01, seed=SEED)
    eng = _mk(mode)
    guard = TickGuard(eng, store=store, metrics=metrics, check_every=2)
    state = eng.init_state()
    saver.save(0, state, meta=eng.meta())
    saver.wait()

    pg = []
    for c in range(nchunks):
        if c == POISON_CHUNK:
            state = poison_state(state, POISON_LANE)
        sl = slice(c * CH, (c + 1) * CH)
        state, p = guard.observe_many(state, jnp.asarray(X[sl]),
                                      jnp.asarray(y[sl]),
                                      jnp.asarray(taus[sl]))
        pg.append(np.asarray(p))
        if c == 3:  # mid-run snapshot through the faulted write path
            saver.save(3, state, meta=eng.meta())
    state = guard.finalize(state)
    saver.close()
    rep = guard.drain()

    # fault-free oracle on the surviving stream: clean traffic, the
    # faulted lane-ticks simply never arrive
    oracle = _mk(mode)
    so = oracle.init_state()
    po = []
    for c in range(nchunks):
        sl = slice(c * CH, (c + 1) * CH)
        so, p = oracle.observe_many(so, jnp.asarray(Xc[sl]),
                                    jnp.asarray(yc[sl]),
                                    jnp.asarray(tc[sl]),
                                    active=jnp.asarray(mask[sl]))
        po.append(np.asarray(p))

    keep = np.array([s for s in range(S) if s != POISON_LANE])
    for c in range(nchunks):
        assert np.array_equal(pg[c][:, keep], po[c][:, keep],
                              equal_nan=True), f"chunk {c} diverged"
    for c in range(POISON_CHUNK):  # pre-poison the lane matches too
        assert np.array_equal(pg[c][:, POISON_LANE],
                              po[c][:, POISON_LANE], equal_nan=True)
    assert _leaves_equal(state, so, rows=keep)
    for t, lane in hits:  # every surviving faulted tick was rejected
        if lane != POISON_LANE:
            assert np.isnan(pg[t // CH][t % CH, lane])

    # accounting: every defense that fired left a counter behind
    assert rep["quarantines"] >= 1 and rep["restores"] >= 1
    assert rep["quarantined_lanes"] == []
    n_surviving = sum(1 for _, lane in hits if lane != POISON_LANE)
    assert n_surviving <= sum(rep["rejected"].values()) <= len(hits)
    assert _metric_sum(metrics, "snapshot_retries_total") == 1
    assert _metric_sum(metrics, "guard_quarantines_total") >= 1
    assert _metric_sum(metrics, "guard_restores_total") >= 1
    assert _metric_sum(metrics, "faults_injected_total") >= 2
    assert store.latest_step() == 3  # the retried snapshot committed
    # the guard never changed the engine's dispatch signature
    assert eng._step_many._cache_size() == 1
    assert oracle._step_many._cache_size() == 1
