"""Bootstrap CP (paper Section 6, Algorithm 3): streaming exactness,
determinism, validity, the vectorized tree kernel, and the registry entry.

The acceptance-critical properties:
* after ANY interleaving of ``incremental_add`` / ``decremental_remove``,
  the state is BIT-identical to ``fit_from_samples`` on the same
  effective sample set (``rebuild``) — lists, trees, cached votes and
  p-values included;
* ``pvalues_optimized`` is deterministic across repeated calls (the seed
  implementation iterated an unordered ``set`` of star samples, making
  p-values hash-order-dependent);
* starved states (``max_bprime`` hit before every point has B clean
  samples) fail loudly at fit time instead of dividing by zero at
  predict time;
* the vmapped jnp forest matches the per-tree numpy oracle in
  ``kernels.ref``;
* empirical coverage of both p-value paths at eps in {0.05, 0.2}.
"""
import numpy as np
import jax
import pytest

try:  # property-test widely with hypothesis; else a fixed grid
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    HAS_HYPOTHESIS = False

from repro.core.measures import bootstrap as boot_m
from repro.data.synthetic import make_classification
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.serving import ConformalPredictor

B, DEPTH = 4, 3


def _data(n, seed, n_features=6, **kw):
    X, y = make_classification(n_samples=n, n_features=n_features,
                               seed=seed, **kw)
    return X.astype(np.float32), y


def _assert_states_equal(a, b):
    for f in ("X", "y", "uids", "W", "star", "elig", "counts", "feat",
              "thresh", "leaf", "pre_pred", "pre_votes"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)
    assert a.draw_ids == b.draw_ids
    assert a.E == b.E
    assert a.E_i == b.E_i
    assert (a.next_uid, a.next_draw) == (b.next_uid, b.next_draw)


# ---------------------------------------------------------------------------
# vectorized tree kernel vs numpy oracle
# ---------------------------------------------------------------------------


def test_forest_kernel_exact_on_integer_grid():
    """Integer-valued features + dyadic uniforms make every threshold
    product exact in f32, so the vmapped jnp path must equal the numpy
    oracle bit for bit — structure, thresholds, and predictions."""
    rng = np.random.default_rng(0)
    m, p, S, depth, nl = 26, 4, 12, 3, 3
    nn = 2 ** (depth + 1) - 1
    X = rng.integers(0, 5, (m, p)).astype(np.float32)
    y = rng.integers(0, nl, m).astype(np.int32)
    W = rng.integers(0, 3, (S, m)).astype(np.int32)
    fc = rng.integers(0, p, (S, nn)).astype(np.int32)
    u = (rng.integers(0, 256, (S, nn)) / 256.0).astype(np.float32)
    feat, thresh, leaf = kops.boot_fit_forest(X, y, W, fc, u,
                                              n_labels=nl, depth=depth)
    Xq = rng.integers(0, 5, (9, p)).astype(np.float32)
    preds = kops.boot_forest_predict(feat, thresh, leaf, Xq)
    for s in range(S):
        f2, t2, l2 = ref.boot_fit_tree(X, y, W[s], fc[s], u[s], nl, depth)
        np.testing.assert_array_equal(feat[s], f2)
        np.testing.assert_array_equal(thresh[s], t2)
        np.testing.assert_array_equal(leaf[s], l2)
        np.testing.assert_array_equal(
            preds[s], ref.boot_predict_tree(f2, t2, l2, Xq))


def test_forest_kernel_structural_match_on_random_data():
    """On continuous data XLA may fuse the threshold mul-add into an FMA
    (1-ulp threshold drift vs numpy), but the chosen features, leaf
    labels and predictions still agree exactly."""
    rng = np.random.default_rng(3)
    m, p, S, depth, nl = 40, 7, 30, 4, 2
    nn = 2 ** (depth + 1) - 1
    X = rng.standard_normal((m, p)).astype(np.float32)
    y = rng.integers(0, nl, m).astype(np.int32)
    W = rng.integers(0, 3, (S, m)).astype(np.int32)
    fc = rng.integers(0, p, (S, nn)).astype(np.int32)
    u = rng.random((S, nn), dtype=np.float32)
    feat, thresh, leaf = kops.boot_fit_forest(X, y, W, fc, u,
                                              n_labels=nl, depth=depth)
    Xq = rng.standard_normal((8, p)).astype(np.float32)
    preds = kops.boot_forest_predict(feat, thresh, leaf, Xq)
    for s in range(S):
        f2, t2, l2 = ref.boot_fit_tree(X, y, W[s], fc[s], u[s], nl, depth)
        np.testing.assert_array_equal(feat[s], f2)
        np.testing.assert_array_equal(leaf[s], l2)
        np.testing.assert_allclose(thresh[s], t2, atol=1e-5)
        np.testing.assert_array_equal(
            preds[s], ref.boot_predict_tree(feat[s], thresh[s], leaf[s],
                                            Xq))


def test_forest_padding_is_bit_neutral():
    """ops pads batch/row dims to pow2 buckets; a sliced-out result must
    not depend on how much padding the bucket added."""
    rng = np.random.default_rng(5)
    m, p, depth, nl = 19, 5, 3, 2
    nn = 2 ** (depth + 1) - 1
    X = rng.standard_normal((m, p)).astype(np.float32)
    y = rng.integers(0, nl, m).astype(np.int32)
    W = rng.integers(0, 3, (7, m)).astype(np.int32)
    fc = rng.integers(0, p, (7, nn)).astype(np.int32)
    u = rng.random((7, nn), dtype=np.float32)
    full = kops.boot_fit_forest(X, y, W, fc, u, n_labels=nl, depth=depth)
    sub = kops.boot_fit_forest(X, y, W[:3], fc[:3], u[:3], n_labels=nl,
                               depth=depth)
    for a, b in zip(full, sub):
        np.testing.assert_array_equal(a[:3], b)


# ---------------------------------------------------------------------------
# determinism + the fixed correctness bugs
# ---------------------------------------------------------------------------


def test_pvalues_optimized_deterministic_across_calls():
    """Regression test for the hash-order bug: star-sample training now
    runs over *sorted* draw ids under a keyed rng, so two fresh calls are
    bit-identical."""
    X, y = _data(30, 0)
    state = boot_m.fit(X[:24], y[:24], n_labels=2, B=B, depth=DEPTH,
                       seed=0)
    p1 = boot_m.pvalues_optimized(state, X[24:])
    p2 = boot_m.pvalues_optimized(state, X[24:])
    assert p1.tobytes() == p2.tobytes()
    p3 = boot_m.pvalues_standard(X[:24], y[:24], X[24:27], n_labels=2,
                                 B=B, depth=DEPTH, seed=0)
    p4 = boot_m.pvalues_standard(X[:24], y[:24], X[24:27], n_labels=2,
                                 B=B, depth=DEPTH, seed=0)
    assert p3.tobytes() == p4.tobytes()


def test_pvalues_standard_chunking_is_pure_batching(monkeypatch):
    """The naive path chunks its LOO tree batches to bound memory at
    O(chunk * n); randomness is keyed per LOO entry, so the chunk-size
    memory knob must be bit-neutral — tuning it to a runner's memory
    cannot change a p-value."""
    X, y = _data(26, 5)
    want = boot_m.pvalues_standard(X[:22], y[:22], X[22:24], n_labels=2,
                                   B=3, depth=2, seed=0)
    for chunk in (3, 7, 11):
        monkeypatch.setattr(boot_m, "_STD_CHUNK_TREES", chunk)
        got = boot_m.pvalues_standard(X[:22], y[:22], X[22:24], n_labels=2,
                                      B=3, depth=2, seed=0)
        np.testing.assert_array_equal(got, want)


def test_fit_deterministic_in_seed():
    X, y = _data(20, 1)
    a = boot_m.fit(X, y, n_labels=2, B=B, depth=DEPTH, seed=7)
    b = boot_m.fit(X, y, n_labels=2, B=B, depth=DEPTH, seed=7)
    _assert_states_equal(a, b)


def test_fit_starvation_raises_at_fit_time():
    """max_bprime hit before every point has B clean samples used to ship
    empty E_i lists that crashed with a division by zero at predict time;
    now fit names the starved points."""
    X, y = _data(20, 2)
    with pytest.raises(ValueError, match="starved"):
        boot_m.fit(X, y, n_labels=2, B=5, depth=DEPTH, seed=0,
                   max_bprime=3)
    try:
        boot_m.fit(X, y, n_labels=2, B=5, depth=DEPTH, seed=0,
                   max_bprime=3)
    except ValueError as e:
        assert "B=5" in str(e)  # names the bound and the starved entries


def test_label_validation():
    X, y = _data(16, 3)
    with pytest.raises(ValueError, match="labels"):
        boot_m.fit(X, y + 5, n_labels=2, B=B, depth=DEPTH, seed=0)


def test_pre_votes_cached_correctly():
    """The once-dead ``pre_votes`` field is now the cached pre-trained
    vote count: per point, how many of its clean pre-trained samples
    predict its own label."""
    X, y = _data(22, 4)
    state = boot_m.fit(X, y, n_labels=2, B=B, depth=DEPTH, seed=1)
    row_of = {d: r for r, d in enumerate(state.draw_ids)}
    for i in range(state.n):
        want = sum(
            1 for d in state.E_i[i]
            if state.star[row_of[d]] == 0
            and state.pre_pred[row_of[d], i] == y[i])
        assert state.pre_votes[i] == want
    # and the star rows never leak into the cache
    assert (state.pre_pred[state.star > 0] == -1).all()


# ---------------------------------------------------------------------------
# streaming exactness (incremental/decremental vs from-scratch build)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    _interleave_cases = lambda f: settings(max_examples=8, deadline=None)(
        given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 10),
              evict_bias=st.floats(0.2, 0.7))(f))
else:  # deterministic fallback grid (hypothesis not installed)
    _interleave_cases = pytest.mark.parametrize(
        "seed,n_ops,evict_bias",
        [(0, 6, 0.5), (1, 1, 0.2), (2, 10, 0.6), (3, 8, 0.35),
         (4, 4, 0.7)])


@_interleave_cases
def test_observe_evict_interleaving_bit_exact_vs_rebuild(seed, n_ops,
                                                         evict_bias):
    """Any interleaving of observe/evict == fit_from_samples on the
    surviving points with the same effective sample set — assignment
    lists, trees, cached predictions/votes, and p-values, bit for bit."""
    X, y = _data(40, seed)
    state = boot_m.fit(X[:16], y[:16], n_labels=2, B=B, depth=DEPTH,
                       seed=seed % 5)
    rng = np.random.default_rng(seed + 1)
    t = 16
    for _ in range(n_ops):
        if state.n > 6 and rng.random() < evict_bias:
            state = boot_m.decremental_remove(
                state, int(rng.integers(0, state.n)))
        else:
            state = boot_m.incremental_add(state, X[t % 40],
                                           int(y[t % 40]))
            t += 1
    rebuilt = boot_m.rebuild(state)
    _assert_states_equal(state, rebuilt)
    Xt = X[35:39]
    pa = boot_m.pvalues_optimized(state, Xt)
    pb = boot_m.pvalues_optimized(rebuilt, Xt)
    assert pa.tobytes() == pb.tobytes()


def test_observe_keeps_old_points_untouched():
    """Old samples are ineligible for a later point (it was not in the
    pool when they were drawn): observe changes only the new point's
    list and leaves every existing assignment alone."""
    X, y = _data(24, 6)
    state = boot_m.fit(X[:20], y[:20], n_labels=2, B=B, depth=DEPTH,
                       seed=2)
    st2 = boot_m.incremental_add(state, X[20], int(y[20]))
    assert st2.E == state.E
    assert st2.E_i[:-1] == state.E_i
    assert len(st2.E_i[-1]) == B
    assert min(st2.E_i[-1]) >= state.next_draw  # fresh draws only
    np.testing.assert_array_equal(st2.pre_votes[:-1], state.pre_votes)


def test_evict_retires_and_backfills_to_cap():
    X, y = _data(24, 7)
    state = boot_m.fit(X, y, n_labels=2, B=B, depth=DEPTH, seed=3)
    st2 = boot_m.decremental_remove(state, 5)
    assert st2.n == 23
    # every sample containing the removed point is gone
    removed_draws = {state.draw_ids[r]
                     for r in np.flatnonzero(state.W[:, 5] > 0)}
    assert not removed_draws & set(st2.draw_ids)
    # and every list is back at the cap
    assert (st2.counts == B).all()
    assert len(st2.E) == B
    # no orphan samples survive (every row serves some list)
    referenced = set(st2.E).union(*map(set, st2.E_i))
    assert set(st2.draw_ids) <= referenced


def test_evict_guards():
    X, y = _data(10, 8)
    state = boot_m.fit(X, y, n_labels=2, B=3, depth=2, seed=0)
    with pytest.raises(IndexError, match="out of range"):
        boot_m.decremental_remove(state, 10)
    state = boot_m.decremental_remove(state, -1)  # negative ok
    assert state.n == 9


# ---------------------------------------------------------------------------
# statistical validity
# ---------------------------------------------------------------------------


def test_coverage_both_paths():
    """Empirical coverage >= 1 - eps (up to binomial noise) at eps in
    {0.05, 0.2}, for both the naive and the Algorithm 3 path.

    Averaged over seeds (matching ``test_validity``): CP validity is
    marginal over the algorithm's own randomness, and conditioning on one
    unlucky shared sample pool (a weak B-tree candidate ensemble shifts
    every test point at once) can exceed eps in a single draw."""
    cov_opt, cov_std = [], []
    for seed in range(3):
        X, y = _data(90, 11 + seed, class_sep=1.5)
        ntr = 50
        Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
        state = boot_m.fit(Xtr, ytr, n_labels=2, B=8, depth=DEPTH,
                           seed=seed)
        p_opt = boot_m.pvalues_optimized(state, Xte)
        p_std = boot_m.pvalues_standard(Xtr, ytr, Xte[:20], n_labels=2,
                                        B=8, depth=DEPTH, seed=seed)
        cov_opt.append(p_opt[np.arange(len(yte)), yte])
        cov_std.append(p_std[np.arange(20), yte[:20]])
    p_opt = np.concatenate(cov_opt)
    p_std = np.concatenate(cov_std)
    for eps in (0.05, 0.2):
        assert np.mean(p_opt > eps) >= 1 - eps - 0.07, (
            eps, float(np.mean(p_opt > eps)))
        assert np.mean(p_std > eps) >= 1 - eps - 0.09, (
            eps, float(np.mean(p_std > eps)))


def test_pvalues_in_unit_interval_and_not_degenerate():
    X, y = _data(40, 12)
    state = boot_m.fit(X[:32], y[:32], n_labels=2, B=B, depth=DEPTH,
                       seed=4)
    p = boot_m.pvalues_optimized(state, X[32:])
    assert (p > 0).all() and (p <= 1).all()
    # for each test point at least one label should look conforming
    assert (p.max(axis=1) > 0.2).all()


# ---------------------------------------------------------------------------
# registry entry (serving surface)
# ---------------------------------------------------------------------------


def test_registry_bootstrap_end_to_end():
    X, y = _data(40, 13)
    cp = ConformalPredictor("bootstrap", B=B, depth=DEPTH,
                            n_labels=2).fit(X[:30], y[:30])
    assert cp.n == 30
    cp.observe(X[30], int(y[30]))
    assert cp.n == 31
    cp.evict(0)
    assert cp.n == 30
    # streamed registry state == rebuild on its own sample set
    _assert_states_equal(cp._state, boot_m.rebuild(cp._state))
    p1 = np.asarray(cp.pvalues(X[31:35]))
    p2 = np.asarray(cp.pvalues(X[31:35]))
    assert p1.shape == (4, 2)
    np.testing.assert_array_equal(p1, p2)
    sets = np.asarray(cp.predict_set(X[31:35], eps=0.2))
    assert sets.shape == (4, 2) and sets.dtype == bool
    with pytest.raises(NotImplementedError, match="interval"):
        cp.intervals(X[31:33], eps=0.2)
    with pytest.raises(TypeError, match="unknown hyperparameters"):
        ConformalPredictor("bootstrap", k=7)


def test_registry_bootstrap_sliding_window_stays_exact():
    X, y = _data(40, 14)
    cp = ConformalPredictor("bootstrap", B=3, depth=2, n_labels=2,
                            seed=5).fit(X[:12], y[:12])
    for t in range(12, 24):
        cp.observe(X[t], int(y[t]))
        if cp.n > 12:
            cp.evict(0)
    assert cp.n == 12
    np.testing.assert_array_equal(np.asarray(cp._state.X),
                                  X[12:24])
    _assert_states_equal(cp._state, boot_m.rebuild(cp._state))


def test_state_is_pytree_with_leading_arrays():
    """ConformalPredictor.n reads tree_leaves(state)[0].shape[0]."""
    X, y = _data(15, 15)
    state = boot_m.fit(X, y, n_labels=2, B=3, depth=2, seed=0)
    leaves = jax.tree_util.tree_leaves(state)
    assert leaves[0].shape[0] == 15
