"""Statistical validity: CP's coverage guarantee Pr[y not in set] <= eps,
p-value distribution properties, ICP validity, fuzziness comparison
(full CP should not be worse than ICP — paper Appendix G direction).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pvalues as pv
from repro.core.predictor import ConformalClassifier, \
    InductiveConformalClassifier
from repro.data.synthetic import make_classification


def test_coverage_guarantee_knn():
    """Empirical coverage >= 1 - eps (up to binomial noise)."""
    rs = []
    for seed in range(5):
        X, y = make_classification(n_samples=150, n_features=6, seed=seed)
        X = X.astype(np.float32)
        clf = ConformalClassifier(measure="knn", k=5, n_labels=2).fit(
            X[:100], y[:100])
        p = clf.predict_pvalues(X[100:150])
        cov, size = pv.coverage(p, jnp.asarray(y[100:150]), 0.2)
        rs.append(float(cov))
    assert np.mean(rs) >= 0.8 - 0.07, rs


def test_pvalue_validity_under_null():
    """p-values for exchangeable data: Pr[p <= eps] <= eps (+ noise)."""
    X, y = make_classification(n_samples=220, n_features=5, seed=7)
    X = X.astype(np.float32)
    clf = ConformalClassifier(measure="simplified_knn", k=5,
                              n_labels=2).fit(X[:160], y[:160])
    p_all = np.asarray(clf.predict_pvalues(X[160:220]))
    p_true = p_all[np.arange(60), y[160:220]]
    for eps in (0.1, 0.25, 0.5):
        assert np.mean(p_true <= eps) <= eps + 0.13, eps


def test_smoothed_pvalue_exact_uniform():
    """Smoothed p-values are exactly U{(i+tau)/(n+1)} -> mean 0.5."""
    rng = np.random.default_rng(0)
    alphas = jnp.asarray(rng.standard_normal(2000), jnp.float32)
    a = jnp.asarray(rng.standard_normal(500), jnp.float32)
    taus = jnp.asarray(rng.random(500), jnp.float32)
    ps = jax.vmap(lambda ai, t: pv.smoothed_pvalue(alphas, ai, t))(a, taus)
    assert abs(float(jnp.mean(ps)) - 0.5) < 0.05


def test_fuzziness_full_cp_not_worse_than_icp():
    """Paper Appendix G: full CP has lower (better) fuzziness than ICP."""
    outs = {}
    X, y = make_classification(n_samples=260, n_features=8, seed=2,
                               class_sep=1.5)
    X = X.astype(np.float32)
    for name, cls in (("cp", ConformalClassifier),
                      ("icp", InductiveConformalClassifier)):
        clf = cls(measure="knn", k=7, n_labels=2).fit(X[:200], y[:200])
        p = clf.predict_pvalues(X[200:260])
        outs[name] = float(jnp.mean(pv.fuzziness(p)))
    assert outs["cp"] <= outs["icp"] + 0.02, outs


def test_prediction_sets_monotone_in_eps():
    X, y = make_classification(n_samples=120, n_features=6, seed=9)
    X = X.astype(np.float32)
    clf = ConformalClassifier(measure="kde", n_labels=2).fit(X[:90], y[:90])
    p = clf.predict_pvalues(X[90:110])
    small = np.asarray(pv.prediction_sets(p, 0.3))
    big = np.asarray(pv.prediction_sets(p, 0.05))
    assert (big >= small).all()  # lower eps -> larger sets
