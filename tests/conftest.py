"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py (its own process)
requests 512 placeholder devices."""
import numpy as np
import pytest

from repro.data.synthetic import make_classification, make_regression


@pytest.fixture(scope="session")
def cls_data():
    X, y = make_classification(n_samples=90, n_features=8, seed=3)
    return X.astype(np.float32), y


@pytest.fixture(scope="session")
def reg_data():
    X, y = make_regression(n_samples=90, n_features=6, seed=4)
    return X.astype(np.float32), y.astype(np.float32)
