"""Sharding-rule unit tests (no devices needed: rules read only mesh shape
and axis names) + the sharded-CP subprocess test.
"""
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import Rules, param_pspecs


class FakeMesh(SimpleNamespace):
    pass


def mesh_like(pod=None, data=16, model=16):
    names = (("pod",) if pod else ()) + ("data", "model")
    shape = {}
    if pod:
        shape["pod"] = pod
    shape["data"] = data
    shape["model"] = model
    return FakeMesh(axis_names=names, shape=shape)


def test_attention_head_sharding_prefers_heads():
    r = Rules(mesh_like())
    assert r.param_spec("layers/0/attn/wq", (6144, 48, 128)) == \
        P("data", "model", None)
    # MQA: 1 kv head cannot shard -> head_dim shards instead
    assert r.param_spec("layers/0/attn/wk", (1152, 1, 256)) == \
        P("data", None, "model")
    # tiny head count AND tiny head_dim: replicate head dims
    assert r.param_spec("layers/0/attn/wq", (64, 4, 8)) == \
        P("data", None, None)


def test_mlp_and_vocab_rules():
    r = Rules(mesh_like())
    assert r.param_spec("layers/0/mlp/w_up", (6144, 24576)) == \
        P("data", "model")
    assert r.param_spec("layers/0/mlp/w_down", (24576, 6144)) == \
        P("model", "data")
    assert r.param_spec("embed", (262144, 1152)) == P("model", "data")
    # non-divisible vocab stays unsharded on that dim
    assert r.param_spec("embed", (92553, 6144)) == P(None, "data")


def test_moe_expert_rules():
    r = Rules(mesh_like())
    # 160 experts shard over model (EP)
    assert r.param_spec("layers/0/moe/w_up", (160, 5120, 1536)) == \
        P("model", "data", None)
    # 8 experts can't: expert-hidden shards instead (TP)
    assert r.param_spec("layers/0/moe/w_up", (8, 6144, 16384)) == \
        P(None, "data", "model")


def test_param_pspecs_stacked_layers_and_opt_state():
    params = {"layers": [{"mlp": {"w_up": jnp.zeros((4, 64, 128))}}],
              "embed": jnp.zeros((256, 64))}
    opt = {"mu": params, "nu": {"layers": [{"mlp": {"w_up": {
        "row": jnp.zeros((4, 64))}}}], "embed": {"full": jnp.zeros(
            (256, 64))}}, "step": jnp.zeros((), jnp.int32)}
    mesh = mesh_like(data=4, model=8)
    ps = param_pspecs(params, mesh)
    assert ps["layers"][0]["mlp"]["w_up"] == P(None, "data", "model")
    os_ = param_pspecs(opt, mesh)
    assert os_["mu"]["layers"][0]["mlp"]["w_up"] == P(None, "data", "model")
    # factored row moment: conservatively replicated (tiny) except the
    # stacked-layer dim
    assert os_["nu"]["layers"][0]["mlp"]["w_up"]["row"] == P(None, None)
    assert os_["step"] == P()


def test_batch_specs_long_context_seq_sharding():
    r = Rules(mesh_like())
    # decode tokens (1, 1): nothing shardable
    assert r.batch_spec("tokens", (1, 1)) == P(None, None)
    # long-context single sequence: shard S
    assert r.batch_spec("tokens", (1, 524288)) == P(None, ("data",))
    assert r.batch_spec("tokens", (256, 4096)) == P(("data",), None)


SHARDED_CP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.data.synthetic import make_classification
    from repro.core.measures import knn as knn_m
    from repro.core import distributed as dist

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    X, y = make_classification(n_samples=101, n_features=6, seed=0)
    X = X.astype(np.float32); y = y.astype(np.int32)
    Xte = X[:6] + 0.05
    st = knn_m.fit(jnp.asarray(X), jnp.asarray(y), k=5)
    ref = np.asarray(knn_m.pvalues_optimized(
        st, jnp.asarray(Xte), k=5, simplified=False, n_labels=2))
    cfg = dist.CpShardingConfig(row_axes=("data",), query_axis="model")
    st_sh = dist.shard_knn_state(st, mesh, cfg)
    fn = dist.make_knn_pvalues_fn(mesh, k=5, simplified=False, n_labels=2,
                                  cfg=cfg)
    Xte_sh = jax.device_put(jnp.asarray(Xte),
                            NamedSharding(mesh, P("model", None)))
    out = np.asarray(fn(st_sh, Xte_sh))
    assert np.abs(out - ref).max() < 1e-6, np.abs(out - ref).max()
    print("SHARDED_OK")
""")


def test_sharded_cp_matches_single_device():
    """Distributed CP == single-device optimized CP (8 virtual devices;
    subprocess so the main test process keeps its single real device)."""
    r = subprocess.run([sys.executable, "-c", SHARDED_CP_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr
