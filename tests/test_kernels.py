"""Per-kernel allclose sweeps: Pallas kernel (interpret=True on CPU) vs the
pure-jnp oracle in kernels/ref.py, across shapes and dtypes.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.cp_update import cp_knn_counts as cp_pallas
from repro.kernels.interval_sweep import interval_sweep as iv_pallas
from repro.kernels.kde_score import kde_rowsums as kde_pallas
from repro.kernels.pairwise_dist import pairwise_sq_dists
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.stream_update import stream_update as su_pallas


@pytest.mark.parametrize("m,n,p", [(8, 8, 4), (65, 33, 7), (128, 256, 30),
                                   (257, 130, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_sweep(m, n, p, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * n))
    A = jax.random.normal(k1, (m, p), dtype)
    B = jax.random.normal(k2, (n, p), dtype)
    got = pairwise_sq_dists(A, B, block_m=64, block_n=64, interpret=True)
    want = ref.sq_dists(A.astype(jnp.float32), B.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("m,n", [(16, 16), (65, 128), (130, 70)])
@pytest.mark.parametrize("exclude_diag", [False, True])
def test_kde_rowsums_sweep(m, n, exclude_diag):
    if exclude_diag and m != n:
        pytest.skip("diag only for square")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m + n), 3)
    A = jax.random.normal(k1, (m, 6), jnp.float32)
    B = A if exclude_diag else jax.random.normal(k2, (n, 6), jnp.float32)
    yA = jax.random.randint(k3, (m,), 0, 3, jnp.int32)
    yB = yA if exclude_diag else jax.random.randint(
        jax.random.PRNGKey(9), (n,), 0, 3, jnp.int32)
    got = kde_pallas(A, B, yA, yB, h=1.3, exclude_diag=exclude_diag,
                     interpret=True)
    want = ref.kde_rowsums(A, B, yA, yB, 1.3, exclude_diag)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,m,l", [(64, 4, 2), (130, 7, 3)])
def test_cp_knn_counts_sweep(n, m, l):
    ks = jax.random.split(jax.random.PRNGKey(n), 6)
    X = jax.random.normal(ks[0], (n, 5), jnp.float32)
    y = jax.random.randint(ks[1], (n,), 0, l, jnp.int32)
    Xt = jax.random.normal(ks[2], (m, 5), jnp.float32)
    sum_same = jax.random.uniform(ks[3], (n,), jnp.float32, 1.0, 4.0)
    kth = jax.random.uniform(ks[4], (n,), jnp.float32, 0.5, 2.0)
    alpha = jax.random.uniform(ks[5], (m, l), jnp.float32, 1.0, 3.0)
    got = cp_pallas(X, y, sum_same, kth, Xt, alpha, n_labels=l,
                    interpret=True)
    want = ref.cp_knn_counts(X, y, sum_same, kth, Xt, alpha)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,m,k", [(64, 4, 5), (130, 7, 1), (200, 33, 7)])
@pytest.mark.parametrize("dead_tail", [0, 17])
def test_interval_sweep_matches_ref(n, m, k, dead_tail):
    """Fused distance + (a_i, b_i) update + critical points vs oracle.

    Finite endpoints agree to f32 tolerance; infinity/empty sentinels
    (including the ``live`` capacity padding) agree exactly.
    """
    ks = jax.random.split(jax.random.PRNGKey(n + k), 6)
    X = jax.random.normal(ks[0], (n, 6), jnp.float32)
    a_prime = jax.random.normal(ks[1], (n,), jnp.float32)
    kth_dist = jax.random.uniform(ks[2], (n,), jnp.float32, 0.5, 4.0)
    kth_label = jax.random.normal(ks[3], (n,), jnp.float32)
    Xt = jax.random.normal(ks[4], (m, 6), jnp.float32)
    a_test = jax.random.normal(ks[5], (m,), jnp.float32)
    live = (jnp.arange(n) < n - dead_tail)
    got_lo, got_hi = iv_pallas(X, a_prime, kth_dist, kth_label, live, Xt,
                               a_test, k=k, block_m=64, block_n=64,
                               interpret=True)
    want_lo, want_hi = ref.reg_interval_endpoints(
        X, a_prime, kth_dist, kth_label, live, Xt, a_test, k)
    for got, want in [(got_lo, want_lo), (got_hi, want_hi)]:
        got, want = np.asarray(got), np.asarray(want)
        assert got.shape == (m, n)
        np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))
        f = np.isfinite(want)
        np.testing.assert_array_equal(got[~f], want[~f])  # +-inf pattern
        np.testing.assert_allclose(got[f], want[f], atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("cap,p,k,n", [(64, 5, 5, 40), (70, 6, 1, 70),
                                       (300, 16, 7, 123), (32, 3, 4, 0)])
@pytest.mark.parametrize("mode", ["class", "reg"])
def test_stream_update_matches_ref(cap, p, k, n, mode):
    """Fused distance row + gated ordered k-best merge vs oracle.

    Covers non-tile-aligned capacities, k=1, an empty window (n=0, all
    rows inert) and both gate modes."""
    ks = jax.random.split(jax.random.PRNGKey(cap + k), 6)
    X = jax.random.normal(ks[0], (cap, p), jnp.float32)
    y = jax.random.randint(ks[1], (cap,), 0, 3, jnp.int32)
    nbr_d = jnp.sort(
        jax.random.uniform(ks[2], (cap, k), jnp.float32, 0.1, 3.0), axis=1)
    nbr_y = jax.random.normal(ks[3], (cap, k), jnp.float32)
    x_new = jax.random.normal(ks[4], (p,), jnp.float32)
    if mode == "class":
        y_in, y_new = y, jnp.int32(1)
    else:
        y_in, y_new = jax.random.normal(ks[5], (cap,), jnp.float32), \
            jnp.float32(0.25)
    nn = jnp.int32(n)
    got = su_pallas(X, y_in, nbr_d, nbr_y, x_new, y_new, nn, mode=mode,
                    block_n=64, interpret=True)
    want = ref.stream_update(X, y_in, nbr_d, nbr_y, x_new, y_new, nn,
                             mode=mode)
    for g, w, name in zip(got, want, ["d_row", "nbr_d", "nbr_y"]):
        g, w = np.asarray(g), np.asarray(w)
        assert g.shape == w.shape, name
        big = w >= 1e29
        np.testing.assert_array_equal(g[big], w[big], err_msg=name)
        np.testing.assert_allclose(g[~big], w[~big], atol=1e-5, rtol=1e-5,
                                   err_msg=name)
    # the sortless CPU production path is bit-identical to the oracle
    fast = ref.stream_update_fast(X, y_in, nbr_d, nbr_y, x_new, y_new, nn,
                                  mode=mode)
    for f, w, name in zip(fast, want, ["d_row", "nbr_d", "nbr_y"]):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(w),
                                      err_msg="fast " + name)


@pytest.mark.parametrize("cap,k,n,head,wrap", [
    (64, 5, 40, 30, 64),   # wrapped over the full capacity
    (64, 3, 20, 15, 24),   # window-confined ring: slots >= wrap inert
    (70, 4, 24, 23, 24),   # full confined ring, head mid-block
    (32, 2, 0, 7, 16),     # empty ring, nonzero head
])
@pytest.mark.parametrize("mode", ["class", "reg"])
def test_stream_update_ring_mode_matches_ref(cap, k, n, head, wrap, mode):
    """Ring-slot liveness (head/wrap) in the fused kernel vs the oracle:
    the live window is slots (head + i) % wrap, everything else inert."""
    p = 6
    ks = jax.random.split(jax.random.PRNGKey(3 * cap + head), 6)
    X = jax.random.normal(ks[0], (cap, p), jnp.float32)
    y = jax.random.randint(ks[1], (cap,), 0, 3, jnp.int32)
    nbr_d = jnp.sort(
        jax.random.uniform(ks[2], (cap, k), jnp.float32, 0.1, 3.0), axis=1)
    nbr_y = jax.random.normal(ks[3], (cap, k), jnp.float32)
    x_new = jax.random.normal(ks[4], (p,), jnp.float32)
    if mode == "class":
        y_in, y_new = y, jnp.int32(1)
    else:
        y_in, y_new = jax.random.normal(ks[5], (cap,), jnp.float32), \
            jnp.float32(0.25)
    args = (X, y_in, nbr_d, nbr_y, x_new, y_new, jnp.int32(n))
    kw = dict(mode=mode, head=jnp.int32(head), wrap=jnp.int32(wrap))
    got = su_pallas(*args, block_n=32, interpret=True, **kw)
    want = ref.stream_update(*args, **kw)
    fast = ref.stream_update_fast(*args, **kw)
    for g, f, w, name in zip(got, fast, want, ["d_row", "nbr_d", "nbr_y"]):
        g, f, w = np.asarray(g), np.asarray(f), np.asarray(w)
        np.testing.assert_array_equal(f, w, err_msg="fast " + name)
        big = w >= 1e29
        np.testing.assert_array_equal(g[big], w[big], err_msg=name)
        np.testing.assert_allclose(g[~big], w[~big], atol=1e-5, rtol=1e-5,
                                   err_msg=name)
    # liveness itself: exactly n slots carry finite distances
    assert int(np.sum(np.asarray(want[0]) < 1e29)) == n


@pytest.mark.parametrize("mode", ["class", "reg"])
def test_stream_update_tie_rule_exact(mode):
    """Distance ties: the kernel's branch-free insert-after-equals must
    reproduce the oracle's stable-sort tie rule bit-for-bit.

    One-hot rows at distance exactly 1.0 from the zero query, neighbour
    lists stuffed with exact 1.0 entries — every value in play is exact
    in f32, so the comparison is equality, not allclose."""
    cap, p, k, n = 16, 8, 3, 12
    X = jnp.eye(cap, p, dtype=jnp.float32)  # d(x_new=0, X_i) == 1.0 exactly
    x_new = jnp.zeros((p,), jnp.float32)
    # lists already containing the candidate distance (and BIG padding)
    base = jnp.asarray([0.5, 1.0, 1.0], jnp.float32)
    nbr_d = jnp.tile(base, (cap, 1))
    nbr_d = nbr_d.at[5].set(jnp.asarray([1.0, 1.0, 2.0], jnp.float32))
    nbr_d = nbr_d.at[6].set(jnp.asarray([0.25, 0.5, 1e30], jnp.float32))
    nbr_y = jnp.arange(cap * k, dtype=jnp.float32).reshape(cap, k)
    if mode == "class":
        y, y_new = jnp.zeros((cap,), jnp.int32), jnp.int32(0)
    else:
        y, y_new = jnp.linspace(-1.0, 1.0, cap).astype(jnp.float32), \
            jnp.float32(9.0)
    got = su_pallas(X, y, nbr_d, nbr_y, x_new, y_new, jnp.int32(n),
                    mode=mode, block_n=8, interpret=True)
    want = ref.stream_update(X, y, nbr_d, nbr_y, x_new, y_new,
                             jnp.int32(n), mode=mode)
    fast = ref.stream_update_fast(X, y, nbr_d, nbr_y, x_new, y_new,
                                  jnp.int32(n), mode=mode)
    for g, f, w, name in zip(got, fast, want, ["d_row", "nbr_d", "nbr_y"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(w),
                                      err_msg="fast " + name)


@pytest.mark.parametrize("cfg", [
    dict(B=1, Sq=64, Skv=64, H=4, Hkv=4, D=16, causal=True, window=None),
    dict(B=2, Sq=63, Skv=63, H=4, Hkv=1, D=32, causal=True, window=None),
    dict(B=1, Sq=128, Skv=128, H=2, Hkv=2, D=16, causal=True, window=17),
    dict(B=1, Sq=64, Skv=64, H=4, Hkv=2, D=16, causal=False, window=None),
    dict(B=1, Sq=16, Skv=80, H=2, Hkv=1, D=16, causal=True, window=None),
])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_sweep(cfg, softcap):
    ks = jax.random.split(jax.random.PRNGKey(cfg["Sq"]), 3)
    q = jax.random.normal(ks[0], (cfg["B"], cfg["Sq"], cfg["H"], cfg["D"]),
                          jnp.float32)
    k = jax.random.normal(ks[1], (cfg["B"], cfg["Skv"], cfg["Hkv"],
                                  cfg["D"]), jnp.float32)
    v = jax.random.normal(ks[2], k.shape, jnp.float32)
    got = fa_pallas(q, k, v, causal=cfg["causal"], window=cfg["window"],
                    softcap=softcap, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=cfg["causal"],
                               window=cfg["window"], softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("Sq,Skv,window", [(96, 96, None), (100, 100, 13),
                                           (64, 160, None)])
def test_chunked_attention_matches_dense(Sq, Skv, window):
    ks = jax.random.split(jax.random.PRNGKey(Sq + Skv), 3)
    q = jax.random.normal(ks[0], (2, Sq, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, Skv, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], k.shape, jnp.float32)
    got = ref.chunked_attention(q, k, v, causal=True, window=window,
                                block_q=32, block_k=32)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ops_dispatch_interpret(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 exercises kernel bodies via ops.py."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    from repro.kernels import ops
    A = jax.random.normal(jax.random.PRNGKey(0), (33, 7), jnp.float32)
    got = ops.sq_dists(A, A)
    want = ref.sq_dists(A, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
