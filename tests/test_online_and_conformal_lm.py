"""Online CP (exchangeability martingale) + conformal LM heads."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as cfgs
from repro.core import online
from repro.core.lm_conformal import ConformalOodDetector, \
    sequence_embedding
from repro.core.measures import knn as knn_m
from repro.data.synthetic import make_classification
from repro.models import lm


def test_online_matches_batch_refit():
    """observe() incremental state == knn fit() from scratch."""
    X, y = make_classification(n_samples=40, n_features=5, seed=1)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.int32)
    k = 4
    st = online.init(40, 5, k, dtype=jnp.float32)
    for i in range(30):
        st, _ = online.observe(st, X[i], y[i], jnp.float32(0.5), k=k)
    ref = knn_m.fit(X[:30], y[:30], k=k)
    np.testing.assert_allclose(np.asarray(st.best[:30]),
                               np.asarray(ref.best_same), atol=1e-5)


def test_martingale_flat_under_exchangeability():
    X, y = make_classification(n_samples=300, n_features=5, seed=2)
    pv, logm = online.run_stream(jnp.asarray(X, jnp.float32),
                                 jnp.asarray(y, jnp.int32), k=5,
                                 key=jax.random.PRNGKey(0))
    # mixture martingale: E[M] = 1; log M should stay small
    assert float(logm[-1]) < 3.0, float(logm[-1])
    assert abs(float(jnp.mean(pv[50:])) - 0.5) < 0.12


def test_martingale_grows_on_changepoint():
    Xa, ya = make_classification(n_samples=150, n_features=5, seed=3)
    Xb, yb = make_classification(n_samples=150, n_features=5, seed=4,
                                 class_sep=1.0)
    Xb = Xb + 8.0  # distribution shift halfway
    X = np.concatenate([Xa, Xb])
    y = np.concatenate([ya, yb])
    pv, logm = online.run_stream(jnp.asarray(X, jnp.float32),
                                 jnp.asarray(y, jnp.int32), k=5,
                                 key=jax.random.PRNGKey(1))
    assert float(logm[-1]) > 5.0, float(logm[-1])  # strong evidence


def test_ood_detector_validity_and_power():
    rng = np.random.default_rng(0)
    calib = rng.standard_normal((200, 16)).astype(np.float32)
    test_in = rng.standard_normal((100, 16)).astype(np.float32)
    test_out = rng.standard_normal((100, 16)).astype(np.float32) + 4.0
    det = ConformalOodDetector(k=5).fit(calib)
    p_in = np.asarray(det.pvalues(test_in))
    p_out = np.asarray(det.pvalues(test_out))
    # validity: Pr[p <= eps] <= eps (+noise) for in-distribution
    for eps in (0.05, 0.2):
        assert np.mean(p_in <= eps) <= eps + 0.08
    # power: OOD points get tiny p-values
    assert np.mean(p_out <= 0.05) > 0.95


def test_sequence_embedding_shapes():
    cfg = cfgs.get("qwen2_1_5b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((3, 12), jnp.int32)}
    emb = sequence_embedding(params, cfg, batch, lm)
    assert emb.shape == (3, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(emb.astype(jnp.float32))))
