"""Tenant-axis sharding tests: spec/mesh/padding helpers (single
device) + subprocess bit-exactness properties under 8 virtual devices.

The subprocess tests are the tentpole's correctness contract: a
shard_map'd engine tick must be bit-identical leaf-for-leaf to the
single-device vmap across ragged active masks, for both engines, with
instrumentation on — and an uneven tenant count padded up to the shard
multiple must leave the live lanes' results untouched.
"""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import distributed as dist


def test_tenant_spec_prefix_broadcast():
    assert dist.tenant_spec(np.zeros((4,))) == P("tenants")
    assert dist.tenant_spec(np.zeros((4, 3))) == P("tenants", None)
    assert dist.tenant_spec(np.zeros((4, 3, 2))) == \
        P("tenants", None, None)


def test_pad_tenant_count():
    assert dist.pad_tenant_count(8, 4) == 8
    assert dist.pad_tenant_count(9, 4) == 12
    assert dist.pad_tenant_count(1, 8) == 8
    assert dist.pad_tenant_count(0, 4) == 0
    with pytest.raises(ValueError, match="shards"):
        dist.pad_tenant_count(8, 0)


def test_tenant_mesh_validation():
    with pytest.raises(ValueError, match="shards must be >= 1"):
        dist.tenant_mesh(0)
    too_many = jax.device_count() + 1
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        dist.tenant_mesh(too_many)
    mesh = dist.tenant_mesh(1)
    assert mesh.axis_names == (dist.TENANT_AXIS,)
    assert mesh.shape[dist.TENANT_AXIS] == 1


def test_put_tenant_sharded_places_leading_axis():
    mesh = dist.tenant_mesh(1)
    tree = {"a": np.arange(8, dtype=np.float32),
            "b": np.zeros((8, 3), np.float32)}
    out = dist.put_tenant_sharded(tree, mesh)
    assert out["a"].sharding.spec == dist.tenant_spec(tree["a"])
    assert out["b"].sharding.spec == dist.tenant_spec(tree["b"])
    np.testing.assert_array_equal(np.asarray(out["b"]), tree["b"])


# --------------------------------------------------------------------------
# subprocess properties (8 virtual devices; child process so the main
# test process keeps its single real device)
# --------------------------------------------------------------------------

_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np, jax, jax.numpy as jnp
    assert jax.device_count() == 8, jax.device_count()

    def leaves_equal(a, b):
        la = jax.tree_util.tree_leaves(a)
        lb = jax.tree_util.tree_leaves(b)
        assert len(la) == len(lb)
        return all(
            np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
            for x, y in zip(la, lb))

    S, T, D, CAP, K, W = 12, 20, 4, 32, 3, 8
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(T, S, D)), jnp.float32)
    ys_cls = jnp.asarray(rng.integers(0, 3, size=(T, S)), jnp.int32)
    ys_reg = jnp.asarray(rng.normal(size=(T, S)), jnp.float32)
    taus = jnp.asarray(rng.uniform(size=(T, S)), jnp.float32)
    act = jnp.asarray(rng.uniform(size=(T, S)) < 0.7)
""")

_CLS_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.serving.engine import ServingEngine
    from repro.telemetry import MetricsRegistry
    ref = None
    for shards in (1, 2, 4):
        eng = ServingEngine(n_sessions=S, capacity=CAP, dim=D, n_labels=3,
                            k=K, window=W, instrument=True,
                            metrics=MetricsRegistry(), shards=shards)
        st = eng.init_state()
        st, p = eng.observe_many(st, xs, ys_cls, taus, active=act)
        pv = eng.predict(st, xs[0])
        stats = eng.telemetry.ticks.drain()
        if ref is None:
            ref = (st, p, pv, stats)
        else:
            assert leaves_equal(st, ref[0]), f"state mismatch @{shards}"
            assert np.array_equal(np.asarray(p), np.asarray(ref[1]),
                                  equal_nan=True), f"pvals @{shards}"
            assert np.array_equal(np.asarray(pv), np.asarray(ref[2]),
                                  equal_nan=True), f"predict @{shards}"
            assert stats == ref[3], (shards, stats, ref[3])
            assert len(eng.telemetry.ticks.shard_vals) == shards
    # grow mode: auto-grow retraces per shard, results still identical
    gref = None
    for shards in (1, 4):
        eng = ServingEngine(n_sessions=S, capacity=8, dim=D, n_labels=3,
                            k=K, window=None, shards=shards)
        st = eng.init_state()
        st, p = eng.observe_many(st, xs, ys_cls, taus)  # grows 8 -> 32
        if gref is None:
            gref = (st, p)
        else:
            assert leaves_equal(st, gref[0]), "grow state mismatch"
            assert np.array_equal(np.asarray(p), np.asarray(gref[1]),
                                  equal_nan=True)
            meta = eng.meta()
            assert meta["shards"] == 4
            assert ServingEngine.from_meta(meta).shards == 4
    print("CLS_SHARDED_OK")
""")

_REG_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.regression.engine import RegressionServingEngine
    from repro.telemetry import MetricsRegistry
    ref = None
    for shards in (1, 2, 4):
        eng = RegressionServingEngine(n_sessions=S, capacity=CAP, dim=D,
                                      k=K, window=W, instrument=True,
                                      metrics=MetricsRegistry(),
                                      shards=shards)
        st = eng.init_state()
        st, p = eng.observe_many(st, xs, ys_reg, taus, active=act)
        iv = eng.intervals(st, xs[0], epsilon=0.1)
        pv = eng.pvalues(st, xs[0], jnp.linspace(-1, 1, 5))
        stats = eng.telemetry.ticks.drain()
        if ref is None:
            ref = (st, p, iv, pv, stats)
        else:
            assert leaves_equal(st, ref[0]), f"state mismatch @{shards}"
            assert np.array_equal(np.asarray(p), np.asarray(ref[1]),
                                  equal_nan=True), f"pvals @{shards}"
            assert np.array_equal(np.asarray(iv), np.asarray(ref[2]),
                                  equal_nan=True), f"intervals @{shards}"
            assert np.array_equal(np.asarray(pv), np.asarray(ref[3]),
                                  equal_nan=True), f"grid @{shards}"
            assert stats == ref[4], (shards, stats, ref[4])
    print("REG_SHARDED_OK")
""")

_PAD_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.core import distributed as dist
    from repro.serving.engine import ServingEngine
    # 10 live tenants, 4 shards: pad to 12 lanes, last 2 never active
    LIVE, SHARDS = 10, 4
    PADDED = dist.pad_tenant_count(LIVE, SHARDS)
    assert PADDED == 12
    ref_eng = ServingEngine(n_sessions=LIVE, capacity=CAP, dim=D,
                            n_labels=3, k=K, window=W)
    rst = ref_eng.init_state()
    rst, rp = ref_eng.observe_many(rst, xs[:, :LIVE], ys_cls[:, :LIVE],
                                   taus[:, :LIVE], active=act[:, :LIVE])
    pad_act = jnp.concatenate(
        [act[:, :LIVE], jnp.zeros((T, PADDED - LIVE), bool)], axis=1)
    eng = ServingEngine(n_sessions=PADDED, capacity=CAP, dim=D,
                        n_labels=3, k=K, window=W, shards=SHARDS)
    st = eng.init_state()
    st, p = eng.observe_many(st, xs[:, :PADDED], ys_cls[:, :PADDED],
                             taus[:, :PADDED], active=pad_act)
    live = jax.tree_util.tree_map(lambda l: l[:LIVE], st)
    assert leaves_equal(live, rst), "live lanes diverged under padding"
    assert np.array_equal(np.asarray(p)[:, :LIVE], np.asarray(rp),
                          equal_nan=True)
    # padded lanes stayed at their init state
    init = jax.tree_util.tree_map(lambda l: l[LIVE:], eng.init_state())
    padded = jax.tree_util.tree_map(lambda l: l[LIVE:], st)
    assert leaves_equal(padded, init), "padding lanes mutated"
    print("PAD_SHARDED_OK")
""")


def _run_child(script: str, sentinel: str) -> None:
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600)
    assert sentinel in r.stdout, r.stdout + r.stderr


def test_sharded_classification_bit_identical():
    _run_child(_CLS_SCRIPT, "CLS_SHARDED_OK")


def test_sharded_regression_bit_identical():
    _run_child(_REG_SCRIPT, "REG_SHARDED_OK")


def test_uneven_tenant_count_pads_cleanly():
    _run_child(_PAD_SCRIPT, "PAD_SHARDED_OK")


# --------------------------------------------------------------------------
# collective-freedom via the auditor: repro.analysis.audit owns the
# single definition of the zero-collective invariant; this child runs
# it against sharded ticks AND proves a smuggled psum is caught.
# --------------------------------------------------------------------------

_AUDIT_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.analysis import audit as audit_m
    from repro.analysis import hlo as hlo_m

    # every sharded engine tick in the matrix must be collective-free
    for t in audit_m.engine_matrix(max_shards=8):
        if t.shards == 1:
            continue
        art = audit_m.Artifact(t)
        r = audit_m.CHECKERS["collective-freedom"](t, art)
        assert r["status"] == "pass", (t.name, r["violations"])
        assert sum(r["info"]["collective_bytes"].values()) == 0, t.name

    # sabotage: a psum smuggled into a shard_map'd tick is caught with
    # the offending HLO op named
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(jax.devices(), ("tenants",))
    bad = jax.jit(shard_map(
        lambda x: x + jax.lax.psum(x, "tenants"), mesh=mesh,
        in_specs=P("tenants"), out_specs=P("tenants")))
    text = bad.lower(jnp.ones((8, 4), jnp.float32)).compile().as_text()
    vs = audit_m.collective_violations(text)
    assert vs and "all-reduce" in vs[0]["kind"], vs
    assert "all-reduce" in vs[0]["line"], vs
    print("AUDIT_SHARDED_OK")
""")


def test_audit_collective_freedom_sharded():
    _run_child(_AUDIT_SCRIPT, "AUDIT_SHARDED_OK")
