"""Unit tests for the analysis layer: the HLO text parser
(``repro.analysis.hlo``) and the jaxpr flop counter
(``repro.analysis.flops``) on hand-computable fixtures.

Until now these were only exercised indirectly (through the ring-layout
and substrate tests); the fixtures here pin the parser behaviours the
static auditor (``repro.analysis.audit``) depends on: tuple result
types, fusion ``calls=`` indirection, while nesting with and without
``known_trip_count`` metadata, dynamic-update-slice aliasing, the
``input_output_alias`` module header, and big-copy detection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import flops as flops_m
from repro.analysis import hlo as hlo_m

# a while loop (trip count 5 in metadata AND as the cond bound constant)
# whose body all-reduces an f32[8,8]; tuple types + to_apply throughout
_WHILE_FIX = """\
HloModule fix_while, input_output_alias={{ {{0}}: (1, {{}}, may-alias) }}

%sum (a: f32[], b: f32[]) -> f32[] {{
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}}

%body (arg.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {{
  %arg.1 = (s32[], f32[8,8]) parameter(0)
  %gte.0 = s32[] get-tuple-element((s32[], f32[8,8]) %arg.1), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %gte.0, s32[] %one)
  %gte.1 = f32[8,8]{{1,0}} get-tuple-element((s32[], f32[8,8]) %arg.1), index=1
  %ar = f32[8,8]{{1,0}} all-reduce(f32[8,8]{{1,0}} %gte.1), replica_groups={{}}, to_apply=%sum
  ROOT %tup = (s32[], f32[8,8]) tuple(s32[] %next, f32[8,8]{{1,0}} %ar)
}}

%cond (arg.2: (s32[], f32[8,8])) -> pred[] {{
  %arg.2 = (s32[], f32[8,8]) parameter(0)
  %g = s32[] get-tuple-element((s32[], f32[8,8]) %arg.2), index=0
  %bound = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %g, s32[] %bound), direction=LT
}}

ENTRY %main (p0: s32[], p1: f32[8,8]) -> (s32[], f32[8,8]) {{
  %p0 = s32[] parameter(0)
  %p1 = f32[8,8]{{1,0}} parameter(1)
  %init = (s32[], f32[8,8]) tuple(s32[] %p0, f32[8,8]{{1,0}} %p1)
  ROOT %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %init), condition=%cond, body=%body{trip}
}}
"""
_WITH_TRIP = _WHILE_FIX.format(
    trip=', backend_config={"known_trip_count":{"n":"5"}}')
_NO_TRIP = _WHILE_FIX.format(trip="")

# a DUS-rooted fusion updating one row of an f32[16,16] in place
_DUS_FIX = """\
HloModule fix_dus

%fused (fp0: f32[16,16], fp1: f32[1,16], fp2: s32[], fp3: s32[]) -> f32[16,16] {
  %fp0 = f32[16,16]{1,0} parameter(0)
  %fp1 = f32[1,16]{1,0} parameter(1)
  %fp2 = s32[] parameter(2)
  %fp3 = s32[] parameter(3)
  ROOT %dus = f32[16,16]{1,0} dynamic-update-slice(f32[16,16]{1,0} %fp0, f32[1,16]{1,0} %fp1, s32[] %fp2, s32[] %fp3)
}

ENTRY %main (p0: f32[16,16], p1: f32[1,16], p2: s32[]) -> f32[16,16] {
  %p0 = f32[16,16]{1,0} parameter(0)
  %p1 = f32[1,16]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %fus = f32[16,16]{1,0} fusion(f32[16,16]{1,0} %p0, f32[1,16]{1,0} %p1, s32[] %p2, s32[] %p2), kind=kLoop, calls=%fused
}
"""

_COPY_FIX = """\
HloModule fix_copy

ENTRY %main (p0: f32[16,16]) -> f32[16,16] {
  %p0 = f32[16,16]{1,0} parameter(0)
  ROOT %c = f32[16,16]{1,0} copy(f32[16,16]{1,0} %p0)
}
"""

_ELEMWISE_FIX = """\
HloModule fix_elem

ENTRY %main (p0: f32[4,4], p1: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  %m = f32[4,4]{1,0} multiply(f32[4,4]{1,0} %p0, f32[4,4]{1,0} %p1)
  ROOT %a = f32[4,4]{1,0} add(f32[4,4]{1,0} %m, f32[4,4]{1,0} %p0)
}
"""


# ---------------------------------------------------------------------------
# parser round-trips
# ---------------------------------------------------------------------------


def test_parse_module_tuple_types_and_while_nesting():
    comps = hlo_m.parse_module(_WITH_TRIP)
    assert set(comps) == {"%sum", "%body", "%cond", "%main"}
    main = comps["%main"]
    # tuple-typed while result: s32[] + f32[8,8] = 4 + 256 bytes
    assert main.defs["%w"] == 4 + 256
    # ordered signature params with their byte sizes
    assert main.params == [("%p0", 4), ("%p1", 256)]
    assert main.whiles == [("%body", "%cond", 5)]
    # to_apply indirection recorded as a called computation
    assert "%sum" in comps["%body"].fusion_calls
    # operand extraction stops at the first attribute assignment
    (ar,) = [o for o in comps["%body"].ops if o.kind == "all-reduce"]
    assert ar.operands == ["%gte.1"] and ar.result_bytes == 256


def test_multiplicities_prefer_known_trip_count():
    info = hlo_m.computation_multiplicities(_WITH_TRIP)
    assert info["entry"] == "%main"
    assert info["trip_fallbacks"] == 0  # metadata, no heuristic
    assert info["mult"]["%body"] == 5.0
    assert info["mult"]["%cond"] == 6.0  # trip + 1 evaluations


def test_multiplicities_heuristic_fallback_is_counted():
    info = hlo_m.computation_multiplicities(_NO_TRIP)
    assert info["trip_fallbacks"] == 1  # warning surfaced to the audit
    # the cond's bound constant still recovers the right trip count
    assert info["mult"]["%body"] == 5.0


def test_collective_bytes_and_count_ops_while_weighted():
    # one f32[8,8] all-reduce per trip: 5 * 256 bytes
    assert hlo_m.collective_bytes(_WITH_TRIP) == {"all-reduce": 1280.0}
    counts = hlo_m.count_ops(_WITH_TRIP)
    assert counts["all-reduce"] == 5.0
    assert counts["while"] == 1.0
    assert hlo_m.collective_bytes(_DUS_FIX) == {}


def test_hbm_bytes_elementwise_fixture():
    # multiply: 3 x 64B; add: 3 x 64B; parameters are free
    assert hlo_m.hbm_bytes(_ELEMWISE_FIX) == 384.0


def test_hbm_bytes_dus_fusion_writes_only_the_row():
    # DUS-rooted fusion: write = the (1, 16) update row (64B), reads =
    # aliased big param (0) + row (64B) + two s32 indices (4B each)
    assert hlo_m.hbm_bytes(_DUS_FIX) == 64.0 + 64.0 + 4.0 + 4.0


def test_dense_materializations_skip_dus_report_copies():
    # the in-place DUS fusion is NOT a dense materialization...
    assert hlo_m.dense_materializations(_DUS_FIX, 16 * 16 * 4) == []
    # ...but a full-size copy is, and carries its source line
    (d,) = hlo_m.dense_materializations(_COPY_FIX, 16 * 16 * 4)
    assert d["kind"] == "copy" and d["bytes"] == 1024
    assert d["line"].startswith("ROOT %c")


def test_input_output_aliases_header():
    assert hlo_m.input_output_aliases(_WITH_TRIP) == {(0,): 1}
    assert hlo_m.input_output_aliases(_DUS_FIX) == {}
    multi = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias),"
             " {2}: (3, {}, must-alias) }\n")
    assert hlo_m.input_output_aliases(multi) == {(0,): 0, (2,): 3}


def test_big_copies_multiplicity_filter():
    (c,) = hlo_m.big_copies(_COPY_FIX, 1024)
    assert c["kind"] == "copy" and c["mult"] == 1.0
    # entry-level one-time copies are below a per-tick min_mult gate
    assert hlo_m.big_copies(_COPY_FIX, 1024, min_mult=1.5) == []
    assert hlo_m.big_copies(_COPY_FIX, 2048) == []


# ---------------------------------------------------------------------------
# real lowerings still parse (guards against HLO text drift)
# ---------------------------------------------------------------------------


def test_parser_on_real_scan_lowering():
    def f(c, xs):
        def step(c, x):
            c = c + jnp.dot(x, x)
            return c, c.sum()
        return jax.lax.scan(step, c, xs)

    text = jax.jit(f).lower(
        jnp.zeros((4, 4)), jnp.zeros((7, 4, 4))).compile().as_text()
    info = hlo_m.computation_multiplicities(text)
    bodies = [m for name, m in info["mult"].items()
              if name != info["entry"] and m >= 7.0]
    assert bodies, info["mult"]  # the scan body runs 7x
    assert hlo_m.collective_bytes(text) == {}
    assert hlo_m.hbm_bytes(text) > 0


def test_donated_jit_aliases_in_real_lowering():
    @jax.jit
    def g(a, b):
        return a * 2.0 + b, b

    donated = jax.jit(lambda a, b: (a * 2.0 + b, b), donate_argnums=(0,))
    plain_text = g.lower(
        jnp.zeros((32, 32)), jnp.zeros((32, 32))).compile().as_text()
    don_text = donated.lower(
        jnp.zeros((32, 32)), jnp.zeros((32, 32))).compile().as_text()
    assert 0 in hlo_m.input_output_aliases(don_text).values()
    assert 0 not in hlo_m.input_output_aliases(plain_text).values()


# ---------------------------------------------------------------------------
# flop counter
# ---------------------------------------------------------------------------


def test_flops_of_known_matmul():
    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    out = flops_m.flops_of(jnp.dot, a, b)
    assert out["flops"] == 2.0 * 8 * 4 * 16  # 2*M*N*K = 1024
    assert out["transcendental"] == 0.0


def test_flops_scan_multiplies_by_length():
    def f(xs):
        def step(c, x):
            return c + x @ x, ()
        c, _ = jax.lax.scan(step, jnp.zeros((8, 8)), xs)
        return c

    out = flops_m.flops_of(f, jax.ShapeDtypeStruct((5, 8, 8), jnp.float32))
    matmul = 2.0 * 8 * 8 * 8
    add = 8 * 8
    assert out["flops"] == 5 * (matmul + add)


def test_flops_transcendental_term():
    out = flops_m.flops_of(jnp.exp,
                           jax.ShapeDtypeStruct((10,), jnp.float32))
    assert out["transcendental"] == 10.0
