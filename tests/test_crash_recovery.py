"""Crash-recovery e2e: a serving child process is SIGKILL'd mid-save,
a second process restarts from the store, and the continuation is
bit-identical to an uninterrupted run of the same stream.

The crash child carries an injected ``store.commit`` delay fault (the
torn-write window, held open for the kill), so the interrupted
snapshot deterministically never commits: the restart must come up
from the earlier committed baseline, replay the remaining chunks, and
land leaf-for-leaf on the oracle's final state — the atomic-commit +
deterministic-replay contract, for both engines at shards 1 and 8.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    mode, shards, root, out, role = (sys.argv[1], int(sys.argv[2]),
                                     sys.argv[3], sys.argv[4], sys.argv[5])
    import numpy as np, jax, jax.numpy as jnp
    from repro.regression.engine import RegressionServingEngine
    from repro.robustness import Fault, FaultInjector, FaultPlan
    from repro.serving import AsyncShardedSaver, ServingEngine, SessionStore

    S, T, CH, CAP, WIN, DIM, K = 8, 24, 6, 16, 8, 3, 3

    def mk():
        if mode == "classification":
            return ServingEngine(n_sessions=S, capacity=CAP, dim=DIM, k=K,
                                 n_labels=2, window=WIN, shards=shards)
        return RegressionServingEngine(n_sessions=S, capacity=CAP,
                                       dim=DIM, k=K, window=WIN,
                                       shards=shards)

    rng = np.random.default_rng(0)
    X = rng.normal(size=(T, S, DIM)).astype(np.float32)
    if mode == "classification":
        y = rng.integers(0, 2, size=(T, S)).astype(np.int64)
    else:
        y = rng.normal(size=(T, S)).astype(np.float32)
    taus = rng.uniform(size=(T, S)).astype(np.float32)

    def run_chunk(eng, state, c):
        sl = slice(c * CH, (c + 1) * CH)
        return eng.observe_many(state, jnp.asarray(X[sl]),
                                jnp.asarray(y[sl]), jnp.asarray(taus[sl]))

    def dump(state):
        leaves = [np.asarray(l) for l in
                  jax.tree_util.tree_leaves(jax.device_get(state))]
        np.savez(out, **{f"leaf{i}": l for i, l in enumerate(leaves)})

    if role == "resume":
        store = SessionStore(root)
        eng, state, step = store.restore_engine()
        print(f"resumed_from {step}", flush=True)
        for c in range(step + 1, T // CH):
            state, _ = run_chunk(eng, state, c)
        dump(state)
        print("done", flush=True)
        sys.exit(0)

    injector = None
    if role == "crash":
        # hold the commit window of step 2 open: the parent's SIGKILL
        # lands mid-save, so step 2 deterministically never commits
        plan = FaultPlan(0, (Fault("store.commit", 2, "delay",
                                   param=120.0),))
        injector = FaultInjector(plan)
    store = SessionStore(root, injector=injector)
    saver = AsyncShardedSaver(store, shards, seed=0)
    eng = mk()
    state = eng.init_state()
    for c in range(T // CH):
        state, _ = run_chunk(eng, state, c)
        if c == 0:
            saver.save(0, state, meta=eng.meta())
            saver.wait()  # committed baseline before the crash window
            print("baseline_committed", flush=True)
        if c == 2 and role == "crash":
            saver.save(2, state, meta=eng.meta())
            print("save_enqueued 2", flush=True)
            import time
            time.sleep(300)  # killed by the parent mid-commit
    saver.close()
    dump(state)
    print("done", flush=True)
""")


def _env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(_REPO, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _wait_for(proc, needle, timeout=600):
    deadline = time.time() + timeout
    seen = []
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            break
        seen.append(line)
        if needle in line:
            return seen
    raise AssertionError(
        f"child never printed {needle!r}; got: {''.join(seen)}")


@pytest.mark.parametrize("mode", ["classification", "regression"])
@pytest.mark.parametrize("shards", [1, 8])
def test_sigkill_mid_save_then_bit_identical_continuation(
        tmp_path, mode, shards):
    script = tmp_path / "child.py"
    script.write_text(_CHILD)
    root = str(tmp_path / "store")
    resume_out = str(tmp_path / "resume.npz")
    oracle_out = str(tmp_path / "oracle.npz")

    def _cmd(role, out, store_root):
        return [sys.executable, str(script), mode, str(shards),
                store_root, out, role]

    # 1. serve, then SIGKILL mid-commit of the step-2 snapshot
    proc = subprocess.Popen(_cmd("crash", str(tmp_path / "crash.npz"),
                                 root),
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True,
                            env=_env())
    try:
        seen = _wait_for(proc, "save_enqueued 2")
        assert any("baseline_committed" in ln for ln in seen)
        time.sleep(0.2)  # let the worker reach the held-open commit
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.stdout.close()
        proc.wait(timeout=60)

    # the interrupted step must not have committed (atomic commit)
    assert not os.path.exists(
        os.path.join(root, f"step_{2:09d}", "COMMITTED"))

    # 2. restart from the store and replay the remaining chunks
    r = subprocess.run(_cmd("resume", resume_out, root),
                       capture_output=True, text=True, env=_env(),
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "resumed_from 0" in r.stdout, r.stdout

    # 3. uninterrupted oracle over the same stream
    r = subprocess.run(_cmd("oracle", oracle_out,
                            str(tmp_path / "store2")),
                       capture_output=True, text=True, env=_env(),
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr

    got = np.load(resume_out)
    want = np.load(oracle_out)
    assert sorted(got.files) == sorted(want.files)
    for name in want.files:
        assert np.array_equal(got[name], want[name], equal_nan=True), \
            f"leaf {name} diverged after crash recovery"
