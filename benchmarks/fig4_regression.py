"""Paper Figure 4: k-NN CP regression — Papadopoulos et al. (2011)
(standard path, O(n^2) per prediction) vs our incremental&decremental
optimization (O(n log n) per prediction) vs ICP regression.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import regression as reg
from repro.data.synthetic import make_regression

N_GRID = (64, 256, 1024, 4096)
M_TEST = 8
K = 7


def run(n_grid=N_GRID):
    rows = []
    for n in n_grid:
        X, y = make_regression(n_samples=n + M_TEST, n_features=30, seed=0)
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        Xtr, ytr, Xte = X[:n], y[:n], X[n:]

        if n <= 1024:
            t = timeit(reg.intervals_standard, Xtr, ytr, Xte, k=K,
                       epsilon=0.1)
            rows.append(row("fig4/papadopoulos2011", f"n={n}", t / M_TEST,
                            "O(n^2 + n log n) per point"))
        st = reg.fit(Xtr, ytr, k=K)
        t = timeit(reg.intervals_optimized, st, Xte, k=K, epsilon=0.1)
        rows.append(row("fig4/optimized", f"n={n}", t / M_TEST,
                        "O(n log n) per point"))
        t = timeit(reg.icp_intervals, Xtr, ytr, Xte, k=K, t=n // 2,
                   epsilon=0.1)
        rows.append(row("fig4/icp", f"n={n}", t / M_TEST, "O(t) per point"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
