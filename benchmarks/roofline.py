"""Deliverable (g): roofline table from the dry-run's compiled artifacts.

Reads dryrun_results.json (produced by ``python -m repro.launch.dryrun
--all --both-meshes --out dryrun_results.json``) and derives, per
(arch x shape x mesh):

    t_compute   = HLO_FLOPs / (chips x 197e12)        [jaxpr-exact FLOPs]
    t_memory    = HBM bytes per device / 819e9        [post-fusion model]
    t_collective= weighted collective bytes / 50e9    [AR counts 2x]
    dominant term, MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
    (inference), and MODEL_FLOPS / HLO_FLOPs (useful-compute fraction).
"""
from __future__ import annotations

import json
import os

from repro.analysis.hlo import model_flops_per_step, roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")

_BW_CACHE: dict = {}


def measure_bandwidth(nbytes: int = 1 << 26, repeats: int = 5) -> float:
    """Measured streaming memory bandwidth (bytes/s) of this backend.

    Times a jitted elementwise add over an ``nbytes`` f32 buffer after
    compile (read N + write N bytes per call, best of ``repeats``) —
    the empirical roof the sliding-tick benches are compared against,
    instead of a hard-coded TPU constant that is meaningless on the CPU
    containers the benches actually run on. Cached per process.
    """
    if nbytes in _BW_CACHE:
        return _BW_CACHE[nbytes]
    import time

    import jax
    import jax.numpy as jnp

    n = nbytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    jax.block_until_ready(f(x))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        y = f(x)
        jax.block_until_ready(y)
        ts.append(time.perf_counter() - t0)
    bw = 2 * n * 4 / min(ts)
    _BW_CACHE[nbytes] = bw
    return bw


def sliding_tick_bytes(sessions: int, cap: int, dim: int,
                       dtype_bytes: int = 4) -> int:
    """Post-fusion traffic model (bytes) for one window-full sliding tick.

    Per session the decremental-evict + incremental-observe tick must
    stream the (cap, cap) pairwise-distance block once (the neighbour
    repair scans it; the donated row/col update rewrites O(cap) of it)
    plus O(cap) feature rows and bookkeeping vectors. This is a *lower*
    bound — achieved time over this model's roof time is the
    "distance from the memory-bandwidth roof" the sliding rows report.
    Fractions above 1 mean the working set is cache-resident (the
    effective bandwidth beats the streaming-DRAM roof — expected on the
    CPU containers for small capacities).
    """
    per_session = cap * cap + cap * (dim + 16)
    return sessions * per_session * dtype_bytes


def derive(cell: dict) -> dict:
    chips = 512 if cell["mesh"] == "2x16x16" else 256
    mem = cell.get("device_hbm_bytes_flash_adjusted",
                   cell["device_hbm_bytes"])
    terms = roofline_terms(cell["flops_global"], mem,
                           cell["collective_bytes"], chips)
    kind = "train" if cell["kind"] == "train" else "inference"
    mf = model_flops_per_step(cell["active_params"],
                              cell["tokens_per_step"], kind)
    useful = mf / max(cell["flops_global"], 1.0)
    t_roof = max(terms["t_compute_s"], 1e-12)
    t_bound = max(terms["t_compute_s"], terms["t_memory_s"],
                  terms["t_collective_s"])
    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "kind")},
        **terms,
        "model_flops": mf,
        "useful_fraction": useful,
        "roofline_fraction": t_roof / t_bound,  # achievable step-time share
        "temp_gib": cell["memory"]["temp_bytes"] / 2 ** 30,
    }


def run(path=RESULTS, mesh_filter="16x16"):
    rows = []
    if not os.path.exists(path):
        return [f"roofline,missing,{path},run the dryrun sweep first"]
    with open(path) as f:
        results = json.load(f)
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} dom        "
           f"{'useful':>7s} {'roof%':>6s} {'tempGiB':>8s}")
    rows.append("roofline," + hdr)
    for cell in results:
        if cell["status"] != "ok":
            if cell["status"] == "skipped" and cell["mesh" if "mesh" in
                                                    cell else "shape"]:
                continue
            continue
        if mesh_filter and cell["mesh"] != mesh_filter:
            continue
        d = derive(cell)
        rows.append(
            f"roofline,{d['arch']:18s} {d['shape']:12s} {d['mesh']:8s} "
            f"{d['t_compute_s']:9.4f} {d['t_memory_s']:9.4f} "
            f"{d['t_collective_s']:9.4f} {d['dominant']:10s} "
            f"{d['useful_fraction']:7.3f} "
            f"{100 * d['roofline_fraction']:5.1f}% {d['temp_gib']:8.2f}")
    return rows


if __name__ == "__main__":
    for r in run(mesh_filter=None):
        print(r)
