"""Deliverable (g): roofline table from the dry-run's compiled artifacts.

Reads dryrun_results.json (produced by ``python -m repro.launch.dryrun
--all --both-meshes --out dryrun_results.json``) and derives, per
(arch x shape x mesh):

    t_compute   = HLO_FLOPs / (chips x 197e12)        [jaxpr-exact FLOPs]
    t_memory    = HBM bytes per device / 819e9        [post-fusion model]
    t_collective= weighted collective bytes / 50e9    [AR counts 2x]
    dominant term, MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
    (inference), and MODEL_FLOPS / HLO_FLOPs (useful-compute fraction).
"""
from __future__ import annotations

import json
import os

from repro.analysis.hlo import model_flops_per_step, roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def derive(cell: dict) -> dict:
    chips = 512 if cell["mesh"] == "2x16x16" else 256
    mem = cell.get("device_hbm_bytes_flash_adjusted",
                   cell["device_hbm_bytes"])
    terms = roofline_terms(cell["flops_global"], mem,
                           cell["collective_bytes"], chips)
    kind = "train" if cell["kind"] == "train" else "inference"
    mf = model_flops_per_step(cell["active_params"],
                              cell["tokens_per_step"], kind)
    useful = mf / max(cell["flops_global"], 1.0)
    t_roof = max(terms["t_compute_s"], 1e-12)
    t_bound = max(terms["t_compute_s"], terms["t_memory_s"],
                  terms["t_collective_s"])
    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "kind")},
        **terms,
        "model_flops": mf,
        "useful_fraction": useful,
        "roofline_fraction": t_roof / t_bound,  # achievable step-time share
        "temp_gib": cell["memory"]["temp_bytes"] / 2 ** 30,
    }


def run(path=RESULTS, mesh_filter="16x16"):
    rows = []
    if not os.path.exists(path):
        return [f"roofline,missing,{path},run the dryrun sweep first"]
    with open(path) as f:
        results = json.load(f)
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} dom        "
           f"{'useful':>7s} {'roof%':>6s} {'tempGiB':>8s}")
    rows.append("roofline," + hdr)
    for cell in results:
        if cell["status"] != "ok":
            if cell["status"] == "skipped" and cell["mesh" if "mesh" in
                                                    cell else "shape"]:
                continue
            continue
        if mesh_filter and cell["mesh"] != mesh_filter:
            continue
        d = derive(cell)
        rows.append(
            f"roofline,{d['arch']:18s} {d['shape']:12s} {d['mesh']:8s} "
            f"{d['t_compute_s']:9.4f} {d['t_memory_s']:9.4f} "
            f"{d['t_collective_s']:9.4f} {d['dominant']:10s} "
            f"{d['useful_fraction']:7.3f} "
            f"{100 * d['roofline_fraction']:5.1f}% {d['temp_gib']:8.2f}")
    return rows


if __name__ == "__main__":
    for r in run(mesh_filter=None):
        print(r)
