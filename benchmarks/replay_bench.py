"""Replay-under-load benchmark: p50/p99 latency curves per workload,
and the cost-model auto-tune versus the hand-tuned chunk.

Two row families, MERGED into BENCH_serve.json (every other row is
preserved — ``serve_bench.py`` owns the throughput rows, this module
owns the ``bench_kind: replay*`` rows):

* ``replay`` — one row per loadgen workload (steady / bursty / diurnal
  / zipf) replayed against the classification engine under real
  (speedup-compressed) arrival timing: device-true p50/p99 service
  latency per op, sojourn p99 (queueing included), steps/s, queue
  depth, SLO-violation fraction. The bursty row's sojourn-vs-service
  gap is the queueing story the tracer alone can't tell.
* ``replay_autotune`` — the same steady trace replayed twice at
  ``speedup=inf``: once with the hand-tuned observe_many chunk (the
  benches' historic 64) and once with ``CostModel.suggest_chunk()``
  fitted from a fresh engine calibration. ``autotune_ratio`` is
  auto/hand steps-per-s (CI floors it at 0.5; parity or better is the
  acceptance bar).

    PYTHONPATH=src python benchmarks/replay_bench.py [--quick] \\
        [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import math


def run_workloads(workloads=None, *, ops=256, tenants=8, capacity=128,
                  dim=8, k=7, rate=300.0, speedup=1.0, slo_ms=25.0,
                  seed=0):
    """One replay row per workload, under arrival timing.

    ``rate=300`` ops/s vs a ~1-2 ms CPU service time keeps the steady
    workload below saturation, so the bursty on/off factor (8x) is what
    pushes the queue — the regime where sojourn p99 separates from
    service p99.
    """
    from repro.telemetry import loadgen, replay

    rows = []
    for w in (workloads or loadgen.WORKLOADS):
        recs = loadgen.generate(
            w, ops=ops, tenants=tenants, capacity=capacity, rate=rate,
            seed=seed, slo_s=slo_ms / 1e3)
        rep = replay(recs, engine="classification", dim=dim, k=k,
                     speedup=speedup, seed=seed).report
        row = {
            "bench_kind": "replay",
            "workload": w,
            "engine": "classification",
            "ops": ops,
            "tenants": rep["tenants"],
            "capacity": rep["capacity"],
            "rate": rate,
            "speedup": speedup,
            "slo_ms": slo_ms,
            "wall_s": rep["wall_s"],
            "steps_per_s": rep["steps_per_s"],
            "slo_violation_frac": rep["slo_violation_frac"],
            "queue_depth_max": rep["queue_depth_max"],
        }
        for op, d in rep["per_op"].items():
            row[f"{op}_p50_s"] = d["p50_s"]
            row[f"{op}_p99_s"] = d["p99_s"]
            row[f"{op}_sojourn_p99_s"] = d["sojourn_p99_s"]
        rows.append(row)
        print(f"[replay_bench] {w:8s} service p99 "
              f"{row['observe_p99_s'] * 1e3:7.2f}ms  sojourn p99 "
              f"{row['observe_sojourn_p99_s'] * 1e3:7.2f}ms  "
              f"slo_viol {row['slo_violation_frac']:.3f}  "
              f"q_max {row['queue_depth_max']:.0f}")
    return rows


def run_autotune(*, ops=384, tenants=8, capacity=128, dim=8, k=7,
                 hand_chunk=64, seed=0):
    """Suggested-vs-hand-tuned chunk on a steady observe-only trace."""
    from repro.telemetry import (CostModel, calibrate_engine, loadgen,
                                 replay)
    from repro.telemetry.tracer import capacity_bucket

    model = CostModel.fit(
        calibrate_engine("classification", tenants=tenants,
                         capacity=capacity, dim=dim, k=k, seed=seed),
        source="calibrate")
    bucket = capacity_bucket(capacity)
    suggested = model.suggest_chunk(cap_bucket=bucket,
                                    engine="classification")
    entry = model.entries[("classification", "observe_many", bucket)]

    # observe-only (predict_every=0): both replays coalesce maximally,
    # so the chunk size is the only variable
    recs = loadgen.generate("steady", ops=ops, tenants=tenants,
                            capacity=capacity, seed=seed, predict_every=0)
    rep_hand = replay(recs, engine="classification", dim=dim, k=k,
                      speedup=math.inf, seed=seed,
                      chunk=hand_chunk).report
    rep_auto = replay(recs, engine="classification", dim=dim, k=k,
                      speedup=math.inf, seed=seed, chunk=suggested).report
    row = {
        "bench_kind": "replay_autotune",
        "engine": "classification",
        "ops": ops,
        "tenants": tenants,
        "capacity": capacity,
        "chunk_hand": hand_chunk,
        "chunk_suggested": suggested,
        "model_dispatch_s": entry["a"],
        "model_per_tick_s": entry["b"],
        "steps_per_s_hand": rep_hand["steps_per_s"],
        "steps_per_s_auto": rep_auto["steps_per_s"],
        "autotune_ratio": rep_auto["steps_per_s"]
        / rep_hand["steps_per_s"],
    }
    print(f"[replay_bench] autotune chunk {suggested} vs hand "
          f"{hand_chunk}: {row['steps_per_s_auto']:.0f}/s vs "
          f"{row['steps_per_s_hand']:.0f}/s "
          f"({row['autotune_ratio']:.2f}x)")
    return [row]


def merge_rows(out: str, rows: list[dict]) -> dict:
    """Replace the ``replay*`` rows of ``out`` in place, keep the rest."""
    try:
        from benchmarks.common import merge_bench_rows
    except ImportError:
        from common import merge_bench_rows
    return merge_bench_rows(out, rows, owned_prefixes=("replay",))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true",
                    help="smaller traces (CI smoke)")
    args = ap.parse_args(argv)
    ops = 96 if args.quick else 256
    rows = run_workloads(ops=ops)
    rows += run_autotune(ops=192 if args.quick else 384)
    merge_rows(args.out, rows)
    print(f"[replay_bench] merged {len(rows)} replay rows -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
