"""Paper Figure 3: one-off training time of the optimized measures vs n
(standard full CP has no training phase — its cost all lands at predict).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m
from repro.data.synthetic import make_classification

N_GRID = (64, 256, 1024, 4096)


def run(n_grid=N_GRID):
    rows = []
    for n in n_grid:
        X, y = make_classification(n_samples=n, n_features=30, seed=0)
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.int32)
        Y = 2.0 * y.astype(jnp.float32) - 1.0
        t = timeit(knn_m.fit, X, y, k=15)
        rows.append(row("fig3/knn/fit", f"n={n}", t, "O(n^2)"))
        t = timeit(kde_m.fit, X, y, h=1.0, n_labels=2)
        rows.append(row("fig3/kde/fit", f"n={n}", t, "O(P_K n^2)"))
        t = timeit(lssvm_m.fit, X, Y, 1.0)
        rows.append(row("fig3/lssvm/fit", f"n={n}", t, "O(n q^2 + q^3)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
