"""Streaming regression-CP benchmark: per-test-point interval latency of
the standard path (Papadopoulos et al. 2011, O(n^2 p) per point) vs the
paper's optimized path vs the multi-tenant streaming engine, plus the
engine's observe throughput. Writes BENCH_regression.json.

    PYTHONPATH=src python benchmarks/regression_bench.py [--quick]

The paper's Section 8.1 claim is the middle column: after the one-off
O(n^2) fit, each test point costs an O(n p) distance row + O(n log n)
sweep instead of an O(n^2 p) neighbour recomputation — the streaming
engine serves exactly that path (and stays bit-identical to it).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, repeats=3):
    """(median steady s, first-call s incl. compile, last output)."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), compile_s, out


def run(ns=(512, 2048), *, m=8, dim=16, k=7, eps=0.1, sessions=4,
        obs_ticks=64):
    from repro.core import regression as reg
    from repro.data.synthetic import make_regression
    from repro.regression import RegressionServingEngine
    from repro.regression import stream as rstream

    results = []
    for n in ns:
        X, y = make_regression(n_samples=n + m, n_features=dim, seed=0)
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        Xtr, ytr, Xt = X[:n], y[:n], X[n:]

        t_std, c_std, iv_std = _timeit(lambda: reg.intervals_standard(
            Xtr, ytr, Xt, k=k, epsilon=eps))

        t_fit, c_fit, state = _timeit(lambda: reg.fit(Xtr, ytr, k=k))
        t_opt, c_opt, iv_opt = _timeit(lambda: reg.intervals_optimized(
            state, Xt, k=k, epsilon=eps))

        # streaming engine: sessions tenants, each holding the same window
        eng = RegressionServingEngine(
            n_sessions=sessions, capacity=n, dim=dim, k=k, window=n)
        one = rstream.from_fit(Xtr, ytr, k=k, capacity=n)
        st = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (sessions,) + a.shape), one)
        t_serve, c_serve, iv_serve = _timeit(
            lambda: eng.intervals(st, Xt, eps))

        # engine observe throughput (sliding window, all tenants): the
        # per-tick path, then the same traffic chunked through
        # observe_many (one scanned dispatch per half)
        key = jax.random.PRNGKey(1)
        xs = jax.random.normal(key, (obs_ticks, sessions, dim), jnp.float32)
        ys_ = jax.random.normal(key, (obs_ticks, sessions), jnp.float32)
        taus = eng.taus(key)
        t0 = time.perf_counter()
        st2, _ = eng.observe(st, xs[0], ys_[0], taus)  # compile
        jax.block_until_ready(st2.n)
        c_observe = time.perf_counter() - t0
        t0 = time.perf_counter()
        for t in range(1, obs_ticks):
            st2, p = eng.observe(st2, xs[t], ys_[t], taus)
        jax.block_until_ready(p)
        dt_obs = time.perf_counter() - t0

        chunk = obs_ticks // 2
        taus_many = jnp.broadcast_to(taus, (chunk, sessions))
        st3 = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (sessions,) + a.shape), one)
        t0 = time.perf_counter()
        st3, _ = eng.observe_many(  # compile + warmup chunk
            st3, xs[:chunk], ys_[:chunk], taus_many)
        jax.block_until_ready(st3.n)
        c_many = time.perf_counter() - t0
        t0 = time.perf_counter()
        st3, p = eng.observe_many(st3, xs[chunk:2 * chunk],
                                  ys_[chunk:2 * chunk], taus_many)
        jax.block_until_ready(p)
        dt_many = time.perf_counter() - t0

        per_std = t_std / m
        per_opt = t_opt / m
        per_serve = t_serve / (m * sessions)
        row = {
            "n": n, "m": m, "dim": dim, "k": k, "epsilon": eps,
            "sessions": sessions,
            "fit_wall_s": t_fit,
            "fit_compile_s": c_fit,
            "standard_compile_s": c_std,
            "optimized_compile_s": c_opt,
            "streaming_compile_s": c_serve,
            "observe_compile_s": c_observe,
            "observe_many_compile_s": c_many,
            "standard_s_per_test_point": per_std,
            "optimized_s_per_test_point": per_opt,
            "streaming_s_per_test_point": per_serve,
            "speedup_optimized_vs_standard": per_std / per_opt,
            "speedup_streaming_vs_standard": per_std / per_serve,
            "observe_session_steps_per_s":
                sessions * (obs_ticks - 1) / dt_obs,
            "observe_many_session_steps_per_s":
                sessions * chunk / dt_many,
            "observe_chunk": chunk,
            "observe_per_tick_overhead_s_est":
                dt_obs / (obs_ticks - 1) - dt_many / chunk,
            "intervals_finite_frac": float(np.mean(np.isfinite(
                np.asarray(iv_serve)))),
            "optimized_equals_standard": bool(np.allclose(
                np.asarray(iv_std), np.asarray(iv_opt), equal_nan=True)),
            "streaming_bit_identical_to_optimized": bool(
                all(np.asarray(iv_serve[s]).tobytes()
                    == np.asarray(iv_opt).tobytes()
                    for s in range(sessions))),
        }
        results.append(row)
        print(f"[regression_bench] n={n:5d}  std {per_std * 1e3:8.2f} ms/pt"
              f"  opt {per_opt * 1e3:8.2f} ms/pt"
              f" ({row['speedup_optimized_vs_standard']:6.1f}x)"
              f"  served {per_serve * 1e3:8.2f} ms/pt"
              f" ({row['speedup_streaming_vs_standard']:6.1f}x)"
              f"  obs {row['observe_session_steps_per_s']:7.0f}/s"
              f" chunked {row['observe_many_session_steps_per_s']:7.0f}/s"
              f"  bitexact={row['streaming_bit_identical_to_optimized']}")
    return results


def run_sliding(caps=(256, 1024, 4096), *, dim=16, k=7, chunk=32, reps=4):
    """Window-full eviction throughput sweep (see serve_bench.run_sliding):
    ring layout vs positional compaction vs the evict-free reference,
    with every measured tick running the labeled decremental eviction."""
    from repro.regression import RegressionServingEngine

    try:  # package import (python -m benchmarks.run) or script run
        from benchmarks import roofline
        from benchmarks.common import bench_sliding
    except ImportError:  # executed as a script: benchmarks/ is on sys.path
        import roofline
        from common import bench_sliding

    rows = []
    for cap in caps:
        sessions = 2 if cap >= 4096 else 4

        def mk(layout, window):
            return RegressionServingEngine(
                n_sessions=sessions, capacity=cap, dim=dim, k=k,
                window=window, layout=layout)

        def traffic(T):
            key = jax.random.PRNGKey(cap + 1)
            kx, ky, kt = jax.random.split(key, 3)
            return (jax.random.normal(kx, (T, sessions, dim), jnp.float32),
                    jax.random.normal(ky, (T, sessions), jnp.float32),
                    jax.random.uniform(kt, (T, sessions), jnp.float32))

        row = bench_sliding(mk, traffic, cap=cap, chunk=chunk, reps=reps)
        row.update(dim=dim, k=k)
        # distance from the measured memory-bandwidth roof
        bw = roofline.measure_bandwidth()
        nbytes = roofline.sliding_tick_bytes(sessions, cap, dim)
        row["mem_bandwidth_bytes_per_s"] = bw
        row["sliding_tick_bytes_model"] = nbytes
        row["mem_roof_fraction"] = (
            (nbytes / bw) * row["session_steps_per_s_sliding"] / sessions)
        rows.append(row)
        print(f"[regression_bench] sliding S={sessions} cap={cap:5d} "
              f"ring {row['session_steps_per_s_sliding']:8.0f}/s  "
              f"compact {row['session_steps_per_s_sliding_compact']:8.0f}/s"
              f"  ({row['ring_speedup_vs_compact']:.2f}x)  "
              f"evict-free {row['session_steps_per_s_evictfree']:8.0f}/s  "
              f"roof {100 * row['mem_roof_fraction']:.0f}%")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_regression.json")
    ap.add_argument("--quick", action="store_true",
                    help="single small config (CI smoke)")
    ap.add_argument("--sessions", type=int, default=4)
    args = ap.parse_args(argv)
    ns = (256,) if args.quick else (512, 2048)
    results = run(ns, m=4 if args.quick else 8, sessions=args.sessions)
    results += run_sliding((256,) if args.quick else (256, 1024, 4096))
    payload = {
        "bench": "regression_intervals",
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[regression_bench] wrote {args.out}")
    for row in results:
        if "intervals_finite_frac" in row and \
                not row["intervals_finite_frac"] > 0:
            raise SystemExit("served intervals are not finite")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
