"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,...]

Prints ``bench,config,us_per_call,derived`` CSV rows. CPU container note:
absolute times are CPU-XLA; the asymptotic slopes across the n-grid are
the quantities that reproduce the paper's figures (see EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import HEADER, row


def _fleet_rows(quick: bool) -> list[str]:
    """Run fleet_bench in a child process and render its rows as CSV."""
    import json
    import os
    import subprocess
    import tempfile

    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "fleet_bench.py")
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "fleet.json")
        cmd = [sys.executable, script, "--out", out]
        if quick:
            cmd.append("--quick")
        subprocess.run(cmd, check=True)
        with open(out) as f:
            results = json.load(f)["results"]
    rows = []
    for r in results:
        if r["bench_kind"] == "fleet_scaling":
            rows.append(row(
                "fleet/scaling",
                f"S={r['tenants']},shards={r['shards']}",
                r["tenants"] / r["session_steps_per_s"],
                f"steps={r['session_steps_per_s']:.0f}/s "
                f"tick_p99={r['tick_p99_s'] * 1e3:.2f}ms "
                f"speedup={r.get('shard_speedup_vs_1shard', 1):.2f}x "
                f"cores={r['host_cores']}"))
        elif r["bench_kind"] == "fleet_lifecycle":
            rows.append(row(
                "fleet/lifecycle", f"S={r['tenants']}",
                r["observe_round_p50_s"],
                f"admit={r['admit_s_per_tenant'] * 1e6:.0f}us "
                f"migrations={r['migrations']} "
                f"round_max={r['observe_round_max_s'] * 1e3:.0f}ms"))
    return rows


def _faults_rows(quick: bool) -> list[str]:
    """Run chaos_bench in a child process and render its rows as CSV."""
    import json
    import os
    import subprocess
    import tempfile

    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "chaos_bench.py")
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "chaos.json")
        cmd = [sys.executable, script, "--out", out]
        if quick:
            cmd.append("--quick")
        subprocess.run(cmd, check=True)
        with open(out) as f:
            results = json.load(f)["results"]
    rows = []
    for r in results:
        if r["bench_kind"] == "chaos_guard_overhead":
            rows.append(row(
                "chaos/guard_overhead",
                f"S={r['sessions']},cap={r['capacity']}",
                r["observe_many_s_guarded"] / r["chunk"],
                f"overhead={100 * r['guard_overhead_frac']:+.1f}% "
                f"plain={r['observe_many_s_plain'] * 1e3:.2f}ms "
                f"bit_identical={r['bit_identical_clean']}"))
        elif r["bench_kind"] == "chaos_fault_saver":
            rows.append(row(
                "chaos/fault_saver", f"S={r['sessions']}",
                r["save_wall_s"],
                f"retries={r['snapshot_retries']:.0f} "
                f"committed={r['committed']}"))
        elif r["bench_kind"] == "chaos_fault_restore":
            rows.append(row(
                "chaos/fault_restore", f"S={r['sessions']}",
                r["restore_wall_s"],
                f"fallbacks={r['restore_fallbacks']:.0f} "
                f"step={r['recovered_step']} "
                f"bit_exact={r['recovered_bit_exact']}"))
    return rows


def _audit_rows(quick: bool) -> list[str]:
    """Run the static invariant audit in a child process, render rows.

    Subprocessed for the same reason as the fleet bench: the sharded
    targets need XLA_FLAGS virtual devices before jax's first import.
    A failing audit raises, so perf runs cannot record bench rows
    against a tree that violates the compiled-artifact invariants."""
    import json
    import os
    import subprocess
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "audit.json")
        cmd = [sys.executable, "-m", "repro.analysis.audit", "--out", out]
        if quick:
            cmd.append("--quick")
        r = subprocess.run(cmd, capture_output=True, text=True)
        print(r.stdout, end="", file=sys.stderr)  # keep the CSV clean
        if r.returncode and not os.path.exists(out):
            raise RuntimeError(f"audit crashed: {r.stderr[-500:]}")
        with open(out) as f:
            rep = json.load(f)
    s = rep["summary"]
    if s["fail"]:
        bad = [r for r in rep["checks"] if r["status"] == "fail"]
        raise RuntimeError(
            f"{s['fail']} audit check(s) failed, first: "
            f"{bad[0]['check']} @ {bad[0]['target']}")
    rows = [row("audit/summary",
                f"targets={len(rep['targets'])},shards<="
                f"{rep['matrix']['max_shards']}",
                rep["elapsed_s"],
                f"pass={s['pass']} fail={s['fail']} "
                f"waived={s['waived']} skipped={s['skipped']} "
                f"trip_fallbacks={s['trip_fallbacks']}")]
    for r in rep["checks"]:
        if r["status"] == "fail":
            rows.append(row(f"audit/{r['check']}", r["target"], 0.0,
                            "FAIL " + (r["violations"][0].get("line", "")
                                       if r["violations"] else "")))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller n-grids (CI mode)")
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)

    from benchmarks import (bootstrap_bench, fig2_predict_time,
                            fig3_train_time, fig4_regression, online_bench,
                            regression_bench, replay_bench, roofline,
                            serve_bench, table2_highdim, table3_parallel)

    def _sliding_rows(fn, tag, caps):
        return [
            row(f"{tag}/sliding", f"S={r['sessions']},cap={r['capacity']}",
                r["sessions"] / r["session_steps_per_s_sliding"],
                f"ring={r['session_steps_per_s_sliding']:.0f}/s "
                f"compact={r['session_steps_per_s_sliding_compact']:.0f}/s "
                f"ring_vs_compact={r['ring_speedup_vs_compact']:.2f}x "
                f"evictfree={r['session_steps_per_s_evictfree']:.0f}/s "
                f"mem_roof={100 * r['mem_roof_fraction']:.0f}% "
                f"compile={r['compile_s_ring']:.2f}s")
            for r in fn(caps)]

    suites = {
        "fig2": lambda: fig2_predict_time.run(
            n_grid=(64, 256) if args.quick else fig2_predict_time.N_GRID),
        "fig3": lambda: fig3_train_time.run(
            n_grid=(64, 256) if args.quick else fig3_train_time.N_GRID),
        "fig4": lambda: fig4_regression.run(
            n_grid=(64, 256) if args.quick else fig4_regression.N_GRID),
        "table2": lambda: table2_highdim.run(
            n_train=256 if args.quick else table2_highdim.N_TRAIN,
            m_test=8 if args.quick else table2_highdim.M_TEST),
        "table3": lambda: table3_parallel.run(
            n=256 if args.quick else table3_parallel.N),
        "bootstrap": lambda: [
            row(f"bootstrap/{k}", f"n={r['n']},B={r['B']}", r[k],
                f"B'={r['b_prime']} "
                f"speedup={r['speedup_optimized_vs_standard']:.1f}x")
            for r in bootstrap_bench.run(
                n_grid=(24,) if args.quick else (48,), m=1, B=5, depth=3)
            for k in ("t_fit_s", "t_optimized_per_point_s",
                      "t_standard_per_point_s", "t_tick_s")],
        "online": lambda: online_bench.run(
            t_grid=(64,) if args.quick else (64, 256, 1024)),
        # window-full sliding eviction: the ring-layout O(cap)-evict
        # columns (ISSUE 5) — keeps the BENCH trajectory comparable
        "serve_sliding": lambda: _sliding_rows(
            serve_bench.run_sliding, "serve",
            (256,) if args.quick else (256, 1024)),
        "reg_sliding": lambda: _sliding_rows(
            regression_bench.run_sliding, "regression",
            (256,) if args.quick else (256, 1024)),
        # telemetry-instrumentation cost on the chunked hot path (the
        # 5% budget CI gates on BENCH_serve.json)
        "serve_overhead": lambda: [
            row("serve/overhead",
                f"S={r['sessions']},cap={r['capacity']}",
                r["observe_many_s_instrumented"] / r["chunk"],
                f"overhead={100 * r['instrumentation_overhead_frac']:+.1f}"
                f"% plain={r['observe_many_s_plain'] * 1e3:.2f}ms")
            for r in serve_bench.run_overhead()],
        # trace replay under load (loadgen workloads) + the cost-model
        # chunk auto-tune vs the hand-tuned constant
        "replay": lambda: [
            row(f"replay/{r['workload']}",
                f"S={r['tenants']},cap={r['capacity']},x{r['speedup']:g}",
                r["observe_p99_s"],
                f"p50={r['observe_p50_s'] * 1e3:.2f}ms "
                f"sojourn_p99={r['observe_sojourn_p99_s'] * 1e3:.2f}ms "
                f"slo_viol={r['slo_violation_frac']:.2f} "
                f"q_max={r['queue_depth_max']:.0f}")
            for r in replay_bench.run_workloads(
                ops=96 if args.quick else 256)
        ] + [
            row("replay/autotune",
                f"chunk={r['chunk_suggested']}vs{r['chunk_hand']}",
                r["tenants"] / r["steps_per_s_auto"],
                f"auto={r['steps_per_s_auto']:.0f}/s "
                f"hand={r['steps_per_s_hand']:.0f}/s "
                f"ratio={r['autotune_ratio']:.2f}x")
            for r in replay_bench.run_autotune(
                ops=192 if args.quick else 384)],
        # sharded-fleet scaling curve. Subprocessed: virtual host
        # devices require XLA_FLAGS before jax's first import, and this
        # module imported jax lines ago.
        "fleet": lambda: _fleet_rows(args.quick),
        # chaos harness: guarded-tick overhead (5% CI budget) + keyed
        # I/O fault smoke (saver retries, restore fallback).
        # Subprocessed like fleet to keep this process's jax state out
        # of the measured child.
        "faults": lambda: _faults_rows(args.quick),
        "roofline": lambda: roofline.run(mesh_filter=None),
        # static invariant audit alongside the perf rows (subprocessed
        # like fleet; raises — and so records ERROR — on any violation)
        "audit": lambda: _audit_rows(args.quick),
    }
    only = set(args.only.split(",")) if args.only else set(suites)

    print(HEADER)
    failed = 0
    for name, fn in suites.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            for r in fn():
                print(r)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,0,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
