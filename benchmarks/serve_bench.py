"""Serving-engine throughput: sessions x steps/s for the micro-batched
online CP step (observe: evict-if-full + incremental learn + smoothed
p-value, all in one donated vmapped jitted dispatch), its chunked
``observe_many`` form (T ticks per dispatch under one lax.scan), and
the fused-kernel read-only predict. Writes BENCH_serve.json.

The spread between the per-tick and chunked rows is the fixed
per-dispatch overhead (host round-trip + buffer shuffling) that
``observe_many`` amortizes; it is reported per tick as
``per_tick_overhead_s_est``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def _bench_observe(eng, state, X, y, taus, steps):
    # warmup tick (trace+compile+execute) timed separately, not dropped
    t0 = time.perf_counter()
    state, p = eng.observe(state, X[:, 0], y[:, 0], taus[:, 0])
    jax.block_until_ready(p)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for t in range(1, steps):
        state, p = eng.observe(state, X[:, t], y[:, t], taus[:, t])
    jax.block_until_ready(p)
    return state, time.perf_counter() - t0, steps - 1, compile_s


def _bench_observe_many(eng, state, X, y, taus, steps, chunk):
    """Same traffic, chunked: one dispatch per ``chunk`` ticks."""
    xs = jnp.swapaxes(X, 0, 1)  # (steps, S, dim)
    ys = jnp.swapaxes(y, 0, 1)
    ts = jnp.swapaxes(taus, 0, 1)
    # warmup chunk (trace+compile+execute) timed separately
    t0 = time.perf_counter()
    state, p = eng.observe_many(state, xs[:chunk], ys[:chunk], ts[:chunk])
    jax.block_until_ready(p)
    compile_s = time.perf_counter() - t0
    ticks = 0
    t0 = time.perf_counter()
    for lo in range(chunk, steps - chunk + 1, chunk):
        state, p = eng.observe_many(state, xs[lo:lo + chunk],
                                    ys[lo:lo + chunk], ts[lo:lo + chunk])
        ticks += chunk
    jax.block_until_ready(p)
    return state, time.perf_counter() - t0, ticks, compile_s


def _bench_predict(eng, state, Xq, repeats=3):
    t0 = time.perf_counter()
    out = eng.predict(state, Xq)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = eng.predict(state, Xq)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, compile_s


def run(grid=((8, 128), (32, 128), (8, 256), (64, 256)), *, steps=192,
        dim=16, k=7, queries=16, chunk=64):
    from repro.serving import ServingEngine

    # the chunked run needs one warmup chunk + at least one timed chunk
    chunk = min(chunk, max(steps // 2, 1))
    results = []
    for n_sessions, capacity in grid:
        window = capacity // 2
        eng = ServingEngine(n_sessions=n_sessions, capacity=capacity,
                            dim=dim, k=k, n_labels=2, window=window)
        key = jax.random.PRNGKey(0)
        kx, ky, kt = jax.random.split(key, 3)
        X = jax.random.normal(kx, (n_sessions, steps, dim), jnp.float32)
        y = jax.random.bernoulli(ky, 0.5, (n_sessions, steps)).astype(
            jnp.int32)
        taus = jax.random.uniform(kt, (n_sessions, steps),
                                  dtype=jnp.float32)
        state, dt, ticks, comp_obs = _bench_observe(
            eng, eng.init_state(), X, y, taus, steps)
        _, dt_many, ticks_many, comp_many = _bench_observe_many(
            eng, eng.init_state(), X, y, taus, steps, chunk)
        Xq = jax.random.normal(kx, (n_sessions, queries, dim), jnp.float32)
        t_pred, comp_pred = _bench_predict(eng, state, Xq)
        row = {
            "sessions": n_sessions,
            "capacity": capacity,
            "window": window,
            "dim": dim,
            "k": k,
            "ticks": ticks,
            "observe_compile_s": comp_obs,
            "observe_many_compile_s": comp_many,
            "predict_compile_s": comp_pred,
            "observe_wall_s": dt,
            "session_steps_per_s": n_sessions * ticks / dt,
            "ticks_per_s": ticks / dt,
            "chunk": chunk,
            "observe_many_ticks": ticks_many,
            "observe_many_wall_s": dt_many,
            "session_steps_per_s_observe_many":
                n_sessions * ticks_many / dt_many,
            "ticks_per_s_observe_many": ticks_many / dt_many,
            # fixed per-dispatch overhead the chunking amortizes away
            "per_tick_overhead_s_est": dt / ticks - dt_many / ticks_many,
            "predict_wall_s_per_call": t_pred,
            "predict_pvalues_per_s": n_sessions * queries / t_pred,
        }
        results.append(row)
        print(f"[serve_bench] S={n_sessions:4d} cap={capacity:4d} "
              f"{row['session_steps_per_s']:10.0f} session-steps/s  "
              f"{row['session_steps_per_s_observe_many']:10.0f} chunked  "
              f"{row['predict_pvalues_per_s']:10.0f} query-pvals/s")
    return results


def run_sliding(caps=(256, 1024, 4096), *, dim=16, k=7, chunk=32, reps=4):
    """Window-full eviction throughput sweep (the ISSUE 5 target regime).

    Every measured tick runs the decremental eviction: the production
    ring layout vs the positional-compaction baseline
    (``layout="compact"`` — the pre-PR algorithm, whose per-tick
    (cap, cap) shifts this PR removes) vs the evict-free grow-mode
    reference. The historic half-full-window grid above leaves eviction
    nearly invisible; these rows are where the O(cap^2)-vs-O(cap)
    difference lives.
    """
    from repro.serving import ServingEngine

    try:  # package import (python -m benchmarks.run) or script run
        from benchmarks import roofline
        from benchmarks.common import bench_sliding
    except ImportError:  # executed as a script: benchmarks/ is on sys.path
        import roofline
        from common import bench_sliding

    rows = []
    for cap in caps:
        sessions = 2 if cap >= 4096 else 4  # (S, cap, cap) f32 memory

        def mk(layout, window):
            return ServingEngine(
                n_sessions=sessions, capacity=cap, dim=dim, k=k,
                n_labels=2, window=window, layout=layout)

        def traffic(T):
            key = jax.random.PRNGKey(cap)
            kx, ky, kt = jax.random.split(key, 3)
            return (jax.random.normal(kx, (T, sessions, dim), jnp.float32),
                    jax.random.bernoulli(ky, 0.5, (T, sessions)).astype(
                        jnp.int32),
                    jax.random.uniform(kt, (T, sessions), jnp.float32))

        row = bench_sliding(mk, traffic, cap=cap, chunk=chunk, reps=reps)
        row.update(dim=dim, k=k)
        # distance from the measured memory-bandwidth roof
        bw = roofline.measure_bandwidth()
        nbytes = roofline.sliding_tick_bytes(sessions, cap, dim)
        row["mem_bandwidth_bytes_per_s"] = bw
        row["sliding_tick_bytes_model"] = nbytes
        row["mem_roof_fraction"] = (
            (nbytes / bw) * row["session_steps_per_s_sliding"] / sessions)
        rows.append(row)
        print(f"[serve_bench] sliding S={sessions} cap={cap:5d} "
              f"ring {row['session_steps_per_s_sliding']:9.0f}/s  "
              f"compact {row['session_steps_per_s_sliding_compact']:9.0f}/s"
              f"  ({row['ring_speedup_vs_compact']:.2f}x)  "
              f"evict-free {row['session_steps_per_s_evictfree']:9.0f}/s  "
              f"roof {100 * row['mem_roof_fraction']:.0f}%")
    return rows


def run_overhead(*, sessions=8, capacity=256, dim=16, k=7, chunk=64,
                 rounds=15, chunks_per_sample=3):
    """Telemetry-instrumentation overhead on the chunked hot path.

    Two engines with identical geometry and traffic — one plain, one
    ``instrument=True`` (device tick counters folded into the scan +
    host-side op timing, ``repro.telemetry``) — alternate timed samples
    of ``chunks_per_sample`` back-to-back ``observe_many`` chunks. The
    reported overhead is the *median of the per-round paired ratios*:
    each round times plain then instrumented back-to-back, so slow
    drift (thermal, noisy-neighbour load) cancels within the pair and
    single-sample OS spikes are discarded by the median — an unpaired
    best-of comparison flaps several percent on shared CPU runners.
    The contract (CI-gated at 5 %) is that instrumentation costs next
    to nothing: the tick stats are a handful of int32 scalars riding
    the existing scan, and the timing wrapper never forces a device
    sync.
    """
    from repro.serving import ServingEngine
    from repro.telemetry import MetricsRegistry

    window = capacity // 2

    def mk(instrument):
        return ServingEngine(
            n_sessions=sessions, capacity=capacity, dim=dim, k=k,
            n_labels=2, window=window, instrument=instrument,
            metrics=MetricsRegistry() if instrument else None)

    key = jax.random.PRNGKey(7)
    kx, ky, kt = jax.random.split(key, 3)
    xs = jax.random.normal(kx, (chunk, sessions, dim), jnp.float32)
    ys = jax.random.bernoulli(ky, 0.5, (chunk, sessions)).astype(jnp.int32)
    ts = jax.random.uniform(kt, (chunk, sessions), jnp.float32)

    engines = {False: mk(False), True: mk(True)}
    states, times = {}, {False: [], True: []}
    for inst, eng in engines.items():
        st, p = eng.observe_many(eng.init_state(), xs, ys, ts)  # compile
        jax.block_until_ready(p)
        states[inst] = st
    for r in range(rounds):
        # interleaved for shared noise; order alternates so a
        # second-sample-in-round position effect cancels in the median
        order = (False, True) if r % 2 == 0 else (True, False)
        for inst in order:
            st = states[inst]
            t0 = time.perf_counter()
            for _ in range(chunks_per_sample):
                st, p = engines[inst].observe_many(st, xs, ys, ts)
            jax.block_until_ready(p)
            times[inst].append(
                (time.perf_counter() - t0) / chunks_per_sample)
            states[inst] = st
    t_plain, t_inst = min(times[False]), min(times[True])
    ratios = sorted(i / p for p, i in zip(times[False], times[True]))
    frac = ratios[len(ratios) // 2] - 1.0
    row = {
        "bench_kind": "instrumentation_overhead",
        "sessions": sessions,
        "capacity": capacity,
        "window": window,
        "chunk": chunk,
        "rounds": rounds,
        "observe_many_s_plain": t_plain,
        "observe_many_s_instrumented": t_inst,
        "instrumentation_overhead_frac": frac,
    }
    print(f"[serve_bench] instrumentation overhead cap={capacity} "
          f"plain {t_plain * 1e3:.2f}ms inst {t_inst * 1e3:.2f}ms "
          f"({100 * row['instrumentation_overhead_frac']:+.1f}%)")
    return [row]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--steps", type=int, default=192)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--quick", action="store_true",
                    help="small grid (CI smoke; capacities stay large "
                         "enough that an O(cap^2) copy regression shows)")
    args = ap.parse_args(argv)
    grid = ((8, 256),) if args.quick else ((8, 128), (32, 128), (8, 256),
                                           (64, 256))
    results = run(grid, steps=args.steps, dim=args.dim, chunk=args.chunk)
    results += run_sliding((256, 1024) if args.quick
                           else (256, 1024, 4096))
    results += run_overhead(chunk=args.chunk)
    # rows of other benches (replay* from replay_bench, fleet* from
    # fleet_bench) are carried over, not clobbered
    try:
        from benchmarks.common import merge_bench_rows
    except ImportError:
        from common import merge_bench_rows
    merge_bench_rows(args.out, results,
                     owned_prefixes=("", "sliding_full_window",
                                     "instrumentation_overhead"))
    print(f"[serve_bench] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
