"""Chaos-engineering benchmarks: guarded-tick overhead and keyed
fault-suite smoke (repro.robustness). Writes ``chaos*`` rows into the
shared BENCH_serve.json.

Rows
----
chaos_guard_overhead
    The ``TickGuard`` admission + poison-sweep cost on the chunked
    observe hot path, measured exactly like serve_bench's
    instrumentation overhead: a plain engine and a guarded one with
    identical geometry and (clean) traffic alternate timed samples, and
    the reported overhead is the median of the per-round paired ratios
    (drift cancels within a pair, OS spikes fall to the median). The
    row also asserts the guard's bit-neutrality contract: the two final
    states must be leaf-for-leaf identical. CI gates the overhead at
    5 % (``.github/workflows/ci.yml`` chaos job).

chaos_fault_saver
    A keyed transient write fault (``write_fail``, times=2) through the
    async sharded saver: the row records the retries the backoff loop
    absorbed and that the step still committed.

chaos_fault_restore
    A flipped byte in the latest committed shard: the row records the
    fallback walk to the previous committed step and that the restored
    state is the previous step's, bit-exact.

    PYTHONPATH=src python benchmarks/chaos_bench.py [--out ...] [--quick]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp


def _traffic(sessions, chunk, dim, seed=7):
    key = jax.random.PRNGKey(seed)
    kx, ky, kt = jax.random.split(key, 3)
    xs = jax.random.normal(kx, (chunk, sessions, dim), jnp.float32)
    ys = jax.random.bernoulli(ky, 0.5, (chunk, sessions)).astype(jnp.int32)
    ts = jax.random.uniform(kt, (chunk, sessions), jnp.float32)
    return xs, ys, ts


def run_guard_overhead(*, sessions=8, capacity=256, dim=16, k=7, chunk=64,
                       rounds=15, chunks_per_sample=3):
    """Paired plain-vs-guarded overhead on the chunked observe path."""
    from repro.robustness import TickGuard
    from repro.serving import ServingEngine

    window = capacity // 2

    def mk():
        return ServingEngine(n_sessions=sessions, capacity=capacity,
                             dim=dim, k=k, n_labels=2, window=window)

    xs, ys, ts = _traffic(sessions, chunk, dim)
    drivers = {False: mk(), True: TickGuard(mk())}
    states, times = {}, {False: [], True: []}
    for g, drv in drivers.items():
        st, p = drv.observe_many(drv.init_state(), xs, ys, ts)  # compile
        jax.block_until_ready(p)
        states[g] = st
    for r in range(rounds):
        # interleaved, alternating order: shared noise cancels in the
        # per-round ratio, position effects cancel in the median
        order = (False, True) if r % 2 == 0 else (True, False)
        for g in order:
            st = states[g]
            t0 = time.perf_counter()
            for _ in range(chunks_per_sample):
                st, p = drivers[g].observe_many(st, xs, ys, ts)
            jax.block_until_ready(p)
            times[g].append((time.perf_counter() - t0) / chunks_per_sample)
            states[g] = st
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(states[False]),
                        jax.tree_util.tree_leaves(states[True])))
    ratios = sorted(g / p for p, g in zip(times[False], times[True]))
    frac = ratios[len(ratios) // 2] - 1.0
    row = {
        "bench_kind": "chaos_guard_overhead",
        "sessions": sessions,
        "capacity": capacity,
        "window": window,
        "chunk": chunk,
        "rounds": rounds,
        "observe_many_s_plain": min(times[False]),
        "observe_many_s_guarded": min(times[True]),
        "guard_overhead_frac": frac,
        "bit_identical_clean": bool(same),
    }
    print(f"[chaos_bench] guard overhead cap={capacity} "
          f"plain {row['observe_many_s_plain'] * 1e3:.2f}ms "
          f"guarded {row['observe_many_s_guarded'] * 1e3:.2f}ms "
          f"({100 * frac:+.1f}%) "
          f"{'bit-identical' if same else 'STATE MISMATCH'}")
    return [row]


def run_fault_suite(*, sessions=4, capacity=32, dim=4, k=3, seed=11):
    """Keyed I/O fault smoke through the saver / store counters."""
    from repro.robustness import (Fault, FaultInjector, FaultPlan,
                                  flip_byte)
    from repro.serving import AsyncShardedSaver, ServingEngine, SessionStore
    from repro.telemetry import MetricsRegistry

    eng = ServingEngine(n_sessions=sessions, capacity=capacity, dim=dim,
                        k=k, n_labels=2, window=capacity // 2)
    state = eng.init_state()
    xs, ys, ts = _traffic(sessions, 8, dim, seed=seed)
    state, _ = eng.observe_many(state, xs, ys, ts)

    rows = []
    # -- transient write fault absorbed by the saver's retry loop ----------
    metrics = MetricsRegistry()
    plan = FaultPlan(seed, (Fault("store.write", 5, "write_fail",
                                  times=2),))
    with tempfile.TemporaryDirectory() as root:
        store = SessionStore(root, metrics=metrics,
                             injector=FaultInjector(plan, metrics=metrics))
        saver = AsyncShardedSaver(store, 1, metrics=metrics, seed=seed)
        t0 = time.perf_counter()
        saver.save(5, state, meta=eng.meta())
        saver.close()
        dt = time.perf_counter() - t0
        committed = store.latest_step() == 5
    retries = metrics.counter("snapshot_retries_total").value
    rows.append({
        "bench_kind": "chaos_fault_saver",
        "sessions": sessions,
        "capacity": capacity,
        "injected_write_failures": 2,
        "snapshot_retries": retries,
        "committed": bool(committed),
        "save_wall_s": dt,
    })
    print(f"[chaos_bench] saver: 2 transient write fault(s) -> "
          f"{retries:.0f} retries, "
          f"{'committed' if committed else 'NOT COMMITTED'}")

    # -- corrupted latest shard: restore walks back one committed step -----
    metrics = MetricsRegistry()
    with tempfile.TemporaryDirectory() as root:
        store = SessionStore(root, metrics=metrics)
        store.save(1, state, meta=eng.meta(), blocking=True)
        state2, _ = eng.observe_many(state, xs, ys, ts)
        store.save(2, state2, meta=eng.meta(), blocking=True)
        step_dir = os.path.join(store.root, f"step_{2:09d}")
        shard = next(os.path.join(step_dir, f)
                     for f in sorted(os.listdir(step_dir))
                     if f.endswith(".npz"))
        flip_byte(shard, seed=seed)
        t0 = time.perf_counter()
        got, got_step, _meta = store.restore()
        dt = time.perf_counter() - t0
        same = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(got)))
    fallbacks = metrics.counter("restore_fallback_total").value
    rows.append({
        "bench_kind": "chaos_fault_restore",
        "sessions": sessions,
        "capacity": capacity,
        "restore_fallbacks": fallbacks,
        "recovered_step": int(got_step),
        "recovered_bit_exact": bool(same),
        "restore_wall_s": dt,
    })
    print(f"[chaos_bench] restore: flipped byte in step 2 -> "
          f"fell back to step {got_step} "
          f"({fallbacks:.0f} fallback(s), "
          f"{'bit-exact' if same else 'MISMATCH'})")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller geometry, fewer rounds")
    args = ap.parse_args(argv)
    # the quick gate keeps the default geometry AND the full round
    # count: the guard's cost is a fixed per-chunk term, so a smaller
    # chunk would inflate the measured fraction past what production
    # chunking ever sees, and fewer rounds lets single-run noise
    # through the paired-ratio median
    results = run_guard_overhead()
    results += run_fault_suite()
    try:
        from benchmarks.common import merge_bench_rows
    except ImportError:
        from common import merge_bench_rows
    merge_bench_rows(args.out, results, owned_prefixes=("chaos",))
    print(f"[chaos_bench] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
