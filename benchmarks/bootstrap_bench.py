"""Paper Section 6 + Figure 5: optimized bootstrap CP.

Measures the (1 - 1/e) predict-phase factor vs standard bootstrap CP on a
small n (the method is numpy/tree-based — the one measure where the paper
itself only reaches a linear-factor win), and the B' vs B*n relation of
Figure 5 (shared bootstrap samples: B' << B*n).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core.measures import bootstrap as boot_m
from repro.data.synthetic import make_classification


def run(n=48, m=2, B=5, depth=3):
    rows = []
    X, y = make_classification(n_samples=n + m, n_features=10, seed=0)
    Xtr, ytr, Xte = X[:n], y[:n], X[n:]

    t0 = time.perf_counter()
    st = boot_m.fit(Xtr, ytr, n_labels=2, B=B, depth=depth, seed=0)
    t_fit = time.perf_counter() - t0
    t0 = time.perf_counter()
    boot_m.pvalues_optimized(st, Xte)
    t_opt = time.perf_counter() - t0

    t0 = time.perf_counter()
    boot_m.pvalues_standard(Xtr, ytr, Xte, n_labels=2, B=B, depth=depth,
                            seed=0)
    t_std = time.perf_counter() - t0

    rows.append(row("bootstrap/fit", f"n={n},B={B}", t_fit,
                    f"B'={st.b_prime} vs B*n={B * n} (fig5: B' << B*n)"))
    rows.append(row("bootstrap/optimized_pred", f"m={m}", t_opt / m, ""))
    rows.append(row("bootstrap/standard_pred", f"m={m}", t_std / m,
                    f"speedup={t_std / max(t_opt, 1e-9):.2f}x "
                    f"(paper: ~1/(1-1/e)=1.58x + shared-sample reuse)"))

    # fig5 relation across n
    for nn in (16, 32, 64):
        Xs, ys = make_classification(n_samples=nn, n_features=10, seed=1)
        s = boot_m.fit(Xs, ys, n_labels=2, B=B, depth=depth, seed=0)
        rows.append(row("fig5/bprime", f"n={nn},B={B}", 0.0,
                        f"B'={s.b_prime} Bn={B * nn}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
