"""Paper Section 6 + Figure 5: optimized bootstrap CP. Writes
BENCH_bootstrap.json.

Three comparisons per training size:

* ``pvalues_standard`` vs ``pvalues_optimized`` — Algorithm 3's shared
  pre-trained samples vs a fresh B-ensemble per LOO entry (the paper's
  linear predict-phase speedup; the acceptance bar is >= 5x at n=256);
* batch ``fit`` vs streaming ``incremental_add`` / ``decremental_remove``
  — the serving path: observe trains only the new point's ~0.37 B fresh
  samples (the incremental-learning win); evict retires every sample
  containing the removed point (~63% of the pool) and is inherently
  refit-like, which the per-tick ratio reports honestly;
* B' vs B*n (Figure 5) — how few shared samples cover every LOO entry.

    PYTHONPATH=src python benchmarks/bootstrap_bench.py [--quick]
        [--out BENCH_bootstrap.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _clock(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def run(n_grid=(64, 256), *, m=2, B=10, depth=5, seed=0, updates=6):
    from repro.core.measures import bootstrap as boot_m
    from repro.data.synthetic import make_classification

    rows = []
    warm_ticks = 4
    for n in n_grid:
        X, y = make_classification(
            n_samples=n + m + updates + warm_ticks, n_features=10,
            seed=seed)
        X = X.astype(np.float32)
        Xtr, ytr = X[:n], y[:n]
        Xte = X[n:n + m]

        boot_m.fit(Xtr, ytr, n_labels=2, B=B, depth=depth, seed=seed)
        t_fit, st = _clock(boot_m.fit, Xtr, ytr, n_labels=2, B=B,
                           depth=depth, seed=seed)
        # steady state: warm both predict paths on one point (compile),
        # then time the full test batch
        boot_m.pvalues_optimized(st, Xte[:1])
        t_opt, _ = _clock(boot_m.pvalues_optimized, st, Xte)
        boot_m.pvalues_standard(Xtr, ytr, Xte[:1], n_labels=2, B=B,
                                depth=depth, seed=seed)
        t_std, _ = _clock(boot_m.pvalues_standard, Xtr, ytr, Xte,
                          n_labels=2, B=B, depth=depth, seed=seed)

        # streaming tick (observe newest + evict oldest) vs batch refit;
        # two warmup ticks compile the update-path shape buckets. Note
        # bootstrap eviction retires every sample containing the evicted
        # point (~63% of the pool), so a tick is inherently refit-like —
        # the measure's headline win is the predict phase above; observe
        # alone is the incremental-learning win.
        stw = st
        for u in range(warm_ticks):
            stw = boot_m.incremental_add(stw, X[n + m + u],
                                         int(y[n + m + u]))
            stw = boot_m.decremental_remove(stw, 0)
        t_obs = t_evt = 0.0
        for u in range(updates):
            dt, stw = _clock(boot_m.incremental_add, stw,
                             X[n + m + warm_ticks + u],
                             int(y[n + m + warm_ticks + u]))
            t_obs += dt
            dt, stw = _clock(boot_m.decremental_remove, stw, 0)
            t_evt += dt
        t_refit, _ = _clock(boot_m.fit, stw.X, stw.y, n_labels=2, B=B,
                            depth=depth, seed=seed)

        t_tick = (t_obs + t_evt) / updates  # one observe + one evict
        row = {
            "n": n, "m": m, "B": B, "depth": depth,
            "b_prime": st.b_prime, "B_times_n": B * n,
            "t_fit_s": t_fit,
            "t_optimized_per_point_s": t_opt / m,
            "t_standard_per_point_s": t_std / m,
            "speedup_optimized_vs_standard": t_std / max(t_opt, 1e-9),
            "t_observe_s": t_obs / updates,
            "t_evict_s": t_evt / updates,
            "t_tick_s": t_tick,
            "t_refit_s": t_refit,
            "speedup_refit_vs_observe":
                t_refit / max(t_obs / updates, 1e-9),
            "speedup_refit_vs_tick": t_refit / max(t_tick, 1e-9),
        }
        rows.append(row)
        print(f"[bootstrap_bench] n={n:4d} B'={st.b_prime:4d} (Bn={B * n}) "
              f"opt={t_opt / m * 1e3:8.1f}ms/pt std={t_std / m:8.2f}s/pt "
              f"({row['speedup_optimized_vs_standard']:6.1f}x)  tick="
              f"{t_tick * 1e3:6.1f}ms refit={t_refit * 1e3:6.1f}ms "
              f"(refit/observe {row['speedup_refit_vs_observe']:4.1f}x, "
              f"refit/tick {row['speedup_refit_vs_tick']:4.1f}x)")
    return rows


def main(argv=None) -> int:
    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_bootstrap.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: n=256 only, one test point")
    ap.add_argument("--b", type=int, default=10)
    ap.add_argument("--depth", type=int, default=5)
    args = ap.parse_args(argv)
    if args.quick:
        rows = run((256,), m=1, B=args.b, depth=args.depth, updates=3)
    else:
        rows = run((64, 256), m=3, B=args.b, depth=args.depth,
                   updates=12)
    payload = {
        "bench": "bootstrap_cp",
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
        "results": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bootstrap_bench] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
