"""Benchmark harness utilities: compile-excluded wall timing, CSV rows,
and bench_kind-scoped row merging for the shared BENCH_serve.json."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def merge_bench_rows(out: str, rows: list[dict], *,
                     owned_prefixes: tuple[str, ...]) -> dict:
    """Replace one bench's rows of ``out`` in place, keep the rest.

    Several bench modules share BENCH_serve.json; each owns a disjoint
    family of rows identified by ``bench_kind`` prefix. A row is owned
    (and therefore replaced by this call) iff its ``bench_kind`` matches
    one of ``owned_prefixes``: the empty prefix ``""`` owns exactly the
    rows with no/empty ``bench_kind`` (the historic un-kinded
    throughput grid), while a non-empty prefix owns every row whose
    kind starts with it (``"replay"`` owns ``replay`` and
    ``replay_autotune``; ``"fleet"`` owns ``fleet_scaling`` and
    ``fleet_lifecycle``). Rows owned by nobody in ``owned_prefixes``
    are carried over untouched, so fleet rows survive a serve_bench
    rewrite and vice versa.
    """

    def owned(kind: str) -> bool:
        return any((kind == p) if p == "" else kind.startswith(p)
                   for p in owned_prefixes)

    if os.path.exists(out):
        with open(out) as f:
            payload = json.load(f)
    else:
        payload = {"bench": "serving_engine",
                   "backend": jax.default_backend(),
                   "device": str(jax.devices()[0]),
                   "results": []}
    payload["results"] = [
        r for r in payload.get("results", [])
        if not owned(str(r.get("bench_kind", "")))] + rows
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


def timeit_compiled(fn, *args, repeats: int = 3, **kw):
    """(median steady seconds per call, first-call seconds).

    The first call runs trace + compile + execute; its wall time is
    returned separately (``compile_s``, an upper bound on compile cost)
    instead of being silently discarded, so benches can report it as
    its own column rather than folding it into — or hiding it from —
    the steady-state numbers.
    """
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), compile_s


def timeit(fn, *args, repeats: int = 3, **kw):
    """Median seconds per call, compile excluded (one warmup)."""
    return timeit_compiled(fn, *args, repeats=repeats, **kw)[0]


def bench_sliding(make_engine, make_traffic, *, cap, chunk=32, reps=4):
    """Window-full sliding-eviction throughput for one engine family.

    The historic serve benches drive a half-full window, where most
    ticks are pure observes and the eviction path's cost is invisible.
    This harness measures the opposite regime — ``window == capacity``
    and the window already full, so EVERY timed tick runs the
    decremental eviction — for the production ring layout, the
    positional-compaction baseline (``layout="compact"``, the pre-PR
    algorithm), and the evict-free grow-mode reference the ISSUE's
    O(cap)-eviction target is measured against.

    ``make_engine(layout, window)`` builds an engine (window=None =>
    grow mode); ``make_traffic(T)`` returns (xs, ys, taus) shaped
    (T, S, ...). Prefill runs through a grow-mode engine (its tick
    statically drops the eviction machinery, so filling a 4096-deep
    window stays cheap); the produced state is layout-compatible with a
    ``window == capacity`` sliding engine (head == 0, ring modulus ==
    capacity). Returns the result row (throughputs + ratios).
    """
    xs, ys, taus = make_traffic(max(cap, chunk))
    x2, y2, t2 = xs[:chunk], ys[:chunk], taus[:chunk]
    sessions = int(x2.shape[1])

    def prefill(depth):
        """Exactly ``depth`` grow-mode ticks (remainder chunk included —
        an under-filled window would let timed 'sliding' ticks skip the
        eviction they are supposed to measure)."""
        eng = make_engine("ring", None)
        state = eng.init_state()
        for lo in range(0, depth, chunk):
            hi = min(lo + chunk, depth)
            state, _ = eng.observe_many(state, xs[lo:hi], ys[lo:hi],
                                        taus[lo:hi])
        return state

    t, comp = {}, {}
    for layout in ("ring", "compact", "grow"):
        if layout == "grow":
            # evict-free reference: occupancy just short of capacity,
            # with enough headroom that the timed chunks never trigger
            # the capacity-doubling growth (which would retrace)
            eng = make_engine("ring", None)
            warm = eng.init_state()
            t0 = time.perf_counter()
            warm, p = eng.observe_many(warm, x2, y2, t2)  # compile
            jax.block_until_ready(p)
            comp[layout] = time.perf_counter() - t0
            del warm
            eng.reset_occupancy()
            state = prefill(cap - reps * chunk - 1)
        else:
            eng = make_engine(layout, cap)  # window == capacity
            state = prefill(cap - chunk)
            # warmup chunk compiles AND fills the window to exactly cap,
            # so every timed tick below evicts
            t0 = time.perf_counter()
            state, p = eng.observe_many(state, x2, y2, t2)
            jax.block_until_ready(p)
            comp[layout] = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            state, p = eng.observe_many(state, x2, y2, t2)
        jax.block_until_ready(p)
        t[layout] = (time.perf_counter() - t0) / (reps * chunk)
        del state

    return {
        "bench_kind": "sliding_full_window",
        "sessions": sessions,
        "capacity": cap,
        "window": cap,
        "chunk": chunk,
        "session_steps_per_s_sliding": sessions / t["ring"],
        "session_steps_per_s_sliding_compact": sessions / t["compact"],
        "session_steps_per_s_evictfree": sessions / t["grow"],
        "ring_speedup_vs_compact": t["compact"] / t["ring"],
        "evict_overhead_vs_evictfree": t["ring"] / t["grow"],
        # first observe_many dispatch per layout: trace+compile+execute
        "compile_s_ring": comp["ring"],
        "compile_s_compact": comp["compact"],
        "compile_s_grow": comp["grow"],
    }


def row(bench: str, config: str, seconds: float, derived: str = "") -> str:
    return f"{bench},{config},{seconds * 1e6:.1f},{derived}"


HEADER = "bench,config,us_per_call,derived"
