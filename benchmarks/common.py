"""Benchmark harness utilities: compile-excluded wall timing, CSV rows."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, repeats: int = 3, **kw):
    """Median seconds per call, compile excluded (one warmup)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(bench: str, config: str, seconds: float, derived: str = "") -> str:
    return f"{bench},{config},{seconds * 1e6:.1f},{derived}"


HEADER = "bench,config,us_per_call,derived"
