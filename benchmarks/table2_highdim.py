"""Paper Table 2 + Appendix G (MNIST): high-dimensional, 10-label setting.

Synthetic stand-in for MNIST (offline container): 784 features, 10 labels.
Reports train/predict time for optimized CP vs ICP, plus the statistical
comparison the paper's optimizations make feasible: fuzziness of full CP vs
ICP (full CP should win — Appendix G).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import icp as icp_m
from repro.core import pvalues as pv
from repro.core.measures import knn as knn_m
from repro.data.synthetic import make_classification

N_TRAIN = 2048
M_TEST = 32
K = 15


def run(n_train=N_TRAIN, m_test=M_TEST):
    rows = []
    X, y = make_classification(
        n_samples=n_train + m_test, n_features=784, n_informative=64,
        n_classes=10, seed=0, class_sep=2.0)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.int32)
    Xtr, ytr = X[:n_train], y[:n_train]
    Xte, yte = X[n_train:], y[n_train:]

    for simplified, name in ((True, "simplified_knn"), (False, "knn")):
        t_fit = timeit(knn_m.fit, Xtr, ytr, k=K)
        st = knn_m.fit(Xtr, ytr, k=K)
        t_pred = timeit(knn_m.pvalues_optimized, st, Xte, k=K,
                        simplified=simplified, n_labels=10)
        p_cp = knn_m.pvalues_optimized(st, Xte, k=K, simplified=simplified,
                                       n_labels=10)
        rows.append(row(f"table2/{name}/optimized_fit",
                        f"n={n_train},p=784,l=10", t_fit, ""))
        rows.append(row(f"table2/{name}/optimized_pred",
                        f"m={m_test}", t_pred / m_test, ""))

        ist = icp_m.fit_knn(Xtr, ytr, k=K, simplified=simplified,
                            t=n_train // 2)
        t_icp = timeit(icp_m.pvalues_knn, ist, Xte, k=K,
                       simplified=simplified, n_labels=10)
        p_icp = icp_m.pvalues_knn(ist, Xte, k=K, simplified=simplified,
                                  n_labels=10)
        rows.append(row(f"table2/{name}/icp_pred", f"m={m_test}",
                        t_icp / m_test, ""))

        fz_cp = float(jnp.mean(pv.fuzziness(p_cp)))
        fz_icp = float(jnp.mean(pv.fuzziness(p_icp)))
        cov_cp, _ = pv.coverage(p_cp, yte, 0.1)
        rows.append(row(f"table2/{name}/fuzziness", "cp_vs_icp", 0.0,
                        f"cp={fz_cp:.5f} icp={fz_icp:.5f} "
                        f"cp_better={fz_cp <= fz_icp} "
                        f"cov@0.1={float(cov_cp):.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
