"""Sessions-scaling curve for the sharded serving fleet.

Two row families, merged into BENCH_serve.json under the ``fleet``
bench_kind prefix (``benchmarks.common.merge_bench_rows`` — the
serve/replay rows are preserved):

* ``fleet_scaling`` — the tenant axis swept 1 -> 10k+ at 1 shard and
  at ``--devices`` shards: chunked session-steps/s, single-tick
  latency p50/p99 (each tick individually synced), per-shard mean
  occupancy from the device tick counters, and the measured
  ``shard_speedup_vs_1shard``. Every row records ``host_cores``: on a
  single-core container the 8 virtual XLA host devices time-slice one
  core, so the honest speedup there is ~1x — the row exists to show
  sharding costs nothing, and the CI gate scales its expectation with
  the core count rather than asserting a parallel win the hardware
  cannot deliver.
* ``fleet_lifecycle`` — tenant admit / serve / bucket-migrate / retire
  wall costs through ``repro.serving.Fleet`` (capacity-bucketed engine
  pools), with the migration count that the bucketed pools confine to
  one tenant's lane instead of a pool-wide retrace.

MUST run as its own process (``python benchmarks/fleet_bench.py`` or
the ``fleet`` suite of ``benchmarks.run``, which subprocesses it):
virtual host devices only exist if XLA_FLAGS is set before jax is
first imported, so all jax-touching imports here are deferred.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] \\
        [--devices 8] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _ensure_devices(n: int) -> None:
    """Force ``n`` virtual CPU devices. Must precede any jax import."""
    if "jax" in sys.modules:
        raise SystemExit(
            "fleet_bench must set XLA_FLAGS before jax is imported; "
            "run it as its own process (benchmarks.run subprocesses it)")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def _host_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def run_scaling(tenants_grid, shard_grid, *, capacity=128, dim=16, k=7,
                chunk=16, chunks=2, lat_ticks=24, seed=0):
    """One row per (tenants, shards) point of the scaling curve."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import ServingEngine

    cores = _host_cores()
    rows, base = [], {}
    for n_sessions in tenants_grid:
        for shards in shard_grid:
            if n_sessions % shards:
                continue
            eng = ServingEngine(
                n_sessions=n_sessions, capacity=capacity, dim=dim, k=k,
                n_labels=2, window=capacity // 2, shards=shards,
                instrument=True)
            key = jax.random.PRNGKey(seed)
            kx, ky, kt = jax.random.split(key, 3)
            T = chunk * (chunks + 1) + lat_ticks
            xs = jax.random.normal(kx, (T, n_sessions, dim), jnp.float32)
            ys = jax.random.bernoulli(ky, 0.5, (T, n_sessions)).astype(
                jnp.int32)
            ts = jax.random.uniform(kt, (T, n_sessions), jnp.float32)

            state = eng.init_state()
            # warmup chunk: trace + compile + execute, timed separately
            t0 = time.perf_counter()
            state, p = eng.observe_many(state, xs[:chunk], ys[:chunk],
                                        ts[:chunk])
            jax.block_until_ready(p)
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            for c in range(1, chunks + 1):
                lo = c * chunk
                state, p = eng.observe_many(
                    state, xs[lo:lo + chunk], ys[lo:lo + chunk],
                    ts[lo:lo + chunk])
            jax.block_until_ready(p)
            wall = time.perf_counter() - t0
            steps_per_s = n_sessions * chunk * chunks / wall

            # single-tick latency distribution: every dispatch synced
            lats = []
            off = chunk * (chunks + 1)
            state1, p = eng.observe(state, xs[off], ys[off], ts[off])
            jax.block_until_ready(p)  # single-tick compile
            state = state1
            for t in range(off + 1, off + lat_ticks):
                t0 = time.perf_counter()
                state, p = eng.observe(state, xs[t], ys[t], ts[t])
                jax.block_until_ready(p)
                lats.append(time.perf_counter() - t0)
            lats = np.asarray(lats)

            drained = eng.telemetry.ticks.drain()
            per_shard = eng.telemetry.ticks.shard_vals or [drained]
            occ = [sh["occupancy_sum"] / max(sh["ticks"], 1)
                   for sh in per_shard]

            row = {
                "bench_kind": "fleet_scaling",
                "tenants": n_sessions,
                "shards": shards,
                "devices": jax.device_count(),
                "host_cores": cores,
                "capacity": capacity,
                "window": capacity // 2,
                "dim": dim,
                "k": k,
                "chunk": chunk,
                "compile_s": compile_s,
                "session_steps_per_s": steps_per_s,
                "tick_p50_s": float(np.percentile(lats, 50)),
                "tick_p99_s": float(np.percentile(lats, 99)),
                "per_shard_occupancy": [round(o, 2) for o in occ],
            }
            if shards == 1:
                base[n_sessions] = steps_per_s
            if n_sessions in base:
                row["shard_speedup_vs_1shard"] = (
                    steps_per_s / base[n_sessions])
            rows.append(row)
            print(f"[fleet_bench] S={n_sessions:6d} shards={shards} "
                  f"{steps_per_s:10.0f} steps/s  tick p50 "
                  f"{row['tick_p50_s'] * 1e3:6.2f}ms p99 "
                  f"{row['tick_p99_s'] * 1e3:6.2f}ms  "
                  f"speedup={row.get('shard_speedup_vs_1shard', 1):.2f}x")
            del state, eng
    return rows


def run_lifecycle(*, tenants=24, steps=72, dim=8, k=5, cap_min=8,
                  cap_max=64, pool_sessions=8, seed=0):
    """Admit / serve / migrate / retire costs through the fleet."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import Fleet
    from repro.telemetry import MetricsRegistry

    metrics = MetricsRegistry()
    fleet = Fleet(dim=dim, k=k, cap_min=cap_min, cap_max=cap_max,
                  pool_sessions=pool_sessions, metrics=metrics)
    t0 = time.perf_counter()
    for tid in range(tenants):
        fleet.admit(tid)
    admit_s = (time.perf_counter() - t0) / tenants

    key = jax.random.PRNGKey(seed)
    round_walls = []
    for step in range(steps):
        key, kx, ky, kt = jax.random.split(key, 4)
        X = jax.random.normal(kx, (tenants, dim), jnp.float32)
        y = jax.random.bernoulli(ky, 0.5, (tenants,)).astype(jnp.int32)
        tau = jax.random.uniform(kt, (tenants,), dtype=jnp.float32)
        items = {tid: (X[tid], y[tid], tau[tid]) for tid in range(tenants)}
        t0 = time.perf_counter()
        out = fleet.observe(items)
        jax.block_until_ready(list(out.values()))
        round_walls.append(time.perf_counter() - t0)
    migrations = int(
        metrics.counter("fleet_migrations_total",
                        mode="classification").value)

    t0 = time.perf_counter()
    for tid in range(tenants):
        fleet.retire(tid)
    retire_s = (time.perf_counter() - t0) / tenants

    walls = np.asarray(round_walls)
    row = {
        "bench_kind": "fleet_lifecycle",
        "tenants": tenants,
        "steps": steps,
        "buckets": list(fleet.buckets),
        "pool_sessions": pool_sessions,
        "host_cores": _host_cores(),
        "admit_s_per_tenant": admit_s,
        "retire_s_per_tenant": retire_s,
        "migrations": migrations,
        # steady rounds vs rounds that absorbed a migration/compile:
        # the median is the serve cost, the max bounds one repad
        "observe_round_p50_s": float(np.percentile(walls, 50)),
        "observe_round_max_s": float(walls.max()),
    }
    print(f"[fleet_bench] lifecycle {tenants} tenants: admit "
          f"{admit_s * 1e6:.0f}us retire {retire_s * 1e6:.0f}us  "
          f"{migrations} migrations  round p50 "
          f"{row['observe_round_p50_s'] * 1e3:.2f}ms max "
          f"{row['observe_round_max_s'] * 1e3:.2f}ms")
    return [row]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual host devices to force (= max shards)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 1k-tenant ceiling, short sweeps")
    ap.add_argument("--tenants", type=int, default=0,
                    help="single tenant count instead of the sweep")
    args = ap.parse_args(argv)

    _ensure_devices(args.devices)
    if args.tenants:
        grid = (args.tenants,)
    elif args.quick:
        grid = (8, 64, 1024)
    else:
        # 1 -> 10k+ tenants; non-multiples of --devices only get the
        # 1-shard point. 1024 is also CI's quick smoke point, so the
        # committed curve carries a row its gate can compare against.
        grid = (1, 8, 64, 512, 1024, 2048, 10240)
    rows = run_scaling(grid, (1, args.devices),
                       chunks=1 if args.quick else 2,
                       lat_ticks=12 if args.quick else 24)
    rows += run_lifecycle(steps=36 if args.quick else 72)

    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import merge_bench_rows
    merge_bench_rows(args.out, rows, owned_prefixes=("fleet",))
    print(f"[fleet_bench] merged {len(rows)} fleet rows -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
