"""Paper Table 3 / Appendix H: does parallelism help CP?

The paper compared a Python multiprocessing pool against sequential loops.
The JAX-native analogue: sequential per-test-point evaluation (lax.map,
the paper's 'sequential') vs batched vmap evaluation (SIMD/MXU batching,
the 'parallel' strategy XLA compiles to one fused program). Same exact
algorithm, same outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.measures import knn as knn_m
from repro.data.synthetic import make_classification

N = 2048
M = 64
K = 15


@functools.partial(jax.jit, static_argnames=("k", "n_labels"))
def _pvalues_vmapped(state, X_test, *, k, n_labels):
    labels = jnp.arange(n_labels, dtype=state.y.dtype)
    n = state.n

    def per_test(x_t):
        d = jnp.sqrt(jnp.maximum(
            jnp.sum((state.X - x_t[None]) ** 2, axis=-1), 0.0))

        def per_label(y_hat):
            alphas = knn_m._updated_scores(state, d, y_hat, False)
            alpha = knn_m._candidate_score(state, d, y_hat, k, False)
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(labels)

    return jax.vmap(per_test)(X_test)  # vmap == 'parallel'


def run(n=N, m=M):
    rows = []
    X, y = make_classification(n_samples=n + m, n_features=30, seed=0)
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.int32)
    st = knn_m.fit(X[:n], y[:n], k=K)
    Xte = X[n:]

    t_seq = timeit(knn_m.pvalues_optimized, st, Xte, k=K, simplified=False,
                   n_labels=2)  # lax.map == sequential
    t_par = timeit(_pvalues_vmapped, st, Xte, k=K, n_labels=2)
    rows.append(row("table3/knn_optimized/sequential", f"n={n},m={m}",
                    t_seq, ""))
    rows.append(row("table3/knn_optimized/parallel", f"n={n},m={m}",
                    t_par, f"speedup={t_seq / max(t_par, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
