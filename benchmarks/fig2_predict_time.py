"""Paper Figure 2: prediction time per test point vs n — standard full CP,
optimized full CP (ours), and ICP, per nonconformity measure.

The paper's headline: optimized CP turns O(n^2 l) per prediction into
O(n l) and lands within a small factor of ICP. Scaled to CPU-feasible n;
the asymptotic slopes (not absolute times) are what reproduces Figure 2.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m
from repro.core import icp as icp_m
from repro.data.synthetic import make_classification

N_GRID = (64, 256, 1024, 4096)
M_TEST = 8
K = 15
H = 1.0
RHO = 1.0


def run(n_grid=N_GRID, include_standard=True):
    rows = []
    for n in n_grid:
        X, y = make_classification(n_samples=n + M_TEST, n_features=30,
                                   seed=0)
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.int32)
        Xtr, ytr, Xte = X[:n], y[:n], X[n:]
        Y = 2.0 * ytr.astype(jnp.float32) - 1.0

        # ---- k-NN family -------------------------------------------------
        for simplified, name in ((False, "knn"), (True, "simplified_knn")):
            if include_standard and n <= 1024:
                t = timeit(knn_m.pvalues_standard, Xtr, ytr, Xte,
                           k=K, simplified=simplified, n_labels=2)
                rows.append(row(f"fig2/{name}/standard", f"n={n}",
                                t / M_TEST, "O(n^2 l) per point"))
            st = knn_m.fit(Xtr, ytr, k=K)
            t = timeit(knn_m.pvalues_optimized, st, Xte, k=K,
                       simplified=simplified, n_labels=2)
            rows.append(row(f"fig2/{name}/optimized", f"n={n}",
                            t / M_TEST, "O(n l) per point"))
            ist = icp_m.fit_knn(Xtr, ytr, k=K, simplified=simplified,
                                t=n // 2)
            t = timeit(icp_m.pvalues_knn, ist, Xte, k=K,
                       simplified=simplified, n_labels=2)
            rows.append(row(f"fig2/{name}/icp", f"n={n}", t / M_TEST,
                            "O((t + n - t) l)"))

        # ---- KDE ----------------------------------------------------------
        if include_standard and n <= 1024:
            t = timeit(kde_m.pvalues_standard, Xtr, ytr, Xte, h=H,
                       p_dim=30, n_labels=2)
            rows.append(row("fig2/kde/standard", f"n={n}", t / M_TEST,
                            "O(P_K n^2 l)"))
        st = kde_m.fit(Xtr, ytr, h=H, n_labels=2)
        t = timeit(kde_m.pvalues_optimized, st, Xte, h=H, p_dim=30,
                   n_labels=2)
        rows.append(row("fig2/kde/optimized", f"n={n}", t / M_TEST,
                        "O(P_K n l)"))
        ist = icp_m.fit_kde(Xtr, ytr, h=H, p_dim=30, n_labels=2, t=n // 2)
        t = timeit(icp_m.pvalues_kde, ist, Xte, h=H, p_dim=30, n_labels=2)
        rows.append(row("fig2/kde/icp", f"n={n}", t / M_TEST, ""))

        # ---- LS-SVM (linear kernel) ---------------------------------------
        if include_standard and n <= 256:
            t = timeit(lssvm_m.pvalues_standard, Xtr, Y, Xte, rho=RHO)
            rows.append(row("fig2/lssvm/standard", f"n={n}", t / M_TEST,
                            "O(n^{w+1} l)"))
        st = lssvm_m.fit(Xtr, Y, RHO)
        t = timeit(lssvm_m.pvalues_optimized, st, Xte)
        rows.append(row("fig2/lssvm/optimized", f"n={n}", t / M_TEST,
                        "O(q^3 + n q) per point"))
        ist = icp_m.fit_lssvm(Xtr, Y, RHO, t=n // 2)
        t = timeit(icp_m.pvalues_lssvm, ist, Xte)
        rows.append(row("fig2/lssvm/icp", f"n={n}", t / M_TEST, ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
