"""Paper Appendix C.5: the online IID test.

Standard k-NN CP recomputes every p-value from scratch: O(n^3) for an
n-step stream. The incremental&decremental state makes each step O(n) —
O(n^2) total. Measures whole-stream cost at growing T.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import online
from repro.core.measures import knn as knn_m
from repro.data.synthetic import make_classification


def _stream_standard(X, y, k):
    """O(n^3): refit + rescore from scratch at every step."""
    ps = []
    for i in range(k + 2, X.shape[0]):
        st = knn_m.fit(X[:i], y[:i], k=k)
        alphas, alpha = knn_m.scores_optimized(
            st, X[i], y[i], k=k, simplified=True)
        ps.append((jnp.sum(alphas >= alpha) + 1.0) / (i + 1.0))
    return jnp.stack(ps)


def run(t_grid=(64, 256, 1024)):
    rows = []
    for T in t_grid:
        X, y = make_classification(n_samples=T, n_features=10, seed=0)
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.int32)
        t_inc = timeit(online.run_stream, X, y, k=7,
                       key=jax.random.PRNGKey(0))
        rows.append(row("online/incremental", f"T={T}", t_inc,
                        "O(T^2) whole stream"))
        if T <= 256:
            t_std = timeit(_stream_standard, X, y, 7)
            rows.append(row("online/standard", f"T={T}", t_std,
                            f"O(T^3); speedup="
                            f"{t_std / max(t_inc, 1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
