"""Online exchangeability testing (paper Section 9 / Vovk et al. 2003).

    PYTHONPATH=src python examples/online_change_detection.py

Streams observations through the incremental&decremental k-NN CP
(each step O(n) instead of the O(n^2) from-scratch recomputation — the
paper's App. C.5 speedup), converts smoothed p-values into a mixture
exchangeability martingale, and flags the injected change point.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online
from repro.data.synthetic import make_classification


def main():
    T, change_at = 400, 250
    Xa, ya = make_classification(n_samples=change_at, n_features=8, seed=0)
    Xb, yb = make_classification(n_samples=T - change_at, n_features=8,
                                 seed=1)
    Xb = Xb + 6.0  # covariate shift
    X = jnp.asarray(np.concatenate([Xa, Xb]), jnp.float32)
    y = jnp.asarray(np.concatenate([ya, yb]), jnp.int32)

    pvals, logm = online.run_stream(X, y, k=7, key=jax.random.PRNGKey(0))
    logm = np.asarray(logm)

    # detection: first time log M exceeds log(100) (Ville: false alarm
    # probability <= 1/100 under exchangeability)
    thresh = np.log(100.0)
    hits = np.flatnonzero(logm > thresh)
    detected = int(hits[0]) if hits.size else None

    print(f"stream length {T}, true change at {change_at}")
    for t in range(0, T, 50):
        bar = "#" * max(0, min(60, int(logm[t])))
        print(f"t={t:4d} log M = {logm[t]:8.2f} {bar}")
    print(f"max log-martingale: {logm.max():.1f} at t={logm.argmax()}")
    if detected is not None:
        print(f"change DETECTED at t={detected} "
              f"(delay {detected - change_at}), "
              f"false-alarm guarantee 1/100")
    else:
        print("no detection (unexpected)")
    pre = logm[change_at - 1]
    print(f"log M just before the change: {pre:.2f} "
          f"(stays ~0 under exchangeability)")


if __name__ == "__main__":
    main()
