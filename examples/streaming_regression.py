"""Streaming prediction intervals (paper Section 8.1, served online).

    PYTHONPATH=src python examples/streaming_regression.py

Feeds several tenants' regression streams through the multi-tenant
``RegressionServingEngine`` — each tick is the paper's incremental (and,
once the sliding window fills, decremental) k-NN regression update, one
vmapped jitted dispatch for all tenants — then reads exact full-CP
prediction intervals and checks empirical coverage. The served intervals
are bit-identical to refitting ``core.regression`` from scratch on each
window; the engine just never pays the refit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.regression import RegressionServingEngine


def main():
    S, T, dim, k, window, eps = 4, 300, 2, 7, 128, 0.2
    key = jax.random.PRNGKey(0)
    kw, kx, kn = jax.random.split(key, 3)

    # tenant s observes y = <w_s, x> + noise
    W = jax.random.normal(kw, (S, dim), jnp.float32)
    X = jax.random.normal(kx, (S, T, dim), jnp.float32)
    y = jnp.einsum("sd,std->st", W, X) \
        + 0.1 * jax.random.normal(kn, (S, T), jnp.float32)

    eng = RegressionServingEngine(n_sessions=S, capacity=window + 1,
                                  dim=dim, k=k, window=window)
    state = eng.init_state()

    hits = np.zeros(S)
    total = 0
    for t in range(T):
        if t >= window:  # price the next point before learning it
            iv = np.asarray(eng.intervals(state, X[:, t][:, None], eps))
            yt = np.asarray(y[:, t])
            hits += (yt >= iv[:, 0, 0]) & (yt <= iv[:, 0, 1])
            total += 1
        tau = eng.taus(jax.random.fold_in(key, t))
        state, _ = eng.observe(state, X[:, t], y[:, t], tau)

    iv = np.asarray(eng.intervals(state, X[:, -8:][0], eps))
    print(f"[streaming_regression] {S} tenants x {T} steps "
          f"(window {window}, eps {eps})")
    for s in range(S):
        cov = hits[s] / total
        print(f"  tenant {s}: coverage {cov:.3f} (target >= {1 - eps:.2f}),"
              f" last interval [{iv[s, -1, 0]:7.2f}, {iv[s, -1, 1]:7.2f}]")
    assert (hits / total >= 1 - eps - 0.08).all(), hits / total
    print("[streaming_regression] OK — streamed intervals cover")


if __name__ == "__main__":
    main()
