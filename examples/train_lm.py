"""End-to-end training driver: train a reduced-family LM for a few hundred
steps on CPU with the full production runtime (checkpointing, restart,
straggler monitor), then attach a conformal OOD head to the trained model.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b \\
        --steps 300 --batch 8 --seq-len 128

The full-scale configs run the same code path on the production mesh; this
drives the reduced config end-to-end. Expect the loss to fall well below
the unigram entropy as the model learns the stream's echo structure.
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
from repro.core.lm_conformal import ConformalOodDetector, sequence_embedding
from repro.data.lm_pipeline import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import OptimizerConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = cfgs.get(args.arch).reduced()
    mesh = make_host_mesh(1, 1)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=max(50, args.steps // 4),
        ckpt_dir=args.ckpt_dir, log_every=max(10, args.steps // 10),
        batch=args.batch, seq_len=args.seq_len)
    ocfg = OptimizerConfig(peak_lr=1e-3, end_lr=1e-4,
                           warmup_steps=args.steps // 20,
                           total_steps=args.steps)
    out = Trainer(cfg, tcfg, mesh, ocfg).run()
    print(f"\nloss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps")

    if "final_params" not in out:
        return
    params = out["final_params"]

    # conformal head on the trained model: calibrate on in-distribution
    # traffic, then score clean vs corrupted requests
    stream = TokenStream(cfg, 256, args.seq_len, seed=7)
    calib = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    emb_fn = jax.jit(lambda p, b: sequence_embedding(p, cfg, b, lm))
    det = ConformalOodDetector(k=7).fit(emb_fn(params, calib))

    test = {k: jnp.asarray(v) for k, v in stream.batch_at(1).items()}
    p_in = np.asarray(det.pvalues(emb_fn(params, test)))
    corrupted = dict(test)
    corrupted["tokens"] = jax.random.randint(
        jax.random.PRNGKey(0), test["tokens"].shape, 0, cfg.vocab_size,
        dtype=jnp.int32)
    p_out = np.asarray(det.pvalues(emb_fn(params, corrupted)))
    print(f"conformal OOD head (trained embeddings): "
          f"mean p in-dist={p_in.mean():.3f} (uniform-ish), "
          f"corrupted={p_out.mean():.3f} (small)")
    print(f"flagged at eps=0.1: in-dist {np.mean(p_in <= 0.1):.2%} "
          f"(guarantee: <= 10%), corrupted {np.mean(p_out <= 0.1):.2%}")


if __name__ == "__main__":
    main()
