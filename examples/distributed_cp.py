"""Multi-device full CP serving (paper technique x the production mesh).

    PYTHONPATH=src python examples/distributed_cp.py

Runs this host with 8 placeholder devices, shards a calibration set across
a (4 data x 2 model) mesh — rows over "data", queries over "model" — and
serves exact full-CP p-values with ONE scalar psum per (query, label),
verifying bit-equality against the single-device optimized path. The same
code drives the 512-chip production mesh (core/distributed.py).
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import distributed as dist  # noqa: E402
from repro.core.measures import knn as knn_m  # noqa: E402
from repro.data.synthetic import make_classification  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    n, m = 20_000, 16
    X, y = make_classification(n_samples=n + m, n_features=30, seed=0)
    X = X.astype(np.float32)
    Xtr, ytr, Xte = X[:n], y[:n].astype(np.int32), X[n:]

    t0 = time.perf_counter()
    state = knn_m.fit(jnp.asarray(Xtr), jnp.asarray(ytr), k=15)
    jax.block_until_ready(state.best_same)
    print(f"fit O(n^2) calibration (n={n}): "
          f"{time.perf_counter() - t0:.2f}s")

    ref = np.asarray(knn_m.pvalues_optimized(
        state, jnp.asarray(Xte), k=15, simplified=False, n_labels=2))

    cfg = dist.CpShardingConfig(row_axes=("data",), query_axis="model")
    st_sh = dist.shard_knn_state(state, mesh, cfg)
    fn = dist.make_knn_pvalues_fn(mesh, k=15, simplified=False, n_labels=2,
                                  cfg=cfg)
    Xte_sh = jax.device_put(jnp.asarray(Xte),
                            NamedSharding(mesh, P("model", None)))
    out = fn(st_sh, Xte_sh)  # compile
    t0 = time.perf_counter()
    out = np.asarray(fn(st_sh, Xte_sh))
    dt = time.perf_counter() - t0
    print(f"sharded predict: {m} queries x 2 labels in {dt * 1e3:.1f}ms "
          f"({n // 4} rows/device)")
    print(f"max |sharded - single-device| = {np.abs(out - ref).max():.2e} "
          f"(exact)")
    print(f"p-values for first 4 queries:\n{np.round(out[:4], 4)}")


if __name__ == "__main__":
    main()
