"""Quickstart: exact optimized full CP vs naive full CP vs ICP.

    PYTHONPATH=src python examples/quickstart.py

Fits every optimized measure on synthetic data, verifies the p-values are
IDENTICAL to the naive full-CP algorithm (the paper's exactness claim),
times both, and prints coverage/fuzziness at eps = 0.1.
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import pvalues as pv
from repro.core import regression as reg
from repro.core.predictor import (ConformalClassifier,
                                  InductiveConformalClassifier)
from repro.data.synthetic import (make_classification, make_regression,
                                  train_test_split)


def main():
    X, y = make_classification(n_samples=600, n_features=30, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X.astype(np.float32), y, 0.1)
    eps = 0.1

    print(f"train n={len(Xtr)}, test m={len(Xte)}, eps={eps}\n")
    print(f"{'measure':16s} {'exact?':7s} {'t_std':>9s} {'t_opt':>9s} "
          f"{'speedup':>8s} {'coverage':>9s} {'avg set':>8s} {'fuzz':>7s}")

    for measure in ("knn", "simplified_knn", "kde", "lssvm"):
        opt = ConformalClassifier(measure=measure, n_labels=2).fit(Xtr, ytr)
        std = ConformalClassifier(measure=measure, n_labels=2,
                                  optimized=False).fit(Xtr, ytr)
        t0 = time.perf_counter()
        p_opt = opt.predict_pvalues(Xte)
        p_opt.block_until_ready()
        t_opt = time.perf_counter() - t0
        t0 = time.perf_counter()
        p_std = std.predict_pvalues(Xte[:8])  # naive is O(n^2 l m): sample
        p_std.block_until_ready()
        t_std = (time.perf_counter() - t0) * len(Xte) / 8
        exact = bool(np.allclose(np.asarray(p_opt[:8]), np.asarray(p_std),
                                 atol=1e-5))
        cov, size = pv.coverage(p_opt, jnp.asarray(yte), eps)
        fz = float(jnp.mean(pv.fuzziness(p_opt)))
        print(f"{measure:16s} {str(exact):7s} {t_std:9.3f} {t_opt:9.3f} "
              f"{t_std / t_opt:7.1f}x {float(cov):9.3f} "
              f"{float(size):8.2f} {fz:7.4f}")

    icp = InductiveConformalClassifier(measure="knn", n_labels=2).fit(
        Xtr, ytr)
    p_icp = icp.predict_pvalues(Xte)
    cov, size = pv.coverage(p_icp, jnp.asarray(yte), eps)
    print(f"{'icp (baseline)':16s} {'n/a':7s} {'-':>9s} {'-':>9s} "
          f"{'-':>8s} {float(cov):9.3f} {float(size):8.2f} "
          f"{float(jnp.mean(pv.fuzziness(p_icp))):7.4f}")

    # regression
    Xr, yr = make_regression(n_samples=400, n_features=20, seed=1)
    Xr = Xr.astype(np.float32)
    yr = yr.astype(np.float32)
    st = reg.fit(Xr[:360], yr[:360], k=7)
    iv = np.asarray(reg.intervals_optimized(st, Xr[360:], k=7, epsilon=0.1))
    hit = np.mean((yr[360:] >= iv[:, 0]) & (yr[360:] <= iv[:, 1]))
    print(f"\nregression: k-NN CP intervals cover {hit:.3f} "
          f"(target >= 0.9), median width "
          f"{np.median(iv[:, 1] - iv[:, 0]):.2f}")


if __name__ == "__main__":
    main()
