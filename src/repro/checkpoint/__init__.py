"""Fault-tolerant checkpointing (async, atomic, elastic restore)."""
from repro.checkpoint.store import CheckpointStore

__all__ = ["CheckpointStore"]
