"""Fault-tolerant checkpointing: sharded npz store, async writes, elastic
restore.

Layout (one directory per step)::

    <root>/step_000420/
        manifest.json         # tree structure, shapes, dtypes, step, config
        shard_00000.npz       # flattened leaves (chunked by byte budget)
        shard_00001.npz
        ...
        COMMITTED             # written LAST: crash-safe commit marker

Design points for the 1000+-node target (DESIGN.md §fault-tolerance):

* atomic commit — a step directory without COMMITTED is garbage-collected
  on restore, so a preempted writer can never corrupt the latest state;
* async — ``save`` snapshots leaves to host RAM and hands off to a writer
  thread; training resumes immediately (double-buffered: at most one
  outstanding save);
* elastic restore — the manifest stores *logical* arrays; ``restore``
  re-places them under any mesh/sharding (device count may change between
  runs), which is what lets a job restart on a resized slice;
* integrity — per-shard checksums in the manifest, verified on restore.

On a real multi-host pod each host would write only its addressable shards
(process-local slice of each array); on this single-process container that
specializes to whole arrays, same code path.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024  # target bytes per shard file
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def _tree_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3, *, injector=None):
        self.root = root
        self.keep = keep
        #: optional ``robustness.faults.FaultInjector`` — the chaos
        #: harness's hook into the write path (sites ``store.write``,
        #: ``store.shard``, ``store.manifest``, ``store.commit``).
        #: ``None`` in production; injection points are no-ops then.
        self.injector = injector
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False,
             extra: dict | None = None):
        """Snapshot to host RAM, then write in a background thread."""
        self.wait()  # at most one outstanding save (double buffer)
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        leaves, treedef = _tree_paths(tree)
        host = [np.asarray(x) for x in leaves]  # sync device->host copy
        treedef_str = str(treedef)

        def write():
            try:
                self._write(step, host, treedef_str, extra or {})
            except Exception as e:  # noqa: BLE001 — surfaced on next save
                self._error = e

        if blocking:
            write()
            if self._error is not None:
                err, self._error = self._error, None
                raise err
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host_leaves, treedef_str: str, extra: dict):
        inj = self.injector
        if inj is not None:
            inj.enter("store.write", step)
        d = _step_dir(self.root, step)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        shards, cur, cur_bytes = [], [], 0
        for i, arr in enumerate(host_leaves):
            cur.append(i)
            cur_bytes += arr.nbytes
            if cur_bytes >= _SHARD_BYTES:
                shards.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            shards.append(cur)

        manifest = {
            "step": step,
            "treedef": treedef_str,
            "n_leaves": len(host_leaves),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in host_leaves],
            "shards": [],
            "extra": extra,
            "time": time.time(),
        }
        for si, idxs in enumerate(shards):
            fname = f"shard_{si:05d}.npz"
            path = os.path.join(tmp, fname)
            if inj is not None:
                inj.enter("store.shard", step)
            np.savez(path, **{str(i): host_leaves[i] for i in idxs})
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if inj is not None:
                # AFTER checksumming: a torn/corrupted write the writer
                # itself cannot see — restore's verify catches it
                inj.mutate_file("store.shard", step, path)
                digest = inj.mutate_digest("store.manifest", step, digest)
            manifest["shards"].append(
                {"file": fname, "leaves": idxs, "sha256": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if inj is not None:
            inj.enter("store.commit", step)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(step))
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def committed_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.root)):
            m = _STEP_RE.match(name)
            if not m:
                continue
            if os.path.exists(os.path.join(self.root, name, "COMMITTED")):
                out.append(int(m.group(1)))
            else:  # uncommitted garbage from a preempted writer
                shutil.rmtree(os.path.join(self.root, name),
                              ignore_errors=True)
        return out

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: int) -> dict:
        """Manifest of a committed step (tree structure, leaf shapes/dtypes,
        ``extra`` metadata) — the public view of the on-disk layout."""
        with open(os.path.join(_step_dir(self.root, step),
                               "manifest.json")) as f:
            return json.load(f)

    def discard(self, step: int) -> None:
        """Drop a step's directory (and any half-written tmp) so
        ``latest_step`` can never point at it — the saver calls this
        after exhausting retries on a failed write. Never raises."""
        shutil.rmtree(_step_dir(self.root, step), ignore_errors=True)
        shutil.rmtree(_step_dir(self.root, step) + ".tmp",
                      ignore_errors=True)

    def restore(self, like_tree, step: int | None = None, *,
                shardings=None, verify: bool = True, on_fallback=None):
        """Restore into the structure of ``like_tree``; re-place on any
        sharding (elastic: the saved mesh need not match).

        ``like_tree`` may be a callable ``manifest -> tree`` so the
        target structure can be rebuilt per candidate step (geometry
        may differ across steps). With ``step=None`` a corrupted latest
        step (unreadable manifest, checksum mismatch, torn shard) FALLS
        BACK to the previous COMMITTED step — ``on_fallback(step, exc)``
        fires per skipped step — instead of raising while valid older
        snapshots sit on disk. An explicit ``step`` still raises: the
        caller asked for that step, not whichever one survives.
        """
        if step is not None:
            return self._restore_step(like_tree, step,
                                      shardings=shardings, verify=verify)
        steps = self.committed_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints in "
                                    f"{self.root}")
        last_err = None
        for s in reversed(steps):
            try:
                return self._restore_step(like_tree, s,
                                          shardings=shardings,
                                          verify=verify)
            except Exception as e:  # noqa: BLE001 — walk-back, re-raised
                last_err = e
                if on_fallback is not None:
                    on_fallback(s, e)
        raise IOError(
            f"all {len(steps)} committed step(s) in {self.root} failed "
            f"to restore; last error: {last_err}") from last_err

    def _restore_step(self, like_tree, step: int, *, shardings=None,
                      verify: bool = True):
        d = _step_dir(self.root, step)
        manifest = self.read_manifest(step)
        if callable(like_tree):
            like_tree = like_tree(manifest)
        leaves, treedef = _tree_paths(like_tree)
        if len(leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves; target tree "
                f"has {len(leaves)} — structure changed?")
        host = [None] * manifest["n_leaves"]
        for sh in manifest["shards"]:
            path = os.path.join(d, sh["file"])
            if verify:
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != sh["sha256"]:
                    raise IOError(f"checksum mismatch in {path}")
            with np.load(path) as z:
                for i in sh["leaves"]:
                    host[i] = z[str(i)]
        shard_list = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(host))
        out = []
        for tgt, arr, shd in zip(leaves, host, shard_list):
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch: ckpt {arr.shape} vs target "
                    f"{tgt.shape}")
            arr = arr.astype(tgt.dtype)
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step


__all__ = ["CheckpointStore"]
