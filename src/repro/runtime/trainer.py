"""Fault-tolerant training runtime.

The loop a pod-scale deployment needs, specialized to whatever mesh exists
at runtime (512-device dry-run mesh or the 1-device CPU smoke mesh):

* checkpoint/restart — async atomic checkpoints every ``ckpt_every`` steps;
  on start the trainer restores the latest committed step and resumes from
  the right position in the deterministic data stream (no data state to
  save);
* preemption handling — SIGTERM/SIGINT set a flag; the loop finishes the
  current step, writes a blocking checkpoint, and exits cleanly (what a
  TPU maintenance event gives you ~30s to do);
* straggler/hang mitigation — per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x EWMA are logged with the step index (on real pods
  this feeds the controller that decides to restart a slow host); a hard
  ``step_timeout_s`` turns a wedged collective into a crash that the
  restart path recovers, instead of an indefinite hang;
* elastic scaling — restore() re-places arrays on the current mesh, so the
  same checkpoint resumes on a different device count (data layout is
  logical, see checkpoint/store.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs.base import ArchConfig
from repro.data.lm_pipeline import TokenStream
from repro.models import lm
from repro.optim import OptimizerConfig, init_opt_state
from repro.launch.steps import make_train_step
from repro.sharding import batch_pspecs, named, param_pspecs
from repro.sharding.activation import activation_mesh


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    batch: int = 8
    seq_len: int = 256
    microbatches: int = 1
    straggler_factor: float = 3.0
    step_timeout_s: float = 600.0
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig, mesh,
                 opt_cfg: OptimizerConfig | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or OptimizerConfig(
            total_steps=tcfg.steps, warmup_steps=max(1, tcfg.steps // 20))
        self.store = CheckpointStore(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self.stream = TokenStream(cfg, tcfg.batch, tcfg.seq_len,
                                  seed=tcfg.seed)
        self._preempted = False
        self._ewma = None
        self.stats_log: list = []

    # -- lifecycle -----------------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):  # noqa: ARG001
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:  # non-main thread (tests)
                pass

    def init_state(self):
        params = lm.init_lm(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        opt_state = init_opt_state(params, self.opt_cfg)
        pspecs = named(param_pspecs(params, self.mesh), self.mesh)
        ospecs = named(param_pspecs(opt_state, self.mesh), self.mesh)
        params = jax.tree.map(jax.device_put, params,
                              pspecs)
        opt_state = jax.tree.map(jax.device_put, opt_state, ospecs)
        return params, opt_state, (pspecs, ospecs)

    def restore_or_init(self):
        params, opt_state, (pspecs, ospecs) = self.init_state()
        start = 0
        latest = self.store.latest_step()
        if latest is not None:
            (params, opt_state), _ = self.store.restore(
                (params, opt_state), latest,
                shardings=(pspecs, ospecs))
            start = latest
            print(f"[trainer] restored step {latest} from "
                  f"{self.tcfg.ckpt_dir}")
        return params, opt_state, start

    # -- the loop --------------------------------------------------------------

    def run(self) -> dict:
        self._install_signal_handlers()
        t = self.tcfg
        params, opt_state, start = self.restore_or_init()
        step_fn = make_train_step(self.cfg, self.opt_cfg, t.microbatches)
        with self.mesh, activation_mesh(self.mesh):
            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

            losses = []
            for step in range(start, t.steps):
                batch = {k: jax.device_put(v)
                         for k, v in self.stream.batch_at(step).items()}
                t0 = time.time()
                params, opt_state, stats = jit_step(params, opt_state,
                                                    batch)
                loss = float(stats["loss"])  # sync point (device barrier)
                dt = time.time() - t0
                if not np.isfinite(loss):
                    raise FloatingPointError(
                        f"non-finite loss at step {step}: {loss}")
                losses.append(loss)

                # straggler detection (per-step EWMA)
                if self._ewma is None:
                    self._ewma = dt
                slow = dt > self.tcfg.straggler_factor * self._ewma
                if slow and step > start + 3:
                    print(f"[trainer] STRAGGLER step {step}: {dt:.2f}s vs "
                          f"EWMA {self._ewma:.2f}s")
                if dt > self.tcfg.step_timeout_s:
                    raise TimeoutError(
                        f"step {step} exceeded {t.step_timeout_s}s")
                self._ewma = 0.9 * self._ewma + 0.1 * dt

                if step % t.log_every == 0 or step == t.steps - 1:
                    rec = {"step": step, "loss": loss,
                           "lr": float(stats["lr"]),
                           "grad_norm": float(stats["grad_norm"]),
                           "sec": round(dt, 3)}
                    self.stats_log.append(rec)
                    print(f"[trainer] {rec}")

                if (step + 1) % t.ckpt_every == 0:
                    self.store.save(step + 1, (params, opt_state))

                if self._preempted:
                    print(f"[trainer] preemption: checkpointing step "
                          f"{step + 1} and exiting")
                    self.store.save(step + 1, (params, opt_state),
                                    blocking=True)
                    return {"losses": losses, "preempted": True,
                            "stop_step": step + 1}

            self.store.save(t.steps, (params, opt_state), blocking=True)
        return {"losses": losses, "preempted": False,
                "stop_step": t.steps, "final_params": params}


__all__ = ["Trainer", "TrainerConfig"]
