"""Fault-tolerant training/serving runtime."""
from repro.runtime.trainer import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig"]
