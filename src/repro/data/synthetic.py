"""Synthetic dataset generators (sklearn re-implementations, offline).

``make_classification`` follows the sklearn recipe: class centroids on the
vertices of a hypercube in an ``n_informative``-dim subspace, random linear
mixing into redundant features, gaussian noise. ``make_regression`` draws a
random (sparse) linear model. Both are deterministic in ``seed``.
"""
from __future__ import annotations

import numpy as np


def make_classification(
    n_samples: int = 100,
    n_features: int = 30,
    n_informative: int = 10,
    n_classes: int = 2,
    class_sep: float = 1.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    n_informative = min(n_informative, n_features)
    y = rng.integers(0, n_classes, size=n_samples)
    # class centroids: random hypercube vertices scaled by class_sep
    centroids = (rng.integers(0, 2, size=(n_classes, n_informative)) * 2 - 1).astype(
        np.float64
    ) * class_sep
    X_inf = rng.standard_normal((n_samples, n_informative)) + centroids[y]
    if n_features > n_informative:
        # redundant/noise features: random linear combos + pure noise
        n_extra = n_features - n_informative
        mix = rng.standard_normal((n_informative, n_extra))
        X_extra = X_inf @ mix * 0.3 + rng.standard_normal((n_samples, n_extra))
        X = np.concatenate([X_inf, X_extra], axis=1)
    else:
        X = X_inf
    perm = rng.permutation(n_features)
    return X[:, perm].astype(np.float64), y.astype(np.int32)


def make_regression(
    n_samples: int = 100,
    n_features: int = 30,
    n_informative: int = 10,
    noise: float = 1.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    n_informative = min(n_informative, n_features)
    X = rng.standard_normal((n_samples, n_features))
    w = np.zeros(n_features)
    w[:n_informative] = rng.standard_normal(n_informative) * 10.0
    y = X @ w + noise * rng.standard_normal(n_samples)
    return X.astype(np.float64), y.astype(np.float64)


def train_test_split(X, y, test_frac: float = 0.3, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(X)
    perm = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    return X[tr], y[tr], X[te], y[te]
