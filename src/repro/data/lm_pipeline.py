"""Deterministic synthetic LM data pipeline.

Stream of (tokens, labels) batches from a seeded Zipf-ish token source with
local n-gram structure (so a small model actually has something to learn in
the end-to-end example). Properties the runtime relies on:

* stateless indexing — batch ``i`` is a pure function of (seed, i), so a
  restored job resumes mid-stream with no data-state checkpointing beyond
  the step counter (the standard deterministic-input-pipeline trick);
* per-host sharding — each data-parallel host materializes only its slice
  (host_id, num_hosts);
* frontends — vlm/audio variants attach deterministic stub patch/frame
  embeddings matching input_specs().
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig


class TokenStream:
    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert batch % num_hosts == 0
        self.cfg = cfg
        self.global_batch = batch
        self.local_batch = batch // num_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        v = cfg.vocab_size
        # frequency-ranked vocab (Zipf alpha=1.1); markov-ish bigram mixing
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (ranks ** -1.1)
        self._probs /= self._probs.sum()
        self._shift = rng.integers(1, v - 1)

    def batch_at(self, index: int) -> dict:
        """Batch ``index`` (pure function of (seed, index, host))."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + index) * 4099 + self.host_id)
        B, S = self.local_batch, self.seq_len
        base = rng.choice(
            self.cfg.vocab_size, size=(B, S + 1), p=self._probs)
        # inject predictable structure: every other token echoes prev+shift
        echo = (base[:, :-1] + self._shift) % self.cfg.vocab_size
        mask = rng.random((B, S)) < 0.5
        seq = base[:, 1:].copy()
        seq[mask] = echo[mask]
        tokens = np.concatenate([base[:, :1], seq], axis=1)
        out = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.cfg.frontend == "vision_stub":
            npz = self.cfg.n_frontend_tokens
            out["patch_embeds"] = rng.standard_normal(
                (B, npz, self.cfg.d_model)).astype(np.float32) * 0.02
            out["tokens"] = out["tokens"][:, :S - npz]
            out["labels"] = out["labels"][:, :S - npz]
        if self.cfg.is_encoder_decoder:
            out["frames"] = rng.standard_normal(
                (B, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return out

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


__all__ = ["TokenStream"]
