"""Dispatching wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run; elsewhere (this CPU container, unit
tests) the pure-jnp reference semantics from ``ref.py`` are used, with
``REPRO_PALLAS_INTERPRET=1`` forcing the Pallas interpret path so the kernel
bodies themselves are exercised end-to-end. float64 inputs (the CP exactness
path under x64) always use the reference — the MXU has no f64.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def sq_dists(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance matrix; Pallas-tiled on TPU."""
    if A.dtype == jnp.float64 or B.dtype == jnp.float64:
        return _ref.sq_dists(A, B)
    if _on_tpu() or _interpret():
        from repro.kernels.pairwise_dist import pairwise_sq_dists

        return pairwise_sq_dists(A, B, interpret=not _on_tpu()).astype(A.dtype)
    return _ref.sq_dists(A, B)


def kde_rowsums(A, B, y_A, y_B, h, exclude_diag=False):
    if A.dtype == jnp.float64:
        return _ref.kde_rowsums(A, B, y_A, y_B, h, exclude_diag)
    if _on_tpu() or _interpret():
        from repro.kernels.kde_score import kde_rowsums as _pallas

        return _pallas(
            A, B, y_A, y_B, h=float(h), exclude_diag=exclude_diag,
            interpret=not _on_tpu(),
        ).astype(A.dtype)
    return _ref.kde_rowsums(A, B, y_A, y_B, h, exclude_diag)


def cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha, n_labels):
    if X.dtype == jnp.float64:
        return _ref.cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha)
    if _on_tpu() or _interpret():
        from repro.kernels.cp_update import cp_knn_counts as _pallas

        return _pallas(
            X, y, sum_same, kth_same, X_test, alpha, n_labels=n_labels,
            interpret=not _on_tpu(),
        )
    return _ref.cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha)


def pallas_active(dtype=jnp.float32) -> bool:
    """True when the f32 kernels dispatch to Pallas (TPU or interpret).

    Callers that keep a bit-exact pure-jnp fallback (the streaming
    regression read path) use this to pick the fused route only where it
    actually runs as a kernel.
    """
    return dtype != jnp.float64 and (_on_tpu() or _interpret())


def interval_sweep(X, a_prime, kth_dist, kth_label, live, X_test, a_test, k):
    """Fused regression-CP critical points (lo, hi); Pallas on TPU."""
    if X.dtype == jnp.float64:
        return _ref.reg_interval_endpoints(
            X, a_prime, kth_dist, kth_label, live, X_test, a_test, k)
    if _on_tpu() or _interpret():
        from repro.kernels.interval_sweep import interval_sweep as _pallas

        return _pallas(
            X, a_prime, kth_dist, kth_label, live, X_test, a_test, k=k,
            interpret=not _on_tpu(),
        )
    return _ref.reg_interval_endpoints(
        X, a_prime, kth_dist, kth_label, live, X_test, a_test, k)


def stream_update(X, y, nbr_d, nbr_y, x_new, y_new, n, *, mode):
    """Fused streaming-observe front end: distance row + gated ordered
    k-best merge for one incoming point; Pallas on TPU.

    ``mode="class"`` (same-label gate, row-difference distances) serves
    ``core.online``; ``mode="reg"`` (k-th-distance gate, ``sq_dists``
    distances, labels ride along) serves ``regression.stream``.
    ``nbr_y=None`` (classification has no label lists) passes zeros
    through. Returns ``(d_row, nbr_d', nbr_y')`` in ``X.dtype``.
    """
    if nbr_y is None:
        nbr_y = jnp.zeros_like(nbr_d)
    if X.dtype == jnp.float64:
        return _ref.stream_update_fast(X, y, nbr_d, nbr_y, x_new, y_new, n,
                                       mode=mode)
    if _on_tpu() or _interpret():
        from repro.kernels.stream_update import stream_update as _pallas

        d, nd, ny = _pallas(X, y, nbr_d, nbr_y, x_new, y_new, n,
                            mode=mode, interpret=not _on_tpu())
        return (d.astype(X.dtype), nd.astype(nbr_d.dtype),
                ny.astype(nbr_y.dtype))
    # sortless form — bit-identical to _ref.stream_update, much faster
    # on CPU (no comparator sort); the parity tests pin the two together
    return _ref.stream_update_fast(X, y, nbr_d, nbr_y, x_new, y_new, n,
                                   mode=mode)


# past this many score elements per (batch, head), fall back to the chunked
# online-softmax path off-TPU so 32k/500k sequences stay memory-bounded
_DENSE_SCORE_LIMIT = 2048 * 2048


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None):
    if _on_tpu() or _interpret():
        from repro.kernels.flash_attention import flash_attention as _pallas

        return _pallas(q, k, v, causal=causal, window=window, scale=scale,
                       softcap=softcap, interpret=not _on_tpu())
    if q.shape[1] * k.shape[1] > _DENSE_SCORE_LIMIT:
        return _ref.chunked_attention(q, k, v, causal=causal, window=window,
                                      scale=scale, softcap=softcap)
    return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                scale=scale, softcap=softcap)
