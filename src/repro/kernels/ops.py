"""Dispatching wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run; elsewhere (this CPU container, unit
tests) the pure-jnp reference semantics from ``ref.py`` are used, with
``REPRO_PALLAS_INTERPRET=1`` forcing the Pallas interpret path so the kernel
bodies themselves are exercised end-to-end. float64 inputs (the CP exactness
path under x64) always use the reference — the MXU has no f64.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def sq_dists(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance matrix; Pallas-tiled on TPU."""
    if A.dtype == jnp.float64 or B.dtype == jnp.float64:
        return _ref.sq_dists(A, B)
    if _on_tpu() or _interpret():
        from repro.kernels.pairwise_dist import pairwise_sq_dists

        return pairwise_sq_dists(A, B, interpret=not _on_tpu()).astype(A.dtype)
    return _ref.sq_dists(A, B)


def kde_rowsums(A, B, y_A, y_B, h, exclude_diag=False):
    if A.dtype == jnp.float64:
        return _ref.kde_rowsums(A, B, y_A, y_B, h, exclude_diag)
    if _on_tpu() or _interpret():
        from repro.kernels.kde_score import kde_rowsums as _pallas

        return _pallas(
            A, B, y_A, y_B, h=float(h), exclude_diag=exclude_diag,
            interpret=not _on_tpu(),
        ).astype(A.dtype)
    return _ref.kde_rowsums(A, B, y_A, y_B, h, exclude_diag)


def cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha, n_labels):
    if X.dtype == jnp.float64:
        return _ref.cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha)
    if _on_tpu() or _interpret():
        from repro.kernels.cp_update import cp_knn_counts as _pallas

        return _pallas(
            X, y, sum_same, kth_same, X_test, alpha, n_labels=n_labels,
            interpret=not _on_tpu(),
        )
    return _ref.cp_knn_counts(X, y, sum_same, kth_same, X_test, alpha)


def pallas_active(dtype=jnp.float32) -> bool:
    """True when the f32 kernels dispatch to Pallas (TPU or interpret).

    Callers that keep a bit-exact pure-jnp fallback (the streaming
    regression read path) use this to pick the fused route only where it
    actually runs as a kernel.
    """
    return dtype != jnp.float64 and (_on_tpu() or _interpret())


def active_route(dtype=jnp.float32) -> dict:
    """Snapshot of the kernel dispatch route for reports/audits.

    Pure host-side introspection (no compilation, no device work) —
    recorded verbatim in the static-audit JSON report so a pass/fail is
    attributable to the backend that produced the HLO.
    """
    return {
        "backend": jax.default_backend(),
        "on_tpu": _on_tpu(),
        "interpret": _interpret(),
        "pallas_active": pallas_active(dtype),
        "f64_reference": dtype == jnp.float64,
    }


def interval_sweep(X, a_prime, kth_dist, kth_label, live, X_test, a_test, k):
    """Fused regression-CP critical points (lo, hi); Pallas on TPU."""
    if X.dtype == jnp.float64:
        return _ref.reg_interval_endpoints(
            X, a_prime, kth_dist, kth_label, live, X_test, a_test, k)
    if _on_tpu() or _interpret():
        from repro.kernels.interval_sweep import interval_sweep as _pallas

        return _pallas(
            X, a_prime, kth_dist, kth_label, live, X_test, a_test, k=k,
            interpret=not _on_tpu(),
        )
    return _ref.reg_interval_endpoints(
        X, a_prime, kth_dist, kth_label, live, X_test, a_test, k)


def stream_update(X, y, nbr_d, nbr_y, x_new, y_new, n, *, mode, head=None,
                  wrap=None):
    """Fused streaming-observe front end: distance row + gated ordered
    k-best merge for one incoming point; Pallas on TPU.

    ``mode="class"`` (same-label gate, row-difference distances) serves
    ``core.online``; ``mode="reg"`` (k-th-distance gate, ``sq_dists``
    distances, labels ride along) serves ``regression.stream``.
    ``nbr_y=None`` (classification has no label lists) passes zeros
    through. ``head``/``wrap`` (traced scalars or None) select the
    serving engines' ring-buffer slot layout — live slots
    ``(head + i) % wrap`` instead of ``[0, n)``. Returns
    ``(d_row, nbr_d', nbr_y')`` in ``X.dtype``.
    """
    if nbr_y is None:
        nbr_y = jnp.zeros_like(nbr_d)
    if X.dtype == jnp.float64:
        return _ref.stream_update_fast(X, y, nbr_d, nbr_y, x_new, y_new, n,
                                       mode=mode, head=head, wrap=wrap)
    if _on_tpu() or _interpret():
        from repro.kernels.stream_update import stream_update as _pallas

        d, nd, ny = _pallas(X, y, nbr_d, nbr_y, x_new, y_new, n,
                            mode=mode, interpret=not _on_tpu(), head=head,
                            wrap=wrap)
        return (d.astype(X.dtype), nd.astype(nbr_d.dtype),
                ny.astype(nbr_y.dtype))
    # sortless form — bit-identical to _ref.stream_update, much faster
    # on CPU (no comparator sort); the parity tests pin the two together
    return _ref.stream_update_fast(X, y, nbr_d, nbr_y, x_new, y_new, n,
                                   mode=mode, head=head, wrap=wrap)


def _pow2(v: int, lo: int = 8) -> int:
    n = lo
    while n < v:
        n *= 2
    return n


def boot_fit_forest(X, y, W, feat_choice, thr_u, *, n_labels, depth):
    """Stacked weighted extra-tree fits for the bootstrap measure.

    The production path on every backend is the vmapped jitted kernel in
    ``boot_forest.py`` (one dispatch trains the whole batch); the
    per-tree numpy oracle in ``ref.py`` is the semantics of record
    (``REPRO_BOOT_FOREST=ref`` forces it, e.g. to bisect a parity
    failure). Batch and row dims are padded to power-of-two buckets so
    the streaming updates (whose shapes drift every tick) reuse a handful
    of compiled programs — zero-weight rows and zero-weight trees are
    masked out of the fit, so padding is bit-neutral. Returns numpy
    ``(feat, thresh, leaf)``, each ``(S, n_nodes)`` — the bootstrap
    state lives on the host.
    """
    import numpy as np

    if os.environ.get("REPRO_BOOT_FOREST") == "ref":
        outs = [_ref.boot_fit_tree(X, y, W[s], feat_choice[s], thr_u[s],
                                   n_labels, depth)
                for s in range(W.shape[0])]
        return tuple(np.stack([o[i] for o in outs]) for i in range(3))
    from repro.kernels.boot_forest import fit_forest

    S, m = W.shape
    # tree batches vary tick-to-tick in the streaming updates; a high
    # floor pins the batch bucket so almost nothing ever recompiles
    Sp, mp = _pow2(S, 64), _pow2(m)
    Xp = np.zeros((mp, X.shape[1]), np.float32)
    Xp[:m] = X
    yp = np.zeros(mp, np.int32)
    yp[:m] = y
    Wp = np.zeros((Sp, mp), np.int32)
    Wp[:S, :m] = W
    nn = feat_choice.shape[1]
    fcp = np.zeros((Sp, nn), np.int32)
    fcp[:S] = feat_choice
    up = np.zeros((Sp, nn), np.float32)
    up[:S] = thr_u
    feat, thresh, leaf = fit_forest(
        jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(Wp),
        jnp.asarray(fcp), jnp.asarray(up), n_labels=n_labels, depth=depth)
    return (np.asarray(feat)[:S], np.asarray(thresh)[:S],
            np.asarray(leaf)[:S])


def boot_forest_predict(feat, thresh, leaf, Xq):
    """Labels (S, q) of S stacked extra-trees on query rows (q, p)."""
    import numpy as np

    if os.environ.get("REPRO_BOOT_FOREST") == "ref":
        return np.stack([_ref.boot_predict_tree(feat[s], thresh[s], leaf[s],
                                                Xq)
                         for s in range(feat.shape[0])])
    from repro.kernels.boot_forest import forest_predict

    S, q = feat.shape[0], Xq.shape[0]
    Sp, qp = _pow2(S, 64), _pow2(q)
    fp = np.full((Sp, feat.shape[1]), -1, np.int32)
    fp[:S] = feat
    tp = np.zeros((Sp, feat.shape[1]), np.float32)
    tp[:S] = thresh
    lp = np.zeros((Sp, feat.shape[1]), np.int32)
    lp[:S] = leaf
    Xp = np.zeros((qp, Xq.shape[1]), np.float32)
    Xp[:q] = Xq
    out = forest_predict(jnp.asarray(fp), jnp.asarray(tp),
                         jnp.asarray(lp), jnp.asarray(Xp))
    return np.asarray(out)[:S, :q]


# past this many score elements per (batch, head), fall back to the chunked
# online-softmax path off-TPU so 32k/500k sequences stay memory-bounded
_DENSE_SCORE_LIMIT = 2048 * 2048


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    softcap=None):
    if _on_tpu() or _interpret():
        from repro.kernels.flash_attention import flash_attention as _pallas

        return _pallas(q, k, v, causal=causal, window=window, scale=scale,
                       softcap=softcap, interpret=not _on_tpu())
    if q.shape[1] * k.shape[1] > _DENSE_SCORE_LIMIT:
        return _ref.chunked_attention(q, k, v, causal=causal, window=window,
                                      scale=scale, softcap=softcap)
    return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                scale=scale, softcap=softcap)
