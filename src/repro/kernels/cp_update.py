"""Fused CP score-update + p-value count kernel (Pallas, TPU).

This is the serving hot spot of the paper's optimized simplified-k-NN CP
(Section 3.1): for a block of test points, compute distances to all training
points (MXU), apply the O(1) incremental&decremental score update (paper
Fig. 1), compare against the candidate scores and accumulate the p-value
counts — all in one VMEM-resident pass. The naive sequence (distances ->
update -> count) round-trips two (m, n) matrices through HBM; fusing removes
both, roughly tripling arithmetic intensity at CP-serving shapes (p ~ 10^2).

Inputs per training point: provisional score sum_same[i] = alpha'_i and the
k-th best same-label distance kth_same[i] = Delta_i^k. alpha[t, l] is the
candidate score of test point t under label l (computed by the caller — it
needs a top-k, which does not belong in this kernel). Output: int32 counts
(m, l) with counts[t, l] = #{i: alpha_i(t, l) >= alpha[t, l]}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist import _pad_to


def _kernel(xt_ref, x_ref, y_ref, sum_ref, kth_ref, alpha_ref, o_ref, *,
            n_labels, bm, bn, n_real):
    j = pl.program_id(1)
    xt = xt_ref[...].astype(jnp.float32)  # (bm, p)
    x = x_ref[...].astype(jnp.float32)  # (bn, p)
    ab = jax.lax.dot_general(
        xt, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a2 = jnp.sum(xt * xt, axis=1, keepdims=True)
    b2 = jnp.sum(x * x, axis=1, keepdims=True)
    d = jnp.sqrt(jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0))  # (bm, bn)

    ytr = y_ref[...].T  # (1, bn)
    sums = sum_ref[...].T  # (1, bn)
    kth = kth_ref[...].T  # (1, bn)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    valid = col < n_real

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    counts = []
    for lbl in range(n_labels):
        upd = (ytr == lbl) & (d < kth)
        alphas = jnp.where(upd, sums - kth + d, sums)
        ge = (alphas >= alpha_ref[:, lbl][:, None]) & valid
        counts.append(jnp.sum(ge.astype(jnp.int32), axis=1))
    o_ref[...] += jnp.stack(counts, axis=1)


@functools.partial(
    jax.jit, static_argnames=("n_labels", "block_m", "block_n", "interpret")
)
def cp_knn_counts(
    X, y, sum_same, kth_same, X_test, alpha, *,
    n_labels: int, block_m: int = 128, block_n: int = 512,
    interpret: bool = False,
):
    m = X_test.shape[0]
    n = X.shape[0]
    bm, bn = min(block_m, m), min(block_n, n)
    Xtp = _pad_to(_pad_to(X_test, 1, 128), 0, bm)
    Xp = _pad_to(_pad_to(X, 1, 128), 0, bn)
    yp = _pad_to(y.astype(jnp.int32)[:, None] + 1, 0, bn) - 1  # pad -> -1
    sp = _pad_to(sum_same.astype(jnp.float32)[:, None], 0, bn)
    kp = _pad_to(kth_same.astype(jnp.float32)[:, None], 0, bn)
    ap = _pad_to(alpha.astype(jnp.float32), 0, bm)
    mp, p = Xtp.shape
    np_, _ = Xp.shape
    kern = functools.partial(
        _kernel, n_labels=n_labels, bm=bm, bn=bn, n_real=n
    )
    out = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, p), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, n_labels), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n_labels), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n_labels), jnp.int32),
        interpret=interpret,
    )(Xtp, Xp, yp, sp, kp, ap)
    return out[:m]
