"""Masked Gaussian-kernel row-sum kernel (Pallas, TPU) for KDE CP.

Computes out[i] = sum_{j: y_B[j]==y_A[i], (j!=i)} exp(-||A_i-B_j||^2/(2h^2))
— the KDE provisional scores (paper Section 4.1) — in a single pass: the
distance cross-term runs on the MXU, the exp/mask/reduce on the VPU, and the
(m,) accumulator is revisited across the n-tile grid dimension (TPU grids are
sequential), so the O(n^2) intermediate distance matrix never touches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist import _pad_to


def _kernel(a_ref, b_ref, ya_ref, yb_ref, o_ref, *, inv2h2, bm, bn,
            n_real, exclude_diag):
    j = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    d2 = a2 + b2.T - 2.0 * ab
    K = jnp.exp(-jnp.maximum(d2, 0.0) * inv2h2)
    mask = ya_ref[...] == yb_ref[...].T  # (bm,1)==(1,bn) -> (bm,bn)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    mask &= col < n_real
    if exclude_diag:
        i = pl.program_id(0)
        row = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        mask &= row != col
    partial = jnp.sum(jnp.where(mask, K, 0.0), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("h", "exclude_diag", "block_m", "block_n", "interpret"),
)
def kde_rowsums(
    A, B, y_A, y_B, *, h: float = 1.0, exclude_diag: bool = False,
    block_m: int = 256, block_n: int = 256, interpret: bool = False,
):
    m, _ = A.shape
    n, _ = B.shape
    bm, bn = min(block_m, m), min(block_n, n)
    Ap = _pad_to(_pad_to(A, 1, 128), 0, bm)
    Bp = _pad_to(_pad_to(B, 1, 128), 0, bn)
    # pad labels with distinct sentinels so padded rows/cols never match
    # (real labels map to y+2 on BOTH sides; pads map to 0 vs -1)
    ya = _pad_to(y_A.astype(jnp.int32)[:, None] + 2, 0, bm)  # pad -> 0
    yb = _pad_to(y_B.astype(jnp.int32)[:, None] + 3, 0, bn) - 1  # pad -> -1
    mp, p = Ap.shape
    np_, _ = Bp.shape
    kern = functools.partial(
        _kernel, inv2h2=1.0 / (2.0 * h * h), bm=bm, bn=bn, n_real=n,
        exclude_diag=exclude_diag,
    )
    out = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, p), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        interpret=interpret,
    )(Ap, Bp, ya, yb)
    return out[:m, 0]
