"""Vectorized extra-tree ensemble for the bootstrap CP measure (Section 6).

The bootstrap machinery trains hundreds of small trees per p-value; the
seed implementation looped Python ``fit_tree`` calls over numpy. Here the
whole ensemble is three stacked ``(S, n_nodes)`` arrays — split feature
(``-1`` = leaf), threshold, majority label — fitted by one vmapped jitted
dispatch. Training sets are expressed as **multiplicity weights** over a
shared row matrix (a bootstrap sample of ``X`` is just an integer count
vector), so every tree in a batch shares one ``(m, p)`` operand and the
node loop vectorizes across trees with no padding or copying.

Randomness is pre-drawn by the caller (per node: a feature index and a
uniform in ``[0, 1)``), which makes tree fitting a *pure function* of
``(X, y, w, feat_choice, thr_u)`` — the numpy oracle in ``ref.py``
consumes the same arrays, and the exactness tests pin the two together.
Routing lives in ``ops.boot_fit_forest`` / ``ops.boot_forest_predict``.

Semantics (mirrors the seed's ``fit_tree`` breadth-first construction):
nodes are visited in breadth-first order; an internal node splits on the
pre-drawn feature at threshold ``lo + u * (hi - lo)`` over its weighted
rows iff it holds more than one drawn instance and ``hi > lo``; rows at a
node that does not split stay there, and prediction reads the majority
label of the deepest node reached.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def n_nodes(depth: int) -> int:
    """Breadth-first node count of a depth-``depth`` complete binary tree."""
    return 2 ** (depth + 1) - 1


def _fit_one(X, y, w, feat_choice, thr_u, n_labels, depth):
    """One weighted extra-tree; (feat, thresh, leaf) each (n_nodes,).

    The breadth-first node visit is a ``fori_loop`` (not a static
    unroll): the streaming bootstrap updates hit many (batch, rows)
    shape buckets, and an unrolled 63-node graph made every new bucket
    pay seconds of XLA compile.
    """
    m = X.shape[0]
    nn = n_nodes(depth)
    n_internal = 2 ** depth - 1

    def body(node, carry):
        node_of, feat, thresh, leaf = carry
        mask = (node_of == node) & (w > 0)
        wm = jnp.where(mask, w, 0).astype(jnp.int32)
        cnt = jnp.zeros(n_labels, jnp.int32).at[y].add(wm)
        leaf = leaf.at[node].set(jnp.argmax(cnt).astype(jnp.int32))
        f = feat_choice[node]
        col = jnp.take(X, f, axis=1)
        lo = jnp.min(jnp.where(mask, col, jnp.inf))
        hi = jnp.max(jnp.where(mask, col, -jnp.inf))
        split = (node < n_internal) & (jnp.sum(wm) > 1) & (hi > lo)
        t = lo + thr_u[node] * (hi - lo)  # NaN when node empty: dead
        feat = feat.at[node].set(jnp.where(split, f, -1))
        thresh = thresh.at[node].set(jnp.where(split, t, 0.0))
        node_of = jnp.where(
            mask & split,
            jnp.where(col > t, 2 * node + 2, 2 * node + 1),
            node_of)
        return node_of, feat, thresh, leaf

    init = (jnp.zeros(m, jnp.int32), jnp.full(nn, -1, jnp.int32),
            jnp.zeros(nn, jnp.float32), jnp.zeros(nn, jnp.int32))
    _, feat, thresh, leaf = jax.lax.fori_loop(0, nn, body, init)
    return feat, thresh, leaf


@functools.partial(jax.jit, static_argnames=("n_labels", "depth"))
def fit_forest(X, y, W, feat_choice, thr_u, *, n_labels, depth):
    """Fit S weighted extra-trees over shared rows in one dispatch.

    X: (m, p) f32 shared rows; y: (m,) i32 labels; W: (S, m) int
    multiplicities (row counts of each bootstrap sample); feat_choice:
    (S, n_nodes) i32 pre-drawn split features; thr_u: (S, n_nodes) f32
    pre-drawn uniforms. Returns stacked (feat, thresh, leaf), each
    (S, n_nodes).
    """
    X = X.astype(jnp.float32)
    return jax.vmap(
        lambda w, fc, u: _fit_one(X, y, w, fc, u, n_labels, depth)
    )(W, feat_choice, thr_u)


@jax.jit
def forest_predict(feat, thresh, leaf, Xq):
    """Predicted labels (S, q) of S stacked trees on query rows (q, p)."""
    depth = (feat.shape[1] + 1).bit_length() - 2
    Xq = Xq.astype(jnp.float32)

    def one(ft, th, lf):
        node = jnp.zeros(Xq.shape[0], jnp.int32)
        for _ in range(depth):
            f = ft[node]
            internal = f >= 0
            xv = jnp.take_along_axis(
                Xq, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
            node = jnp.where(
                internal,
                jnp.where(xv > th[node], 2 * node + 2, 2 * node + 1),
                node)
        return lf[node]

    return jax.vmap(one)(feat, thresh, leaf)
