"""Pallas TPU kernels for the CP hot spots and the LM attention layer.

Each kernel module ships pl.pallas_call + explicit BlockSpec VMEM tiling;
ops.py is the jit dispatching wrapper and ref.py the pure-jnp oracle
used by the per-kernel allclose sweeps in tests/.
"""
