"""Tiled pairwise squared-Euclidean distance kernel (Pallas, TPU).

The paper's optimized-CP training phase is dominated by the O(n^2) pairwise
distance matrix (Section 3.1). On TPU we compute ||a-b||^2 = ||a||^2 +
||b||^2 - 2 a.b so that the cross term runs on the MXU; row norms are
recomputed per tile (P flops/element — negligible next to the matmul).

BlockSpec tiling: A tiles (bm, P) and B tiles (bn, P) stay resident in VMEM
for a (bm, bn) output tile; P is zero-padded to a lane multiple (128) so the
MXU operates on aligned shapes. Accumulation is f32 regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    o_ref[...] = (a2 + b2.T - 2.0 * ab).astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def pairwise_sq_dists(
    A: jnp.ndarray,
    B: jnp.ndarray,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Squared distances (m, n) between rows of A (m, p) and B (n, p)."""
    m, _ = A.shape
    n, _ = B.shape
    bm, bn = min(block_m, m), min(block_n, n)
    Ap = _pad_to(_pad_to(A, 1, 128), 0, bm)
    Bp = _pad_to(_pad_to(B, 1, 128), 0, bn)
    mp, p = Ap.shape
    np_, _ = Bp.shape
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, p), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(Ap, Bp)
    return out[:m, :n]
