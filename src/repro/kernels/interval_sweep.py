"""Fused regression-CP interval-sweep front end (Pallas, TPU).

The streaming regression read path (paper Section 8.1 served online) is,
per test point: an O(n) distance row, the O(1)-per-row incremental &
decremental update of the affine score coefficients (a_i, b_i), and the
critical points of S_i = {t : |a_i + b_i t| >= |a + t|} that feed the
O(n log n) hull sweep. The naive sequence round-trips the (m, n) distance
matrix plus the (m, n) coefficient matrices through HBM; this kernel fuses
distances (MXU), the coefficient update and the root computation (VPU)
into one VMEM-resident pass, emitting only the (m, n) critical-point
matrices the sweep needs.

The candidate-score vector ``a_test`` (a top-k over the distance row) and
the sort-based sweep itself stay with the caller — neither belongs in a
tiled kernel. ``live`` masks capacity padding: dead columns emit the
neutral empty interval (+inf, -inf), which the sweep ignores bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist import _pad_to


def _kernel(xt_ref, a_ref, x_ref, ap_ref, kd_ref, kl_ref, live_ref,
            lo_ref, hi_ref, *, k, eps):
    INF = jnp.inf
    xt = xt_ref[...].astype(jnp.float32)  # (bm, p)
    x = x_ref[...].astype(jnp.float32)  # (bn, p)
    ab = jax.lax.dot_general(
        xt, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    a2 = jnp.sum(xt * xt, axis=1, keepdims=True)
    b2 = jnp.sum(x * x, axis=1, keepdims=True)
    d = jnp.sqrt(jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0))  # (bm, bn)

    a_prime = ap_ref[...].T  # (1, bn)
    kth = kd_ref[...].T  # (1, bn)
    upd = a_prime + kl_ref[...].T / k
    live = live_ref[...].T > 0.5  # (1, bn)

    enters = live & (d < kth)
    a_i = jnp.where(enters, upd, a_prime)
    b_i = jnp.where(enters, -1.0 / k, 0.0)
    a = a_ref[...]  # (bm, 1) candidate score per test row

    A2 = b_i * b_i - 1.0
    B1 = a_i * b_i - a
    C0 = a_i * a_i - a * a
    disc = B1 * B1 - A2 * C0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    denom = jnp.where(jnp.abs(A2) < eps, 1.0, A2)
    r1 = (-B1 + sq) / denom
    r2 = (-B1 - sq) / denom
    quad_lo = jnp.where(disc >= 0.0, jnp.minimum(r1, r2), INF)
    quad_hi = jnp.where(disc >= 0.0, jnp.maximum(r1, r2), -INF)
    t0 = -C0 / jnp.where(jnp.abs(B1) < eps, 1.0, 2.0 * B1)
    lin_lo = jnp.where(B1 > eps, t0,
                       jnp.where(B1 < -eps, -INF,
                                 jnp.where(C0 >= 0.0, -INF, INF)))
    lin_hi = jnp.where(B1 > eps, INF,
                       jnp.where(B1 < -eps, t0,
                                 jnp.where(C0 >= 0.0, INF, -INF)))
    is_quad = jnp.abs(A2) >= eps
    lo = jnp.where(is_quad, quad_lo, lin_lo)
    hi = jnp.where(is_quad, quad_hi, lin_hi)
    lo_ref[...] = jnp.where(live, lo, INF)
    hi_ref[...] = jnp.where(live, hi, -INF)


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "block_n", "interpret")
)
def interval_sweep(
    X, a_prime, kth_dist, kth_label, live, X_test, a_test, *,
    k: int, block_m: int = 128, block_n: int = 512,
    interpret: bool = False,
):
    """Critical points (lo, hi), each (m, n), for the regression sweep."""
    m = X_test.shape[0]
    n = X.shape[0]
    bm, bn = min(block_m, m), min(block_n, n)
    Xtp = _pad_to(_pad_to(X_test, 1, 128), 0, bm)
    Xp = _pad_to(_pad_to(X, 1, 128), 0, bn)
    app = _pad_to(a_prime.astype(jnp.float32)[:, None], 0, bn)
    kdp = _pad_to(kth_dist.astype(jnp.float32)[:, None], 0, bn)
    klp = _pad_to(kth_label.astype(jnp.float32)[:, None], 0, bn)
    lvp = _pad_to(live.astype(jnp.float32)[:, None], 0, bn)  # pad -> dead
    atp = _pad_to(a_test.astype(jnp.float32)[:, None], 0, bm)
    mp, p = Xtp.shape
    np_, _ = Xp.shape
    kern = functools.partial(_kernel, k=k, eps=1e-12)
    lo, hi = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, p), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )(Xtp, atp, Xp, app, kdp, klp, lvp)
    return lo[:m, :n], hi[:m, :n]
