"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes and
asserts allclose against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sq_dists(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances (m, n) between rows of A (m,p) and B (n,p)."""
    a2 = jnp.sum(A * A, axis=-1, keepdims=True)
    b2 = jnp.sum(B * B, axis=-1, keepdims=True)
    return a2 + b2.T - 2.0 * (A @ B.T)


def kde_rowsums(
    A: jnp.ndarray, B: jnp.ndarray, y_A: jnp.ndarray, y_B: jnp.ndarray,
    h: float, exclude_diag: bool = False,
) -> jnp.ndarray:
    """Masked Gaussian-kernel row sums: out[i] = sum_j K((A_i-B_j)/h) over
    j with y_B[j] == y_A[i] (and j != i when exclude_diag)."""
    d2 = sq_dists(A, B)
    K = jnp.exp(-d2 / (2.0 * h * h))
    mask = y_A[:, None] == y_B[None, :]
    if exclude_diag:
        m, n = d2.shape
        mask = mask & ~jnp.eye(m, n, dtype=bool)
    return jnp.sum(jnp.where(mask, K, 0.0), axis=-1)


def cp_knn_counts(
    X: jnp.ndarray, y: jnp.ndarray, sum_same: jnp.ndarray, kth_same: jnp.ndarray,
    X_test: jnp.ndarray, alpha: jnp.ndarray,
) -> jnp.ndarray:
    """Fused simplified-k-NN CP update + p-value partial counts.

    For each test point t and label l: counts[t, l] =
      #{i : alpha_i(t, l) >= alpha[t, l]}, where alpha_i is the provisional
    score sum_same[i], updated to sum_same[i] - kth_same[i] + d(x_i, x_t)
    when the test point enters i's same-label neighbourhood.

    alpha: (m, l) candidate scores. Returns int32 (m, l).
    """
    d = jnp.sqrt(jnp.maximum(sq_dists(X_test, X), 0.0))  # (m, n)
    n_labels = alpha.shape[1]
    labels = jnp.arange(n_labels, dtype=y.dtype)
    same = y[None, :] == labels[:, None]  # (l, n)
    upd = same[None] & (d[:, None, :] < kth_same[None, None, :])  # (m, l, n)
    alphas = jnp.where(
        upd, (sum_same - kth_same)[None, None, :] + d[:, None, :],
        sum_same[None, None, :],
    )
    return jnp.sum(alphas >= alpha[:, :, None], axis=-1).astype(jnp.int32)


def reg_interval_endpoints(
    X: jnp.ndarray, a_prime: jnp.ndarray, kth_dist: jnp.ndarray,
    kth_label: jnp.ndarray, live: jnp.ndarray, X_test: jnp.ndarray,
    a_test: jnp.ndarray, k: int, eps: float = 1e-12,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused regression-CP critical points (paper Section 8.1).

    For each (test point t, training row i): the distance d(x_i, x_t), the
    O(1) incremental&decremental update of the affine score coefficients
        a_i = a'_i + [d < Delta_i^k] y_(k)(x_i)/k,   b_i in {0, -1/k},
    and the boundary points of S_i = {t : |a_i + b_i t| >= |a_test + t|}
    (the roots of (a_i + b_i t)^2 - (a_test + t)^2, at most two). Returns
    (lo, hi), each (m, n); empty sets (and rows with ``live`` False) are
    the neutral (+inf, -inf). Semantics of record for the Pallas kernel in
    ``interval_sweep.py``; arithmetic mirrors ``regression._interval_ge``
    exactly so the streaming read path stays bit-identical to the batch
    optimized path.
    """
    INF = jnp.inf
    d = jnp.sqrt(jnp.maximum(sq_dists(X_test, X), 0.0))  # (m, n)
    upd = a_prime + kth_label / k
    enters = live[None, :] & (d < kth_dist[None, :])
    a_i = jnp.where(enters, upd[None, :], a_prime[None, :])
    b_i = jnp.where(enters, -1.0 / k, 0.0)
    a = a_test[:, None]  # (m, 1)

    A2 = b_i * b_i - 1.0
    B1 = a_i * b_i - a
    C0 = a_i * a_i - a * a
    disc = B1 * B1 - A2 * C0
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    denom = jnp.where(jnp.abs(A2) < eps, 1.0, A2)
    r1 = (-B1 + sq) / denom
    r2 = (-B1 - sq) / denom
    qlo = jnp.minimum(r1, r2)
    qhi = jnp.maximum(r1, r2)
    quad_lo = jnp.where(disc >= 0.0, qlo, INF)
    quad_hi = jnp.where(disc >= 0.0, qhi, -INF)
    t0 = -C0 / jnp.where(jnp.abs(B1) < eps, 1.0, 2.0 * B1)
    lin_lo = jnp.where(B1 > eps, t0,
                       jnp.where(B1 < -eps, -INF,
                                 jnp.where(C0 >= 0.0, -INF, INF)))
    lin_hi = jnp.where(B1 > eps, INF,
                       jnp.where(B1 < -eps, t0,
                                 jnp.where(C0 >= 0.0, INF, -INF)))
    is_quad = jnp.abs(A2) >= eps
    lo = jnp.where(is_quad, quad_lo, lin_lo)
    hi = jnp.where(is_quad, quad_hi, lin_hi)
    lo = jnp.where(live[None, :], lo, INF)
    hi = jnp.where(live[None, :], hi, -INF)
    return lo, hi


_BIG = 1e30  # matches core.online.BIG / core.regression.BIG


def _ring_live(cap: int, head, n, wrap=None) -> jnp.ndarray:
    """(cap,) live mask of a ring window: slot ``(head + i) % wrap`` is
    live for ``i in [0, n)``; slots ``>= wrap`` never are. ``head=None``
    (or 0, full-capacity ``wrap``) is the historic linear layout, where
    this reduces to ``arange(cap) < n`` bit-for-bit. Mirrors
    ``core.online.ring_live`` (not imported here: ``core.online`` sits
    above this module in the import graph)."""
    idx = jnp.arange(cap, dtype=jnp.int32)
    if head is None:
        return idx < n
    m = jnp.asarray(cap if wrap is None else wrap, jnp.int32)
    age = jnp.where(idx >= head, idx - head, idx - head + m)
    return (age < n) & (idx < m)


def stream_update(
    X: jnp.ndarray, y: jnp.ndarray, nbr_d: jnp.ndarray, nbr_y: jnp.ndarray,
    x_new: jnp.ndarray, y_new: jnp.ndarray, n: jnp.ndarray, *, mode: str,
    head=None, wrap=None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused streaming observe front end: distance row + k-best merge.

    One incoming point against a capacity-padded window: computes the
    (cap,) distance row (BIG on inert rows), and merges the point into
    every live row's ordered k-best neighbour list. Two modes, matching
    the two serving engines' maintained statistics bit-for-bit:

    * ``mode="class"`` — the paper's simplified-k-NN classification state
      (``core.online``): distances in the row-difference form
      ``sqrt(sum((x_i - x)^2))``, a row's list admits the candidate iff
      same label; ``nbr_y`` is passed through untouched.
    * ``mode="reg"`` — the Section 8.1 regression state
      (``regression.stream``): distances in the MXU-friendly
      ``a^2 + b^2 - 2ab`` form of ``sq_dists``, a row's list admits the
      candidate iff it beats the current k-th distance (ties keep the
      incumbent); neighbour *labels* ride along, inserted strictly below
      equal distances (fit's stable-argsort tie rule), and BIG slots
      carry the row's own label.

    The caller keeps the new row's own top-k list, the D row/column
    scatter (an O(cap) in-place dynamic-update-slice under donation) and
    the p-value — none of which belong in a tiled kernel. Returns
    ``(d_row (cap,), nbr_d' (cap, k), nbr_y' (cap, k))``. Semantics of
    record for the Pallas kernel in ``stream_update.py``; expressions
    mirror ``core.online._observe_impl`` / ``regression.stream.observe``
    exactly, so routing through this oracle keeps the streaming states
    bit-identical to refit-from-scratch.

    ``head`` (traced scalar or None) selects the serving engines'
    ring-buffer slot layout: live slots are ``(head + i) % wrap`` rather
    than ``[0, n)`` (``wrap`` defaults to the capacity). Per-slot
    arithmetic is unchanged — only the live mask moves — so the emitted
    distances/list values are the same bits wherever a slot is live
    under both layouts.
    """
    cap, k = nbr_d.shape
    live = _ring_live(cap, head, n, wrap)
    if mode == "class":
        d = jnp.sqrt(jnp.maximum(
            jnp.sum((X - x_new[None]) ** 2, axis=-1), 0.0))
        d = jnp.where(live, d, _BIG)
        same = (y == y_new) & live
        cand = jnp.where(same, d, _BIG)
        merged = jnp.sort(
            jnp.concatenate([nbr_d, cand[:, None]], axis=1), axis=1)[:, :k]
        return d, merged, nbr_y
    if mode != "reg":
        raise ValueError(f"unknown stream_update mode {mode!r}")
    d = jnp.sqrt(jnp.maximum(sq_dists(x_new[None], X)[0], 0.0))
    d_row = jnp.where(live, d, _BIG)
    enters = live & (d < nbr_d[:, -1])
    cand_d = jnp.where(enters, d, _BIG)
    merged_d = jnp.concatenate([nbr_d, cand_d[:, None]], axis=1)
    merged_y = jnp.concatenate(
        [nbr_y, jnp.full((cap, 1), y_new, nbr_y.dtype)], axis=1)
    order = jnp.argsort(merged_d, axis=1, stable=True)
    nd = jnp.take_along_axis(merged_d, order, axis=1)[:, :k]
    ny = jnp.take_along_axis(merged_y, order, axis=1)[:, :k]
    ny = jnp.where(nd >= _BIG, y[:, None], ny)
    return d_row, nd, ny


def _ordered_insert(L, c):
    """Branch-free ordered insert: candidate ``c`` (cap,) into each
    ascending row of ``L`` (cap, k), strictly after equal values, largest
    entry dropped. Equivalent to ``sort(concat([L, c], 1))[:, :k]`` with
    the stable candidate-last tie rule — every output is a selected
    input value, so the two forms are bit-identical. Returns
    ``(newL, pos, cols)`` so callers can mirror the move on a parallel
    label matrix."""
    k = L.shape[1]
    pos = jnp.sum((L <= c[:, None]).astype(jnp.int32), axis=1,
                  keepdims=True)
    cols = jnp.arange(k)[None, :]
    Lsh = jnp.concatenate([L[:, :1], L[:, :k - 1]], axis=1)
    newL = jnp.where(cols < pos, L,
                     jnp.where(cols == pos, c[:, None], Lsh))
    return newL, pos, cols


def stream_update_fast(
    X: jnp.ndarray, y: jnp.ndarray, nbr_d: jnp.ndarray, nbr_y: jnp.ndarray,
    x_new: jnp.ndarray, y_new: jnp.ndarray, n: jnp.ndarray, *, mode: str,
    head=None, wrap=None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sortless form of ``stream_update`` — the production CPU path.

    Bit-identical to the sort-based oracle above (the ordered insert
    selects the same values the sort would; the parity tests pin the two
    together, ties included) but avoids XLA's comparator sort, which
    dominates the observe tick on CPU at large capacities.
    """
    cap, k = nbr_d.shape
    live = _ring_live(cap, head, n, wrap)
    if mode == "class":
        d = jnp.sqrt(jnp.maximum(
            jnp.sum((X - x_new[None]) ** 2, axis=-1), 0.0))
        d = jnp.where(live, d, _BIG)
        same = (y == y_new) & live
        cand = jnp.where(same, d, _BIG)
        merged, _, _ = _ordered_insert(nbr_d, cand)
        return d, merged, nbr_y
    if mode != "reg":
        raise ValueError(f"unknown stream_update mode {mode!r}")
    d = jnp.sqrt(jnp.maximum(sq_dists(x_new[None], X)[0], 0.0))
    d_row = jnp.where(live, d, _BIG)
    enters = live & (d < nbr_d[:, -1])
    cand_d = jnp.where(enters, d, _BIG)
    newL, pos, cols = _ordered_insert(nbr_d, cand_d)
    Ysh = jnp.concatenate([nbr_y[:, :1], nbr_y[:, :k - 1]], axis=1)
    newY = jnp.where(cols < pos, nbr_y,
                     jnp.where(cols == pos,
                               jnp.asarray(y_new, nbr_y.dtype), Ysh))
    newY = jnp.where(newL >= _BIG, y[:, None], newY)
    return d_row, newL, newY


def boot_fit_tree(X, y, w, feat_choice, thr_u, n_labels, depth):
    """Numpy oracle for one weighted extra-tree (``boot_forest._fit_one``).

    Semantics of record for the bootstrap measure's base learner: a
    breadth-first extra-tree over multiplicity-weighted rows. All float
    arithmetic stays in f32 and mirrors the jnp kernel expression
    (``t = lo + u * (hi - lo)``), so the parity tests can pin the vmapped
    path to this one exactly.
    """
    X = np.asarray(X, np.float32)
    m = X.shape[0]
    nn = 2 ** (depth + 1) - 1
    n_internal = 2 ** depth - 1
    node_of = np.zeros(m, np.int32)
    feat = np.full(nn, -1, np.int32)
    thresh = np.zeros(nn, np.float32)
    leaf = np.zeros(nn, np.int32)
    inf32 = np.float32(np.inf)
    for node in range(nn):
        mask = (node_of == node) & (w > 0)
        cnt = np.zeros(n_labels, np.int64)
        np.add.at(cnt, y[mask], w[mask])
        leaf[node] = np.argmax(cnt)
        if node < n_internal:
            f = feat_choice[node]
            col = X[:, f]
            lo = np.where(mask, col, inf32).min()
            hi = np.where(mask, col, -inf32).max()
            if int(cnt.sum()) > 1 and hi > lo:
                t = np.float32(lo + thr_u[node] * (hi - lo))
                feat[node], thresh[node] = f, t
                node_of[mask] = np.where(
                    col[mask] > t, 2 * node + 2, 2 * node + 1)
    return feat, thresh, leaf


def boot_predict_tree(feat, thresh, leaf, Xq):
    """Numpy oracle for ``boot_forest.forest_predict`` on one tree."""
    Xq = np.asarray(Xq, np.float32)
    q = Xq.shape[0]
    depth = (len(feat) + 1).bit_length() - 2
    node = np.zeros(q, np.int32)
    for _ in range(depth):
        f = feat[node]
        internal = f >= 0
        xv = Xq[np.arange(q), np.maximum(f, 0)]
        node = np.where(
            internal,
            np.where(xv > thresh[node], 2 * node + 2, 2 * node + 1),
            node).astype(np.int32)
    return leaf[node]


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, window: int | None = None, scale: float | None = None,
    softcap: float | None = None,
) -> jnp.ndarray:
    """Reference attention. q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D).

    GQA: H must be a multiple of Hkv. window: sliding-window size (keys
    within [i-window+1, i] attend), applied with causal. softcap: gemma-style
    tanh logit cap.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, window: int | None = None, scale: float | None = None,
    softcap: float | None = None, block_q: int = 1024, block_k: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention with O(S * block) memory, pure jnp.

    Same semantics as ``flash_attention``; the XLA-compiled analogue of the
    Pallas kernel for long sequences off-TPU — a lax.map over query blocks,
    each scanning key blocks with running (max, denom, acc) statistics. This
    is what the 32k/500k dry-run cells lower to on the CPU container.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    scale_f = scale if scale is not None else float(D ** -0.5)

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k
    kb = kp.reshape(B, nk, block_k, Hkv, D)
    vb = vp.reshape(B, nk, block_k, Hkv, D)

    def q_block(iq, q_blk):  # q_blk: (B, bq, H, D)
        q_pos = (iq * block_q + jnp.arange(block_q))[:, None] + (Skv - Sq)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ik, k_blk, v_blk = inp  # (B, bk, Hkv, D)
            k_rep = jnp.repeat(k_blk, rep, axis=2)
            v_rep = jnp.repeat(v_blk, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_rep).astype(
                jnp.float32) * scale_f
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = (ik * block_k + jnp.arange(block_k))[None, :]
            mask = k_pos < Skv
            if causal:
                mask = mask & (k_pos <= q_pos)
            if window is not None:
                mask = mask & (k_pos > q_pos - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_rep.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, H, block_q), -1e30, jnp.float32),
            jnp.zeros((B, H, block_q), jnp.float32),
            jnp.zeros((B, H, block_q, D), jnp.float32),
        )
        # checkpoint per kv-step: the backward otherwise saves every
        # (bq, bk) score tile AND boolean mask across the scan — gigabytes
        # per layer at 4k+ context (the Pallas kernel's VJP recomputes
        # tiles the same way on the real TPU)
        (m_f, l_f, acc_f), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init,
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, bq, H, D)

    qb = jnp.moveaxis(qp.reshape(B, nq, block_q, H, D), 1, 0)
    out = jax.lax.map(lambda t: q_block(t[0], t[1]), (jnp.arange(nq), qb))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * block_q, H, D)
    return out[:, :Sq]
