"""FlashAttention kernel (Pallas, TPU): online-softmax tiled attention.

TPU-native adaptation: q/k/v tiles sized for VMEM residency with the (bq, bk)
logits tile on the MXU; running max/denominator kept in f32 VMEM scratch
across the sequential kv-grid dimension. Supports causal masking, sliding
windows (gemma3 local layers, mixtral SWA, recurrentgemma local attention)
and GQA (kv-head indexing in the BlockSpec index_map — repeated K/V are never
materialized, which matters at kv=1). Fully-masked tiles are skipped with
``pl.when`` (halves work for causal, and turns SWA cost from O(S^2) into
O(S*W)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            scale, causal, window, softcap, bq, bk, sq, skv, nkv):
    iq, ik = pl.program_id(2), pl.program_id(3)
    q_pos_min = iq * bq + (skv - sq)
    q_pos_max = q_pos_min + bq - 1
    k_pos_min = ik * bk

    # tile-level skip: fully-masked (bq, bk) tiles do no work
    live = True
    if causal:
        live = k_pos_min <= q_pos_max
    if window is not None:
        live = jnp.logical_and(live, k_pos_min + bk - 1 > q_pos_min - window)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qp = q_pos_min + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_pos_min + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kp < skv
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_s[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, :, 0, :] = (acc[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "softcap", "block_q",
                     "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: int | None = None,
    scale: float | None = None, softcap: float | None = None,
    block_q: int = 512, block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D) with H % Hkv == 0."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    scale = scale if scale is not None else float(1.0 / (D ** 0.5))
    bq, bk = min(block_q, Sq), min(block_k, Skv)

    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nkv = qp.shape[1] // bq, kp.shape[1] // bk

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap,
        bq=bq, bk=bk, sq=Sq, skv=Skv, nkv=nkv,
    )
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
