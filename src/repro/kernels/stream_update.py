"""Fused streaming-observe front end (Pallas, TPU).

The O(cap) hot path of both serving engines' ``observe`` tick is, per
incoming point: a distance row against the capacity-padded window (MXU
for the regression state's ``a^2+b^2-2ab`` form, VPU for the
classification state's row-difference form), a per-row admission gate,
and an ordered insert into every live row's k-best neighbour list. The
naive sequence round-trips the (cap,) distance row and the (cap, k)
lists through HBM several times (distances, gate, concat, sort, take);
this kernel fuses all of it into one VMEM-resident pass over row blocks.

The ordered insert is branch-free: with an ascending list L and
candidate c, ``pos = #{j : L[j] <= c}`` places the candidate strictly
below equal values — exactly the stable-argsort-with-candidate-last tie
rule the streaming exactness proofs rest on — and the new list is an
elementwise select between L, c, and L shifted right by one. No sort
runs in the kernel.

Stays with the caller (none of it belongs in a tiled kernel):

* the new row's *own* k-best list — a top_k over the emitted distance
  row;
* the scatter of the distance row into the maintained pairwise matrix
  ``D``'s row idx and column idx. ``D`` cannot be aliased through
  ``pallas_call`` without tile-aligning (i.e. copying) the whole
  (cap, cap) buffer, which is exactly the O(cap^2) traffic this change
  removes — instead the caller's two ``.at[idx].set`` updates lower to
  in-place dynamic-update-slices once the jitted step donates its input
  state (``donate_argnums``), which is O(cap) HBM traffic;
* the smoothed p-value (an O(cap) reduction over pre-update scores).

``kernels/ref.py::stream_update`` is the semantics of record; the
parity test sweeps both modes against it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise_dist import _pad_to

_BIG = 1e30  # matches core.online.BIG / core.regression.BIG


def _kernel(scal_ref, x_ref, X_ref, y_ref, nd_ref, ny_ref,
            d_ref, ndo_ref, nyo_ref, *, k, mode, block_n):
    n = scal_ref[0, 0]
    y_new = scal_ref[0, 1]
    head = scal_ref[0, 2]  # ring-buffer start slot; 0 == linear layout
    wrap = scal_ref[0, 3]  # ring modulus; == cap in the linear layout
    x = x_ref[...].astype(jnp.float32)  # (1, p)
    X = X_ref[...].astype(jnp.float32)  # (bn, p)
    if mode == "class":
        diff = X - x
        d2 = jnp.sum(diff * diff, axis=1, keepdims=True)  # (bn, 1)
    else:
        ab = jax.lax.dot_general(
            X, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (bn, 1)
        a2 = jnp.sum(X * X, axis=1, keepdims=True)
        b2 = jnp.sum(x * x, axis=1, keepdims=True)  # (1, 1)
        d2 = a2 + b2 - 2.0 * ab
    d = jnp.sqrt(jnp.maximum(d2, 0.0))  # (bn, 1)

    j = pl.program_id(0)
    rows = (jax.lax.broadcasted_iota(jnp.float32, d.shape, 0)
            + jnp.float32(block_n) * j.astype(jnp.float32))
    # ring liveness: slot (head + i) % wrap is live for i < n. Row ids,
    # head, wrap and n are exact in f32 (cap << 2^24); the explicit
    # rows < wrap guard keeps slots beyond the ring modulus (and the
    # block-size padding rows) inert even when the wrap term would hand
    # them a small age.
    age = jnp.where(rows < head, rows - head + wrap, rows - head)
    live = (age < n) & (rows < wrap)
    d_row = jnp.where(live, d, _BIG)

    L = nd_ref[...].astype(jnp.float32)  # (bn, k) ascending, BIG-padded
    yb = y_ref[...].astype(jnp.float32)  # (bn, 1)
    if mode == "class":
        gate = live & (yb == y_new)
        c = jnp.where(gate, d_row, _BIG)
    else:
        gate = live & (d < L[:, k - 1:k])  # strict: ties keep incumbent
        c = jnp.where(gate, d, _BIG)

    # branch-free ordered insert, after equal values (candidate has the
    # largest arrival index); c == BIG lands at pos == k => list unchanged
    pos = jnp.sum((L <= c).astype(jnp.int32), axis=1, keepdims=True)
    cols = jax.lax.broadcasted_iota(jnp.int32, L.shape, 1)
    Lsh = jnp.concatenate([L[:, :1], L[:, :k - 1]], axis=1)
    newL = jnp.where(cols < pos, L, jnp.where(cols == pos, c, Lsh))

    d_ref[...] = d_row
    ndo_ref[...] = newL
    if mode == "reg":
        Y = ny_ref[...].astype(jnp.float32)
        Ysh = jnp.concatenate([Y[:, :1], Y[:, :k - 1]], axis=1)
        newY = jnp.where(cols < pos, Y, jnp.where(cols == pos, y_new, Ysh))
        # missing-neighbour slots carry the row's own label (fit's
        # convention at window size n == k)
        nyo_ref[...] = jnp.where(newL >= _BIG, yb, newY)
    else:
        nyo_ref[...] = ny_ref[...]


@functools.partial(
    jax.jit, static_argnames=("mode", "block_n", "interpret")
)
def stream_update(
    X, y, nbr_d, nbr_y, x_new, y_new, n, *,
    mode: str, block_n: int = 256, interpret: bool = False, head=None,
    wrap=None,
):
    """Fused distance row + gated ordered k-best merge for one new point.

    Returns ``(d_row (cap,), nbr_d' (cap, k), nbr_y' (cap, k))``, all
    f32 — see ``ref.stream_update`` for the exact semantics per mode.
    ``head`` selects the serving engines' ring-buffer slot layout (live
    slots ``(head + i) % wrap``, slots >= wrap inert); None/0 with a
    full-capacity ``wrap`` is the linear layout.
    """
    if mode not in ("class", "reg"):
        raise ValueError(f"unknown stream_update mode {mode!r}")
    cap, _ = X.shape
    k = nbr_d.shape[1]
    bn = min(block_n, cap)
    Xp = _pad_to(_pad_to(X, 1, 128), 0, bn)
    xp = _pad_to(x_new.astype(jnp.float32)[None], 1, 128)
    yp = _pad_to(y.astype(jnp.float32)[:, None], 0, bn)
    ndp = _pad_to(nbr_d.astype(jnp.float32), 0, bn)
    nyp = _pad_to(nbr_y.astype(jnp.float32), 0, bn)
    if head is None:
        head = 0
    if wrap is None:
        wrap = cap
    scal = jnp.stack([jnp.asarray(n, jnp.float32).reshape(()),
                      jnp.asarray(y_new, jnp.float32).reshape(()),
                      jnp.asarray(head, jnp.float32).reshape(()),
                      jnp.asarray(wrap, jnp.float32).reshape(())])[None]
    capp, p = Xp.shape
    kern = functools.partial(_kernel, k=k, mode=mode, block_n=bn)
    d, nd2, ny2 = pl.pallas_call(
        kern,
        grid=(capp // bn,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda j: (0, 0)),
            pl.BlockSpec((1, p), lambda j: (0, 0)),
            pl.BlockSpec((bn, p), lambda j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda j: (j, 0)),
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda j: (j, 0)),
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capp, 1), jnp.float32),
            jax.ShapeDtypeStruct((capp, k), jnp.float32),
            jax.ShapeDtypeStruct((capp, k), jnp.float32),
        ],
        interpret=interpret,
    )(scal, xp, Xp, yp, ndp, nyp)
    return d[:cap, 0], nd2[:cap], ny2[:cap]
