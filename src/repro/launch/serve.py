"""Serving launcher: batched decode + conformal guarantees per request.

    python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
        --requests 16 --gen-tokens 8 --calib 512

Multi-tenant online CP mode (``--sessions N``) serves N concurrent
per-tenant conformal sessions through ``repro.serving.ServingEngine``:
one vmapped jitted step per tick advances every tenant's sliding-window
CP state (the paper's incremental&decremental O(n) updates), drifted
tenants are flagged by their exchangeability martingales, and tenant
state is snapshotted/restored through the crash-safe checkpoint store::

    python -m repro.launch.serve --sessions 32 --steps 200 --window 64

Adding ``--regression`` switches those sessions to streaming full-CP
*regression* (paper Section 8.1 served online, ``repro.regression``):
each tick prices the observed label (martingale drift detection), and
the read path returns exact prediction intervals for every tenant in
one dispatch::

    python -m repro.launch.serve --sessions 32 --regression --steps 200 \\
        --window 128 --capacity 128 --dim 2 --drift 3.0

(k-NN regression needs a dense neighbourhood to price drift: prefer low
--dim / window >= 100 for the drift demo.)

``--measure NAME`` instead serves the sessions through the measure
*registry* (``repro.serving.registry.ConformalPredictor``) — one exact-
shape predictor per tenant, sliding-window via the paper's incremental
``observe`` / decremental ``evict``. This is how the measures without a
fixed-shape vmapped engine (notably ``bootstrap``, Algorithm 3) are
served end-to-end::

    python -m repro.launch.serve --sessions 4 --measure bootstrap \\
        --steps 48 --window 24 --boot-b 5 --tree-depth 3

(Registry mode flags drift on the running-max log martingale; expect few
or no flags for bootstrap — its ensemble retrains on the live window
every tick and re-conforms within a few ticks of a change. The
sustained-drift detection demo is the vmapped engine mode above.)

Every serving mode reports through one telemetry pipeline
(``repro.telemetry``): per-op latency histograms, device-side tick
counters and online validity monitors (rolling coverage vs 1-eps,
p-value-uniformity KS, drift martingales) all render via the metrics
text export. ``--metrics-out`` dumps the same snapshot as JSON and
``--trace-out`` records one JSONL trace record per engine op::

    python -m repro.launch.serve --sessions 8 --steps 64 \\
        --metrics-out metrics.json --trace-out trace.jsonl

``--replay TRACE`` turns the launcher into a load-test driver
(``repro.telemetry.replay``): TRACE is either a recorded JSONL trace
file or a ``loadgen:<workload>`` spec (steady / bursty / diurnal /
zipf) synthesized on the fly. The trace's ops are dispatched against a
fresh engine (classification, or regression with ``--regression``),
preserving inter-arrival timing compressed by ``--speedup`` (default
``inf`` = as-fast-as-possible), and the report adds p50/p99 per-op
latency, steps/s, queue depth and the ``--slo-ms`` violation fraction.
``--auto-tune`` fits the per-(op, capacity-bucket) cost model
(``repro.telemetry.costmodel``) and replaces the hand-tuned
observe_many chunk with ``suggest_chunk()``::

    python -m repro.launch.serve --replay loadgen:bursty --steps 256 \\
        --sessions 8 --speedup inf --slo-ms 50 --auto-tune

Pipeline per batch of requests:
    1. prefill the prompt, build per-layer KV/recurrent caches,
    2. greedy decode ``gen_tokens`` steps with the serve_step,
    3. conformal OOD p-value per request (simplified k-NN CP over sequence
       embeddings, the paper's optimized O(n)-per-query path) — the serving
       feature the paper's speedups make affordable at this layer.

Prefill fills the KV caches by running serve_step over prompt positions
(teacher-forced); production prefill is the fused prefill_step (dry-run
cell), cache handoff being the same structure.
"""
from __future__ import annotations

import argparse
import time


def _class_drift_traffic(args, S, T, dim):
    """Per-tenant synthetic classification traffic; odd tenants drift at
    T/2 (the online change-detection workload of paper App. C.5).
    Shared by the engine and registry serving modes."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(args.seed)
    kx, ky, kt = jax.random.split(key, 3)
    X = jax.random.normal(kx, (S, T, dim), jnp.float32)
    centers = jnp.arange(S, dtype=jnp.float32)[:, None, None] * 0.1
    y = jax.random.bernoulli(ky, 0.5, (S, T)).astype(jnp.int32)
    X = X + centers + y[..., None].astype(jnp.float32)
    drifted = jnp.arange(S) % 2 == 1
    X = jnp.where((drifted[:, None] & (jnp.arange(T)[None, :] >= T // 2))
                  [..., None], X + args.drift, X)
    taus = jax.random.uniform(kt, (S, T), dtype=jnp.float32)
    return X, y, taus, drifted


def _chaos_traffic(args, X, y, taus, *, mode):
    """``--faults SEED``: corrupt the (S, T) synthetic traffic with a
    keyed ``robustness.faults.FaultPlan`` (NaN/Inf features,
    out-of-range labels/taus). Returns numpy copies — the engine casts
    on dispatch — or the inputs untouched when chaos is off."""
    if args.faults < 0:
        return X, y, taus
    import numpy as np

    from repro.robustness import VALUE_FAULTS, FaultPlan, corrupt_traffic

    X, y, taus = np.array(X), np.array(y), np.array(taus)
    S, T = y.shape
    plan = FaultPlan.random(args.faults, steps=T, tenants=S,
                            rate=args.fault_rate, kinds=VALUE_FAULTS)
    hits = corrupt_traffic(plan, X, y, taus, mode=mode, n_labels=2,
                           time_axis=1)
    print(f"[serve] chaos: {len(plan)} traffic fault(s) over {T} steps "
          f"(seed {args.faults}, rate {args.fault_rate}, "
          f"{len({h[1] for h in hits})} tenant(s) hit)")
    return X, y, taus


def _maybe_guard(args, eng, state, metrics, tracer):
    """``--guard``: wrap the engine in a ``TickGuard`` (admission +
    poison-lane quarantine). With ``--snapshot-dir`` an initial
    committed snapshot seeds the quarantine-restore source."""
    if not args.guard:
        return eng, None
    from repro.robustness import TickGuard

    store = None
    if args.snapshot_dir:
        from repro.serving import SessionStore
        store = SessionStore(args.snapshot_dir, metrics=metrics,
                             tracer=tracer)
        store.save(0, state, meta=eng.meta(), blocking=True)
    guard = TickGuard(eng, store=store, metrics=metrics)
    src = "snapshot" if store is not None else "none (tripped lanes stay frozen)"
    print(f"[serve] guard: admission + quarantine on (restore source: {src})")
    return guard, guard


def _drain_guard(guard, state):
    if guard is None:
        return state
    state = guard.finalize(state)  # flush the deferred poison sweep
    rep = guard.drain()
    print(f"[serve] guard: rejected {sum(rep['rejected'].values())} "
          f"input(s) {dict(rep['rejected'])}, "
          f"{rep['quarantines']} quarantine(s), "
          f"{rep['restores']} restore(s), "
          f"{len(rep['quarantined_lanes'])} lane(s) still frozen")
    return state


def _snapshot_injector(args, metrics):
    """``--faults`` + ``--snapshot-dir``: an I/O fault injector for the
    snapshot roundtrip — one transient write failure on the final save,
    so every chaos run exercises the async saver's retry loop (the
    randomized keyed plans live in the test/bench suites)."""
    if args.faults < 0:
        return None
    from repro.robustness import Fault, FaultInjector, FaultPlan
    plan = FaultPlan(args.faults, (
        Fault("store.write", args.steps, "write_fail", times=1),))
    return FaultInjector(plan, metrics=metrics)


def _check_shards(shards: int, sessions: int) -> None:
    """CLI-friendly validation of --shards against --sessions and the
    visible device count (engine ctors raise ValueError for the same)."""
    if shards < 1:
        raise SystemExit("--shards must be >= 1")
    if shards == 1:
        return
    if sessions % shards:
        raise SystemExit(
            f"--sessions {sessions} is not divisible by --shards "
            f"{shards}; pad the session count")
    import jax

    if shards > jax.device_count():
        raise SystemExit(
            f"--shards {shards} exceeds the {jax.device_count()} visible "
            "device(s); on CPU, set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=N before launching")


def _telemetry(args):
    """One metrics registry + optional JSONL tracer per serving run."""
    from repro.telemetry import MetricsRegistry, Tracer

    metrics = MetricsRegistry()
    tracer = (Tracer(args.trace_out, annotate=args.annotate)
              if args.trace_out else None)
    return metrics, tracer


def _validity_metrics(pvals, drifted, args, *, engine, metrics,
                      use_max=False):
    """Feed the recorded per-tenant p-value stream ((S, T), NaN on
    warmup/inactive ticks) through the online validity monitors
    (``repro.telemetry.validity``) and publish the results as metrics:
    rolling empirical coverage vs 1-eps, the p-value-uniformity KS
    distance, and the exchangeability drift martingales (per-tenant
    ``drift_log_m`` gauges for the first 8 tenants, aggregate gauges for
    all). ``use_max`` flags drift on the running max of log M (valid by
    Ville's inequality) — the right read-out for measures that
    re-conform quickly after a change. Returns the per-tenant flags."""
    import numpy as np

    from repro.telemetry.validity import (CoverageMonitor, DriftMonitor,
                                          UniformityMonitor)

    p = np.asarray(pvals, float)
    S, T = p.shape
    cov = CoverageMonitor(args.eps, S, window=T)
    uni = UniformityMonitor(S, window=T)
    drift = DriftMonitor(S, threshold=args.log_threshold)
    for t in range(T):
        col = p[:, t]
        cov.update(col)
        uni.update(col)
        drift.update(col)
    cov.export(metrics, engine=engine)
    uni.export(metrics, engine=engine)
    drift.export(metrics, engine=engine, use_max=use_max)
    stat = drift.max_log_m if use_max else drift.log_m()
    for s in range(min(S, 8)):
        metrics.gauge("drift_log_m", engine=engine,
                      tenant=s, injected=bool(drifted[s])).set(
            float(stat[s]))
    metrics.gauge("drift_tenants_injected", engine=engine).set(
        int(np.asarray(drifted).sum()))
    return drift.flagged(use_max=use_max)


def _emit_report(args, metrics, tracer, *, mode) -> None:
    """THE report path — every serving mode renders through the metrics
    text export (single formatting code path) and the same two output
    files (``--metrics-out`` JSON dump, ``--trace-out`` JSONL trace)."""
    print(f"[serve] telemetry ({mode}):")
    for line in metrics.to_text().splitlines():
        print("  " + line)
    if args.metrics_out:
        metrics.dump(args.metrics_out)
        print(f"[serve] metrics -> {args.metrics_out}")
    if tracer is not None:
        tracer.close()
        print(f"[serve] trace -> {tracer.path}")


def _serve_sessions(args) -> int:
    """Multi-tenant online CP serving on the micro-batching engine."""
    import jax
    import numpy as np

    from repro.serving import ServingEngine

    metrics, tracer = _telemetry(args)
    S, T, dim = args.sessions, args.steps, args.dim
    if T < 2:
        raise SystemExit(
            "--steps must be >= 2 (tick 0 is the compile warmup)")
    _check_shards(args.shards, S)
    eng = ServingEngine(
        n_sessions=S, capacity=args.capacity, dim=dim, k=args.k,
        n_labels=2, window=args.window, instrument=True, metrics=metrics,
        tracer=tracer, shards=args.shards)
    state = eng.init_state()
    metrics.gauge("serve_shards", mode="classification").set(args.shards)
    print(f"[serve] engine: {S} sessions x cap {args.capacity} "
          f"(window={args.window}, k={args.k}, shards={args.shards})")

    X, y, taus, drifted = _class_drift_traffic(args, S, T, dim)
    X, y, taus = _chaos_traffic(args, X, y, taus, mode="classification")
    drv, guard = _maybe_guard(args, eng, state, metrics, tracer)
    pvals = np.zeros((S, T), np.float32)
    state, _ = drv.observe(  # warmup tick 0 outside the clock (compile)
        state, X[:, 0], y[:, 0], taus[:, 0])
    pvals[:, 0] = np.nan
    t0 = time.time()
    for t in range(1, T):
        state, p = drv.observe(state, X[:, t], y[:, t], taus[:, t])
        pvals[:, t] = np.asarray(p)
    dt = time.time() - t0
    metrics.gauge("serve_wall_s", mode="classification").set(dt)
    metrics.gauge("serve_session_steps_per_s", mode="classification").set(
        S * (T - 1) / dt)
    eng.telemetry.drain()
    state = _drain_guard(guard, state)
    _validity_metrics(pvals[:, 1:], drifted, args, engine="classification",
                      metrics=metrics)

    rc = 0
    if args.snapshot_dir:
        rc = _snapshot_roundtrip(args, state, eng, metrics, tracer)
    _emit_report(args, metrics, tracer, mode="classification")
    return rc


def _snapshot_roundtrip(args, state, eng, metrics, tracer) -> int:
    """Save + restore the final state, asserting bit-exactness. With
    ``--shards > 1`` the save goes through the async double-buffered
    sharded saver (host I/O of shard i overlaps the device pull of
    shard i+1 and any still-running compute)."""
    import jax
    import numpy as np

    from repro.serving import AsyncShardedSaver, SessionStore

    injector = _snapshot_injector(args, metrics)
    store = SessionStore(args.snapshot_dir, metrics=metrics, tracer=tracer,
                         injector=injector)
    if args.shards > 1 or injector is not None:
        # chaos mode routes even single-shard saves through the async
        # saver: its keyed-backoff retry loop is what absorbs injected
        # transient write failures
        saver = AsyncShardedSaver(store, max(args.shards, 1),
                                  metrics=metrics, seed=args.seed)
        saver.save(args.steps, state, meta=eng.meta())
        saver.close()
    else:
        store.save(args.steps, state, meta=eng.meta(), blocking=True)
    eng2, state2, step = store.restore_engine()
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(state2)))
    print(f"[serve] snapshot@step {step} -> restore "
          f"{'bit-exact' if same else 'MISMATCH'}")
    return 0 if same else 1


def _serve_registry(args) -> int:
    """Multi-tenant sliding-window serving through the measure registry.

    Python-loops over tenants (the registry predictors are the exact-
    shape API; the vmapped engines in ``repro.serving`` / ``repro.
    regression`` cover knn/regression) — this is the serving path for
    measures without a fixed-shape engine, e.g. ``bootstrap``.

    Drift is flagged on the *running maximum* of the log martingale: a
    registry measure that retrains on the live window every tick (the
    bootstrap ensemble especially) re-conforms within a few ticks of a
    change, so the evidence is a brief spike, not a sustained climb —
    and with a strongly adaptive measure even the spike can stay under
    the threshold. That fast re-conformance is expected behavior, not a
    detection bug; the sustained-drift showcase is the vmapped k-NN
    engine mode above.
    """
    import numpy as np

    from repro.serving import registry
    from repro.telemetry import EngineTelemetry

    spec = registry.get(args.measure)
    if spec.intervals is not None:
        raise SystemExit(
            f"--measure {args.measure} is a regression measure; use "
            "--regression for the engine-served regression path")
    S, T, dim, w = args.sessions, args.steps, args.dim, args.window
    warm = min(w, max(8, T // 4))
    if T <= warm + 2:
        raise SystemExit(f"--steps must exceed the warmup ({warm + 2})")

    metrics, tracer = _telemetry(args)
    tele = EngineTelemetry(engine="registry", metrics=metrics,
                           tracer=tracer)
    X, y, _, drifted = _class_drift_traffic(args, S, T, dim)
    X, y = np.asarray(X), np.asarray(y)

    hp_all = {"k": args.k, "n_labels": 2, "B": args.boot_b,
              "depth": args.tree_depth}
    hp = {k: v for k, v in hp_all.items() if k in spec.defaults}
    t0 = time.time()
    pvals = np.full((S, T), np.nan, np.float32)
    for s in range(S):
        cp = registry.ConformalPredictor(
            args.measure,
            **({**hp, "seed": args.seed + s} if "seed" in spec.defaults
               else hp))
        with tele.timed("fit", signature=args.measure, tenants=1):
            cp.fit(X[s, :warm], y[s, :warm])
        for t in range(warm, T):
            with tele.timed("pvalues", signature=args.measure, tenants=1):
                pvals[s, t] = np.asarray(
                    cp.pvalues(X[s, t][None]))[0, y[s, t]]
            with tele.timed("observe", signature=args.measure, tenants=1):
                cp.observe(X[s, t], int(y[s, t]))
            if cp.n > w:
                with tele.timed("evict", signature=args.measure,
                                tenants=1):
                    cp.evict(0)
    dt = time.time() - t0
    metrics.gauge("serve_wall_s", mode="registry",
                  measure=args.measure).set(dt)
    metrics.gauge("serve_session_steps_per_s", mode="registry",
                  measure=args.measure).set(S * (T - warm) / dt)
    _validity_metrics(pvals[:, warm:], drifted, args, engine="registry",
                      metrics=metrics, use_max=True)
    _emit_report(args, metrics, tracer, mode=f"registry:{args.measure}")
    return 0


def _serve_regression(args) -> int:
    """Multi-tenant streaming regression CP on the regression engine."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.regression import RegressionServingEngine

    metrics, tracer = _telemetry(args)
    S, T, dim = args.sessions, args.steps, args.dim
    if T < 2:
        raise SystemExit(
            "--steps must be >= 2 (tick 0 is the compile warmup)")
    _check_shards(args.shards, S)
    eng = RegressionServingEngine(
        n_sessions=S, capacity=args.capacity, dim=dim, k=args.k,
        window=args.window, instrument=True, metrics=metrics,
        tracer=tracer, shards=args.shards)
    state = eng.init_state()
    metrics.gauge("serve_shards", mode="regression").set(args.shards)
    print(f"[serve] regression engine: {S} sessions x cap {args.capacity} "
          f"(window={args.window}, k={args.k}, shards={args.shards})")

    # per-tenant linear traffic y = <w_s, x> + noise; odd tenants change
    # their regression function at T/2 (streaming drift detection)
    key = jax.random.PRNGKey(args.seed)
    kw, kx, kn, kt = jax.random.split(key, 4)
    W = jax.random.normal(kw, (S, dim), jnp.float32)
    X = jax.random.normal(kx, (S, T, dim), jnp.float32)
    noise = 0.1 * jax.random.normal(kn, (S, T), jnp.float32)
    y = jnp.einsum("sd,std->st", W, X) + noise
    drifted = jnp.arange(S) % 2 == 1
    late = jnp.arange(T)[None, :] >= T // 2
    y = jnp.where(drifted[:, None] & late, y + args.drift, y)
    taus = jax.random.uniform(kt, (S, T), dtype=jnp.float32)
    X, y, taus = _chaos_traffic(args, X, y, taus, mode="regression")
    drv, guard = _maybe_guard(args, eng, state, metrics, tracer)

    pvals = np.zeros((S, T), np.float32)
    state, _ = drv.observe(  # warmup tick 0 outside the clock (compile)
        state, X[:, 0], y[:, 0], taus[:, 0])
    pvals[:, 0] = np.nan
    t0 = time.time()
    for t in range(1, T):
        state, p = drv.observe(state, X[:, t], y[:, t], taus[:, t])
        pvals[:, t] = np.asarray(p)
    dt = time.time() - t0
    metrics.gauge("serve_wall_s", mode="regression").set(dt)
    metrics.gauge("serve_session_steps_per_s", mode="regression").set(
        S * (T - 1) / dt)
    eng.telemetry.drain()
    state = _drain_guard(guard, state)

    warm = 2 * args.k  # k-NN warmup: earliest p-values are degenerate
    _validity_metrics(pvals[:, warm:], drifted, args, engine="regression",
                      metrics=metrics)

    # exact prediction intervals for a fresh query batch, every tenant
    # in one dispatch
    Xq = jax.random.normal(jax.random.PRNGKey(args.seed + 1),
                           (4, dim), jnp.float32)
    iv = np.asarray(eng.intervals(state, Xq, epsilon=args.eps))
    widths = iv[:, :, 1] - iv[:, :, 0]
    metrics.gauge("intervals_finite_frac", engine="regression").set(
        float(np.isfinite(iv).mean()))
    metrics.gauge("intervals_median_width", engine="regression").set(
        float(np.nanmedian(widths)))

    rc = 0
    if args.snapshot_dir:
        rc = _snapshot_roundtrip(args, state, eng, metrics, tracer)
    _emit_report(args, metrics, tracer, mode="regression")
    return rc


def _serve_replay(args) -> int:
    """Trace replay / load-test mode (``--replay``): drive one engine
    from a recorded trace or a ``loadgen:<workload>`` spec, report
    p50/p99-under-load, and (``--auto-tune``) swap the hand-tuned
    observe_many chunk for the cost model's ``suggest_chunk``."""
    from repro.telemetry import (CostModel, calibrate_engine, iter_trace,
                                 loadgen, replay)
    from repro.telemetry.tracer import capacity_bucket

    kind = "regression" if args.regression else "classification"
    slo_s = args.slo_ms / 1000.0 if args.slo_ms > 0 else None
    speedup = float(args.speedup)  # accepts "inf"

    if args.replay.startswith("loadgen:"):
        plan = None
        if args.faults >= 0:
            from repro.robustness import VALUE_FAULTS, FaultPlan
            plan = FaultPlan.random(
                args.faults, steps=args.steps, tenants=args.sessions or 8,
                rate=args.fault_rate,
                kinds=VALUE_FAULTS + ("duplicate_arrival", "delay"),
                param=0.001)
            print(f"[serve] chaos: stamping {len(plan)} fault(s) onto "
                  f"the generated trace (seed {args.faults})")
        workload = args.replay.split(":", 1)[1]
        records = loadgen.generate(
            workload, ops=args.steps, tenants=args.sessions or 8,
            capacity=args.capacity, engine=kind, rate=args.rate,
            seed=args.seed, slo_s=slo_s, faults=plan)
        src = args.replay
    else:
        records = list(iter_trace(args.replay))
        src = args.replay
    tenants = max(int(r.get("tenants", 1)) for r in records)
    cap = max((int(r.get("capacity", 0)) for r in records),
              default=0) or args.capacity

    # cost model: load one > fit from the trace's steady timing > probe
    # the engine (loadgen traces record arrivals, not costs)
    model = None
    chunk = None
    if args.cost_model:
        model = CostModel.load(args.cost_model)
        print(f"[serve] cost model <- {args.cost_model}")
    elif args.auto_tune or args.cost_model_out:
        model = CostModel.fit(records, source=src)
        if not model.entries:
            print("[serve] trace carries no steady timing; "
                  "calibrating the engine")
            model = CostModel.fit(
                calibrate_engine(kind, tenants=tenants, capacity=cap,
                                 dim=args.dim, k=args.k, seed=args.seed),
                source="calibrate")
    if args.auto_tune and model is not None and model.entries:
        chunk = model.suggest_chunk(cap_bucket=capacity_bucket(cap),
                                    engine=kind)
        print(f"[serve] auto-tune: observe_many chunk <- {chunk}")
    if args.cost_model_out and model is not None:
        model.save(args.cost_model_out)
        print(f"[serve] cost model -> {args.cost_model_out}")

    if args.shards > 1 and args.shards > tenants:
        raise SystemExit(f"--shards {args.shards} exceeds the trace's "
                         f"{tenants} tenants")
    metrics, tracer = _telemetry(args)
    metrics.gauge("serve_shards", mode="replay").set(args.shards)
    res = replay(records, engine=kind, dim=args.dim, k=args.k,
                 window=min(args.window, cap),  # trace may be smaller
                 speedup=speedup, seed=args.seed,
                 slo_s=slo_s, chunk=chunk, eps=args.eps, metrics=metrics,
                 tracer=tracer, shards=args.shards,
                 shed_depth=args.shed_depth if args.shed_depth > 0 else None,
                 guard=args.guard)
    rep = res.report
    print(f"[serve] replay {src} -> {kind} engine "
          f"({rep['tenants']} tenants x cap {rep['capacity']}, "
          f"{rep['shards']} shard(s)): "
          f"{rep['ops_replayed']} ops ({rep['ops_skipped']} skipped), "
          f"{rep['ticks']} ticks in {rep['wall_s']:.3f}s "
          f"({rep['steps_per_s']:.0f} session steps/s)")
    if rep["shards"] > 1:
        for sh in rep["per_shard"]:
            print(f"  shard {sh['shard']}: {sh['tenants']} tenants, "
                  f"{sh['session_steps']} steps, occupancy mean "
                  f"{sh['occupancy_mean']:.1f} max {sh['occupancy_max']}")
    for op, d in rep["per_op"].items():
        print(f"  {op:12s} p50={d['p50_s'] * 1e3:8.3f}ms "
              f"p99={d['p99_s'] * 1e3:8.3f}ms "
              f"sojourn_p99={d['sojourn_p99_s'] * 1e3:8.3f}ms "
              f"n={d['count']:.0f}")
    if slo_s is not None:
        print(f"  SLO {args.slo_ms:g}ms: violation fraction "
              f"{rep['slo_violation_frac']:.4f}")
    print(f"  queue depth max {rep['queue_depth_max']:.0f}")
    if rep.get("duplicates_dropped"):
        print(f"  chaos: {rep['duplicates_dropped']} duplicate "
              f"arrival(s) dropped")
    if rep.get("shed_depth") is not None:
        print(f"  shed(depth {rep['shed_depth']}): "
              f"{rep['shed_ops']} read(s) shed, "
              f"{rep['deferred_observes']} observe(s) deferred")
    if "guard" in rep:
        g = rep["guard"]
        print(f"  guard: rejected {sum(g['rejected'].values())} input(s) "
              f"{dict(g['rejected'])}, {g['quarantines']} quarantine(s), "
              f"{g['restores']} restore(s)")
    _emit_report(args, metrics, tracer, mode=f"replay:{kind}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--calib", type=int, default=256)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    # multi-tenant online CP mode (repro.serving)
    ap.add_argument("--sessions", type=int, default=0,
                    help="serve N concurrent CP sessions (0 = LM mode)")
    ap.add_argument("--steps", type=int, default=128)
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--k", type=int, default=7)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--drift", type=float, default=2.0)
    ap.add_argument("--log-threshold", type=float, default=2.0)
    ap.add_argument("--snapshot-dir", default="")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the session axis across N devices "
                         "(engine modes: one shard_map'd dispatch per "
                         "tick, bit-identical to --shards 1; replay "
                         "mode: N per-shard engines with merged "
                         "metrics). On CPU, force virtual devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N")
    ap.add_argument("--regression", action="store_true",
                    help="with --sessions: serve streaming regression CP "
                         "(prediction intervals) instead of classification")
    ap.add_argument("--measure", default="",
                    help="with --sessions: serve through the measure "
                         "registry (e.g. bootstrap) instead of the "
                         "vmapped engine")
    ap.add_argument("--boot-b", type=int, default=5,
                    help="bootstrap ensemble size B (--measure bootstrap)")
    ap.add_argument("--tree-depth", type=int, default=3,
                    help="bootstrap tree depth (--measure bootstrap)")
    # trace replay / load testing (repro.telemetry.replay)
    ap.add_argument("--replay", default="",
                    help="replay a JSONL trace file, or synthesize one "
                         "with loadgen:<workload> (steady|bursty|diurnal|"
                         "zipf, --steps ops, --sessions tenants)")
    ap.add_argument("--speedup", default="inf",
                    help="compress the trace's inter-arrival times by "
                         "this factor; 'inf' (default) replays "
                         "back-to-back (deterministic, CI mode)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="latency SLO in ms; report the fraction of "
                         "replayed ops whose sojourn exceeds it "
                         "(0 = no SLO)")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="loadgen mean arrival rate, ops/s of the trace "
                         "clock (rescaled by --speedup)")
    ap.add_argument("--auto-tune", action="store_true",
                    help="with --replay: fit the per-(op, capacity-"
                         "bucket) cost model and use its suggest_chunk "
                         "instead of the hand-tuned observe_many chunk")
    ap.add_argument("--cost-model", default="",
                    help="load a fitted cost model JSON instead of "
                         "fitting/calibrating one")
    ap.add_argument("--cost-model-out", default="",
                    help="save the fitted cost model JSON here")
    # telemetry (repro.telemetry) — serving modes only
    ap.add_argument("--metrics-out", default="",
                    help="write the end-of-run metrics snapshot to this "
                         "JSON file (the same snapshot the report prints)")
    ap.add_argument("--trace-out", default="",
                    help="record one JSONL trace record per engine op to "
                         "this file (schema: repro.telemetry.tracer)")
    ap.add_argument("--annotate", action="store_true",
                    help="with --trace-out: wrap traced ops in "
                         "jax.profiler.TraceAnnotation scopes")
    # chaos / fault tolerance (repro.robustness)
    ap.add_argument("--faults", type=int, default=-1, metavar="SEED",
                    help="inject a keyed random fault plan (repro."
                         "robustness.FaultPlan.random) with this seed: "
                         "engine modes corrupt the synthetic traffic and "
                         "(with --snapshot-dir) the snapshot I/O path; "
                         "loadgen replay stamps value/duplicate/delay "
                         "faults onto the trace. -1 (default) disables")
    ap.add_argument("--fault-rate", type=float, default=0.02,
                    help="per-(step, site) fault probability for --faults")
    ap.add_argument("--guard", action="store_true",
                    help="wrap the engine in the robustness TickGuard: "
                         "in-graph admission of observe inputs + poison-"
                         "lane quarantine (restore from --snapshot-dir "
                         "when set). Engine-serving and replay modes")
    ap.add_argument("--shed-depth", type=int, default=0,
                    help="with --replay: shed reads once the replay "
                         "backlog exceeds this depth and defer observes "
                         "past twice it (0 = no shedding)")
    # static invariant audit (repro.analysis.audit)
    ap.add_argument("--audit", action="store_true",
                    help="run the compiled-artifact invariant audit over "
                         "the engine matrix and exit (no serving); "
                         "nonzero exit on any violation")
    ap.add_argument("--audit-out", default="audit_report.json",
                    help="with --audit: JSON report path")
    args = ap.parse_args(argv)

    if args.audit:
        from repro.analysis import audit as audit_m
        return audit_m.main(
            ["--out", args.audit_out, "--no-reexec",
             "--max-shards", str(max(args.shards, 1))])
    if args.replay:
        if args.measure:
            raise SystemExit("--replay and --measure are exclusive")
        return _serve_replay(args)
    if args.sessions > 0:
        if args.measure:
            if args.regression:
                raise SystemExit("--measure and --regression are exclusive")
            if args.guard or args.faults >= 0:
                raise SystemExit("--guard/--faults cover the engine and "
                                 "replay modes, not --measure")
            return _serve_registry(args)
        if args.regression:
            return _serve_regression(args)
        return _serve_sessions(args)
    if args.regression:
        raise SystemExit("--regression requires --sessions N")
    if args.measure:
        raise SystemExit("--measure requires --sessions N")

    import jax
    import jax.numpy as jnp

    import repro.configs as cfgs
    from repro.core.lm_conformal import (ConformalOodDetector,
                                         sequence_embedding)
    from repro.data.lm_pipeline import TokenStream
    from repro.models import lm

    cfg = cfgs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, P, G = args.requests, args.prompt_len, args.gen_tokens
    params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)

    # ---- calibration traffic -> conformal OOD head ------------------------
    stream = TokenStream(cfg, args.calib, P, seed=args.seed)
    calib_batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    emb_fn = jax.jit(lambda p, b: sequence_embedding(p, cfg, b, lm))
    calib_emb = emb_fn(params, calib_batch)
    ood = ConformalOodDetector(k=7).fit(calib_emb)
    print(f"[serve] conformal OOD head fit on {args.calib} sequences")

    # ---- requests: half in-distribution, half corrupted --------------------
    req_stream = TokenStream(cfg, B, P, seed=args.seed + 1)
    req = {k: jnp.asarray(v) for k, v in req_stream.batch_at(0).items()}
    tokens = req["tokens"]
    key = jax.random.PRNGKey(args.seed + 2)
    noise = jax.random.randint(key, tokens[B // 2:].shape, 0,
                               cfg.vocab_size, dtype=tokens.dtype)
    tokens = tokens.at[B // 2:].set(noise)  # OOD half: uniform tokens
    req["tokens"] = tokens

    # ---- decode loop -------------------------------------------------------
    max_len = P + G
    cache = lm.init_cache(cfg, B, max_len)
    if cfg.is_encoder_decoder:
        cache["cross"] = lm.prefill_cross_cache(params, cfg, req["frames"])
    step = jax.jit(
        lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i),
        donate_argnums=(2,))

    t0 = time.time()
    logits = None
    for i in range(P):  # prefill via teacher-forced decode steps
        logits, cache = step(params, tokens[:, i:i + 1], cache, i)
    generated = []
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for g in range(G):
        generated.append(cur)
        logits, cache = step(params, cur, cache, P + g)
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    gen = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0

    # ---- conformal OOD p-values per request -------------------------------
    req_emb = emb_fn(params, req)
    pvals = ood.pvalues(req_emb)
    print(f"[serve] {B} requests x {G} tokens in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s)")
    for i in range(B):
        flag = "OOD!" if pvals[i] <= args.eps else "ok  "
        print(f"  req {i:2d} [{flag}] p={float(pvals[i]):.3f} "
              f"gen={[int(t) for t in gen[i][:6]]}")
    in_p = pvals[:B // 2]
    out_p = pvals[B // 2:]
    print(f"[serve] mean p in-dist={float(jnp.mean(in_p)):.3f} "
          f"corrupted={float(jnp.mean(out_p)):.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
