"""Serving launcher: batched decode + conformal guarantees per request.

    python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
        --requests 16 --gen-tokens 8 --calib 512

Pipeline per batch of requests:
    1. prefill the prompt, build per-layer KV/recurrent caches,
    2. greedy decode ``gen_tokens`` steps with the serve_step,
    3. conformal OOD p-value per request (simplified k-NN CP over sequence
       embeddings, the paper's optimized O(n)-per-query path) — the serving
       feature the paper's speedups make affordable at this layer.

Prefill fills the KV caches by running serve_step over prompt positions
(teacher-forced); production prefill is the fused prefill_step (dry-run
cell), cache handoff being the same structure.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--calib", type=int, default=256)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    import repro.configs as cfgs
    from repro.core.lm_conformal import (ConformalOodDetector,
                                         sequence_embedding)
    from repro.data.lm_pipeline import TokenStream
    from repro.models import lm

    cfg = cfgs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, P, G = args.requests, args.prompt_len, args.gen_tokens
    params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)

    # ---- calibration traffic -> conformal OOD head ------------------------
    stream = TokenStream(cfg, args.calib, P, seed=args.seed)
    calib_batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    emb_fn = jax.jit(lambda p, b: sequence_embedding(p, cfg, b, lm))
    calib_emb = emb_fn(params, calib_batch)
    ood = ConformalOodDetector(k=7).fit(calib_emb)
    print(f"[serve] conformal OOD head fit on {args.calib} sequences")

    # ---- requests: half in-distribution, half corrupted --------------------
    req_stream = TokenStream(cfg, B, P, seed=args.seed + 1)
    req = {k: jnp.asarray(v) for k, v in req_stream.batch_at(0).items()}
    tokens = req["tokens"]
    key = jax.random.PRNGKey(args.seed + 2)
    noise = jax.random.randint(key, tokens[B // 2:].shape, 0,
                               cfg.vocab_size, dtype=tokens.dtype)
    tokens = tokens.at[B // 2:].set(noise)  # OOD half: uniform tokens
    req["tokens"] = tokens

    # ---- decode loop -------------------------------------------------------
    max_len = P + G
    cache = lm.init_cache(cfg, B, max_len)
    if cfg.is_encoder_decoder:
        cache["cross"] = lm.prefill_cross_cache(params, cfg, req["frames"])
    step = jax.jit(
        lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i),
        donate_argnums=(2,))

    t0 = time.time()
    logits = None
    for i in range(P):  # prefill via teacher-forced decode steps
        logits, cache = step(params, tokens[:, i:i + 1], cache, i)
    generated = []
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for g in range(G):
        generated.append(cur)
        logits, cache = step(params, cur, cache, P + g)
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    gen = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0

    # ---- conformal OOD p-values per request -------------------------------
    req_emb = emb_fn(params, req)
    pvals = ood.pvalues(req_emb)
    print(f"[serve] {B} requests x {G} tokens in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s)")
    for i in range(B):
        flag = "OOD!" if pvals[i] <= args.eps else "ok  "
        print(f"  req {i:2d} [{flag}] p={float(pvals[i]):.3f} "
              f"gen={[int(t) for t in gen[i][:6]]}")
    in_p = pvals[:B // 2]
    out_p = pvals[B // 2:]
    print(f"[serve] mean p in-dist={float(jnp.mean(in_p)):.3f} "
          f"corrupted={float(jnp.mean(out_p)):.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
