"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state: device count is locked on first jax init, and the
smoke tests / benches must keep seeing the single real CPU device while the
dry-run process (which sets XLA_FLAGS *before* any import) sees 512.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-agnostic jax.make_mesh (Auto axis types where supported).

    jax >= 0.6 takes ``axis_types``; on 0.4.x the kwarg (and
    ``jax.sharding.AxisType``) don't exist and Auto is the behaviour.
    """
    try:
        kinds = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=kinds)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh((data, model), ("data", "model"))


__all__ = ["make_mesh", "make_production_mesh", "make_host_mesh"]
