"""Training launcher.

    python -m repro.launch.train --arch qwen2-1.5b --reduced \\
        --steps 200 --batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt

``--reduced`` swaps in the smoke-scale config of the same family (the CPU
container path); full-scale configs target the production mesh (see
launch/dryrun.py for the compile-only proof). The trainer provides
checkpoint/restart, preemption handling, straggler logging (runtime/).
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    import repro.configs as cfgs
    from repro.launch.mesh import make_host_mesh
    from repro.optim import OptimizerConfig
    from repro.runtime import Trainer, TrainerConfig

    cfg = cfgs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=args.log_every, seed=args.seed,
        batch=args.batch, seq_len=args.seq_len,
        microbatches=args.microbatches)
    ocfg = OptimizerConfig(peak_lr=args.lr, end_lr=args.lr / 10,
                           warmup_steps=max(1, args.steps // 20),
                           total_steps=args.steps)
    out = Trainer(cfg, tcfg, mesh, ocfg).run()
    print(f"[train] done: steps={out['stop_step']} "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
