"""The three lowered entry points: train_step, prefill_step, serve_step.

``train_step`` is the full production step — loss, grads, clip, AdamW — so
``compiled.memory_analysis()`` accounts for optimizer state and gradient
buffers, and ``cost_analysis()`` sees forward+backward+update FLOPs.
``serve_step`` is one-token decode against a preallocated KV/recurrent
cache. ``prefill_step`` is a forward pass producing logits.

``input_specs`` builds ShapeDtypeStruct stand-ins (weak-type-correct,
sharding-annotated, zero allocation) for every (arch x shape) cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec, shape_by_name
from repro.models import lm
from repro.optim import OptimizerConfig, apply_updates, init_opt_state
from repro.sharding import batch_pspecs, cache_pspecs, param_pspecs


# ---------------------------------------------------------------------------
# step functions (pure; closed over cfg via partial)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    microbatches: int = 1, mesh=None):
    """Full production step: (micro-batched) grads -> clip -> AdamW.

    ``microbatches > 1`` scans gradient accumulation over batch slices —
    activation memory scales with the slice while the accumulator is one
    param-sharded grad tree (the knob that fits 64k-token-per-device cells
    into HBM; see EXPERIMENTS.md §Dry-run).

    When ``mesh`` is given, per-microbatch grads AND the f32 accumulator
    are constrained to the parameter sharding: without this the partitioner
    materializes replicated f32 weight-grad all-reduces inside the
    accumulation loop (EXPERIMENTS.md §Perf granite iteration 1)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm.train_step_loss(p, cfg, batch))(params)

    if mesh is not None:
        from repro.sharding import param_pspecs

        def shard_like_params(tree):
            specs = param_pspecs(tree, mesh)
            return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                                specs)
    else:
        def shard_like_params(tree):
            return tree

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = shard_like_params(grads)
        else:
            split = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def body(acc, mb):
                loss_a, g_a = acc
                l, g = grads_of(params, mb)
                g = shard_like_params(g)
                g_a = jax.tree.map(
                    lambda x, y: x + y.astype(x.dtype), g_a, g)
                return (loss_a + l, shard_like_params(g_a)), None

            init = (jnp.zeros((), jnp.float32),
                    shard_like_params(jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)))
            (loss, grads), _ = jax.lax.scan(body, init, split)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, stats = apply_updates(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def default_microbatches(cfg: ArchConfig, shape: ShapeSpec, mesh,
                         target_tokens_per_device: int = 16_384) -> int:
    """Largest power-of-2 split keeping per-device microbatch tokens at the
    target while the per-microbatch batch still shards over dp."""
    import numpy as np

    from repro.sharding import dp_axes

    axes = dp_axes(mesh)
    if resolve_strategy(cfg, shape.name, mesh) == "fsdp":
        axes = axes + ("model",)
    dp = int(np.prod([mesh.shape[a] for a in axes]))
    B, S = shape.global_batch, shape.seq_len
    if B % dp:
        return 1
    b_dev = B // dp
    k = 1
    while (k < b_dev and (b_dev // k) * S > target_tokens_per_device
           and b_dev % (2 * k) == 0):
        k *= 2
    return k


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            logits, _ = lm.forward_encdec(params, cfg, batch)
        else:
            logits, _, _ = lm.forward(params, cfg, batch)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, tokens, cache, index):
        logits, new_cache = lm.decode_step(params, cfg, tokens, cache, index)
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# shape stand-ins
# ---------------------------------------------------------------------------


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_struct(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract input batch for one workload shape (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.is_encoder_decoder:
        return {
            "frames": _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                           jnp.dtype(cfg.dtype)),
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        s_txt = S - cfg.n_frontend_tokens
        return {
            "tokens": _sds((B, s_txt), jnp.int32),
            "patch_embeds": _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype)),
            "labels": _sds((B, s_txt), jnp.int32),
        }
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def _attach(tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, NamedSharding(mesh, sp)),
        tree, spec_tree)


def resolve_strategy(cfg: ArchConfig, shape_name: str, mesh) -> str:
    """Per-cell strategy with a divisibility guard: fsdp needs the global
    batch to split across EVERY mesh axis (e.g. granite's fsdp override
    applies on the 256-chip pod but falls back to tp_sp on 512 chips)."""
    import numpy as np

    strategy = cfg.strategy_for(shape_name)
    if strategy == "fsdp":
        total = int(np.prod(list(mesh.shape.values())))
        if shape_by_name(shape_name).global_batch % total:
            return "tp_sp"
    return strategy


def input_specs(cfg: ArchConfig, shape_name: str, mesh,
                opt_cfg: OptimizerConfig | None = None):
    """Sharded ShapeDtypeStructs for one (arch x shape) dry-run cell.

    Returns (kind, args): train -> (params, opt_state, batch);
    prefill -> (params, batch); decode -> (params, tokens, cache, index).
    """
    shape = shape_by_name(shape_name)
    opt_cfg = opt_cfg or OptimizerConfig()

    params = jax.eval_shape(
        lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    pspecs = param_pspecs(params, mesh)
    params = _attach(params, pspecs, mesh)

    strategy = resolve_strategy(cfg, shape.name, mesh)
    batch = batch_struct(cfg, shape)
    bspecs = batch_pspecs(batch, mesh, strategy)
    batch = _attach(batch, bspecs, mesh)

    if shape.kind == "train":
        opt_state = jax.eval_shape(
            lambda: init_opt_state(params, opt_cfg))
        ospecs = param_pspecs(opt_state, mesh)
        opt_state = _attach(opt_state, ospecs, mesh)
        return "train", (params, opt_state, batch)

    if shape.kind == "prefill":
        return "prefill", (params, batch)

    # decode: preallocated cache of seq_len, one new token
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = cache_pspecs(cache, mesh, resolve_strategy(cfg, shape.name,
                                                        mesh))
    cache = _attach(cache, cspecs, mesh)
    index = _sds((), jnp.int32)
    return "decode", (params, batch["tokens"], cache, index)


def cell_fn_and_args(cfg: ArchConfig, shape_name: str, mesh,
                     opt_cfg: OptimizerConfig | None = None,
                     microbatches: int | None = None):
    """(kind, fn, args, donate_argnums) for one (arch x shape) cell."""
    kind, args = input_specs(cfg, shape_name, mesh, opt_cfg)
    opt_cfg = opt_cfg or OptimizerConfig()
    if kind == "train":
        if microbatches is None:
            microbatches = default_microbatches(
                cfg, shape_by_name(shape_name), mesh,
                target_tokens_per_device=cfg.microbatch_target_tokens)
        return (kind, make_train_step(cfg, opt_cfg, microbatches, mesh),
                args, (0, 1))
    if kind == "prefill":
        return kind, make_prefill_step(cfg), args, ()
    return kind, make_serve_step(cfg), args, (2,)


def lower_cell(cfg: ArchConfig, shape_name: str, mesh,
               opt_cfg: OptimizerConfig | None = None, donate: bool = True):
    """jit-lower one (arch x shape x mesh) cell. Returns the Lowered."""
    from repro.sharding.activation import activation_mesh

    kind, fn, args, donate_argnums = cell_fn_and_args(
        cfg, shape_name, mesh, opt_cfg)
    with mesh, activation_mesh(mesh, resolve_strategy(cfg, shape_name,
                                                      mesh)):
        return jax.jit(
            fn, donate_argnums=donate_argnums if donate else ()).lower(*args)


__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "batch_struct", "input_specs", "lower_cell"]
