import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first init. 512 placeholder host devices back both production
# meshes (the 16x16 single pod uses the first 256).

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, on the 16x16 single-pod and
2x16x16 two-pod meshes:

    lowered  = jax.jit(step).lower(*input_specs(...))   # sharding-annotated
    compiled = lowered.compile()
    compiled.memory_analysis()   # fits per-device HBM?
    compiled.cost_analysis()     # FLOPs / bytes for the roofline table

plus a collective-bytes sweep over the optimized HLO (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute operand
sizes) — the third roofline term. Results go to JSON for EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    import jax

    import repro.configs as cfgs
    from repro.analysis.flops import flops_of
    from repro.analysis.hlo import collective_bytes, count_ops, hbm_bytes
    from repro.configs.base import shape_by_name
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import cell_fn_and_args

    cfg = cfgs.get(arch)
    if shape_name not in cfg.shapes:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "shape not applicable (DESIGN.md "
                          "§Arch-applicability)"}

    from repro.sharding.activation import activation_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    kind, fn, args, donate = cell_fn_and_args(cfg, shape_name, mesh)
    t0 = time.time()
    from repro.launch.steps import resolve_strategy
    with mesh, activation_mesh(mesh, resolve_strategy(cfg, shape_name,
                                                      mesh)):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    ops = count_ops(hlo_text)
    dev_bytes = hbm_bytes(hlo_text)
    dev_bytes_flash = hbm_bytes(hlo_text, flash_adjusted=True)
    with mesh, activation_mesh(mesh, resolve_strategy(cfg, shape_name,
                                                      mesh)):
        jflops = flops_of(fn, *args)  # global, scan-trip exact

    shape = shape_by_name(shape_name)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "status": "ok",
        "flops_global": float(jflops["flops"]),
        "device_hbm_bytes": float(dev_bytes),
        "device_hbm_bytes_flash_adjusted": float(dev_bytes_flash),
        "collective_bytes": {k: float(v) for k, v in coll.items()},
        "hlo_ops": ops,
        "xla_cost_flops_per_device_loopbody_once": float(
            compiled.cost_analysis().get("flops", 0.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        },
        "tokens_per_step": tokens,
        "n_params": cfg.n_params(),
        "active_params": cfg.active_params(),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {res['mesh']}: OK "
              f"flops={res['flops_global']:.3e} "
              f"hbm/dev={dev_bytes:.3e}B "
              f"coll/dev={sum(coll.values()):.3e}B "
              f"temp/dev={res['memory']['temp_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    import repro.configs as cfgs
    from repro.configs.base import LM_SHAPES

    if args.all:
        archs = list(cfgs.names())
        shapes = [s.name for s in LM_SHAPES]
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results, failed = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, multi_pod=mp))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failed += 1
                    traceback.print_exc()
                    results.append({
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "failed", "error": f"{type(e).__name__}: {e}",
                    })

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} cells to {args.out}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
