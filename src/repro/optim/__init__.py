"""Optimizer substrate: AdamW / factored moments, schedules, clipping."""
from repro.optim.adamw import (OptimizerConfig, apply_updates, global_norm,
                               init_opt_state, lr_schedule)

__all__ = ["OptimizerConfig", "apply_updates", "global_norm",
           "init_opt_state", "lr_schedule"]
