"""Optimizers: AdamW and a factored-second-moment variant (Adafactor-style).

Self-contained (no optax in the offline container). State trees mirror the
param tree, so sharding rules apply to optimizer state for free (ZeRO-style:
moments shard exactly like their parameters — over BOTH the data/FSDP and
model axes, giving full 256-way state sharding on the production mesh).

``factored=True`` replaces the (fp32) second moment of every >=2-D parameter
with row/col statistics — an 8x HBM cut on the 236B MoE where Adam moments
would dominate the per-device memory budget (DESIGN.md §memory).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    factored: bool = False  # factored 2nd moment for >=2D params
    moment_dtype: Any = jnp.float32


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup then cosine decay to end_lr."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.end_lr + 0.5 * (cfg.peak_lr - cfg.end_lr) * (
        1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def init_opt_state(params, cfg: OptimizerConfig) -> dict:
    def mu(p):
        return jnp.zeros(p.shape, cfg.moment_dtype)

    def nu(p):
        if cfg.factored and _factorable(p.shape):
            return {
                "row": jnp.zeros(p.shape[:-1], cfg.moment_dtype),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                 cfg.moment_dtype),
            }
        return {"full": jnp.zeros(p.shape, cfg.moment_dtype)}

    return {
        "mu": jax.tree.map(mu, params),
        "nu": jax.tree.map(nu, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state, cfg: OptimizerConfig):
    """One AdamW / factored-Adam step. Returns (params, opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        if "full" in v:
            v_new = {"full": cfg.b2 * v["full"].astype(jnp.float32)
                     + (1 - cfg.b2) * g * g}
            v_hat = v_new["full"] / c2
        else:
            row = cfg.b2 * v["row"].astype(jnp.float32) \
                + (1 - cfg.b2) * jnp.mean(g * g, axis=-1)
            col = cfg.b2 * v["col"].astype(jnp.float32) \
                + (1 - cfg.b2) * jnp.mean(g * g, axis=-2)
            v_new = {"row": row, "col": col}
            # rank-1 reconstruction: v ~ row x col / mean(row)
            denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
            v_hat = (row[..., None] * col[..., None, :] / denom[..., None]
                     ) / c2
        update = (m_new / c1) / (jnp.sqrt(v_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), jax.tree.map(
            lambda a, b: b.astype(a.dtype), v, v_new)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])

    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, stats


__all__ = ["OptimizerConfig", "init_opt_state", "apply_updates",
           "lr_schedule", "global_norm"]
