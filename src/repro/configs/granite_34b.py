"""granite-34b [dense]: 88L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Llama-architecture code model: SwiGLU, RMSNorm, RoPE, multi-query attention,
tied embeddings. [arXiv:2405.04324; hf]

long_500k skipped: pure full attention (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    act="silu",
    # train_4k: global batch (256) == chip count -> pure ZeRO-3 beats
    # Megatron TP+SP by ~3.4x on the collective term (EXPERIMENTS.md §Perf)
    parallelism_overrides=(("train_4k", "fsdp"),),
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[arXiv:2405.04324; hf]",
)
