"""recurrentgemma-9b [hybrid]: 38 blocks d=4096, pattern
(RG-LRU, RG-LRU, local-attn) — 1 attention per 2 recurrent blocks — 16H
MQA (kv=1, 256-dim heads, window 2048), d_ff=12288, vocab=256000.
[arXiv:2402.19427; unverified]

lru_width = d_model (4096); gate projections are full WxW (the released
model uses block-diagonal — an immaterial difference for roofline/sharding,
noted in DESIGN.md). Gemma-style (1+w) RMSNorm + sqrt(d) embed scaling.
long_500k included: hybrid recurrent + local attention is sub-quadratic.
"""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    "attn_local" if (i % 3) == 2 else "rglru" for i in range(38))

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=_PATTERN,
    window=2048,
    lru_width=4096,
    conv1d_width=4,
    rms_offset=1.0,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="[arXiv:2402.19427; unverified]",
)
