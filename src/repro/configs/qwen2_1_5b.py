"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

QKV bias (the qwen2 signature), 128-dim heads, SwiGLU, tied embeddings.
[arXiv:2407.10671; hf]

long_500k skipped: pure full attention (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    # global batch (256) == single-pod chip count: pure ZeRO-3 cuts the
    # train_4k step bound 4-20x vs TP+SP (EXPERIMENTS.md §Perf sweep);
    # guarded fallback to tp_sp on the 512-chip mesh
    parallelism_overrides=(("train_4k", "fsdp"),),
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[arXiv:2407.10671; hf]",
)
