"""The paper's own experimental configuration (Appendix E).

Benchmarks default to these hyperparameters; the n-grid is scaled to the
CPU container (the paper spans numpy.logspace(1, 5, 13) on a 48-thread
Xeon with 10h/48h timeouts).
"""
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class PaperConfig:
    # App. E hyperparameter table
    knn_k: int = 15                   # Euclidean distance, k = 15
    kde_bandwidth: float = 1.0        # Gaussian kernel, h = 1
    lssvm_kernel: str = "linear"      # linear kernel
    lssvm_rho: float = 1.0            # rho = 1
    bootstrap_B: int = 10             # Random Forest, B = 10 trees
    tree_depth: int = 10              # depth <= 10, sqrt(p) features/split
    # §7.1 setup
    n_features: int = 30              # make_classification(30 features)
    n_test: int = 100                 # 100 test points per size
    n_seeds: int = 5                  # 5 initialization seeds
    icp_train_frac: float = 0.5      # t/n = 0.5
    # App. G (MNIST): 784 features, 10 labels, 60k/10k split
    mnist_features: int = 784
    mnist_labels: int = 10

    def paper_n_grid(self) -> np.ndarray:
        """The paper's exact grid: numpy.logspace(1, 5, 13) (footnote 3)."""
        return np.logspace(1, 5, 13, dtype="int")


CONFIG = PaperConfig()
