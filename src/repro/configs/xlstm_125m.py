"""xlstm-125m [ssm]: 12 blocks d=768 4H, vocab=50304, no separate FFN
(d_ff=0): mLSTM blocks (matrix memory, chunkwise-parallel) with periodic
sLSTM blocks (scalar memory, sequential scan) at a 5:1 ratio.
[arXiv:2405.04517; unverified]

long_500k included: linear-time recurrence, O(1) decode state.
"""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    "slstm" if (i % 6) == 5 else "mlstm" for i in range(12))

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    layer_pattern=_PATTERN,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=1.334,
    conv1d_width=4,
    act="gelu",
    tie_embeddings=False,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="[arXiv:2405.04517; unverified]",
)
