"""internvl2-26b [vlm]: InternLM2-20B backbone, 48L d=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553 + InternViT vision frontend. [arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, 256, d_model) which are prepended to the
token embeddings; loss is computed on text positions only.
long_500k skipped: pure full attention (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    frontend="vision_stub",
    n_frontend_tokens=256,
    act="silu",
    # ZeRO-3 for train_4k (batch==chip count): step bound ~7.5s vs ~40s
    # tp_sp (EXPERIMENTS.md §Perf sweep)
    parallelism_overrides=(("train_4k", "fsdp"),),
    tie_embeddings=False,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[arXiv:2404.16821; hf]",
)
