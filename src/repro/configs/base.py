"""Architecture + shape configuration schema.

Every assigned architecture is one ``ArchConfig`` in ``repro.configs.<id>``;
``repro.configs.get(name)`` resolves it. A config fully determines parameter
shapes, layer pattern, sharding rules and the input specs for each of the
four assigned workload shapes (train_4k / prefill_32k / decode_32k /
long_500k). ``reduced()`` derives the CPU-smoke-test variant of the same
family (same layer kinds and code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff: int = 0  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # expert placement: "ep" (expert dim over model axis), "tp" (expert
    # hidden over model), or "dense" (no dispatch: all experts for every
    # token, router-mask combine — wins for small E at large batch,
    # EXPERIMENTS.md §Perf mixtral)
    partition: str = "ep"  # "ep" | "tp" | "dense"
    partition_decode: str = ""  # override for one-token decode ("" = same)


@dataclass(frozen=True)
class MlaConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # layer pattern: one kind per layer; "" means all "attn".
    # kinds: attn | attn_local | rglru | mlstm | slstm | dense_ffn_attn
    layer_pattern: tuple = ()

    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    window: int = 0  # sliding-window size for attn_local (0 = full)
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rms_offset: float = 0.0  # gemma-style (1+w) scaling
    act: str = "silu"
    post_norms: bool = False  # gemma3 post-attn/post-ffn norms
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)

    moe: MoeConfig = field(default_factory=MoeConfig)
    mla: MlaConfig | None = None

    # recurrent families
    lru_width: int = 0
    conv1d_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.334

    # encoder-decoder (audio) / frontend stubs (vlm, audio)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: str = ""  # "" | "vision_stub" | "audio_stub"
    n_frontend_tokens: int = 0  # patches / frames supplied by input_specs

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True
    microbatch_target_tokens: int = 16_384  # per-device activation budget
    # "tp_sp": Megatron TP + sequence parallelism over the model axis;
    # "fsdp": pure ZeRO-3 — batch shards over every mesh axis, weights are
    # gathered per layer (wins when global_batch >= device count and the
    # model fits one layer at a time; see EXPERIMENTS.md §Perf)
    parallelism: str = "tp_sp"
    # per-shape strategy overrides, e.g. (("train_4k", "fsdp"),)
    parallelism_overrides: tuple = ()

    # which assigned shapes this arch runs; long_500k only for sub-quadratic
    # families (see DESIGN.md §Arch-applicability)
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")

    source: str = ""  # provenance note [source; verified-tier]

    # ---------------------------------------------------------------- helpers

    def strategy_for(self, shape_name: str) -> str:
        for name, strat in self.parallelism_overrides:
            if name == shape_name:
                return strat
        return self.parallelism

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab rounded up to 256 so logits/embeddings shard over the
        model axis (e.g. internvl's 92553 -> 92672; a replicated 32k x V
        logits buffer costs 12 GiB/device otherwise). Pad ids are masked
        to -inf in lm_logits and never appear in labels."""
        return -(-self.vocab_size // 256) * 256

    @property
    def pattern(self) -> tuple:
        if self.layer_pattern:
            assert len(self.layer_pattern) == self.n_layers, self.name
            return self.layer_pattern
        return ("attn",) * self.n_layers

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d + (0 if self.tie_embeddings else v * d)
        for kind in self.pattern:
            if kind in ("attn", "attn_local", "dense_ffn_attn"):
                if self.mla is not None and kind != "dense_ffn_attn_plain":
                    m = self.mla
                    total += d * m.q_lora_rank
                    total += m.q_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.qk_rope_head_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * hd  # wq
                    total += 2 * d * self.n_kv_heads * hd  # wk, wv
                    total += self.n_heads * hd * d  # wo
                if kind == "dense_ffn_attn" or self.moe.n_experts == 0:
                    total += 3 * d * self.d_ff
                else:
                    mo = self.moe
                    total += d * mo.n_experts  # router
                    total += mo.n_experts * 3 * d * mo.d_ff
                    total += mo.n_shared_experts * 3 * d * mo.d_ff
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * d  # in/gate/out proj
                total += self.conv1d_width * w + 4 * w  # conv + lru gates
                total += 3 * d * self.d_ff
            elif kind == "mlstm":
                di = int(self.d_model * self.mlstm_proj_factor)
                total += 2 * d * di + di * d + 3 * di * di // 4  # rough qkv
            elif kind == "slstm":
                total += 4 * d * d + int(2 * d * d * self.slstm_proj_factor)
            total += 2 * d  # norms
        return int(total)

    def active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top-k experts only."""
        if self.moe.n_experts == 0:
            return self.n_params()
        mo = self.moe
        n_moe_layers = sum(
            1 for k in self.pattern
            if k in ("attn", "attn_local") and self.moe.n_experts > 0)
        inactive = (mo.n_experts - mo.n_experts_per_token)
        return int(self.n_params()
                   - n_moe_layers * inactive * 3 * self.d_model * mo.d_ff)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pat = self.pattern
        # keep one full pattern period (or 4 layers) to exercise every kind
        n = min(len(pat), max(2, _pattern_period(pat)))
        kw = dict(
            n_layers=n,
            layer_pattern=pat[:n],
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            lru_width=64 if self.lru_width else 0,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            dtype="float32",
            param_dtype="float32",
            remat="none",
            window=min(self.window, 8) if self.window else 0,
        )
        if self.moe.n_experts:
            # capacity_factor = E/K makes dispatch lossless (cap = T), so
            # decode-vs-full parity tests see no overflow drops
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, n_experts_per_token=2,
                n_shared_experts=min(self.moe.n_shared_experts, 1), d_ff=32,
                capacity_factor=2.0)
        if self.mla is not None:
            kw["mla"] = MlaConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        return self.replace(**kw)


def _pattern_period(pat: tuple) -> int:
    """Smallest p with pat[i] == pat[i % p] for all i (<= len(pat))."""
    for p in range(1, len(pat)):
        if all(pat[i] == pat[i % p] for i in range(len(pat))):
            return p
    return len(pat)


__all__ = ["ArchConfig", "MoeConfig", "MlaConfig", "ShapeSpec", "LM_SHAPES",
           "shape_by_name"]
