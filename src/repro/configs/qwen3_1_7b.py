"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.

QK-norm (per-head RMSNorm on q/k), no QKV bias, 128-dim heads, SwiGLU,
tied embeddings. [hf:Qwen/Qwen3-8B; hf]

long_500k skipped: pure full attention (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="silu",
    # global batch (256) == single-pod chip count: pure ZeRO-3 cuts the
    # train_4k step bound 4-20x vs TP+SP (EXPERIMENTS.md §Perf sweep);
    # guarded fallback to tp_sp on the 512-chip mesh
    parallelism_overrides=(("train_4k", "fsdp"),),
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[hf:Qwen/Qwen3-8B; hf]",
)
