"""deepseek-v2-236b [moe]: 60L d=5120 128H, MLA (kv_lora=512), MoE with
2 shared + 160 routed experts top-6 (expert d_ff=1536), vocab=102400.
[arXiv:2405.04434; hf]

Layer 0 is a dense FFN (d_ff=12288) per the released config; layers 1-59
are MoE. MLA decode runs the *absorbed* form: the KV cache holds only the
(512 + 64)-dim latents — the architecture's signature memory saving.
Experts shard over the model axis (EP: 160/16 = 10 per device).
long_500k skipped: full attention (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, MlaConfig, MoeConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,  # dense layer-0 FFN width
    vocab_size=102400,
    head_dim=192,  # qk_nope (128) + qk_rope (64)
    layer_pattern=("dense_ffn_attn",) + ("attn",) * 59,
    mla=MlaConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoeConfig(n_experts=160, n_experts_per_token=6, n_shared_experts=2,
                  d_ff=1536, partition="ep"),
    act="silu",
    tie_embeddings=False,
    microbatch_target_tokens=8_192,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[arXiv:2405.04434; hf]",
)
