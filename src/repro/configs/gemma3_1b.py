"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global attention pattern (window 512 local; global layers use the
1M-theta long-context RoPE), 256-dim heads, QK-norm, GeGLU, gemma-style
(1+w) RMSNorm with post-norms, tied + sqrt(d)-scaled embeddings.
[hf:google/gemma-3-1b-pt; unverified]

long_500k included: 22/26 layers are sliding-window (sub-quadratic); the 4
global layers are linear-in-S at decode (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    "attn" if (i % 6) == 5 else "attn_local" for i in range(26))

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    layer_pattern=_PATTERN,
    qk_norm=True,
    window=512,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    rms_offset=1.0,
    post_norms=True,
    embed_scale=True,
    act="gelu",
    # global batch (256) == single-pod chip count: pure ZeRO-3 cuts the
    # train_4k step bound 4-20x vs TP+SP (EXPERIMENTS.md §Perf sweep);
    # guarded fallback to tp_sp on the 512-chip mesh
    parallelism_overrides=(("train_4k", "fsdp"),),
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="[hf:google/gemma-3-1b-pt; unverified]",
)
