"""Architecture registry: ``get(name)`` -> ArchConfig; ``names()`` lists.

One module per assigned architecture, plus the paper's own experiment
configuration (``paper``). Reduced smoke variants come from
``get(name).reduced()``.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, LM_SHAPES, MlaConfig, MoeConfig,
                                ShapeSpec, shape_by_name)

ARCH_NAMES = (
    "gemma3_1b",
    "granite_34b",
    "qwen3_1_7b",
    "qwen2_1_5b",
    "mixtral_8x22b",
    "deepseek_v2_236b",
    "internvl2_26b",
    "recurrentgemma_9b",
    "whisper_base",
    "xlstm_125m",
)

_ALIASES = {n.replace("_", "-"): n for n in ARCH_NAMES}
_ALIASES.update({"qwen3-1.7b": "qwen3_1_7b", "qwen2-1.5b": "qwen2_1_5b"})


def get(name: str) -> ArchConfig:
    name = _ALIASES.get(name, name)
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def names() -> tuple:
    return ARCH_NAMES


__all__ = ["ArchConfig", "MoeConfig", "MlaConfig", "ShapeSpec", "LM_SHAPES",
           "shape_by_name", "get", "names", "ARCH_NAMES"]
