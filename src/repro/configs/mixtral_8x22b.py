"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8), MoE 8 experts top-2
(d_ff=16384 each), vocab=32768, sliding-window attention.
[arXiv:2401.04088; hf]

Experts (8) < model-axis width (16), so the experts are tensor-parallel
inside (partition="tp": d_ff shards over "model"); deepseek uses "ep".
long_500k included: SWA makes every layer sub-quadratic.
"""
from repro.configs.base import ArchConfig, MoeConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,  # == expert d_ff; dense layers unused
    vocab_size=32768,
    head_dim=128,
    layer_pattern=("attn_local",) * 56,
    window=4096,
    # grouped (per-data-shard) sort dispatch with expert-hidden TP: the
    # final EXPERIMENTS.md §Perf iteration — 8.8x lower step bound than
    # the global-dispatch baseline and 1.5x better than dense-mixture,
    # while keeping top-2 (not all-8) expert FLOPs
    moe=MoeConfig(n_experts=8, n_experts_per_token=2, d_ff=16384,
                  partition="tp"),
    act="silu",
    microbatch_target_tokens=8_192,
    tie_embeddings=False,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="[arXiv:2401.04088; hf]",
)
