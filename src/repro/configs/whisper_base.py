"""whisper-base [audio]: enc-dec, 6L encoder + 6L decoder, d=512 8H (MHA)
d_ff=2048 vocab=51865, LayerNorm + GELU + attention biases.
[arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (B, 1500, 512) straight into the encoder.
Decoder uses learned positions (table sized to the 32k assigned shapes —
the backbone spec governs, not whisper's 448-token context).
long_500k skipped: enc-dec audio backbone, not a long-context family.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    n_encoder_layers=6,
    frontend="audio_stub",
    n_frontend_tokens=1500,
    norm="layernorm",
    qkv_bias=True,
    act="gelu",
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="[arXiv:2212.04356; unverified]",
)
