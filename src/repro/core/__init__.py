"""Core: the paper contribution — exact full-CP optimization via
incremental&decremental nonconformity measures."""
