"""Host-side bookkeeping shared by the two serving engines.

`serving.engine.ServingEngine` (classification) and
`regression.engine.RegressionServingEngine` differ only in their state
pytree and per-tick step; the stateful host-side logic around the jitted
dispatch — grow-mode capacity provisioning, the sliding-window occupancy
invariant, and the scan-chunk wrapper — is identical and easy to let
drift apart. It lives here once, parameterized on an ``n_of`` accessor
that reads the per-session occupancy array from the engine's state.
(This module is import-neutral: both engine modules can use it without
touching the ``repro.serving`` package __init__, which would be
circular.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_chunk(vstep, stats_fn=None):
    """Wrap a vmapped per-tick step into a T-tick ``lax.scan`` chunk.

    One jitted dispatch advances T ticks; the leading-axis chunk length
    is the only retrace axis (the scan is rolled). Donating the carry at
    the jit boundary makes every per-tick (cap, cap) row/column insert
    an in-place dynamic-update-slice.

    ``stats_fn`` (optional — the telemetry hook, built by
    ``telemetry.device.make_chunk_stats_fn``) is evaluated ONCE per
    chunk, on the pre-chunk state and the full (T, S) active mask,
    *outside* the scan body — the tick statistics are a closed form of
    the integer bookkeeping leaves (occupancy / ring head / modulus)
    and the active mask, and even a few extra ops inside the compiled
    per-tick loop measure as a several-% regression. The chunk then
    returns ``(state, (pvals, stats))`` with ``stats`` one int32
    vector. The stats never read the float state, so the step's
    p-values and state stay bit-identical to the uninstrumented chunk
    (tested) and the donated in-place (cap, cap) updates are
    unaffected.
    """
    def chunk(state, xs, ys, taus, windows, actives):
        if stats_fn is not None:
            st = stats_fn(state, windows, actives)

        def body(s, inp):
            x, y, tau, act = inp
            return vstep(s, x, y, tau, windows, act)

        out, ps = jax.lax.scan(body, state, (xs, ys, taus, actives))
        return (out, (ps, st)) if stats_fn is not None else (out, ps)

    return chunk


def ensure_room(eng, state, ticks: int, n_of):
    """Grow-mode host-side capacity check for the next ``ticks`` ticks.

    n grows by at most 1 per tick, so a host counter upper-bounds
    occupancy; the true max is synced only at startup and when the bound
    would cross capacity (after external state swaps, call the engine's
    ``reset_occupancy`` to re-sync). Mutates ``eng._n_bound``; returns
    the (possibly grown) state.
    """
    if eng.window is not None:
        return state
    cap = state.capacity
    if eng._n_bound is None or eng._n_bound + ticks > cap:
        eng._n_bound = int(jnp.max(n_of(state)))
        while eng._n_bound + ticks > cap:
            state = eng.grow(state)
            cap = state.capacity
    eng._n_bound += ticks
    return state


def check_window_occupancy(eng, state, n_of, wrap_of=None) -> None:
    """One-time ring/occupancy invariant check for sliding engines.

    The fused sliding step runs on the ``[:wmax]`` block of every leaf
    with ring modulus ``wmax``, which is only valid while (a) no
    session's occupancy exceeds the window and (b) every session's
    stored ring modulus (``wrap``) equals the engine's ``wmax`` — a
    state evolved under a different modulus places live slots where this
    engine would not look. Engine-produced states keep both invariants
    by construction; this guards externally supplied states with a
    single device sync per engine lifetime (``reset_occupancy`` re-arms
    it).

    Grow-mode engines (no window) need the modulus check too: their
    insert slot is ``(head + n) % wrap``, so a sliding-engine state
    (wrap == its window block) handed to a grow engine would wrap at
    the smaller modulus and silently overwrite live points once n
    crosses it. Their required modulus is the full capacity.
    """
    if eng._w_checked:
        return
    if eng.window is None:
        if wrap_of is not None:
            w = wrap_of(state)
            lo, hi = int(jnp.min(w)), int(jnp.max(w))
            if lo != state.capacity or hi != state.capacity:
                raise ValueError(
                    f"state ring modulus {lo}..{hi} does not match this "
                    f"grow-mode engine's capacity {state.capacity}: the "
                    "state was evolved under a sliding window's confined "
                    "ring. Normalize it first (session to_linear / "
                    "grow), or serve it with a sliding engine whose "
                    "window matches")
        eng._w_checked = True
        return
    nmax = int(jnp.max(n_of(state)))
    if nmax > eng._wmax:
        raise ValueError(
            f"state occupancy {nmax} exceeds the sliding window "
            f"{eng.window}: this engine keeps live rows inside the "
            "[:window] block; evict down to the window (or use a "
            "larger-window engine) before serving")
    if wrap_of is not None:
        w = wrap_of(state)
        lo, hi = int(jnp.min(w)), int(jnp.max(w))
        if lo != eng._wmax or hi != eng._wmax:
            raise ValueError(
                f"state ring modulus {lo}..{hi} does not match this "
                f"engine's window block {eng._wmax}: the state was "
                "evolved under a different ring layout. Normalize it "
                "first (session to_linear + init with wrap=window), or "
                "serve it with an engine whose window matches")
    eng._w_checked = True


__all__ = ["scan_chunk", "ensure_room", "check_window_occupancy"]
