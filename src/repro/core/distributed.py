"""Multi-pod full CP — the paper's technique as a sharded serving feature.

The paper's optimized predict phase is, per (test point, label):

    1. an O(n) vector of distances/kernel values to the calibration rows,
    2. an O(1)-per-row incremental&decremental score update,
    3. a rank statistic  #{i : alpha_i >= alpha}.

All three are row-parallel, so the calibration state shards perfectly along
the ("pod", "data") mesh axes: each device holds n/D rows, steps 1-2 are
local, and step 3 is ONE scalar all-reduce per (test, label). The global
candidate score needs the *global* k nearest neighbours of the test point —
a local top-k followed by an all-gather of D*k candidates (k <= 32, so this
collective is tiny next to the count psum).

Test queries shard along the remaining "model" axis: model-parallel groups
serve disjoint query slices, giving data x query 2-D parallelism. On the
2 x 16 x 16 production mesh a 10^9-row calibration set costs ~4M rows/device
per query — the paper's "full CP on large datasets", three orders beyond its
single-host experiments.

Everything here is exact: outputs equal the single-device optimized path
(property-tested), which itself equals naive full CP.

Beyond the calibration-row sharding above, this module also owns the
**tenant-axis** sharding used by the serving engines
(``serving.engine`` / ``regression.engine``): a multi-tenant tick is
embarrassingly parallel across tenants (no cross-tenant communication),
so the stacked session state shards along its leading axis over a 1-D
``("tenants",)`` mesh and a tick runs as ONE shard_map'd dispatch with
**zero collectives** in the body — each device advances its tenant
slice with the exact same per-lane graph as the single-device vmap, so
results are bit-identical leaf-for-leaf (property-tested in
tests/test_distributed.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.measures.knn import KnnState

BIG = 1e30

if hasattr(jax, "shard_map"):  # jax >= 0.6

    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:  # jax 0.4.x: experimental location, check_rep instead of check_vma
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, mesh, in_specs, out_specs):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# calibration-state sharding
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CpShardingConfig:
    """Mesh-axis assignment for sharded CP serving."""

    row_axes: tuple = ("data",)  # calibration rows shard here
    query_axis: str | None = "model"  # test queries shard here (None = repl.)


def pad_rows(arr: np.ndarray, n_padded: int, fill) -> np.ndarray:
    """Pad axis 0 to n_padded with an inert fill value."""
    pad = n_padded - arr.shape[0]
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, widths, constant_values=fill)


def shard_knn_state(state: KnnState, mesh, cfg: CpShardingConfig) -> KnnState:
    """Pad rows to the row-shard multiple and place on the mesh.

    Padding rows get label -1 (matches no candidate label) and BIG distance
    lists, so they never enter any count: exactness is preserved.
    """
    shards = int(np.prod([mesh.shape[a] for a in cfg.row_axes]))
    n = state.X.shape[0]
    n_pad = -(-n // shards) * shards
    X = pad_rows(np.asarray(state.X), n_pad, 0.0)
    y = pad_rows(np.asarray(state.y), n_pad, -1)
    bs = pad_rows(np.asarray(state.best_same), n_pad, BIG)
    bd = pad_rows(np.asarray(state.best_diff), n_pad, BIG)
    row_spec = P(cfg.row_axes)
    put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
    return KnnState(
        X=put(X, P(cfg.row_axes, None)),
        y=put(y, row_spec),
        best_same=put(bs, P(cfg.row_axes, None)),
        best_diff=put(bd, P(cfg.row_axes, None)),
    )


# ---------------------------------------------------------------------------
# sharded k-NN CP predict
# ---------------------------------------------------------------------------


def _global_k_best(local_d, mask, k, row_axes):
    """Global k smallest masked distances across the row shards.

    Local top-k (O(n_local)) -> all-gather (D*k values) -> top-k again.
    """
    cand = jnp.where(mask, local_d, BIG)
    # top_k sorts -cand descending, so the negation is ascending (asserted
    # by tests/test_regression_stream.py::test_topk_negation_is_ascending)
    local_best = -jax.lax.top_k(-cand, k)[0]  # (k,) ascending
    gathered = jax.lax.all_gather(local_best, row_axes, tiled=True)  # (D*k,)
    return -jax.lax.top_k(-gathered, k)[0]


def make_knn_pvalues_fn(mesh, *, k: int, simplified: bool, n_labels: int,
                        cfg: CpShardingConfig = CpShardingConfig()):
    """Builds a jitted sharded p-value function: (state, X_test) -> (m, l).

    The returned function expects ``state`` sharded by ``shard_knn_state``
    and X_test sharded along cfg.query_axis (rows) or replicated.
    """
    row_axes = cfg.row_axes

    def local_counts(X, y, best_same, best_diff, X_test):
        """Body run per device: local update + count, then cross-shard
        reductions. X: (n_loc, p); X_test: (m_loc, p)."""
        n_total = jax.lax.psum(
            jnp.sum(y >= 0), row_axes)  # live rows only

        # cancellation-safe: base (k-1 best) + (kth or d); never subtract
        base_same = jnp.sum(best_same[:, :-1], axis=-1)
        kth_same = best_same[:, -1]
        base_diff = jnp.sum(best_diff[:, :-1], axis=-1)
        kth_diff = best_diff[:, -1]

        def per_test(x_t):
            d = jnp.sqrt(jnp.maximum(
                jnp.sum((X - x_t[None]) ** 2, axis=-1), 0.0))

            def per_label(y_hat):
                same = y == y_hat
                # candidate score from GLOBAL k-NN of the test point
                num = jnp.sum(_global_k_best(d, same, k, row_axes))
                if simplified:
                    alpha = num
                else:
                    den = jnp.sum(_global_k_best(d, ~same & (y >= 0), k,
                                                 row_axes))
                    alpha = num / den
                # O(1)-per-row incremental&decremental update (paper Fig. 1)
                upd = same & (d < kth_same)
                a_num = base_same + jnp.where(upd, d, kth_same)
                if simplified:
                    alphas = a_num
                else:
                    updd = (~same) & (y >= 0) & (d < kth_diff)
                    a_den = base_diff + jnp.where(updd, d, kth_diff)
                    alphas = a_num / a_den
                live = y >= 0
                cnt = jax.lax.psum(
                    jnp.sum(jnp.where(live, alphas >= alpha, False)
                            .astype(jnp.int32)),
                    row_axes)
                return (cnt + 1.0) / (n_total + 1.0)

            return jax.vmap(per_label)(
                jnp.arange(n_labels, dtype=y.dtype))

        return jax.lax.map(per_test, X_test)

    in_specs = (
        P(row_axes, None), P(row_axes), P(row_axes, None), P(row_axes, None),
        P(cfg.query_axis, None) if cfg.query_axis else P(None, None),
    )
    out_spec = (P(cfg.query_axis, None) if cfg.query_axis
                else P(None, None))

    sharded = _shard_map(local_counts, mesh, in_specs, out_spec)

    @jax.jit
    def pvalues(state: KnnState, X_test):
        return sharded(state.X, state.y, state.best_same, state.best_diff,
                       X_test)

    return pvalues


# ---------------------------------------------------------------------------
# sharded KDE CP predict
# ---------------------------------------------------------------------------


def make_kde_pvalues_fn(mesh, *, h: float, p_dim: int, n_labels: int,
                        cfg: CpShardingConfig = CpShardingConfig()):
    """Sharded KDE full CP. prelim/class counts shard with the rows; the
    candidate's kernel sum and the rank count are each one psum."""
    row_axes = cfg.row_axes

    def local_counts(X, y, prelim, X_test):
        live = y >= 0
        n_total = jax.lax.psum(jnp.sum(live), row_axes)
        counts_l = jax.vmap(
            lambda lb: jnp.sum((y == lb).astype(jnp.int32)))(
            jnp.arange(n_labels, dtype=y.dtype))
        class_counts = jax.lax.psum(counts_l, row_axes)  # (l,)
        hp = h ** p_dim

        def per_test(x_t):
            d2 = jnp.maximum(jnp.sum((X - x_t[None]) ** 2, axis=-1), 0.0)
            kv = jnp.exp(-d2 / (2.0 * h * h))

            def per_label(y_hat):
                same = (y == y_hat)
                ksum = jax.lax.psum(jnp.sum(jnp.where(same, kv, 0.0)),
                                    row_axes)
                c = class_counts[y_hat.astype(jnp.int32)]
                alpha = -jnp.where(c > 0, ksum / (c * hp), 0.0)
                sums = jnp.where(same, prelim + kv, prelim)
                n_y = (class_counts[jnp.clip(y, 0).astype(jnp.int32)]
                       - 1 + same.astype(class_counts.dtype))
                alphas = -jnp.where(n_y > 0, sums / (n_y * hp), 0.0)
                cnt = jax.lax.psum(
                    jnp.sum(jnp.where(live, alphas >= alpha, False)
                            .astype(jnp.int32)),
                    row_axes)
                return (cnt + 1.0) / (n_total + 1.0)

            return jax.vmap(per_label)(jnp.arange(n_labels, dtype=y.dtype))

        return jax.lax.map(per_test, X_test)

    in_specs = (
        P(row_axes, None), P(row_axes), P(row_axes),
        P(cfg.query_axis, None) if cfg.query_axis else P(None, None),
    )
    out_spec = (P(cfg.query_axis, None) if cfg.query_axis
                else P(None, None))

    sharded = _shard_map(local_counts, mesh, in_specs, out_spec)

    @jax.jit
    def pvalues(X, y, prelim, X_test):
        return sharded(X, y, prelim, X_test)

    return pvalues


# ---------------------------------------------------------------------------
# tenant-axis sharding (the serving engines' multi-device path)
# ---------------------------------------------------------------------------

TENANT_AXIS = "tenants"


def tenant_mesh(shards: int):
    """1-D ``("tenants",)`` mesh over the first ``shards`` devices."""
    from jax.sharding import Mesh

    devs = jax.devices()
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards > len(devs):
        raise ValueError(
            f"shards={shards} exceeds the {len(devs)} visible device(s); "
            "on CPU, force virtual devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before importing jax")
    return Mesh(np.array(devs[:shards]), (TENANT_AXIS,))


def tenant_spec(leaf) -> P:
    """Leading-axis tenant PartitionSpec for one stacked state leaf."""
    return P(TENANT_AXIS, *([None] * (np.ndim(leaf) - 1)))


def put_tenant_sharded(tree, mesh):
    """Place every leaf of a stacked state pytree with its leading axis
    sharded across the tenant mesh (trailing axes replicated)."""
    return jax.tree_util.tree_map(
        lambda a: jax.device_put(a, NamedSharding(mesh, tenant_spec(a))),
        tree)


def pad_tenant_count(n: int, shards: int) -> int:
    """Smallest multiple of ``shards`` >= n (the padded lane count).

    Uneven tenant counts shard by padding with inactive lanes: padded
    lanes stay at their init state (``active`` masks them out of every
    tick), so the live lanes' results are unchanged — the padding-shard
    case is property-tested in tests/test_distributed.py.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    return -(-n // shards) * shards


def shard_tenant_chunk(chunk, mesh, *, with_stats: bool):
    """shard_map a ``scan_chunk`` tick body over the tenant mesh.

    Inputs follow the engines' dispatch signature
    ``(state, xs, ys, taus, windows, actives)``: state leaves and
    ``windows`` shard their leading (S,) axis, the (T, S, ...) traffic
    arrays shard axis 1. The body contains no collectives — every
    device runs the unmodified chunk on its tenant slice, so the
    composed jit(shard_map(chunk)) keeps buffer donation and
    bit-exactness. With ``with_stats`` the chunk's (len(STAT_KEYS),)
    telemetry vector comes back per shard as a (shards, len) stacked
    array (still no collectives: the cross-shard merge is deferred to
    ``telemetry.device.TickStats.drain``).
    """
    ax = TENANT_AXIS
    in_specs = (P(ax), P(None, ax), P(None, ax), P(None, ax), P(ax),
                P(None, ax))
    if not with_stats:
        return _shard_map(chunk, mesh, in_specs, (P(ax), P(None, ax)))

    def body(state, xs, ys, taus, windows, actives):
        out, (ps, st) = chunk(state, xs, ys, taus, windows, actives)
        return out, (ps, st[None])  # (1, len): one stat row per shard

    return _shard_map(body, mesh, in_specs,
                      (P(ax), (P(None, ax), P(ax, None))))


def shard_tenant_fn(fn, mesh, in_tenant, out_spec=None):
    """shard_map a read-path fn whose args are tenant-stacked or global.

    ``in_tenant`` is one bool per positional arg: True shards the arg's
    leading axis across the tenant mesh, False replicates it (query
    grids, traced scalars). The default out_spec shards the leading
    axis of every output.
    """
    in_specs = tuple(P(TENANT_AXIS) if t else P() for t in in_tenant)
    if out_spec is None:
        out_spec = P(TENANT_AXIS)
    return _shard_map(fn, mesh, in_specs, out_spec)


__all__ = [
    "CpShardingConfig", "pad_rows", "shard_knn_state",
    "make_knn_pvalues_fn", "make_kde_pvalues_fn",
    "TENANT_AXIS", "tenant_mesh", "tenant_spec", "put_tenant_sharded",
    "pad_tenant_count", "shard_tenant_chunk", "shard_tenant_fn",
]
