"""Full k-NN CP regression (paper Section 8.1) — standard + optimized paths.

Full CP regression cannot enumerate Y. Instead every score is affine in the
candidate label t = y~:

    alpha_i(t) = |a_i + b_i t|          (training points, b_i in {0, -1/k})
    alpha(t)   = |a  + b  t|,  b = 1    (test point)

where, writing y_(j)(x_i) for the label of x_i's j-th nearest neighbour in
Z \\ {(x_i, y_i)}:

    if x is among x_i's k NNs:  a_i = y_i - (1/k) sum_{j<k} y_(j)(x_i),  b_i = -1/k
    else:                       a_i = y_i - (1/k) sum_{j<=k} y_(j)(x_i), b_i = 0
    test:                       a   = -(1/k) sum_{j<=k} y_(j)(x),        b   = 1

The p-value p(t) = (#{i: alpha_i(t) >= alpha(t)} + 1) / (n+1) is piecewise
constant; each i contributes a *set* S_i = {t : |a_i + b_i t| >= |a + t|}
whose boundary points come from (a_i + b_i t)^2 = (a + t)^2 — at most two
roots. With |b_i| < 1, S_i is a closed interval (possibly empty); with
|b_i| = 1 (k = 1) it is a half-line or all of R. A sorted sweep over the
<= 2n critical points yields exact p-values and prediction intervals in
O(n log n).

Two paths, exactness-tested against each other:

* standard (Papadopoulos et al. 2011): per test point recompute every
  training point's k NNs in the augmented set — O(n^2 + 2n log 2n) each.
* optimized (the paper's contribution): fit() precomputes each training
  point's k-NN label sums, k-th neighbour label and k-th distance — O(n^2)
  once; per test point only an O(n) distance row + O(1)-per-point update is
  needed before the same sweep — O(2n log 2n) each.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

BIG = 1e30
INF = jnp.inf


def _dists(A, B):
    return jnp.sqrt(jnp.maximum(kops.sq_dists(A, B), 0.0))


# ---------------------------------------------------------------------------
# shared: interval geometry + sweep
# ---------------------------------------------------------------------------


def _interval_ge(a_i, b_i, a, eps=1e-12):
    """Interval [lo, hi] of {t : |a_i + b_i t| >= |a + t|} (b = 1).

    g(t) = (a_i + b_i t)^2 - (a + t)^2 = (b_i^2-1) t^2 + 2(a_i b_i - a) t
           + (a_i^2 - a^2) >= 0.
    For |b_i| < 1 the parabola opens down: solution is between the roots
    (empty if no real roots). For |b_i| = 1 it is linear. Returns
    (lo, hi) with +-inf sentinels; empty intervals return (inf, -inf).
    """
    A2 = b_i * b_i - 1.0
    B1 = a_i * b_i - a
    C0 = a_i * a_i - a * a
    disc = B1 * B1 - A2 * C0

    # quadratic branch (A2 < 0): roots (-B1 +- sqrt(disc)) / A2
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    r1 = (-B1 + sq) / jnp.where(jnp.abs(A2) < eps, 1.0, A2)
    r2 = (-B1 - sq) / jnp.where(jnp.abs(A2) < eps, 1.0, A2)
    qlo = jnp.minimum(r1, r2)
    qhi = jnp.maximum(r1, r2)
    quad_lo = jnp.where(disc >= 0.0, qlo, INF)
    quad_hi = jnp.where(disc >= 0.0, qhi, -INF)

    # linear branch (A2 ~ 0): 2 B1 t + C0 >= 0
    t0 = -C0 / jnp.where(jnp.abs(B1) < eps, 1.0, 2.0 * B1)
    lin_lo = jnp.where(B1 > eps, t0, jnp.where(B1 < -eps, -INF, jnp.where(C0 >= 0.0, -INF, INF)))
    lin_hi = jnp.where(B1 > eps, INF, jnp.where(B1 < -eps, t0, jnp.where(C0 >= 0.0, INF, -INF)))

    is_quad = jnp.abs(A2) >= eps
    return (jnp.where(is_quad, quad_lo, lin_lo),
            jnp.where(is_quad, quad_hi, lin_hi))


def pvalue_at(a_vec, b_vec, a, t_query):
    """Exact p-values at explicit query labels t_query: (nq,).

    Reference semantics for the sweep; also used to probe arbitrary labels.
    """
    n = a_vec.shape[0]
    ai = jnp.abs(a_vec[None, :] + b_vec[None, :] * t_query[:, None])
    at = jnp.abs(a + t_query)[:, None]
    cnt = jnp.sum(ai >= at, axis=1)
    return (cnt + 1.0) / (n + 1.0)


def hull_sweep(lo, hi, empty, thresh):
    """Convex hull of {t : #{i : t in [lo_i, hi_i]} > thresh} — the sweep.

    Shared by ``prediction_interval`` (exact-shape) and the capacity-padded
    streaming read path (``repro.regression.session``): padded rows enter as
    ``empty`` and contribute neutral (+inf, delta 0) events, which sort after
    every finite event and leave the finite prefix sums — and therefore the
    hull — bit-identical to the unpadded sweep.
    """
    # event sweep over sorted bounds: +1 at lo (inclusive), -1 after hi.
    # Empty intervals (lo > hi) are neutralized (delta 0) so they cannot
    # perturb counts at the infinity event cluster.
    pts = jnp.concatenate([jnp.where(empty, INF, lo),
                           jnp.where(empty, INF, hi)])
    deltas = jnp.concatenate([jnp.where(empty, 0.0, 1.0),
                              jnp.where(empty, 0.0, -1.0)])
    # order ties so that +1 events at a point apply before -1 events leave:
    # sort by (point, -delta) -> stable count at closed endpoints
    order = jnp.lexsort((-deltas, pts))
    pts_s = pts[order]
    runs = jnp.cumsum(deltas[order])
    ok = runs > thresh
    any_ok = jnp.any(ok & jnp.isfinite(pts_s))
    lo_out = jnp.min(jnp.where(ok, pts_s, INF))
    # the run [pts_s[j], pts_s[j+1]) has count runs[j]; interval closes at the
    # next event point after the last ok run
    nxt = jnp.concatenate([pts_s[1:], jnp.array([INF])])
    hi_out = jnp.max(jnp.where(ok, nxt, -INF))
    return jnp.where(any_ok, lo_out, jnp.nan), jnp.where(any_ok, hi_out, jnp.nan)


def prediction_interval(a_vec, b_vec, a, epsilon):
    """Smallest interval containing {t : p(t) > eps} via critical-point sweep.

    Counts N(t) = #{i : t in S_i} change by +1 at lo_i and -1 past hi_i.
    Since the test point's own score always >= itself, p(t) =
    (N(t) + 1)/(n + 1) > eps <=> N(t) > eps (n+1) - 1. The set {p > eps} is
    a finite union of intervals; full CP regression conventionally reports
    its convex hull (Vovk et al. 2005). Runs in O(n log n).
    """
    n = a_vec.shape[0]
    lo, hi = jax.vmap(_interval_ge, in_axes=(0, 0, None))(a_vec, b_vec, a)
    thresh = epsilon * (n + 1.0) - 1.0
    return hull_sweep(lo, hi, lo > hi, thresh)


# ---------------------------------------------------------------------------
# standard path (Papadopoulos et al. 2011): O(n^2) per test point
# ---------------------------------------------------------------------------


def _knn_stats_augmented(X, y, x_t, k):
    """Per-training-point (a_i, b_i) with the test object x_t inserted.

    Recomputes every training point's k NNs in (Z \\ {i}) u {x}. O(n^2).
    """
    n = X.shape[0]
    D = _dists(X, X)
    D = jnp.where(jnp.eye(n, dtype=bool), BIG, D)
    d_t = _dists(x_t[None], X)[0]  # (n,) distances x_i -> x

    Da = jnp.concatenate([D, d_t[:, None]], axis=1)  # (n, n+1); col n == test
    ya = jnp.concatenate([y, jnp.zeros((1,), dtype=y.dtype)])  # test label unused

    _, idx = jax.lax.top_k(-Da, k)  # k nearest per row (distances unused)
    is_test = idx == n
    labels = ya[idx]  # (n, k); bogus where is_test
    test_in = jnp.any(is_test, axis=1)

    sum_no_test = jnp.sum(jnp.where(is_test, 0.0, labels), axis=1)
    a_i = y - sum_no_test / k
    b_i = jnp.where(test_in, -1.0 / k, 0.0)
    return a_i, b_i


@functools.partial(jax.jit, static_argnames=("k",))
def ab_standard(X, y, x_t, *, k):
    """(a_vec, b_vec, a) for one test object — standard path."""
    a_vec, b_vec = _knn_stats_augmented(X, y, x_t, k)
    d_t = _dists(x_t[None], X)[0]
    neg, idx = jax.lax.top_k(-d_t, k)
    a = -jnp.sum(y[idx]) / k
    return a_vec, b_vec, a


@functools.partial(jax.jit, static_argnames=("k",))
def pvalues_standard(X, y, X_test, t_query, *, k):
    """p-values at query labels for each test point: (m, nq)."""

    def per_test(x_t):
        a_vec, b_vec, a = ab_standard(X, y, x_t, k=k)
        return pvalue_at(a_vec, b_vec, a, t_query)

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k", "epsilon"))
def intervals_standard(X, y, X_test, *, k, epsilon):
    def per_test(x_t):
        a_vec, b_vec, a = ab_standard(X, y, x_t, k=k)
        return jnp.stack(prediction_interval(a_vec, b_vec, a, epsilon))

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# optimized path (the paper): O(n^2) fit once, O(n log n) per test point
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class KnnRegState:
    """Provisional per-point neighbour statistics (test object unknown).

    a_prime[i] = y_i - (1/k) sum_{j<=k} y_(j)(x_i)   (b'_i = 0 implicitly)
    kth_dist[i] = Delta_i^k; kth_label[i] = y_(k)(x_i): dropping the k-th
    neighbour when the test object enters gives the updated a_i in O(1).
    """

    X: jnp.ndarray  # (n, p)
    y: jnp.ndarray  # (n,)
    a_prime: jnp.ndarray  # (n,)
    kth_dist: jnp.ndarray  # (n,)
    kth_label: jnp.ndarray  # (n,)

    def tree_flatten(self):
        return ((self.X, self.y, self.a_prime, self.kth_dist,
                 self.kth_label), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@functools.partial(jax.jit, static_argnames=("k",))
def fit(X, y, *, k) -> KnnRegState:
    """O(n^2): pairwise distances + per-point k-NN label statistics."""
    n = X.shape[0]
    D = _dists(X, X)
    D = jnp.where(jnp.eye(n, dtype=bool), BIG, D)
    neg, idx = jax.lax.top_k(-D, k)
    # top_k sorts -D descending, so -neg is ascending (nearest first) and
    # ties break toward the lower index — asserted by
    # tests/test_regression_stream.py::test_topk_negation_is_ascending
    knn_d = -neg
    labels = y[idx]  # (n, k) neighbour labels, nearest first
    a_prime = y - jnp.sum(labels, axis=1) / k
    return KnnRegState(X, y, a_prime, knn_d[:, -1], labels[:, -1])


@functools.partial(jax.jit, static_argnames=("k",))
def ab_optimized(state: KnnRegState, x_t, *, k):
    """(a_vec, b_vec, a) for one test object — O(n) + one local top-k."""
    d_t = _dists(x_t[None], state.X)[0]
    enters = d_t < state.kth_dist  # x displaces the k-th neighbour of x_i
    a_vec = jnp.where(
        enters, state.a_prime + state.kth_label / k, state.a_prime)
    b_vec = jnp.where(enters, -1.0 / k, 0.0)
    neg, idx = jax.lax.top_k(-d_t, k)
    a = -jnp.sum(state.y[idx]) / k
    return a_vec, b_vec, a


@functools.partial(jax.jit, static_argnames=("k",))
def pvalues_optimized(state: KnnRegState, X_test, t_query, *, k):
    def per_test(x_t):
        a_vec, b_vec, a = ab_optimized(state, x_t, k=k)
        return pvalue_at(a_vec, b_vec, a, t_query)

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k", "epsilon"))
def intervals_optimized(state: KnnRegState, X_test, *, k, epsilon):
    def per_test(x_t):
        a_vec, b_vec, a = ab_optimized(state, x_t, k=k)
        return jnp.stack(prediction_interval(a_vec, b_vec, a, epsilon))

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# ICP regression baseline (Papadopoulos et al. 2002)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "t", "epsilon"))
def icp_intervals(X, y, X_test, *, k, t, epsilon):
    """k-NN ICP regression: |y - knn_mean| scores on the calibration set.

    Interval = knn_mean(x) +- the ceil((1-eps)(n_cal+1))-th smallest score.
    """
    X_tr, y_tr = X[:t], y[:t]
    X_cal, y_cal = X[t:], y[t:]

    def knn_mean(x):
        d = _dists(x[None], X_tr)[0]
        _, idx = jax.lax.top_k(-d, k)
        return jnp.mean(y_tr[idx])

    mu_cal = jax.lax.map(knn_mean, X_cal)
    scores = jnp.abs(y_cal - mu_cal)
    n_cal = scores.shape[0]
    # quantile index per ICP: smallest q with (#{score <= q}+1)/(n_cal+1) >= 1-eps
    rank = jnp.ceil((1.0 - epsilon) * (n_cal + 1)).astype(jnp.int32) - 1
    rank = jnp.clip(rank, 0, n_cal - 1)
    qhat = jnp.sort(scores)[rank]

    mu_test = jax.lax.map(knn_mean, X_test)
    return jnp.stack([mu_test - qhat, mu_test + qhat], axis=1)


__all__ = [
    "pvalue_at", "hull_sweep", "prediction_interval",
    "ab_standard", "pvalues_standard", "intervals_standard",
    "KnnRegState", "fit", "ab_optimized", "pvalues_optimized",
    "intervals_optimized", "icp_intervals",
]
