"""Inductive Conformal Prediction (paper Section 2.3, Appendix A, Algorithm 2).

ICP is the computational baseline for every experiment in the paper: split Z
into a proper training set (size t) and a calibration set (size n-t), train
the nonconformity measure once on the proper set, score the calibration set
once, and compute every test p-value against those fixed calibration scores:

    p = (#{i in calib : alpha_i >= alpha} + 1) / (n - t + 1)

Train+calibrate is O(T_A(t) + P_A(n-t)); one p-value is O(P_A(1) + n - t).
Coverage still holds, but statistical efficiency (fuzziness) is strictly
weaker than full CP (paper Appendix G) — that trade-off is the reason the
paper's exact full-CP optimizations matter.

Each ``Icp*`` class pairs with one of the measures in ``core/measures``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m


def icp_pvalue(calib_scores: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """ICP p-value; broadcasts over leading dims of alpha."""
    nc = calib_scores.shape[-1]
    count = jnp.sum(calib_scores >= alpha[..., None], axis=-1)
    return (count + 1.0) / (nc + 1.0)


# ---------------------------------------------------------------------------
# k-NN ICP
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class IcpKnnState:
    X_train: jnp.ndarray  # (t, p) proper training set
    y_train: jnp.ndarray  # (t,)
    calib_scores: jnp.ndarray  # (n - t,)

    def tree_flatten(self):
        return ((self.X_train, self.y_train, self.calib_scores), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _knn_score_against(X_ref, y_ref, x, y_hat, *, k, simplified):
    """A((x, y_hat); reference set) for the (simplified) k-NN measure."""
    d = jnp.sqrt(jnp.maximum(
        jnp.sum((X_ref - x[None]) ** 2, axis=-1), 0.0))
    num = jnp.sum(knn_m._k_best(d, y_ref == y_hat, k))
    if simplified:
        return num
    return num / jnp.sum(knn_m._k_best(d, y_ref != y_hat, k))


@functools.partial(jax.jit, static_argnames=("k", "simplified", "t"))
def fit_knn(X, y, *, k, simplified, t) -> IcpKnnState:
    """Train on Z[:t], score Z[t:] against Z[:t]."""
    X_tr, y_tr = X[:t], y[:t]
    X_cal, y_cal = X[t:], y[t:]
    scores = jax.vmap(
        lambda xc, yc: _knn_score_against(
            X_tr, y_tr, xc, yc, k=k, simplified=simplified)
    )(X_cal, y_cal)
    return IcpKnnState(X_tr, y_tr, scores)


@functools.partial(jax.jit, static_argnames=("k", "simplified", "n_labels"))
def pvalues_knn(state: IcpKnnState, X_test, *, k, simplified, n_labels):
    labels = jnp.arange(n_labels, dtype=state.y_train.dtype)

    def per_test(x_t):
        def per_label(y_hat):
            a = _knn_score_against(
                state.X_train, state.y_train, x_t, y_hat,
                k=k, simplified=simplified)
            return icp_pvalue(state.calib_scores, a)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# KDE ICP
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class IcpKdeState:
    X_train: jnp.ndarray
    y_train: jnp.ndarray
    class_counts: jnp.ndarray  # (n_labels,) counts in the proper set
    calib_scores: jnp.ndarray

    def tree_flatten(self):
        return ((self.X_train, self.y_train, self.class_counts,
                 self.calib_scores), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _kde_score_against(X_ref, y_ref, counts, x, y_hat, *, h, p_dim):
    d2 = jnp.maximum(jnp.sum((X_ref - x[None]) ** 2, axis=-1), 0.0)
    kv = jnp.exp(-d2 / (2.0 * h * h))
    same = y_ref == y_hat
    c = counts[y_hat.astype(jnp.int32)]
    return -jnp.where(
        c > 0, jnp.sum(jnp.where(same, kv, 0.0)) / (c * h ** p_dim), 0.0)


@functools.partial(jax.jit, static_argnames=("h", "p_dim", "n_labels", "t"))
def fit_kde(X, y, *, h, p_dim, n_labels, t) -> IcpKdeState:
    X_tr, y_tr = X[:t], y[:t]
    counts = jnp.sum(
        y_tr[None, :] == jnp.arange(n_labels, dtype=y.dtype)[:, None], axis=1)
    scores = jax.vmap(
        lambda xc, yc: _kde_score_against(
            X_tr, y_tr, counts, xc, yc, h=h, p_dim=p_dim)
    )(X[t:], y[t:])
    return IcpKdeState(X_tr, y_tr, counts, scores)


@functools.partial(jax.jit, static_argnames=("h", "p_dim", "n_labels"))
def pvalues_kde(state: IcpKdeState, X_test, *, h, p_dim, n_labels):
    labels = jnp.arange(n_labels, dtype=state.y_train.dtype)

    def per_test(x_t):
        def per_label(y_hat):
            a = _kde_score_against(
                state.X_train, state.y_train, state.class_counts, x_t, y_hat,
                h=h, p_dim=p_dim)
            return icp_pvalue(state.calib_scores, a)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# LS-SVM ICP (binary, labels in {-1, +1})
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class IcpLssvmState:
    w: jnp.ndarray  # (q,) model trained on the proper set
    calib_scores: jnp.ndarray

    def tree_flatten(self):
        return ((self.w, self.calib_scores), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@functools.partial(jax.jit, static_argnames=("t",))
def fit_lssvm(Phi, Y, rho, *, t) -> IcpLssvmState:
    w = lssvm_m._train_w(Phi[:t], Y[:t], rho)
    scores = -Y[t:] * (Phi[t:] @ w)
    return IcpLssvmState(w, scores)


@jax.jit
def pvalues_lssvm(state: IcpLssvmState, Phi_test):
    labels = jnp.array([-1.0, 1.0], dtype=Phi_test.dtype)
    f = Phi_test @ state.w  # (m,)
    alphas = -labels[None, :] * f[:, None]  # (m, 2)
    return icp_pvalue(state.calib_scores, alphas)


__all__ = [
    "icp_pvalue",
    "IcpKnnState", "fit_knn", "pvalues_knn",
    "IcpKdeState", "fit_kde", "pvalues_kde",
    "IcpLssvmState", "fit_lssvm", "pvalues_lssvm",
]
