"""High-level conformal prediction API over every measure in the framework.

``ConformalClassifier`` is the user-facing entry point (examples, serving,
benchmarks). It dispatches to the paper-optimized implementations by default
and can be forced onto the naive path (``optimized=False``) for exactness
testing and the paper's standard-vs-optimized benchmark tables.

    clf = ConformalClassifier(measure="knn", k=15, n_labels=2)
    clf.fit(X, y)
    p = clf.predict_pvalues(X_test)          # (m, l)
    sets = clf.predict_set(X_test, eps=0.1)  # (m, l) bool

Measures: "knn", "simplified_knn", "kde", "lssvm" (binary), "bootstrap".
``InductiveConformalClassifier`` is the ICP baseline with the same surface.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import icp as icp_m
from repro.core import pvalues as pv
from repro.core.measures import bootstrap as boot_m
from repro.core.measures import kde as kde_m
from repro.core.measures import knn as knn_m
from repro.core.measures import lssvm as lssvm_m

MEASURES = ("knn", "simplified_knn", "kde", "lssvm", "bootstrap")


def _as_f(x):
    return jnp.asarray(x)


@dataclass
class ConformalClassifier:
    """Full (transductive) CP classifier; exact optimized path by default."""

    measure: str = "knn"
    n_labels: int = 2
    k: int = 15
    h: float = 1.0  # KDE bandwidth
    rho: float = 1.0  # LS-SVM regularizer
    feature_map: str = "linear"  # LS-SVM phi
    rff_dim: int = 128
    B: int = 10  # bootstrap ensemble size
    tree_depth: int = 5
    optimized: bool = True
    seed: int = 0
    _state: Any = field(default=None, repr=False)
    _fitdata: Any = field(default=None, repr=False)
    _phi: Any = field(default=None, repr=False)

    def __post_init__(self):
        if self.measure not in MEASURES:
            raise ValueError(
                f"measure {self.measure!r} not in {MEASURES}")
        if self.measure == "lssvm" and self.n_labels != 2:
            raise ValueError("lssvm measure is binary (labels {-1,+1}); use "
                             "one-vs-rest for more labels (paper Section 5)")

    # -- fit ---------------------------------------------------------------

    def fit(self, X, y) -> "ConformalClassifier":
        X = _as_f(X)
        y = jnp.asarray(y)
        self._fitdata = (X, y)
        if not self.optimized:
            return self  # standard full CP has no training phase (Table 1)
        if self.measure in ("knn", "simplified_knn"):
            self._state = knn_m.fit(X, y.astype(jnp.int32), k=self.k)
        elif self.measure == "kde":
            self._state = kde_m.fit(
                X, y.astype(jnp.int32), h=self.h, n_labels=self.n_labels)
        elif self.measure == "lssvm":
            phi, _ = lssvm_m.feature_map(
                self.feature_map, X.shape[1], self.rff_dim, self.seed)
            self._phi = phi
            Y = self._to_pm1(y)
            self._state = lssvm_m.fit(phi(X), Y, self.rho)
        elif self.measure == "bootstrap":
            self._state = boot_m.fit(
                np.asarray(X), np.asarray(y), n_labels=self.n_labels,
                B=self.B, depth=self.tree_depth, seed=self.seed)
        return self

    @staticmethod
    def _to_pm1(y):
        return (2 * y.astype(jnp.float32) - 1.0)

    # -- predict -----------------------------------------------------------

    def predict_pvalues(self, X_test) -> jnp.ndarray:
        X_test = _as_f(X_test)
        X, y = self._fitdata
        simplified = self.measure == "simplified_knn"
        if self.measure in ("knn", "simplified_knn"):
            if self.optimized:
                return knn_m.pvalues_optimized(
                    self._state, X_test, k=self.k, simplified=simplified,
                    n_labels=self.n_labels)
            return knn_m.pvalues_standard(
                X, y.astype(jnp.int32), X_test, k=self.k,
                simplified=simplified, n_labels=self.n_labels)
        if self.measure == "kde":
            if self.optimized:
                return kde_m.pvalues_optimized(
                    self._state, X_test, h=self.h, p_dim=X.shape[1],
                    n_labels=self.n_labels)
            return kde_m.pvalues_standard(
                X, y.astype(jnp.int32), X_test, h=self.h, p_dim=X.shape[1],
                n_labels=self.n_labels)
        if self.measure == "lssvm":
            if self.optimized:
                return lssvm_m.pvalues_optimized(
                    self._state, self._phi(X_test))
            phi, _ = lssvm_m.feature_map(
                self.feature_map, X.shape[1], self.rff_dim, self.seed)
            return lssvm_m.pvalues_standard(
                phi(X), self._to_pm1(y), phi(X_test), rho=self.rho)
        if self.measure == "bootstrap":
            if self.optimized:
                return jnp.asarray(
                    boot_m.pvalues_optimized(self._state, np.asarray(X_test)))
            return jnp.asarray(boot_m.pvalues_standard(
                np.asarray(X), np.asarray(y), np.asarray(X_test),
                n_labels=self.n_labels, B=self.B, depth=self.tree_depth,
                seed=self.seed))
        raise AssertionError(self.measure)

    def predict_set(self, X_test, eps: float) -> jnp.ndarray:
        return pv.prediction_sets(self.predict_pvalues(X_test), eps)

    def predict_point(self, X_test) -> jnp.ndarray:
        """Point prediction: argmax p-value (forced single label)."""
        return jnp.argmax(self.predict_pvalues(X_test), axis=-1)


@dataclass
class InductiveConformalClassifier:
    """ICP baseline (paper Section 2.3); same surface as the full CP class."""

    measure: str = "knn"
    n_labels: int = 2
    k: int = 15
    h: float = 1.0
    rho: float = 1.0
    feature_map: str = "linear"
    rff_dim: int = 128
    train_frac: float = 0.5
    seed: int = 0
    _state: Any = field(default=None, repr=False)
    _phi: Any = field(default=None, repr=False)
    _pdim: int = 0

    def fit(self, X, y) -> "InductiveConformalClassifier":
        X = _as_f(X)
        y = jnp.asarray(y).astype(jnp.int32)
        t = max(1, int(X.shape[0] * self.train_frac))
        self._pdim = X.shape[1]
        simplified = self.measure == "simplified_knn"
        if self.measure in ("knn", "simplified_knn"):
            self._state = icp_m.fit_knn(
                X, y, k=self.k, simplified=simplified, t=t)
        elif self.measure == "kde":
            self._state = icp_m.fit_kde(
                X, y, h=self.h, p_dim=X.shape[1], n_labels=self.n_labels, t=t)
        elif self.measure == "lssvm":
            phi, _ = lssvm_m.feature_map(
                self.feature_map, X.shape[1], self.rff_dim, self.seed)
            self._phi = phi
            Y = 2 * y.astype(jnp.float32) - 1.0
            self._state = icp_m.fit_lssvm(phi(X), Y, self.rho, t=t)
        else:
            raise ValueError(f"ICP measure {self.measure!r} unsupported")
        return self

    def predict_pvalues(self, X_test) -> jnp.ndarray:
        X_test = _as_f(X_test)
        simplified = self.measure == "simplified_knn"
        if self.measure in ("knn", "simplified_knn"):
            return icp_m.pvalues_knn(
                self._state, X_test, k=self.k, simplified=simplified,
                n_labels=self.n_labels)
        if self.measure == "kde":
            return icp_m.pvalues_kde(
                self._state, X_test, h=self.h, p_dim=self._pdim,
                n_labels=self.n_labels)
        if self.measure == "lssvm":
            return icp_m.pvalues_lssvm(self._state, self._phi(X_test))
        raise AssertionError(self.measure)

    def predict_set(self, X_test, eps: float) -> jnp.ndarray:
        return pv.prediction_sets(self.predict_pvalues(X_test), eps)


__all__ = ["ConformalClassifier", "InductiveConformalClassifier", "MEASURES"]
