"""Online CP: exchangeability martingales / IID testing (paper §9, App. C.5).

Vovk et al. (2003): observe a stream x_1, x_2, ...; at step n compute a
smoothed p-value for x_{n+1} against {x_1..x_n} (Algorithm 1), then *learn*
x_{n+1}. Betting functions turn the p-value stream into a martingale M_n
whose growth is evidence against exchangeability (change-point detection,
feature selection (Cherubin et al. 2018)).

Complexity (paper App. C.5): with standard k-NN CP the n-step stream costs
O(n^3); with this module's incremental&decremental k-NN it is O(n^2) —
each step is one O(n) update (the paper's headline online win).

The state is preallocated to a static capacity so the whole stream step is
one fixed-shape jitted function (no retracing as n grows) — the production
serving form of the paper's "adapting our optimizations to this setting is
trivial" remark.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

BIG = 1e30


# ---------------------------------------------------------------------------
# ring-buffer slot arithmetic
# ---------------------------------------------------------------------------
#
# The serving engines store their sliding window in a *circular* layout:
# a scalar ``head`` names the slot of the oldest live point and the live
# window occupies slots ``(head + i) % wrap`` for ``i in [0, n)``. The
# modulus ``wrap`` (<= the padded capacity) is part of the state: a
# sliding engine whose window statically bounds occupancy runs its ring
# inside the leading ``[:wrap]`` block of every leaf, so per-tick cost
# scales with the window while the padded capacity can stay larger.
# Slots at or beyond ``wrap`` are never live. Evicting the oldest point
# is then a head advance (plus an O(cap) list repair) — no positional
# compaction ever moves the (cap, cap) distance matrix. Arrival order,
# which the tie rules rest on, is tracked two ways: the *age* of a slot
# is derived from ``head`` (0 = oldest), and an explicit per-slot
# arrival-id vector ``aid`` (a monotone counter stamped at insert)
# provides the total order the labeled backfill breaks distance ties
# with. ``head == 0`` with no wrap-around is exactly the historic
# linear layout, and every function below degenerates to the old bits
# there.


def ring_age(cap: int, head, wrap=None):
    """(cap,) arrival age of each slot under a ring at ``head`` with
    modulus ``wrap`` (default: the full capacity): the oldest live slot
    has age 0; ages ``>= n`` are not live; slots ``>= wrap`` get the
    sentinel age ``cap`` (never live, since n <= wrap <= cap). ``head``
    and ``wrap`` may be traced."""
    idx = jnp.arange(cap, dtype=jnp.int32)
    if wrap is None:
        return jnp.where(idx >= head, idx - head, idx - head + cap)
    wrap = jnp.asarray(wrap, jnp.int32)
    raw = jnp.where(idx >= head, idx - head, idx - head + wrap)
    return jnp.where(idx < wrap, raw, cap)


def ring_live(cap: int, head, n, wrap=None):
    """(cap,) live mask of a ring holding ``n`` points at ``head``."""
    return ring_age(cap, head, wrap) < n


def ring_slots(cap: int, head, wrap=None):
    """(cap,) slot index of each arrival rank: entry i is the slot of
    the i-th oldest point, ``(head + i) % wrap`` — the gather
    permutation from ring layout to the historic linear (arrival-order)
    layout. Entries at ranks >= wrap alias earlier slots; callers mask
    everything at rank >= n, so the aliases never surface."""
    s = jnp.arange(cap, dtype=jnp.int32) + jnp.asarray(head, jnp.int32)
    m = jnp.asarray(cap if wrap is None else wrap, jnp.int32)
    return jnp.where(s >= m, s - m, s)


def ring_mod(v, m):
    """``v % m`` for a traced scalar already in ``[0, 2 m)`` — the ring
    steps' head/insert-slot arithmetic (one compare+subtract, no rem)."""
    return jnp.where(v >= m, v - m, v)


def next_aid(aid, head, n, wrap):
    """Arrival id for the next insert: one past the newest live slot's
    (the per-slot counters are strictly increasing with recency, so the
    newest holds the max). An empty window restarts at 0 — ids only
    order the *live* points. The int32 counter is allowed to wrap: every
    consumer compares ids as wraparound *differences* from the oldest
    live id (``drop_backfill``), which stay exact because live ids span
    at most one window of inserts (far below 2^31)."""
    newest = ring_mod(head + n - 1 + wrap * (n == 0).astype(n.dtype), wrap)
    return jnp.where(n > 0, aid[newest] + 1, 0)


def cshift(a, s, fill):
    """Conditionally drop the leading row: shift rows up by ``s`` (a
    traced 0/1 scalar) with ``fill`` entering at the tail — one padded
    dynamic slice, bitwise identity when ``s == 0``. The compaction
    primitive of the serving engines' fused sliding step."""
    pad = [(0, 1)] + [(0, 0)] * (a.ndim - 1)
    ap = jnp.pad(a, pad, constant_values=fill)
    start = (s,) + (jnp.int32(0),) * (a.ndim - 1)
    return jax.lax.dynamic_slice(ap, start, a.shape)


def drop_backfill_core(L, es, cand, Ds, *, k):
    """Shared decremental list repair for the serving engines' eviction.

    For each row: drop the first slot of the ascending k-best list ``L``
    holding the evicted distance ``es`` (the evicted point has the
    lowest arrival index, so on ties it occupies the first slot holding
    its value), then backfill the new k-th best by multiset rank over
    the stored distances: the k-1 survivors hold every remaining
    candidate value below their max t' plus m' occurrences of t' itself,
    so the next value is t' again if the window (``Ds`` masked by
    ``cand``) holds more than m' occurrences of it, else the smallest
    stored distance above t'. Every output is a selected stored value —
    bit-identical to a full re-sort, a fraction of the compute.

    Returns ``(newL, pos0, cols, b, tprime, mprime)`` so label-carrying
    callers (the regression state) can mirror the move on a parallel
    label matrix. Both exactness proofs (classification and regression)
    rest on this one function.
    """
    cap = L.shape[0]
    pos0 = jnp.sum((L < es[:, None]).astype(jnp.int32), axis=1)
    Lup = jnp.concatenate([L[:, 1:], jnp.full_like(L[:, :1], BIG)], axis=1)
    # t' = max of the k-1 survivors; m' = its multiplicity among them
    if k >= 2:
        tprime = jnp.where(pos0 <= k - 2, L[:, k - 1], L[:, k - 2])
    else:
        # empty survivor list: below every distance (distances are >= 0)
        tprime = jnp.full((cap,), -1.0, L.dtype)
    mprime = (jnp.sum((L == tprime[:, None]).astype(jnp.int32), axis=1)
              - (es == tprime).astype(jnp.int32))
    # one variadic reduce computes the count and the min together — a
    # single fused pass over the stored (cap, cap) distances instead of
    # two (integer sum and f32 min are order-free, so the fused pass is
    # bit-identical to separate reductions). This pass is the whole
    # per-tick cost of eviction under the ring layout.
    cnt, gtmin = jax.lax.reduce(
        (jnp.where(cand & (Ds == tprime[:, None]), 1, 0).astype(jnp.int32),
         jnp.where(cand & (Ds > tprime[:, None]), Ds, BIG)),
        (jnp.int32(0), jnp.asarray(BIG, Ds.dtype)),
        lambda acc, x: (acc[0] + x[0], jnp.minimum(acc[1], x[1])),
        (1,))
    b = jnp.where(cnt > mprime, tprime, gtmin)
    cols = jnp.arange(k)
    newL = jnp.where(cols[None, :] < pos0[:, None], L,
                     jnp.where(cols[None, :] < k - 1, Lup, b[:, None]))
    return newL, pos0, cols, b, tprime, mprime


def drop_backfill(L, es, cand, Ds, aff, *, k, Ly=None, La=None, ys=None,
                  aid=None, age=None, slots=None, aid0=None):
    """The one shared decremental list repair of both serving engines.

    For each row flagged in ``aff``: drop the first slot of the ascending
    k-best list ``L`` holding that row's evicted distance ``es`` and
    backfill the new k-th best by multiset rank over the stored distances
    (``drop_backfill_core`` above). Rows not flagged pass through
    bitwise untouched. Classification (``Ly is None``) repairs distances
    only and returns ``newL``.

    The labeled form (regression: pass ``Ly``/``La``/``ys``/``aid`` and
    the ring geometry ``age``/``slots``) also repairs the parallel
    neighbour-*label* lists ``Ly`` and the neighbour-*arrival-id* lists
    ``La`` and returns ``(newL, newLy, newLa)``. The backfill label
    must follow fit's ties-toward-*earliest-arrival* order: among the
    candidate columns at the backfill distance b, the occurrences the
    surviving list already holds are the earliest arrivals, so the
    label comes from the next-earliest — the candidate with the
    smallest arrival id above the largest id the list already stores at
    that distance (read from ``La``; -1, i.e. below every live id, when
    the backfill value is new to the list). Arrival order is read from
    the per-slot arrival ids ``aid`` (strictly increasing with recency,
    distinct), NOT from the slot position — under the ring layout the
    two disagree across the wrap-around seam. Every id comparison is a
    wraparound int32 *difference* from ``aid0`` (the evicted — globally
    earliest — live id): live ids span at most one window of inserts,
    far below 2^31, so the differences stay exact even after the raw
    counters overflow on a long-lived stream. The pick itself needs no
    sort and no (slow) index-reduction: arrival *rank* is a pure
    function of the slot (``age``), so one plain masked min over the
    broadcast ranks finds the earliest valid rank, and ``slots`` (the
    rank -> slot permutation, ``ring_slots``) converts it back to a
    column index with a single gather. For a linear-layout caller
    ``age`` and ``slots`` are both ``jnp.arange(cap)``.
    """
    newL, pos0, cols, b, tprime, mprime = drop_backfill_core(
        L, es, cand, Ds, k=k)
    if Ly is None:
        return jnp.where(aff[:, None], newL, L)

    # largest arrival id the list already holds at the backfill value
    # (as a wraparound difference from the anchor ``aid0``). When
    # b == t', the list's occurrences of t' are the earliest arrivals
    # at that distance, so anything above ``thr`` is new; the dropped
    # (evicted) occurrence may contribute to the max but it rebases to
    # exactly 0, below every surviving id. When b == gtmin the list
    # holds no occurrence of b (gtmin > t' strictly) and the pick is
    # simply the earliest.
    cap = L.shape[0]
    aid0 = jnp.asarray(aid0, jnp.int32)
    rel_La = La.astype(jnp.int32) - aid0  # int32 wrap-subtract
    thr = jnp.where(
        b == tprime,
        jnp.max(jnp.where(L == tprime[:, None], rel_La, -1), axis=1), -1)
    rel_aid = (aid.astype(jnp.int32) - aid0)[None, :]
    valid = cand & (Ds == b[:, None]) & (rel_aid > thr[:, None])
    # min over arrival *rank* (a pure function of the slot), then one
    # gather through the rank -> slot permutation — no sort and no slow
    # index-reduction anywhere in the pick
    amin = jnp.min(jnp.where(valid, age[None, :].astype(jnp.int32), cap),
                   axis=1)
    sel = slots[jnp.minimum(amin, cap - 1)]
    yb = ys[sel]  # rows where b >= BIG pick garbage, fixed up below
    ab = aid[sel].astype(jnp.int32)

    Lyup = jnp.concatenate([Ly[:, 1:], Ly[:, :1]], axis=1)
    newLy = jnp.where(cols[None, :] < pos0[:, None], Ly,
                      jnp.where(cols[None, :] < k - 1, Lyup, yb[:, None]))
    Laup = jnp.concatenate([La[:, 1:], La[:, :1]], axis=1)
    newLa = jnp.where(cols[None, :] < pos0[:, None], La,
                      jnp.where(cols[None, :] < k - 1, Laup, ab[:, None]))
    # missing-neighbour slots carry the row's own label (fit convention)
    # and the neutral arrival id 0
    newLy = jnp.where(newL >= BIG, ys[:, None], newLy)
    newLa = jnp.where(newL >= BIG, 0, newLa)
    return (jnp.where(aff[:, None], newL, L),
            jnp.where(aff[:, None], newLy, Ly),
            jnp.where(aff[:, None], newLa, La))


@jax.tree_util.register_pytree_node_class
@dataclass
class OnlineKnnState:
    """Capacity-padded incremental simplified-k-NN CP state.

    Rows >= n are inert: distances to them are BIG, their scores never
    counted. ``best`` holds each live point's k best same-label distances.
    """

    X: jnp.ndarray  # (cap, p)
    y: jnp.ndarray  # (cap,)
    best: jnp.ndarray  # (cap, k) ascending same-label distances, BIG-padded
    n: jnp.ndarray  # () live count

    def tree_flatten(self):
        return ((self.X, self.y, self.best, self.n), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> OnlineKnnState:
    return OnlineKnnState(
        X=jnp.zeros((capacity, p), dtype=dtype),
        y=jnp.full((capacity,), -1, dtype=jnp.int32),
        best=jnp.full((capacity, k), BIG, dtype=dtype),
        n=jnp.zeros((), dtype=jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def observe(state: OnlineKnnState, x_new, y_new, tau, *, k):
    """One online step: smoothed p-value for (x_new, y_new), then learn it.

    Returns (new_state, p_value). O(capacity) — O(n) amortized on TPU since
    inert rows are masked arithmetic, not skipped.
    """
    new_state, p, _ = _observe_impl(state, x_new, y_new, tau, k=k)
    return new_state, p


@functools.partial(jax.jit, static_argnames=("k",))
def observe_with_dists(state: OnlineKnnState, x_new, y_new, tau, *, k,
                       head=None, wrap=None):
    """``observe`` that also returns the live-masked distance vector.

    Identical arithmetic to ``observe`` (same p-value bits); the extra
    return is the (cap,) vector of distances from ``x_new`` to each live
    row, BIG on inert rows — callers that maintain auxiliary per-pair
    state (``repro.serving.session`` keeps the pairwise distance matrix
    for exact decremental eviction) reuse it instead of recomputing.

    ``head`` (traced scalar, default linear layout) switches the state
    to ring-buffer slot semantics: the live window occupies slots
    ``(head + i) % wrap`` (modulus ``wrap``, default the capacity) and
    the new point lands at slot ``(head + n) % wrap`` instead of slot
    ``n``. The p-value is a layout-free reduction over the same live
    multiset, so its bits do not depend on ``head``/``wrap``.
    """
    return _observe_impl(state, x_new, y_new, tau, k=k, head=head,
                         wrap=wrap)


def _observe_impl(state: OnlineKnnState, x_new, y_new, tau, *, k,
                  head=None, wrap=None):
    cap = state.X.shape[0]
    if head is None:
        live = jnp.arange(cap) < state.n
        # the clamp is bit-neutral under the n < cap precondition; it
        # keeps a gated caller's discarded write in bounds at n == cap
        # (an out-of-bounds dynamic-update start is implementation-
        # defined once XLA fuses it with a pad — it can read the fill)
        idx = jnp.minimum(state.n, cap - 1)
        head = jnp.zeros((), jnp.int32)
    else:
        live = ring_live(cap, head, state.n, wrap)
        m = jnp.asarray(cap if wrap is None else wrap, jnp.int32)
        tail = head + state.n
        idx = jnp.where(tail >= m, tail - m, tail)
    # fused distance row + same-label k-best merge: one Pallas pass on
    # TPU; the CPU/f64 reference is expression-identical to the historic
    # inline code, so the stream's p-value bits are unchanged
    d, merged, _ = kops.stream_update(
        state.X, state.y, state.best, None, x_new, y_new, state.n,
        mode="class", head=head, wrap=wrap)
    same = (state.y == y_new) & live

    # candidate score: sum of k best same-label distances
    cand = jnp.where(same, d, BIG)
    alpha = jnp.sum(-jax.lax.top_k(-cand, k)[0])

    # provisional -> updated scores for live points (O(1) each);
    # cancellation-safe base + (kth or d) form, never subtracting BIG
    base = jnp.sum(state.best[:, :-1], axis=1)
    kth = state.best[:, -1]
    upd = same & (d < kth)
    alphas = base + jnp.where(upd, d, kth)

    # smoothed p-value over live points + the candidate itself; the
    # astype is a no-op at f32/f64 but pins sub-f32 state dtypes (the
    # int/float promotion otherwise widens p to f32, which breaks the
    # engine's masked cond whose skip branch is a state-dtype NaN)
    gt = jnp.sum(jnp.where(live, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live, alphas == alpha, False))
    p = ((gt + tau * (eq + 1.0)) / (state.n + 1.0)).astype(state.X.dtype)

    # learn: the merged lists come from the fused pass; the new row's own
    # list is the k best same-label distances seen so far
    own = jnp.sort(-jax.lax.top_k(-cand, k)[0])
    new_state = OnlineKnnState(
        X=state.X.at[idx].set(x_new),
        y=state.y.at[idx].set(y_new.astype(state.y.dtype)),
        best=merged.at[idx].set(own),
        n=state.n + 1,
    )
    return new_state, p, d


# ---------------------------------------------------------------------------
# betting martingales over the p-value stream
# ---------------------------------------------------------------------------


def power_martingale_increment(p, epsilon=0.92):
    """Power betting function: f(p) = eps * p^(eps-1); integral over [0,1]=1."""
    return epsilon * jnp.power(jnp.maximum(p, 1e-12), epsilon - 1.0)


@jax.jit
def simple_mixture_log_martingale(pvals: jnp.ndarray) -> jnp.ndarray:
    """Log of the simple-mixture martingale: integral over eps of the power
    martingale, approximated on a grid (valid as a mixture of martingales).
    Returns log M_n for each prefix n: (T,)."""
    eps_grid = jnp.linspace(0.05, 0.95, 19)
    # log increments per (eps, t)
    logf = (jnp.log(eps_grid)[:, None]
            + (eps_grid[:, None] - 1.0) * jnp.log(jnp.maximum(pvals, 1e-12))[None, :])
    logM = jnp.cumsum(logf, axis=1)  # per-eps martingale paths
    return jax.scipy.special.logsumexp(logM, axis=0) - jnp.log(len(eps_grid))


def run_stream(X, y, *, k, key, capacity=None):
    """Feed a full stream; returns (pvalues (T,), log mixture martingale)."""
    T, p_dim = X.shape
    cap = capacity or T
    state = init(cap, p_dim, k, dtype=X.dtype)
    taus = jax.random.uniform(key, (T,), dtype=X.dtype)

    def step(st, inp):
        x, yv, tau = inp
        st, pv = observe(st, x, yv, tau, k=k)
        return st, pv

    _, pvals = jax.lax.scan(step, state, (X, y, taus))
    return pvals, simple_mixture_log_martingale(pvals)


__all__ = ["OnlineKnnState", "init", "observe", "observe_with_dists",
           "run_stream", "power_martingale_increment",
           "simple_mixture_log_martingale", "ring_age", "ring_live",
           "ring_slots", "cshift", "drop_backfill", "drop_backfill_core",
           "BIG"]
