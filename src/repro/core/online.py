"""Online CP: exchangeability martingales / IID testing (paper §9, App. C.5).

Vovk et al. (2003): observe a stream x_1, x_2, ...; at step n compute a
smoothed p-value for x_{n+1} against {x_1..x_n} (Algorithm 1), then *learn*
x_{n+1}. Betting functions turn the p-value stream into a martingale M_n
whose growth is evidence against exchangeability (change-point detection,
feature selection (Cherubin et al. 2018)).

Complexity (paper App. C.5): with standard k-NN CP the n-step stream costs
O(n^3); with this module's incremental&decremental k-NN it is O(n^2) —
each step is one O(n) update (the paper's headline online win).

The state is preallocated to a static capacity so the whole stream step is
one fixed-shape jitted function (no retracing as n grows) — the production
serving form of the paper's "adapting our optimizations to this setting is
trivial" remark.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

BIG = 1e30


def cshift(a, s, fill):
    """Conditionally drop the leading row: shift rows up by ``s`` (a
    traced 0/1 scalar) with ``fill`` entering at the tail — one padded
    dynamic slice, bitwise identity when ``s == 0``. The compaction
    primitive of the serving engines' fused sliding step."""
    pad = [(0, 1)] + [(0, 0)] * (a.ndim - 1)
    ap = jnp.pad(a, pad, constant_values=fill)
    start = (s,) + (jnp.int32(0),) * (a.ndim - 1)
    return jax.lax.dynamic_slice(ap, start, a.shape)


def drop_backfill_core(L, es, cand, Ds, *, k):
    """Shared decremental list repair for the serving engines' eviction.

    For each row: drop the first slot of the ascending k-best list ``L``
    holding the evicted distance ``es`` (the evicted point has the
    lowest arrival index, so on ties it occupies the first slot holding
    its value), then backfill the new k-th best by multiset rank over
    the stored distances: the k-1 survivors hold every remaining
    candidate value below their max t' plus m' occurrences of t' itself,
    so the next value is t' again if the window (``Ds`` masked by
    ``cand``) holds more than m' occurrences of it, else the smallest
    stored distance above t'. Every output is a selected stored value —
    bit-identical to a full re-sort, a fraction of the compute.

    Returns ``(newL, pos0, cols, b, tprime, mprime)`` so label-carrying
    callers (the regression state) can mirror the move on a parallel
    label matrix. Both exactness proofs (classification and regression)
    rest on this one function.
    """
    cap = L.shape[0]
    pos0 = jnp.sum((L < es[:, None]).astype(jnp.int32), axis=1)
    Lup = jnp.concatenate([L[:, 1:], jnp.full_like(L[:, :1], BIG)], axis=1)
    # t' = max of the k-1 survivors; m' = its multiplicity among them
    if k >= 2:
        tprime = jnp.where(pos0 <= k - 2, L[:, k - 1], L[:, k - 2])
    else:
        # empty survivor list: below every distance (distances are >= 0)
        tprime = jnp.full((cap,), -1.0, L.dtype)
    mprime = (jnp.sum((L == tprime[:, None]).astype(jnp.int32), axis=1)
              - (es == tprime).astype(jnp.int32))
    cnt = jnp.sum(jnp.where(cand & (Ds == tprime[:, None]), 1, 0), axis=1)
    gtmin = jnp.min(
        jnp.where(cand & (Ds > tprime[:, None]), Ds, BIG), axis=1)
    b = jnp.where(cnt > mprime, tprime, gtmin)
    cols = jnp.arange(k)
    newL = jnp.where(cols[None, :] < pos0[:, None], L,
                     jnp.where(cols[None, :] < k - 1, Lup, b[:, None]))
    return newL, pos0, cols, b, tprime, mprime


@jax.tree_util.register_pytree_node_class
@dataclass
class OnlineKnnState:
    """Capacity-padded incremental simplified-k-NN CP state.

    Rows >= n are inert: distances to them are BIG, their scores never
    counted. ``best`` holds each live point's k best same-label distances.
    """

    X: jnp.ndarray  # (cap, p)
    y: jnp.ndarray  # (cap,)
    best: jnp.ndarray  # (cap, k) ascending same-label distances, BIG-padded
    n: jnp.ndarray  # () live count

    def tree_flatten(self):
        return ((self.X, self.y, self.best, self.n), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> OnlineKnnState:
    return OnlineKnnState(
        X=jnp.zeros((capacity, p), dtype=dtype),
        y=jnp.full((capacity,), -1, dtype=jnp.int32),
        best=jnp.full((capacity, k), BIG, dtype=dtype),
        n=jnp.zeros((), dtype=jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def observe(state: OnlineKnnState, x_new, y_new, tau, *, k):
    """One online step: smoothed p-value for (x_new, y_new), then learn it.

    Returns (new_state, p_value). O(capacity) — O(n) amortized on TPU since
    inert rows are masked arithmetic, not skipped.
    """
    new_state, p, _ = _observe_impl(state, x_new, y_new, tau, k=k)
    return new_state, p


@functools.partial(jax.jit, static_argnames=("k",))
def observe_with_dists(state: OnlineKnnState, x_new, y_new, tau, *, k):
    """``observe`` that also returns the live-masked distance vector.

    Identical arithmetic to ``observe`` (same p-value bits); the extra
    return is the (cap,) vector of distances from ``x_new`` to each live
    row, BIG on inert rows — callers that maintain auxiliary per-pair
    state (``repro.serving.session`` keeps the pairwise distance matrix
    for exact decremental eviction) reuse it instead of recomputing.
    """
    return _observe_impl(state, x_new, y_new, tau, k=k)


def _observe_impl(state: OnlineKnnState, x_new, y_new, tau, *, k):
    cap = state.X.shape[0]
    live = jnp.arange(cap) < state.n
    # fused distance row + same-label k-best merge: one Pallas pass on
    # TPU; the CPU/f64 reference is expression-identical to the historic
    # inline code, so the stream's p-value bits are unchanged
    d, merged, _ = kops.stream_update(
        state.X, state.y, state.best, None, x_new, y_new, state.n,
        mode="class")
    same = (state.y == y_new) & live

    # candidate score: sum of k best same-label distances
    cand = jnp.where(same, d, BIG)
    alpha = jnp.sum(-jax.lax.top_k(-cand, k)[0])

    # provisional -> updated scores for live points (O(1) each);
    # cancellation-safe base + (kth or d) form, never subtracting BIG
    base = jnp.sum(state.best[:, :-1], axis=1)
    kth = state.best[:, -1]
    upd = same & (d < kth)
    alphas = base + jnp.where(upd, d, kth)

    # smoothed p-value over live points + the candidate itself; the
    # astype is a no-op at f32/f64 but pins sub-f32 state dtypes (the
    # int/float promotion otherwise widens p to f32, which breaks the
    # engine's masked cond whose skip branch is a state-dtype NaN)
    gt = jnp.sum(jnp.where(live, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live, alphas == alpha, False))
    p = ((gt + tau * (eq + 1.0)) / (state.n + 1.0)).astype(state.X.dtype)

    # learn: the merged lists come from the fused pass; the new row's own
    # list is the k best same-label distances seen so far
    own = jnp.sort(-jax.lax.top_k(-cand, k)[0])
    idx = state.n
    new_state = OnlineKnnState(
        X=state.X.at[idx].set(x_new),
        y=state.y.at[idx].set(y_new.astype(state.y.dtype)),
        best=merged.at[idx].set(own),
        n=state.n + 1,
    )
    return new_state, p, d


# ---------------------------------------------------------------------------
# betting martingales over the p-value stream
# ---------------------------------------------------------------------------


def power_martingale_increment(p, epsilon=0.92):
    """Power betting function: f(p) = eps * p^(eps-1); integral over [0,1]=1."""
    return epsilon * jnp.power(jnp.maximum(p, 1e-12), epsilon - 1.0)


@jax.jit
def simple_mixture_log_martingale(pvals: jnp.ndarray) -> jnp.ndarray:
    """Log of the simple-mixture martingale: integral over eps of the power
    martingale, approximated on a grid (valid as a mixture of martingales).
    Returns log M_n for each prefix n: (T,)."""
    eps_grid = jnp.linspace(0.05, 0.95, 19)
    # log increments per (eps, t)
    logf = (jnp.log(eps_grid)[:, None]
            + (eps_grid[:, None] - 1.0) * jnp.log(jnp.maximum(pvals, 1e-12))[None, :])
    logM = jnp.cumsum(logf, axis=1)  # per-eps martingale paths
    return jax.scipy.special.logsumexp(logM, axis=0) - jnp.log(len(eps_grid))


def run_stream(X, y, *, k, key, capacity=None):
    """Feed a full stream; returns (pvalues (T,), log mixture martingale)."""
    T, p_dim = X.shape
    cap = capacity or T
    state = init(cap, p_dim, k, dtype=X.dtype)
    taus = jax.random.uniform(key, (T,), dtype=X.dtype)

    def step(st, inp):
        x, yv, tau = inp
        st, pv = observe(st, x, yv, tau, k=k)
        return st, pv

    _, pvals = jax.lax.scan(step, state, (X, y, taus))
    return pvals, simple_mixture_log_martingale(pvals)


__all__ = ["OnlineKnnState", "init", "observe", "observe_with_dists",
           "run_stream", "power_martingale_increment",
           "simple_mixture_log_martingale"]
