"""k-NN and simplified k-NN nonconformity measures (paper Sections 3, 3.1).

Two implementation paths with **identical outputs**:

* ``scores_standard`` / ``pvalues_standard`` — the naive full-CP algorithm:
  for every test candidate, recompute all LOO scores against the augmented
  training set from scratch. O(n^2 l m) for m test points (paper baseline).
* ``fit`` + ``pvalues_optimized`` — the paper's incremental&decremental
  optimization: a one-off O(n^2) training phase precomputes, per training
  point, the k best same-label (and, for the ratio measure, different-label)
  distances; prediction is O(n l m). The test-time update is the O(1)-per-
  point rule of paper Fig. 1: if the test object enters point i's
  neighbourhood, swap the k-th best distance for d(x_i, x).

Distances are Euclidean. Missing neighbours (fewer than k candidates) use a
BIG sentinel in *both* paths, so outputs agree exactly even in edge cases.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

BIG = 1e30


def _dists_to_train(X_test, X):
    """Euclidean distances (m, n) from test rows to training rows."""
    return jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, X), 0.0))


def _k_best(d, mask, k):
    """k smallest of d where mask, ascending, padded with BIG."""
    d = jnp.where(mask, d, BIG)
    return jnp.sort(-jax.lax.top_k(-d, k)[0])


# ---------------------------------------------------------------------------
# standard (naive) path
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "simplified"))
def scores_standard(X, y, x_test, y_hat, *, k, simplified):
    """Naive LOO scores for one candidate: (alphas (n,), alpha). O(n^2)."""
    n = X.shape[0]
    Xa = jnp.concatenate([X, x_test[None]], axis=0)
    ya = jnp.concatenate([y, jnp.array([y_hat], dtype=y.dtype)])
    D = _dists_to_train(Xa, Xa)
    eye = jnp.eye(n + 1, dtype=bool)
    same = (ya[:, None] == ya[None, :]) & ~eye
    diff = (ya[:, None] != ya[None, :]) & ~eye

    def row_score(drow, srow, frow):
        num = jnp.sum(_k_best(drow, srow, k))
        if simplified:
            return num
        return num / jnp.sum(_k_best(drow, frow, k))

    scores = jax.vmap(row_score)(D, same, diff)
    return scores[:n], scores[n]


@functools.partial(jax.jit, static_argnames=("k", "simplified", "n_labels"))
def pvalues_standard(X, y, X_test, *, k, simplified, n_labels):
    """Naive full CP p-values for all test points x all labels: (m, l)."""
    labels = jnp.arange(n_labels, dtype=y.dtype)
    n = X.shape[0]

    def one(x_t, y_hat):
        alphas, alpha = scores_standard(X, y, x_t, y_hat, k=k, simplified=simplified)
        return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

    def per_test(x_t):
        return jax.vmap(lambda lb: one(x_t, lb))(labels)

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# optimized (incremental&decremental) path
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class KnnState:
    """Provisional per-training-point state (paper Section 3.1).

    ``best_same``/``best_diff`` hold each point's k best distances to
    same/different-label training points (ascending, BIG-padded). Their sums
    are the provisional scores alpha'_i; the last column is Delta_i^k.
    """

    X: jnp.ndarray  # (n, p)
    y: jnp.ndarray  # (n,)
    best_same: jnp.ndarray  # (n, k)
    best_diff: jnp.ndarray  # (n, k)

    def tree_flatten(self):
        return ((self.X, self.y, self.best_same, self.best_diff), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self):
        return self.X.shape[0]


@functools.partial(jax.jit, static_argnames=("k",))
def fit(X, y, *, k) -> KnnState:
    """O(n^2) training phase: pairwise distances + k-best neighbour stats."""
    D = _dists_to_train(X, X)
    n = X.shape[0]
    eye = jnp.eye(n, dtype=bool)
    same = (y[:, None] == y[None, :]) & ~eye
    diff = (y[:, None] != y[None, :]) & ~eye
    best_same = jax.vmap(lambda d, m: _k_best(d, m, k))(D, same)
    best_diff = jax.vmap(lambda d, m: _k_best(d, m, k))(D, diff)
    return KnnState(X, y, best_same, best_diff)


def _updated_scores(state: KnnState, d, y_hat, simplified: bool):
    """O(1)-per-point incremental&decremental update (paper Fig. 1).

    Cancellation-safe form: base = sum of the k-1 best distances; the score
    is base + (kth or d). Never subtracts, so the BIG padding sentinel
    (fewer than k same-label neighbours) cannot swallow the finite part in
    f32 — exactness holds even when a class has < k members."""
    base_same = jnp.sum(state.best_same[:, :-1], axis=-1)
    kth_same = state.best_same[:, -1]
    same = state.y == y_hat
    upd = same & (d < kth_same)
    num = base_same + jnp.where(upd, d, kth_same)
    if simplified:
        return num
    base_diff = jnp.sum(state.best_diff[:, :-1], axis=-1)
    kth_diff = state.best_diff[:, -1]
    updd = (~same) & (d < kth_diff)
    den = base_diff + jnp.where(updd, d, kth_diff)
    return num / den


def _candidate_score(state: KnnState, d, y_hat, k, simplified):
    num = jnp.sum(_k_best(d, state.y == y_hat, k))
    if simplified:
        return num
    return num / jnp.sum(_k_best(d, state.y != y_hat, k))


@functools.partial(jax.jit, static_argnames=("k", "simplified"))
def scores_optimized(state: KnnState, x_test, y_hat, *, k, simplified):
    """(alphas, alpha) for one candidate — exactness-tested vs standard."""
    d = _dists_to_train(x_test[None], state.X)[0]
    alphas = _updated_scores(state, d, y_hat, simplified)
    return alphas, _candidate_score(state, d, y_hat, k, simplified)


@functools.partial(jax.jit, static_argnames=("k", "simplified", "n_labels"))
def pvalues_optimized(state: KnnState, X_test, *, k, simplified, n_labels):
    """Optimized full CP p-values (m, l); O(n l) per test point."""
    labels = jnp.arange(n_labels, dtype=state.y.dtype)
    n = state.n

    def per_test(x_t):
        d = _dists_to_train(x_t[None], state.X)[0]

        def per_label(y_hat):
            alphas = _updated_scores(state, d, y_hat, simplified)
            alpha = _candidate_score(state, d, y_hat, k, simplified)
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k",))
def incremental_add(state: KnnState, x_new, y_new, *, k) -> KnnState:
    """Online learning (paper Section 9): learn one example in O(n k).

    Every training point whose neighbourhood the new point enters gets its
    k-best list re-sorted with the new distance; the new point's own lists
    are the k best of its distance row.
    """
    d = _dists_to_train(x_new[None], state.X)[0]
    same = state.y == y_new

    def insert(best, mask):
        cand = jnp.where(mask, d, BIG)
        merged = jnp.sort(
            jnp.concatenate([best, cand[:, None]], axis=1), axis=1
        )[:, :k]
        return merged

    new_same = insert(state.best_same, same)
    new_diff = insert(state.best_diff, ~same)
    own_same = _k_best(d, same, k)[None]
    own_diff = _k_best(d, ~same, k)[None]
    return KnnState(
        jnp.concatenate([state.X, x_new[None]], axis=0),
        jnp.concatenate([state.y, jnp.array([y_new], dtype=state.y.dtype)]),
        jnp.concatenate([new_same, own_same], axis=0),
        jnp.concatenate([new_diff, own_diff], axis=0),
    )


def decremental_remove(state: KnnState, i: int, *, k) -> KnnState:
    """Decremental unlearning (paper Fig. 1 backwards): forget point ``i``.

    Only points whose same- (or, for the ratio measure, different-) label
    k-neighbourhood contained point i are affected; each backfills its
    list with the next-best distance over the remaining set. Distances
    are recomputed for the O(k)-expected affected rows only — O(a n p)
    work for a affected rows, the paper's decremental cost, not a refit.
    Exact vs. ``fit`` on the remaining data. ``i`` must be a concrete int
    (the result shape shrinks by one row — host-level, like
    incremental_add's growth; the fixed-shape serving form in
    ``repro.serving`` instead keeps the distance matrix and never
    recomputes).
    """
    n = state.n
    i = int(i)
    if not -n <= i < n:
        raise IndexError(f"index {i} out of range for {n} training points")
    i %= n  # negative indices: the mask arithmetic below needs 0 <= i < n
    d_i = _dists_to_train(state.X[i][None], state.X)[0]
    keep = jnp.arange(n) != i
    aff_s = ((state.y == state.y[i]) & keep
             & (d_i <= state.best_same[:, -1]))
    aff_d = ((state.y != state.y[i]) & keep
             & (d_i <= state.best_diff[:, -1]))
    rows = np.flatnonzero(np.asarray(aff_s | aff_d))
    best_same, best_diff = state.best_same, state.best_diff
    if rows.size:
        r = rows.size
        D = _dists_to_train(state.X[rows], state.X)  # (r, n)
        yr = state.y[rows]
        same_pair = (yr[:, None] == state.y[None, :]) & keep[None, :]
        same_pair = same_pair.at[jnp.arange(r), rows].set(False)  # no self
        diff_pair = (yr[:, None] != state.y[None, :]) & keep[None, :]
        rec_s = jax.vmap(lambda d, m: _k_best(d, m, k))(D, same_pair)
        rec_d = jax.vmap(lambda d, m: _k_best(d, m, k))(D, diff_pair)
        best_same = best_same.at[rows].set(
            jnp.where(aff_s[rows][:, None], rec_s, best_same[rows]))
        best_diff = best_diff.at[rows].set(
            jnp.where(aff_d[rows][:, None], rec_d, best_diff[rows]))
    return KnnState(
        jnp.delete(state.X, i, axis=0),
        jnp.delete(state.y, i, axis=0),
        jnp.delete(best_same, i, axis=0),
        jnp.delete(best_diff, i, axis=0),
    )
