"""Kernel LS-SVM nonconformity measure (paper Section 5, Appendix B).

A((x,y); S) = -y * w_S . phi(x), with w_S ridge-trained on S and phi an
explicit feature map (linear / polynomial / random Fourier features — finite
q generalizes "multiple kernels" exactly as the paper's use of Lee et al.).

Standard path: one O(q^3 + n q^2) solve per LOO entry -> O(n^{w+1} l m).
Optimized path (Section 5.1, Lee et al. 2019): train w, C once; per test
candidate do ONE incremental rank-1 update (add the candidate), then the LOO
decrement for every training point. Beyond the paper (DESIGN.md §3.5): the
decremented *score* collapses to

    alpha_i = -y_i * (rho*u_i + (s_i - t_i)*y_i) / (rho + s_i - t_i)

with u = Phi^T w+, s = diag(Phi^T C+ Phi), t = ||phi_i||^2 — three GEMMs,
O(n q^2) total instead of n separate O(q^3) downdates. Exactness vs
from-scratch retraining is property-tested.

Useful identities (Phi = [phi(x_1)..phi(x_n)], A = Phi Phi^T + rho I_q):
    w = A^{-1} Phi Y,   C = Phi(Phi^T Phi + rho I_n)^{-1} Phi^T = I_q - rho A^{-1}.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# feature maps (finite-q kernels)
# ---------------------------------------------------------------------------


def feature_map(kind: str, p: int, q: int = 0, seed: int = 0):
    """Returns phi: (n, p) -> (n, q_out)."""
    if kind == "linear":
        return lambda X: X, p
    if kind == "poly2":
        # degree-2 polynomial features: [x, x_i*x_j upper triangle]
        iu = jnp.triu_indices(p)

        def phi(X):
            quad = (X[:, :, None] * X[:, None, :])[:, iu[0], iu[1]]
            return jnp.concatenate([X, quad], axis=1)

        return phi, p + (p * (p + 1)) // 2
    if kind == "rff":
        # random Fourier features for the RBF kernel
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        W = jax.random.normal(k1, (p, q))
        b = jax.random.uniform(k2, (q,), maxval=2 * jnp.pi)

        def phi(X):
            return jnp.sqrt(2.0 / q) * jnp.cos(X @ W + b)

        return phi, q
    raise ValueError(f"unknown feature map {kind!r}")


# ---------------------------------------------------------------------------
# standard (naive) path
# ---------------------------------------------------------------------------


def _train_w(Phi, Y, rho):
    q = Phi.shape[1]
    A = Phi.T @ Phi + rho * jnp.eye(q, dtype=Phi.dtype)
    return jnp.linalg.solve(A, Phi.T @ Y)


@functools.partial(jax.jit, static_argnames=("rho",))
def scores_standard(Phi, Y, phi_test, y_hat, *, rho):
    """Naive LOO: retrain from scratch per left-out point. O(n q^3)."""
    n = Phi.shape[0]
    Phi_a = jnp.concatenate([Phi, phi_test[None]], axis=0)
    Y_a = jnp.concatenate([Y, y_hat[None].astype(Y.dtype)])

    def loo(i):
        mask = jnp.arange(n + 1) != i
        Phi_m = jnp.where(mask[:, None], Phi_a, 0.0)
        Y_m = jnp.where(mask, Y_a, 0.0)
        w = _train_w(Phi_m, Y_m, rho)
        return -Y_a[i] * (Phi_a[i] @ w)

    scores = jax.lax.map(loo, jnp.arange(n + 1))
    return scores[:n], scores[n]


@functools.partial(jax.jit, static_argnames=("rho",))
def pvalues_standard(Phi, Y, Phi_test, *, rho):
    """Naive full CP p-values for binary labels (-1, +1): (m, 2)."""
    n = Phi.shape[0]

    def per_test(phi_t):
        def per_label(y_hat):
            alphas, alpha = scores_standard(Phi, Y, phi_t, y_hat, rho=rho)
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(jnp.array([-1.0, 1.0], dtype=Phi.dtype))

    return jax.lax.map(per_test, Phi_test)


# ---------------------------------------------------------------------------
# optimized (incremental&decremental, Lee et al. 2019) path
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class LssvmState:
    Phi: jnp.ndarray  # (n, q) feature-mapped training set
    Y: jnp.ndarray  # (n,) labels in {-1, +1}
    w: jnp.ndarray  # (q,) trained model
    C: jnp.ndarray  # (q, q) auxiliary matrix of Lee et al.
    rho: jnp.ndarray  # () regularizer

    def tree_flatten(self):
        return ((self.Phi, self.Y, self.w, self.C, self.rho), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.jit
def fit(Phi, Y, rho) -> LssvmState:
    """One-off O(n q^2 + q^3) training (paper: O(n^w))."""
    q = Phi.shape[1]
    A = Phi.T @ Phi + rho * jnp.eye(q, dtype=Phi.dtype)
    Ainv = jnp.linalg.inv(A)
    w = Ainv @ (Phi.T @ Y)
    C = jnp.eye(q, dtype=Phi.dtype) - rho * Ainv
    return LssvmState(Phi, Y, w, C, jnp.asarray(rho, dtype=Phi.dtype))


@jax.jit
def incremental_add(state: LssvmState, phi_new, y_new) -> LssvmState:
    """Lee et al. incremental update: O(q^2). Exactness property-tested."""
    C, w, rho = state.C, state.w, state.rho
    Iq = jnp.eye(C.shape[0], dtype=C.dtype)
    Cphi = (C - Iq) @ phi_new
    denom = phi_new @ phi_new + rho - phi_new @ C @ phi_new
    w_new = w + Cphi * (phi_new @ w - y_new) / denom
    C_new = C + jnp.outer(Cphi, Cphi) / denom
    return LssvmState(
        jnp.concatenate([state.Phi, phi_new[None]], axis=0),
        jnp.concatenate([state.Y, y_new[None].astype(state.Y.dtype)]),
        w_new, C_new, rho,
    )


def _downdate(state: LssvmState, phi_i, y_i):
    """Shared Lee et al. removal terms: (Cphi, denom, downdated w)."""
    C, w, rho = state.C, state.w, state.rho
    Iq = jnp.eye(C.shape[0], dtype=C.dtype)
    Cphi = (C - Iq) @ phi_i
    denom = -phi_i @ phi_i + rho + phi_i @ C @ phi_i
    return Cphi, denom, w - Cphi * (phi_i @ w - y_i) / denom


@jax.jit
def decremental_remove_w(state: LssvmState, phi_i, y_i) -> jnp.ndarray:
    """Lee et al. decremental update of w only: O(q^2)."""
    return _downdate(state, phi_i, y_i)[2]


def decremental_remove(state: LssvmState, i: int) -> LssvmState:
    """Full Lee et al. decremental update: forget training point ``i``.

    Sherman–Morrison downdate of both w and C in O(q^2) (with
    A = Phi^T Phi + rho I and C = I - rho A^{-1}, removing phi_i gives
    C' = C - Cphi Cphi^T / (rho + phi_i.C.phi_i - ||phi_i||^2)) — the
    exact inverse of ``incremental_add``. ``i`` must be a concrete int
    (shape shrinks; host-level)."""
    Cphi, denom, w_new = _downdate(state, state.Phi[i], state.Y[i])
    C_new = state.C - jnp.outer(Cphi, Cphi) / denom
    return LssvmState(
        jnp.delete(state.Phi, i, axis=0),
        jnp.delete(state.Y, i, axis=0),
        w_new, C_new, state.rho,
    )


@jax.jit
def loo_scores(state: LssvmState) -> jnp.ndarray:
    """Vectorized LOO scores alpha_i = -y_i * w_{-i}.phi_i for ALL i at once.

    Three GEMMs (O(n q^2)) replace n rank-1 downdates (DESIGN.md §3.5).
    """
    Phi, Y, w, C, rho = state.Phi, state.Y, state.w, state.C, state.rho
    u = Phi @ w  # (n,)
    s = jnp.einsum("nq,qr,nr->n", Phi, C, Phi)  # diag(Phi C Phi^T)
    t = jnp.sum(Phi * Phi, axis=1)
    denom = rho + s - t
    return -Y * (rho * u + (s - t) * Y) / denom


@jax.jit
def scores_optimized(state: LssvmState, phi_test, y_hat):
    """(alphas, alpha) for one candidate: ONE incremental add + batched LOO."""
    alpha = -y_hat * (phi_test @ state.w)  # candidate scored by w on Z
    st_plus = incremental_add(state, phi_test, y_hat)
    alphas = loo_scores(st_plus)[:-1]
    return alphas, alpha


@jax.jit
def pvalues_optimized(state: LssvmState, Phi_test):
    """Optimized full CP p-values for binary labels (-1, +1): (m, 2).

    C+, s = diag(Phi C+ Phi^T) and t = ||phi_i||^2 are label-independent, so
    they are computed once per test point and shared across both candidate
    labels; only the O(n q) terms u = Phi w+ and the score combine are
    per-label.
    """
    Phi, Y, w, C, rho = state.Phi, state.Y, state.w, state.C, state.rho
    n, q = Phi.shape
    Iq = jnp.eye(q, dtype=C.dtype)
    labels = jnp.array([-1.0, 1.0], dtype=Phi.dtype)

    def per_test(phi_t):
        Cphi = (C - Iq) @ phi_t
        denom_add = phi_t @ phi_t + rho - phi_t @ C @ phi_t
        C_plus = C + jnp.outer(Cphi, Cphi) / denom_add
        Phi_a = jnp.concatenate([Phi, phi_t[None]], axis=0)
        s = jnp.einsum("nq,qr,nr->n", Phi_a, C_plus, Phi_a)
        t = jnp.sum(Phi_a * Phi_a, axis=1)
        denom = rho + s - t
        fw = phi_t @ w

        def per_label(y_hat):
            w_plus = w + Cphi * (fw - y_hat) / denom_add
            Y_a = jnp.concatenate([Y, y_hat[None].astype(Y.dtype)])
            u = Phi_a @ w_plus
            alphas = (-Y_a * (rho * u + (s - t) * Y_a) / denom)[:n]
            alpha = -y_hat * fw
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, Phi_test)
