"""Bootstrap nonconformity measure (paper Section 6, Algorithm 3), streaming.

Standard bootstrap CP trains a fresh B-classifier ensemble for every LOO
entry: O(S_g(n) B n l m). The paper's optimization pre-samples bootstrap
draws of the augmented set Z* = Z u {*} (with * a placeholder for the test
point) until every example has >= B samples *not containing it* (footnote 1:
per-example lists are capped at B); samples without * are pre-trained at fit
time. At prediction only the samples that do contain * are trained — a
(1 - e^{-1}) ~ 0.632x predict cost, and shared classifiers make the
effective number of trainings B' << B n.

This module extends Algorithm 3 to the serving setting with exact
incremental (``incremental_add``) and decremental (``decremental_remove``)
updates over a *shared sample pool*:

* Every bootstrap sample is stored as a multiplicity vector over the
  current training points (``W``), a placeholder count (``star``), and an
  **eligibility epoch** (``elig``): a sample drawn at time t is a draw from
  Z*_t, so it may only serve points that were in the pool when it was drawn
  (points born later could never have appeared in it).
* ``incremental_add`` oversamples: fresh draws over the enlarged Z* until
  the new point has B clean samples (existing points are untouched — their
  lists stay at the cap).
* ``decremental_remove`` retires every sample containing the removed point
  (their training multisets no longer exist), backfills damaged per-point
  lists from the earliest surviving eligible samples, and only then
  oversamples; samples no longer referenced by any list are pruned.

**Exactness contract.** All derived structures (assignment lists ``E`` /
``E_i``, per-point counts, pre-trained trees, cached predictions and vote
counts) are maintained so that after ANY interleaving of observe/evict the
state is bit-identical to ``fit_from_samples`` — a from-scratch batch build
on the same effective sample set (``rebuild``); ``fit`` itself is
draw-then-``fit_from_samples``, so batch and streaming share one code
path. Randomness is keyed, never sequential: bootstrap draws by draw id
(``DrawStream``), pre-trained trees by (seed, draw id), predict-time
star trees by (seed, test index, label) consumed over *sorted* sample ids
— repeated ``pvalues_optimized`` calls are bit-identical (the seed
implementation iterated an unordered ``set``, making p-values depend on
Python hash order).

The base learner is a vectorized extra-tree ensemble (random split feature
+ random threshold, majority leaves), fitted as stacked ``(S, n_nodes)``
arrays in one vmapped dispatch via ``kernels.ops.boot_fit_forest`` (numpy
oracle in ``kernels.ref``). The bootstrap machinery is learner-agnostic;
the paper's Random-Forest instantiation differs only in the tree fitting
rule.
"""
from __future__ import annotations

from bisect import insort
from dataclasses import dataclass

import jax
import numpy as np

from repro.kernels import ops as kops

# rng stream tags: every random quantity is keyed, never sequential
_DRAW_TAG = 0  # bootstrap index draws (DrawStream)
_TREE_TAG = 1  # pre-trained trees, by draw id
_STAR_TAG = 2  # predict-time star trees, by (test index, label)
_STD_TAG = 3  # the naive path, by (test index, label)


class DrawStream:
    """Keyed RNG stream for bootstrap draws (the registry ``ctx``).

    ``draw(d, n)`` is a pure function of ``(seed, d)``: draw d of Z* for a
    pool of n training points — n+1 indices in ``[0, n]``, value n being
    the placeholder *. Keying by draw id (instead of consuming one
    sequential generator) keeps every draw reproducible independently of
    the call history, which is what lets ``rebuild`` verify a streamed
    state against a from-scratch build.
    """

    def __init__(self, seed: int):
        self.seed = int(seed)

    def draw(self, draw_id: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, _DRAW_TAG, draw_id))
        return rng.integers(0, n + 1, size=n + 1)


def _node_rand(rng, S, n_nodes, p):
    """Pre-drawn per-node randomness for S trees: feature ids + uniforms."""
    fc = rng.integers(0, p, size=(S, n_nodes)).astype(np.int32)
    u = rng.random(size=(S, n_nodes), dtype=np.float32)
    return fc, u


def _tree_rand(seed, draw_ids, n_nodes, p):
    """Per-sample keyed randomness: tree of draw d is a function of d only."""
    fc = np.empty((len(draw_ids), n_nodes), np.int32)
    u = np.empty((len(draw_ids), n_nodes), np.float32)
    for r, d in enumerate(draw_ids):
        rng = np.random.default_rng((seed, _TREE_TAG, int(d)))
        fc[r] = rng.integers(0, p, size=n_nodes)
        u[r] = rng.random(size=n_nodes, dtype=np.float32)
    return fc, u


def _validate_labels(y, n_labels):
    if y.size and (int(y.min()) < 0 or int(y.max()) >= n_labels):
        raise ValueError(
            f"labels must lie in [0, {n_labels}); got range "
            f"[{int(y.min())}, {int(y.max())}]")


@jax.tree_util.register_pytree_node_class
@dataclass
class BootstrapState:
    """Algorithm 3 state over a shared, epoch-tagged sample pool.

    Sample rows are kept in ascending ``draw_ids`` order (the canonical
    replay order of ``fit_from_samples``). ``E`` / ``E_i`` hold draw ids,
    sorted ascending, capped at B; the invariant after every successful
    update is ``counts == B`` everywhere and ``len(E) == B``. ``feat`` /
    ``thresh`` / ``leaf`` are the stacked pre-trained extra-trees (star
    rows are deterministic fill: feat -1, thresh 0, leaf 0); ``pre_pred``
    caches their predictions on every current training point (star rows
    -1), and ``pre_votes`` the per-point pre-trained vote count — the
    cached half of the score that ``pvalues_optimized`` never recomputes.
    """

    X: np.ndarray  # (n, p) f32 training points
    y: np.ndarray  # (n,) i32 labels
    n_labels: int
    B: int
    depth: int
    seed: int
    uids: np.ndarray  # (n,) i64 birth ids, ascending (arrival order)
    next_uid: int
    draw_ids: list  # (S,) sample draw ids, ascending
    next_draw: int
    W: np.ndarray  # (S, n) i32 multiplicity of each point in each sample
    star: np.ndarray  # (S,) i32 multiplicity of the placeholder *
    elig: np.ndarray  # (S,) i64 epoch: sample serves i iff uids[i] < elig
    E: list  # draw ids without * (pre-trained; score the candidate)
    E_i: list  # per point: draw ids without that point (capped at B)
    counts: np.ndarray  # (n,) i64 == len(E_i[i])
    feat: np.ndarray  # (S, n_nodes) i32
    thresh: np.ndarray  # (S, n_nodes) f32
    leaf: np.ndarray  # (S, n_nodes) i32
    pre_pred: np.ndarray  # (S, n) i32 pre-trained predictions (-1 on star)
    pre_votes: np.ndarray  # (n,) i64 cached pre-trained vote counts

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def b_prime(self) -> int:
        """Live shared-sample count B' (paper Figure 5: B' << B n)."""
        return len(self.draw_ids)

    def tree_flatten(self):
        aux = (self.n_labels, self.B, self.depth, self.seed, self.uids,
               self.next_uid, self.draw_ids, self.next_draw, self.W,
               self.star, self.elig, self.E, self.E_i, self.counts,
               self.feat, self.thresh, self.leaf, self.pre_pred,
               self.pre_votes)
        return (self.X, self.y), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)


def _n_nodes(depth):
    return 2 ** (depth + 1) - 1


def _train_rows(X, y, W_rows, dids, seed, n_labels, depth):
    """Fit the pre-trained trees of the given sample rows (one dispatch)
    and cache their predictions on every current training point."""
    fc, u = _tree_rand(seed, dids, _n_nodes(depth), X.shape[1])
    feat, thresh, leaf = kops.boot_fit_forest(
        X, y, W_rows, fc, u, n_labels=n_labels, depth=depth)
    pre_pred = kops.boot_forest_predict(feat, thresh, leaf, X)
    return feat, thresh, leaf, pre_pred.astype(np.int32)


def _pre_votes_of(E_i, draw_ids, star, pre_pred, y):
    """pre_votes[i] = #{pre-trained d in E_i[i] : tree_d(x_i) == y_i}."""
    row_of = {d: r for r, d in enumerate(draw_ids)}
    votes = np.zeros(len(E_i), np.int64)
    for i, lst in enumerate(E_i):
        for d in lst:
            r = row_of[d]
            if star[r] == 0 and pre_pred[r, i] == y[i]:
                votes[i] += 1
    return votes


def _starved_error(B, names, counts, context):
    return ValueError(
        f"bootstrap {context} starved: entries {names} have fewer than "
        f"B={B} clean samples (counts {counts}); raise max_bprime/"
        f"max_draws or lower B")


def fit_from_samples(X, y, draw_ids, W, star, elig, uids, *, n_labels, B,
                     depth, seed, next_uid=None,
                     next_draw=None) -> BootstrapState:
    """From-scratch batch build on an explicit sample set (replay).

    The canonical assignment rule: samples in ascending draw order; each
    sample joins ``E_i[i]`` for every point it is absent from and eligible
    for (``uids[i] < elig``) whose list is below B — points in ascending
    position, the placeholder last. Raises ``ValueError`` naming any point
    (or ``'*'``) left with fewer than B clean samples — the guard that
    used to be a division-by-zero crash at predict time.

    ``fit`` routes through this builder, and ``rebuild`` re-invokes it on
    a streamed state's sample set: the exactness tests assert streamed ==
    rebuilt, bit for bit.
    """
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int32)
    n = X.shape[0]
    _validate_labels(y, n_labels)
    S = len(draw_ids)
    W = np.asarray(W, np.int32).reshape(S, n)
    star = np.asarray(star, np.int32)
    elig = np.asarray(elig, np.int64)
    uids = np.asarray(uids, np.int64)
    counts = np.zeros(n, np.int64)
    E_i = [[] for _ in range(n)]
    E = []
    for s in range(S):
        d = int(draw_ids[s])
        for i in np.flatnonzero((W[s] == 0) & (uids < elig[s])
                                & (counts < B)):
            E_i[i].append(d)
            counts[i] += 1
        if star[s] == 0 and len(E) < B:
            E.append(d)
    starved = np.flatnonzero(counts < B).tolist()
    names = [int(i) for i in starved] + (["*"] if len(E) < B else [])
    if names:
        got = [int(counts[i]) for i in starved] + (
            [len(E)] if len(E) < B else [])
        raise _starved_error(B, names, got, "fit")

    nn = _n_nodes(depth)
    feat = np.full((S, nn), -1, np.int32)
    thresh = np.zeros((S, nn), np.float32)
    leaf = np.zeros((S, nn), np.int32)
    pre_pred = np.full((S, n), -1, np.int32)
    pre_rows = np.flatnonzero(star == 0)
    if pre_rows.size:
        f, t, lf, pp = _train_rows(
            X, y, W[pre_rows], [draw_ids[r] for r in pre_rows], seed,
            n_labels, depth)
        feat[pre_rows], thresh[pre_rows] = f, t
        leaf[pre_rows], pre_pred[pre_rows] = lf, pp
    pre_votes = _pre_votes_of(E_i, draw_ids, star, pre_pred, y)
    if next_uid is None:
        next_uid = int(uids.max()) + 1 if n else 0
    if next_draw is None:
        next_draw = int(draw_ids[-1]) + 1 if S else 0
    return BootstrapState(
        X, y, n_labels, B, depth, int(seed), uids, int(next_uid),
        [int(d) for d in draw_ids], int(next_draw), W, star, elig, E, E_i,
        counts, feat, thresh, leaf, pre_pred, pre_votes)


def rebuild(state: BootstrapState) -> BootstrapState:
    """From-scratch build on the state's effective sample set.

    The exactness oracle: a streamed state must equal its rebuild, bit
    for bit (trees, assignment lists, cached votes, p-values).
    """
    return fit_from_samples(
        state.X, state.y, state.draw_ids, state.W, state.star, state.elig,
        state.uids, n_labels=state.n_labels, B=state.B, depth=state.depth,
        seed=state.seed, next_uid=state.next_uid,
        next_draw=state.next_draw)


def fit(X, y, *, n_labels, B=10, depth=5, seed=0, max_bprime=100000,
        stream=None) -> BootstrapState:
    """Algorithm 3 TRAIN: oversample until every point has B clean samples,
    then build the state through ``fit_from_samples``."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int32)
    n = X.shape[0]
    if n < 1:
        raise ValueError("bootstrap fit needs at least one training point")
    _validate_labels(y, n_labels)
    if stream is None:
        stream = DrawStream(seed)
    counts = np.zeros(n + 1, np.int64)  # clean-sample counts; last is *
    draw_ids, W_rows, star_cts = [], [], []
    d = 0
    # max_bprime bounds B' — ACCEPTED shared samples, not attempted draws
    # (rejected draws are free: no tree is ever trained for them). The
    # attempt backstop only guards the measure-zero never-useful spin.
    max_attempts = max(100 * max_bprime, 10000)
    while counts.min() < B and len(draw_ids) < max_bprime \
            and d < max_attempts:
        idx = stream.draw(d, n)
        w = np.bincount(idx[idx < n], minlength=n).astype(np.int32)
        st = int(np.sum(idx == n))
        absent = np.concatenate([w == 0, [st == 0]])
        helped = absent & (counts < B)
        if helped.any():  # footnote 1: keep a draw only if it helps someone
            counts += helped
            draw_ids.append(d)
            W_rows.append(w)
            star_cts.append(st)
        d += 1
    if counts.min() < B:
        starved = np.flatnonzero(counts < B)
        names = ["*" if i == n else int(i) for i in starved]
        raise _starved_error(B, names, counts[starved].tolist(),
                             f"fit (max_bprime={max_bprime})")
    S = len(draw_ids)
    return fit_from_samples(
        X, y, draw_ids,
        np.asarray(W_rows, np.int32).reshape(S, n),
        np.asarray(star_cts, np.int32), np.full(S, n, np.int64),
        np.arange(n, dtype=np.int64), n_labels=n_labels, B=B, depth=depth,
        seed=seed, next_uid=n, next_draw=d)


# ---------------------------------------------------------------------------
# incremental / decremental updates (the serving path)
# ---------------------------------------------------------------------------


def incremental_add(state: BootstrapState, x, y_new, *, stream=None,
                    max_draws=100000) -> BootstrapState:
    """Learn one example: oversample fresh draws over the enlarged Z* until
    the new point has B clean samples. Existing points' lists are already
    at the cap and old samples are ineligible for the new point (it was
    not in the pool when they were drawn), so only the new point's list,
    the new trees, and one cached-prediction column change."""
    x = np.asarray(x, np.float32).reshape(-1)
    if x.shape[0] != state.X.shape[1]:
        raise ValueError(
            f"x has {x.shape[0]} features, state has {state.X.shape[1]}")
    y_new = int(y_new)
    _validate_labels(np.asarray([y_new]), state.n_labels)
    if stream is None:
        stream = DrawStream(state.seed)
    B, n_old = state.B, state.n
    n = n_old + 1
    uid = state.next_uid

    X = np.concatenate([state.X, x[None]], axis=0)
    y = np.append(state.y, np.int32(y_new))
    uids = np.append(state.uids, np.int64(uid))
    S_old = len(state.draw_ids)
    W = np.concatenate([state.W, np.zeros((S_old, 1), np.int32)], axis=1)
    # cached predictions of every pre-trained tree on the new point
    if S_old:
        col = kops.boot_forest_predict(
            state.feat, state.thresh, state.leaf, x[None])[:, 0]
        col = np.where(state.star > 0, -1, col).astype(np.int32)
    else:
        col = np.zeros(0, np.int32)
    pre_pred = np.concatenate([state.pre_pred, col[:, None]], axis=1)

    draw_ids = list(state.draw_ids)
    E_i = [list(lst) for lst in state.E_i] + [[]]
    d = state.next_draw
    new_W, new_star, new_ids = [], [], []
    attempts = 0
    while len(E_i[-1]) < B:
        if attempts >= max_draws:
            raise _starved_error(B, [n_old], [len(E_i[-1])],
                                 f"incremental_add (max_draws={max_draws})")
        idx = stream.draw(d, n)
        w = np.bincount(idx[idx < n], minlength=n).astype(np.int32)
        if w[-1] == 0:  # clean for the new point — the only deficient entry
            draw_ids.append(d)
            new_ids.append(d)
            new_W.append(w)
            new_star.append(int(np.sum(idx == n)))
            E_i[-1].append(d)
        d += 1
        attempts += 1

    R = len(new_ids)
    W = np.concatenate([W, np.asarray(new_W, np.int32).reshape(R, n)])
    star = np.append(state.star, np.asarray(new_star, np.int32))
    elig = np.append(state.elig, np.full(R, uid + 1, np.int64))
    nn = state.feat.shape[1]
    feat = np.concatenate([state.feat, np.full((R, nn), -1, np.int32)])
    thresh = np.concatenate([state.thresh, np.zeros((R, nn), np.float32)])
    leaf = np.concatenate([state.leaf, np.zeros((R, nn), np.int32)])
    pre_pred = np.concatenate([pre_pred, np.full((R, n), -1, np.int32)])
    new_pre = np.flatnonzero(np.asarray(new_star, np.int32) == 0)
    if new_pre.size:
        rows = S_old + new_pre
        f, t, lf, pp = _train_rows(
            X, y, W[rows], [new_ids[r] for r in new_pre], state.seed,
            state.n_labels, state.depth)
        feat[rows], thresh[rows], leaf[rows], pre_pred[rows] = f, t, lf, pp

    counts = np.append(state.counts, np.int64(B))
    pre_votes = np.append(state.pre_votes, 0)
    row_of = {dd: r for r, dd in enumerate(draw_ids)}
    for dd in E_i[-1]:
        r = row_of[dd]
        if star[r] == 0 and pre_pred[r, -1] == y_new:
            pre_votes[-1] += 1
    return BootstrapState(
        X, y, state.n_labels, B, state.depth, state.seed, uids, uid + 1,
        draw_ids, d, W, star, elig, list(state.E), E_i, counts, feat,
        thresh, leaf, pre_pred, pre_votes)


def decremental_remove(state: BootstrapState, i: int, *, stream=None,
                       max_draws=100000) -> BootstrapState:
    """Forget training point ``i``: retire every sample containing it,
    backfill damaged lists from the earliest surviving eligible samples
    (the replay rule), oversample only if those run out, and prune samples
    no longer referenced by any list."""
    n_old = state.n
    if n_old < 2:
        raise ValueError("cannot evict from a 1-point bootstrap state")
    if not -n_old <= i < n_old:
        raise IndexError(
            f"index {i} out of range for {n_old} training points")
    i %= n_old
    if stream is None:
        stream = DrawStream(state.seed)
    B = state.B
    n = n_old - 1

    retired_rows = state.W[:, i] > 0
    keep = ~retired_rows
    retired = {state.draw_ids[r] for r in np.flatnonzero(retired_rows)}
    col_keep = np.arange(n_old) != i
    draw_ids = [dd for dd, k in zip(state.draw_ids, keep) if k]
    W = state.W[keep][:, col_keep]
    star, elig = state.star[keep], state.elig[keep]
    feat, thresh = state.feat[keep], state.thresh[keep]
    leaf = state.leaf[keep]
    pre_pred = state.pre_pred[keep][:, col_keep]
    X = state.X[col_keep]
    y = state.y[col_keep]
    uids = state.uids[col_keep]
    E_i = [[dd for dd in lst if dd not in retired]
           for j, lst in enumerate(state.E_i) if j != i]
    E = [dd for dd in state.E if dd not in retired]

    # backfill from surviving samples, earliest first — restores each list
    # to "the B earliest eligible clean samples", which is what the replay
    # in fit_from_samples produces
    member = [set(lst) for lst in E_i]
    Eset = set(E)
    if any(len(lst) < B for lst in E_i) or len(E) < B:
        for s, dd in enumerate(draw_ids):
            for j in np.flatnonzero((W[s] == 0) & (uids < elig[s])):
                if len(E_i[j]) < B and dd not in member[j]:
                    insort(E_i[j], dd)
                    member[j].add(dd)
            if star[s] == 0 and len(E) < B and dd not in Eset:
                insort(E, dd)
                Eset.add(dd)

    # oversample for whatever is still deficient
    d = state.next_draw
    new_W, new_star, new_ids = [], [], []
    attempts = 0
    while any(len(lst) < B for lst in E_i) or len(E) < B:
        if attempts >= max_draws:
            names = [j for j, lst in enumerate(E_i) if len(lst) < B]
            got = [len(E_i[j]) for j in names]
            if len(E) < B:
                names, got = names + ["*"], got + [len(E)]
            raise _starved_error(
                B, names, got, f"decremental_remove (max_draws={max_draws})")
        idx = stream.draw(d, n)
        w = np.bincount(idx[idx < n], minlength=n).astype(np.int32)
        st = int(np.sum(idx == n))
        helped = False
        for j in np.flatnonzero(w == 0):
            if len(E_i[j]) < B:
                E_i[j].append(d)  # d exceeds every existing id: stays sorted
                member[j].add(d)
                helped = True
        if st == 0 and len(E) < B:
            E.append(d)
            Eset.add(d)
            helped = True
        if helped:
            draw_ids.append(d)
            new_ids.append(d)
            new_W.append(w)
            new_star.append(st)
        d += 1
        attempts += 1

    R = len(new_ids)
    nn = state.feat.shape[1]
    if R:
        W = np.concatenate([W, np.asarray(new_W, np.int32).reshape(R, n)])
        star = np.append(star, np.asarray(new_star, np.int32))
        elig = np.append(elig, np.full(R, state.next_uid, np.int64))
        feat = np.concatenate([feat, np.full((R, nn), -1, np.int32)])
        thresh = np.concatenate([thresh, np.zeros((R, nn), np.float32)])
        leaf = np.concatenate([leaf, np.zeros((R, nn), np.int32)])
        pre_pred = np.concatenate([pre_pred, np.full((R, n), -1, np.int32)])
        new_pre = np.flatnonzero(np.asarray(new_star, np.int32) == 0)
        if new_pre.size:
            rows = (len(draw_ids) - R) + new_pre
            f, t, lf, pp = _train_rows(
                X, y, W[rows], [new_ids[r] for r in new_pre], state.seed,
                state.n_labels, state.depth)
            feat[rows], thresh[rows] = f, t
            leaf[rows], pre_pred[rows] = lf, pp

    # prune samples referenced by no list (their only subscriber left)
    referenced = set().union(Eset, *member) if member else set(Eset)
    live = np.array([dd in referenced for dd in draw_ids], bool)
    draw_ids = [dd for dd, k in zip(draw_ids, live) if k]
    W, star, elig = W[live], star[live], elig[live]
    feat, thresh, leaf = feat[live], thresh[live], leaf[live]
    pre_pred = pre_pred[live]

    counts = np.asarray([len(lst) for lst in E_i], np.int64)
    pre_votes = _pre_votes_of(E_i, draw_ids, star, pre_pred, y)
    return BootstrapState(
        X, y, state.n_labels, B, state.depth, state.seed, uids,
        state.next_uid, draw_ids, d, W, star, elig, E, E_i, counts, feat,
        thresh, leaf, pre_pred, pre_votes)


# ---------------------------------------------------------------------------
# p-values
# ---------------------------------------------------------------------------


def pvalues_optimized(state: BootstrapState, X_test) -> np.ndarray:
    """Algorithm 3 COMPUTE_PVALUE for each test point x label: (m, l).

    Per (test point, label) only the *-containing samples referenced by
    some ``E_i`` list are trained, in sorted-draw-id order under a keyed
    rng — deterministic across repeated calls. Pre-trained contributions
    come entirely from the cached ``pre_votes``.
    """
    X_test = np.asarray(X_test, np.float32)
    if X_test.ndim == 1:
        X_test = X_test[None]
    n, p = state.X.shape
    n_labels = state.n_labels
    if not len(state.E) or (state.counts == 0).any():
        bad = np.flatnonzero(state.counts == 0).tolist()
        raise _starved_error(state.B, bad + ([] if state.E else ["*"]),
                             [], "pvalues (corrupt state)")
    row_of = {dd: r for r, dd in enumerate(state.draw_ids)}
    star_ref = sorted({dd for lst in state.E_i for dd in lst
                       if state.star[row_of[dd]] > 0})
    srows = np.asarray([row_of[dd] for dd in star_ref], np.int64)
    S_star = len(star_ref)
    member = np.zeros((n, S_star), bool)
    star_pos = {dd: j for j, dd in enumerate(star_ref)}
    for i, lst in enumerate(state.E_i):
        for dd in lst:
            j = star_pos.get(dd)
            if j is not None:
                member[i, j] = True
    W_star = (np.concatenate([state.W[srows], state.star[srows][:, None]],
                             axis=1) if S_star else None)
    erows = np.asarray([row_of[dd] for dd in state.E], np.int64)
    nn = state.feat.shape[1]
    denom = state.counts.astype(np.float64)
    out = np.zeros((X_test.shape[0], n_labels))
    # candidate scores come entirely from pre-trained trees: one batched
    # dispatch over the whole test set
    cpred_all = kops.boot_forest_predict(
        state.feat[erows], state.thresh[erows], state.leaf[erows], X_test)
    for t in range(X_test.shape[0]):
        x_t = X_test[t]
        Xa = np.concatenate([state.X, x_t[None]], axis=0)
        cpred = cpred_all[:, t]
        for lbl in range(n_labels):
            star_votes = np.zeros(n, np.int64)
            if S_star:
                ya = np.append(state.y, np.int32(lbl))
                rng = np.random.default_rng(
                    (state.seed, _STAR_TAG, t, lbl))
                fc, u = _node_rand(rng, S_star, nn, p)
                f_, t_, l_ = kops.boot_fit_forest(
                    Xa, ya, W_star, fc, u, n_labels=n_labels,
                    depth=state.depth)
                preds = kops.boot_forest_predict(f_, t_, l_, state.X)
                star_votes = np.sum(
                    member & (preds.T == state.y[:, None]), axis=1)
            alphas = -(state.pre_votes + star_votes) / denom
            alpha = -float(np.sum(cpred == lbl)) / len(state.E)
            out[t, lbl] = (np.sum(alphas >= alpha) + 1.0) / (n + 1.0)
    return out


# per-dispatch tree-batch bound for the naive path: bounds host/device
# memory at O(chunk * n) instead of O(n^2 * B) when n is large
_STD_CHUNK_TREES = 4096


def pvalues_standard(X, y, X_test, *, n_labels, B=10, depth=5, seed=0):
    """Naive bootstrap CP: a fresh ensemble per LOO entry, O(S_g(n) B n l m).

    The B (n+1) trees of one (test point, label) candidate are fitted as
    stacked dispatches of at most ``_STD_CHUNK_TREES`` trees (the same
    vectorized base learner as the optimized path; chunking over LOO
    entries keeps the multiplicity matrix at O(chunk * n) memory).
    Randomness is keyed per (t, lbl, LOO entry), so repeated calls are
    deterministic AND the chunk size is pure batching — tuning
    ``_STD_CHUNK_TREES`` to a runner's memory cannot change a p-value."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int32)
    X_test = np.asarray(X_test, np.float32)
    if X_test.ndim == 1:
        X_test = X_test[None]
    _validate_labels(y, n_labels)
    n, p = X.shape
    m = X_test.shape[0]
    nn = _n_nodes(depth)
    loo_chunk = max(1, _STD_CHUNK_TREES // B)
    out = np.zeros((m, n_labels))
    for t in range(m):
        Xa = np.concatenate([X, X_test[t][None]], axis=0)
        for lbl in range(n_labels):
            ya = np.append(y, np.int32(lbl))
            alphas = np.zeros(n + 1)
            for lo in range(0, n + 1, loo_chunk):
                hi = min(lo + loo_chunk, n + 1)
                c = hi - lo
                idx = np.empty((c, B, n), np.int64)
                fc = np.empty((c * B, nn), np.int32)
                u = np.empty((c * B, nn), np.float32)
                for j, i in enumerate(range(lo, hi)):
                    rng = np.random.default_rng(
                        (seed, _STD_TAG, t, lbl, i))
                    idx[j] = rng.integers(0, n, size=(B, n))
                    fc[j * B:(j + 1) * B], u[j * B:(j + 1) * B] = \
                        _node_rand(rng, B, nn, p)
                # bootstrap of size n over each LOO keep-set: keep-set
                # position k of entry i is augmented row k + (k >= i)
                rows = idx + (idx >= np.arange(lo, hi)[:, None, None])
                S = c * B
                W = np.zeros((S, n + 1), np.int32)
                np.add.at(W, (np.repeat(np.arange(S), n),
                              rows.reshape(S, n).ravel()), 1)
                f_, t_, l_ = kops.boot_fit_forest(
                    Xa, ya, W, fc, u, n_labels=n_labels, depth=depth)
                preds = kops.boot_forest_predict(f_, t_, l_, Xa[lo:hi])
                own = preds.reshape(c, B, c)[
                    np.arange(c), :, np.arange(c)]  # (c, B)
                alphas[lo:hi] = -np.mean(own == ya[lo:hi, None], axis=1)
            out[t, lbl] = (np.sum(alphas[:n] >= alphas[n]) + 1.0) / (n + 1.0)
    return out
