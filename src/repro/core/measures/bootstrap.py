"""Bootstrap nonconformity measure (paper Section 6, Algorithm 3).

Standard bootstrap CP trains a fresh B-classifier ensemble for every LOO
entry: O(S_g(n) B n l m). The paper's optimization pre-samples B' bootstrap
draws of the augmented set Z* = Z u {*} (with * a placeholder for the test
point) until every example has >= B samples *not containing it*; samples
without * are pre-trained at fit time. At prediction only the samples that do
contain * (a (1-1/e) fraction) are trained — a (1-e^{-1}) ~ 0.632x predict
cost, and shared classifiers make the effective number of trainings B' << Bn.

The base learner here is a vectorized extra-tree (random split feature +
random threshold, majority leaves) — the bootstrap machinery is learner-
agnostic; the paper's Random-Forest instantiation differs only in the tree
fitting rule (DESIGN.md §7.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


# ---------------------------------------------------------------------------
# base learner: vectorized extra-trees
# ---------------------------------------------------------------------------


@dataclass
class ExtraTree:
    feat: np.ndarray  # (n_nodes,) split feature (internal) / -1 (leaf)
    thresh: np.ndarray  # (n_nodes,)
    leaf_label: np.ndarray  # (n_nodes,) majority label at node


def fit_tree(X, y, n_labels, depth, rng) -> ExtraTree:
    """Extra-tree: random feature + random threshold per node."""
    n, p = X.shape
    n_nodes = 2 ** (depth + 1) - 1
    feat = np.full(n_nodes, -1, dtype=np.int32)
    thresh = np.zeros(n_nodes, dtype=np.float64)
    leaf = np.zeros(n_nodes, dtype=np.int32)
    # node assignment per sample, breadth-first
    node_of = np.zeros(n, dtype=np.int64)
    for node in range(n_nodes):
        m = node_of == node
        cnt = np.bincount(y[m], minlength=n_labels) if m.any() else np.zeros(n_labels)
        leaf[node] = int(np.argmax(cnt)) if m.any() else 0
        if node < 2 ** depth - 1 and m.sum() > 1:  # internal level
            f = int(rng.integers(0, p))
            lo, hi = X[m, f].min(), X[m, f].max()
            if hi > lo:
                t = float(rng.uniform(lo, hi))
                feat[node], thresh[node] = f, t
                go_right = m & (X[:, f] > t)
                node_of[m] = 2 * node + 1
                node_of[go_right] = 2 * node + 2
    return ExtraTree(feat, thresh, leaf)


def predict_tree(tree: ExtraTree, X) -> np.ndarray:
    n = X.shape[0]
    node = np.zeros(n, dtype=np.int64)
    depth = int(np.log2(len(tree.feat) + 1)) - 1
    for _ in range(depth):
        f = tree.feat[node]
        internal = f >= 0
        go_right = internal & (X[np.arange(n), np.maximum(f, 0)] > tree.thresh[node])
        node = np.where(internal, np.where(go_right, 2 * node + 2, 2 * node + 1), node)
    return tree.leaf_label[node]


def fit_forest(X, y, n_labels, B, depth, rng):
    return [fit_tree(X, y, n_labels, depth, rng) for _ in range(B)]


def forest_confidence(forest, X, n_labels) -> np.ndarray:
    """f(x) in [0,1]^l: normalized vote counts. (m, l)."""
    votes = np.zeros((X.shape[0], n_labels))
    for t in forest:
        pred = predict_tree(t, X)
        votes[np.arange(X.shape[0]), pred] += 1.0
    return votes / len(forest)


# ---------------------------------------------------------------------------
# standard (naive) bootstrap CP
# ---------------------------------------------------------------------------


def pvalues_standard(X, y, X_test, *, n_labels, B=10, depth=5, seed=0):
    """Naive bootstrap CP: fresh ensemble per LOO entry. O(S_g(n) B n l m)."""
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    m = X_test.shape[0]
    out = np.zeros((m, n_labels))
    for t in range(m):
        for lbl in range(n_labels):
            Xa = np.concatenate([X, X_test[t : t + 1]], axis=0)
            ya = np.concatenate([y, [lbl]]).astype(y.dtype)
            alphas = np.zeros(n + 1)
            for i in range(n + 1):
                keep = np.arange(n + 1) != i
                idx = rng.integers(0, n, size=(B, n))  # bootstrap of size n
                Xi, yi = Xa[keep], ya[keep]
                forest = [
                    fit_tree(Xi[idx[b] % n], yi[idx[b] % n], n_labels, depth, rng)
                    for b in range(B)
                ]
                conf = forest_confidence(forest, Xa[i : i + 1], n_labels)[0]
                alphas[i] = -conf[ya[i]]
            out[t, lbl] = (np.sum(alphas[:n] >= alphas[n]) + 1.0) / (n + 1.0)
    return out


# ---------------------------------------------------------------------------
# optimized bootstrap CP (Algorithm 3)
# ---------------------------------------------------------------------------


@dataclass
class BootstrapState:
    X: np.ndarray
    y: np.ndarray
    n_labels: int
    B: int
    depth: int
    samples: list  # B' bootstrap index arrays over Z* (index n == placeholder)
    E: list  # sample ids not containing * (pretrained; used for the candidate)
    E_i: list  # per training point: sample ids not containing i (capped at B)
    pretrained: dict  # sample id -> ExtraTree (samples without *)
    pre_votes: np.ndarray  # (n,) votes... see fit(); per (i, b) predictions
    pre_pred: dict  # (sample id) -> np.ndarray predicted labels for all X
    b_prime: int = 0
    rng_seed: int = 0


def fit(X, y, *, n_labels, B=10, depth=5, seed=0, max_bprime=100000) -> BootstrapState:
    """Algorithm 3 TRAIN: oversample until every point has B clean samples."""
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    counts = np.zeros(n + 1, dtype=np.int64)  # clean-sample count per example
    samples, E, E_i = [], [], [[] for _ in range(n)]
    b = 0
    while counts.min() < B and b < max_bprime:
        idx = rng.integers(0, n + 1, size=n + 1)  # sample Z* with replacement
        present = np.zeros(n + 1, dtype=bool)
        present[idx] = True
        absent = ~present
        # footnote 1: cap per-example sample lists at B
        useful = False
        for i in np.flatnonzero(absent):
            if counts[i] < B:
                counts[i] += 1
                useful = True
                if i < n:
                    E_i[i].append(b)
                else:
                    E.append(b)
        if useful:
            samples.append(idx)
            b += 1
    # pretrain every sample that does not contain the placeholder (index n)
    pretrained, pre_pred = {}, {}
    for sid, idx in enumerate(samples):
        if not np.any(idx == n):
            tree = fit_tree(X[idx], y[idx], n_labels, depth, rng)
            pretrained[sid] = tree
            pre_pred[sid] = predict_tree(tree, X)  # predictions for all x_i
    return BootstrapState(
        X, y, n_labels, B, depth, samples, E, E_i, pretrained,
        np.zeros(n), pre_pred, b_prime=len(samples), rng_seed=seed,
    )


def pvalues_optimized(state: BootstrapState, X_test) -> np.ndarray:
    """Algorithm 3 COMPUTE_PVALUE for each test point x label."""
    X, y, n_labels = state.X, state.y, state.n_labels
    n = X.shape[0]
    rng = np.random.default_rng(state.rng_seed + 1)
    out = np.zeros((X_test.shape[0], n_labels))
    for t in range(X_test.shape[0]):
        x_t = X_test[t : t + 1]
        Xa = np.concatenate([X, x_t], axis=0)
        for lbl in range(n_labels):
            ya = np.concatenate([y, [lbl]]).astype(y.dtype)
            # train (once per (t, lbl)) the samples that contain *
            star_trees = {}
            needed = {
                sid for i in range(n) for sid in state.E_i[i]
                if sid not in state.pretrained
            }
            for sid in needed:
                idx = state.samples[sid]
                star_trees[sid] = fit_tree(Xa[idx], ya[idx], n_labels,
                                           state.depth, rng)
            alphas = np.zeros(n)
            for i in range(n):
                votes = 0
                for sid in state.E_i[i]:
                    if sid in state.pretrained:
                        pred = state.pre_pred[sid][i]
                    else:
                        pred = predict_tree(star_trees[sid], X[i : i + 1])[0]
                    votes += int(pred == y[i])
                alphas[i] = -votes / len(state.E_i[i])
            # candidate: E's samples never contain *, all pretrained
            cvotes = 0
            for sid in state.E:
                pred = predict_tree(state.pretrained[sid], x_t)[0]
                cvotes += int(pred == lbl)
            alpha = -cvotes / len(state.E)
            out[t, lbl] = (np.sum(alphas >= alpha) + 1.0) / (n + 1.0)
    return out
