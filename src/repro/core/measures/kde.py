"""KDE nonconformity measure (paper Section 4) — standard + optimized paths.

A((x,y); S) = -(1/(n_y h^p)) * sum_{x_i in S, y_i=y} K((x-x_i)/h), Gaussian K.

Optimized path (Section 4.1): the training phase precomputes the provisional
sums alpha'_i = sum_{j != i, y_j = y_i} K((x_i-x_j)/h) — an O(P_K n^2) one-off
cost (the ``kde_score`` Pallas kernel on TPU). At test time, for candidate
(x, y_hat), each score needs only the single new kernel value K((x-x_i)/h)
and the class-count renormalization — O(P_K n) per candidate, matching the
naive output exactly (the class count n_y(i) counts the augmented set
S_i = Z u {(x,y_hat)} \\ {i}).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _kvals(A, B, h):
    """Gaussian kernel matrix K((A_i - B_j)/h), (m, n)."""
    return jnp.exp(-jnp.maximum(kops.sq_dists(A, B), 0.0) / (2.0 * h * h))


# ---------------------------------------------------------------------------
# standard (naive) path
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("h", "p_dim"))
def scores_standard(X, y, x_test, y_hat, *, h, p_dim):
    """Naive LOO scores for one candidate. O(P_K n^2)."""
    n = X.shape[0]
    Xa = jnp.concatenate([X, x_test[None]], axis=0)
    ya = jnp.concatenate([y, jnp.array([y_hat], dtype=y.dtype)])
    K = _kvals(Xa, Xa, h)
    eye = jnp.eye(n + 1, dtype=bool)
    same = (ya[:, None] == ya[None, :]) & ~eye
    sums = jnp.sum(jnp.where(same, K, 0.0), axis=1)
    n_y = jnp.sum(same, axis=1)
    hp = h ** p_dim
    scores = -jnp.where(n_y > 0, sums / (n_y * hp), 0.0)
    return scores[:n], scores[n]


@functools.partial(jax.jit, static_argnames=("h", "p_dim", "n_labels"))
def pvalues_standard(X, y, X_test, *, h, p_dim, n_labels):
    labels = jnp.arange(n_labels, dtype=y.dtype)
    n = X.shape[0]

    def per_test(x_t):
        def per_label(y_hat):
            alphas, alpha = scores_standard(X, y, x_t, y_hat, h=h, p_dim=p_dim)
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, X_test)


# ---------------------------------------------------------------------------
# optimized (incremental&decremental) path
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class KdeState:
    X: jnp.ndarray  # (n, p)
    y: jnp.ndarray  # (n,)
    prelim: jnp.ndarray  # (n,) alpha'_i: same-label kernel sums, no self
    class_counts: jnp.ndarray  # (n_labels,)

    def tree_flatten(self):
        return ((self.X, self.y, self.prelim, self.class_counts), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@functools.partial(jax.jit, static_argnames=("h", "n_labels"))
def fit(X, y, *, h, n_labels) -> KdeState:
    """O(P_K n^2) training phase (kde_score kernel on TPU)."""
    prelim = kops.kde_rowsums(X, X, y, y, h, exclude_diag=True)
    counts = jnp.sum(
        y[None, :] == jnp.arange(n_labels, dtype=y.dtype)[:, None], axis=1
    )
    return KdeState(X, y, prelim, counts)


def _updated_scores(state: KdeState, kvals, y_hat, h, p_dim):
    """O(1)-per-point update: add the test kernel value for same-label points."""
    same = state.y == y_hat
    sums = jnp.where(same, state.prelim + kvals, state.prelim)
    n_y = state.class_counts[state.y] - 1 + same.astype(state.class_counts.dtype)
    hp = h ** p_dim
    return -jnp.where(n_y > 0, sums / (n_y * hp), 0.0)


@functools.partial(jax.jit, static_argnames=("h", "p_dim"))
def scores_optimized(state: KdeState, x_test, y_hat, *, h, p_dim):
    kv = _kvals(x_test[None], state.X, h)[0]
    alphas = _updated_scores(state, kv, y_hat, h, p_dim)
    same = state.y == y_hat
    c = state.class_counts[y_hat.astype(jnp.int32)]
    alpha = -jnp.where(
        c > 0, jnp.sum(jnp.where(same, kv, 0.0)) / (c * h ** p_dim), 0.0
    )
    return alphas, alpha


@functools.partial(jax.jit, static_argnames=("h", "p_dim", "n_labels"))
def pvalues_optimized(state: KdeState, X_test, *, h, p_dim, n_labels):
    labels = jnp.arange(n_labels, dtype=state.y.dtype)
    n = state.X.shape[0]

    def per_test(x_t):
        kv = _kvals(x_t[None], state.X, h)[0]

        def per_label(y_hat):
            alphas = _updated_scores(state, kv, y_hat, h, p_dim)
            same = state.y == y_hat
            c = state.class_counts[y_hat.astype(jnp.int32)]
            alpha = -jnp.where(
                c > 0, jnp.sum(jnp.where(same, kv, 0.0)) / (c * h ** p_dim), 0.0
            )
            return (jnp.sum(alphas >= alpha) + 1.0) / (n + 1.0)

        return jax.vmap(per_label)(labels)

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("h",))
def incremental_add(state: KdeState, x_new, y_new, *, h) -> KdeState:
    """Online learning: O(P_K n) per new example (paper Section 9)."""
    kv = _kvals(x_new[None], state.X, h)[0]
    same = state.y == y_new
    prelim = jnp.where(same, state.prelim + kv, state.prelim)
    own = jnp.sum(jnp.where(same, kv, 0.0))
    counts = state.class_counts.at[y_new.astype(jnp.int32)].add(1)
    return KdeState(
        jnp.concatenate([state.X, x_new[None]], axis=0),
        jnp.concatenate([state.y, jnp.array([y_new], dtype=state.y.dtype)]),
        jnp.concatenate([prelim, own[None]]),
        counts,
    )


def decremental_remove(state: KdeState, i: int, *, h) -> KdeState:
    """Decremental unlearning (paper Section 4.1): forget point ``i``.

    Each same-label point sheds the removed point's kernel contribution
    from its provisional sum — O(P_K n). ``i`` must be a concrete int
    (shape shrinks; host-level, mirroring incremental_add's growth).
    """
    kv = _kvals(state.X[i][None], state.X, h)[0]
    same = state.y == state.y[i]
    prelim = jnp.where(same, state.prelim - kv, state.prelim)
    counts = state.class_counts.at[state.y[i].astype(jnp.int32)].add(-1)
    return KdeState(
        jnp.delete(state.X, i, axis=0),
        jnp.delete(state.y, i, axis=0),
        jnp.delete(prelim, i, axis=0),
        counts,
    )
