"""Conformal prediction as a first-class LM serving feature.

The bridge between the paper and the LM stack: the model's final hidden
state is the object space X, and the paper's *optimized* full-CP measures
run on top of it, giving distribution-free guarantees at serving time:

* ``ConformalLmClassifier`` — full k-NN CP over a small label set (the
  paper's classification setting; labels = task classes, e.g. a verbalizer
  token per class). Exact optimized predict: O(n) per (query, label)
  after the O(n^2) calibration fit, vs O(n^2) per query naive.
* ``ConformalOodDetector`` — simplified k-NN CP with a single "label"
  (conformal anomaly detection, Laxhammar & Falkman 2010): p-value for
  "this request looks like calibration traffic". A p-value ~ U[0,1] for
  in-distribution inputs; small p flags OOD requests with an exact
  finite-sample guarantee: Pr[p <= eps] <= eps under exchangeability.

Both shard across the serving mesh via core.distributed (rows over the
data axes, one psum per p-value), which is how a 10^8-row calibration set
serves interactive traffic — the paper's technique at pod scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core.measures import knn as knn_m

BIG = 1e30


@dataclass
class ConformalLmClassifier:
    """Full k-NN CP over LM embeddings for an l-label task."""

    n_labels: int
    k: int = 15
    _state: Any = field(default=None, repr=False)
    _sharded_fn: Any = field(default=None, repr=False)
    _mesh: Any = field(default=None, repr=False)

    def fit(self, embeddings, labels, mesh=None,
            cfg: dist.CpShardingConfig = dist.CpShardingConfig()):
        """O(n^2) training phase (paper Section 3.1); optionally sharded."""
        emb = jnp.asarray(embeddings, jnp.float32)
        lab = jnp.asarray(labels, jnp.int32)
        self._state = knn_m.fit(emb, lab, k=self.k)
        if mesh is not None and len(mesh.devices.flatten()) > 1:
            self._mesh = mesh
            self._state = dist.shard_knn_state(self._state, mesh, cfg)
            self._sharded_fn = dist.make_knn_pvalues_fn(
                mesh, k=self.k, simplified=False, n_labels=self.n_labels,
                cfg=cfg)
        return self

    def pvalues(self, query_embeddings) -> jnp.ndarray:
        q = jnp.asarray(query_embeddings, jnp.float32)
        if self._sharded_fn is not None:
            return self._sharded_fn(self._state, q)
        return knn_m.pvalues_optimized(
            self._state, q, k=self.k, simplified=False,
            n_labels=self.n_labels)

    def prediction_sets(self, query_embeddings, eps: float):
        return self.pvalues(query_embeddings) > eps


@dataclass
class ConformalOodDetector:
    """Simplified k-NN CP anomaly detector over LM embeddings."""

    k: int = 15
    _emb: Any = field(default=None, repr=False)
    _best: Any = field(default=None, repr=False)

    def fit(self, embeddings):
        emb = jnp.asarray(embeddings, jnp.float32)
        n = emb.shape[0]
        d2 = jnp.maximum(
            jnp.sum(emb * emb, 1)[:, None] + jnp.sum(emb * emb, 1)[None, :]
            - 2 * emb @ emb.T, 0.0)
        d = jnp.sqrt(d2)
        d = jnp.where(jnp.eye(n, dtype=bool), BIG, d)
        self._best = jnp.sort(-jax.lax.top_k(-d, self.k)[0], axis=1)
        self._emb = emb
        return self

    def pvalues(self, query_embeddings) -> jnp.ndarray:
        """Exact full-CP p-values, optimized update (paper Fig. 1)."""
        q = jnp.asarray(query_embeddings, jnp.float32)
        d = jnp.sqrt(jnp.maximum(
            jnp.sum(q * q, 1)[:, None] + jnp.sum(self._emb * self._emb, 1)
            - 2 * q @ self._emb.T, 0.0))  # (m, n)
        sum_best = jnp.sum(self._best, axis=1)
        kth = self._best[:, -1]
        upd = d < kth[None, :]
        alphas = jnp.where(upd, sum_best - kth + d, sum_best)  # (m, n)
        alpha = jnp.sum(-jax.lax.top_k(-d, self.k)[0], axis=1)  # (m,)
        cnt = jnp.sum(alphas >= alpha[:, None], axis=1)
        n = self._emb.shape[0]
        return (cnt + 1.0) / (n + 1.0)


def hidden_states(params, cfg, batch, lm_module) -> jnp.ndarray:
    """Final-norm hidden states (B, S, D) for embedding extraction."""
    from repro.models import blocks as blk

    x = lm_module.embed_tokens(params, cfg, batch["tokens"])
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _, _ = blk.apply_stack_full(params["layers"], x, cfg, positions)
    return blk.apply_norm(params["final_norm"], x, cfg)


def sequence_embedding(params, cfg, batch, lm_module) -> jnp.ndarray:
    h = hidden_states(params, cfg, batch, lm_module)
    return jnp.mean(h, axis=1)  # (B, D)


__all__ = ["ConformalLmClassifier", "ConformalOodDetector",
           "hidden_states", "sequence_embedding"]
