"""p-value machinery shared by every conformal predictor in the framework.

A full-CP p-value for a candidate ``(x, y_hat)`` given training scores
``alphas[i] = A((x_i,y_i); {(x,y_hat)} u Z \\ {(x_i,y_i)})`` and the candidate's
own score ``alpha = A((x,y_hat); Z)`` is::

    p = (#{i: alphas[i] >= alpha} + 1) / (n + 1)

The ``+1`` counts the candidate itself (whose score trivially >= itself).
Smoothed p-values randomize ties and make the p-value exactly uniform under
exchangeability — required by the online exchangeability martingale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pvalue(alphas: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """p-value from per-training-example scores. Broadcasts over leading dims.

    alphas: (..., n) training scores; alpha: (...) candidate score.
    """
    n = alphas.shape[-1]
    count = jnp.sum(alphas >= alpha[..., None], axis=-1)
    return (count + 1.0) / (n + 1.0)


def smoothed_pvalue(
    alphas: jnp.ndarray, alpha: jnp.ndarray, tau: jnp.ndarray
) -> jnp.ndarray:
    """Smoothed p-value: ties broken by tau ~ U[0,1]; exactly uniform."""
    n = alphas.shape[-1]
    gt = jnp.sum(alphas > alpha[..., None], axis=-1)
    eq = jnp.sum(alphas == alpha[..., None], axis=-1)
    return (gt + tau * (eq + 1.0)) / (n + 1.0)


def prediction_sets(pvalues: jnp.ndarray, epsilon: float) -> jnp.ndarray:
    """Boolean membership matrix (m, l): label in the set iff p > epsilon."""
    return pvalues > epsilon


def fuzziness(pvalues: jnp.ndarray) -> jnp.ndarray:
    """Statistical-efficiency criterion (Vovk et al. 2016): sum of p-values
    excluding the largest; lower is better. pvalues: (m, l) -> (m,)."""
    return jnp.sum(pvalues, axis=-1) - jnp.max(pvalues, axis=-1)


def coverage(pvalues: jnp.ndarray, y_true: jnp.ndarray, epsilon: float):
    """Empirical coverage of the epsilon-prediction set and mean set size."""
    sets = prediction_sets(pvalues, epsilon)
    hit = jnp.take_along_axis(sets, y_true[:, None].astype(jnp.int32), axis=1)[:, 0]
    return jnp.mean(hit.astype(jnp.float32)), jnp.mean(
        jnp.sum(sets, axis=-1).astype(jnp.float32)
    )


def count_ge(alphas: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Partial count #{alphas >= alpha} (for sharded/distributed psum)."""
    return jnp.sum((alphas >= alpha[..., None]).astype(jnp.int32), axis=-1)


def pvalue_from_counts(counts: jnp.ndarray, n: int) -> jnp.ndarray:
    return (counts + 1.0) / (n + 1.0)
