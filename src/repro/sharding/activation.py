"""Activation sharding constraints, mesh-aware but model-code friendly.

Model code calls ``constrain(x, (BATCH_AXES, None, "model"))`` without
knowing which mesh (if any) is active: launch code wraps tracing in
``activation_mesh(mesh)``, and outside that context (CPU smoke tests,
single-device examples) constraints are no-ops. Entries may be a single
axis name or a tuple of axes sharded jointly; axes missing from the mesh or
not dividing the dimension are dropped silently.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")  # sentinel resolved against the active strategy

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_activation_mesh", default=None)


@contextlib.contextmanager
def activation_mesh(mesh, strategy: str = "tp_sp"):
    """Enable activation constraints for code traced inside this context.

    strategy:
      "tp_sp" — batch over (pod, data); tensor/sequence parallelism over
                "model" (Megatron-SP, the default);
      "fsdp"  — batch over (pod, data, model): pure ZeRO-3 data
                parallelism; every "model" entry in activation specs
                resolves to None (weights are gathered per layer instead —
                EXPERIMENTS.md §Perf granite iteration 4).
    """
    if strategy == "fsdp":
        batch_axes = ("pod", "data", "model")
        tensor_ok = False
    else:
        batch_axes = ("pod", "data")
        tensor_ok = True
    token = _ACTIVE.set({
        "sizes": {a: int(mesh.shape[a]) for a in mesh.axis_names},
        "batch_axes": batch_axes,
        "tensor_ok": tensor_ok,
    })
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def grad_compressed_boundary(x, spec: tuple):
    """Identity with a compressed, layout-pinned backward edge.

    At sequence-parallel block boundaries the cotangent is (a) f32 —
    upcast by the norm internals — and (b) materialized by XLA as a
    replicated all-reduce. This custom_vjp casts the boundary cotangent to
    bf16 (gradient compression on the ICI wire, 2x) and constrains it to
    the boundary's own sharding, steering the partitioner to a
    reduce-scatter instead of an all-reduce (up to another 2x x TP-degree
    in moved bytes). Forward is exact; backward loses only the bf16
    rounding of an activation gradient — the same precision grads already
    have everywhere else in the network.
    """
    if _ACTIVE.get() is None:
        return x
    return _gc_boundary(x, tuple(spec))


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _gc_boundary(x, spec):
    return x


def _gc_fwd(x, spec):
    return x, None


def _gc_bwd(spec, _res, g):
    g = g.astype(jnp.bfloat16).astype(g.dtype)
    return (constrain(g, spec),)


_gc_boundary.defvjp(_gc_fwd, _gc_bwd)


def constrain(x, spec: tuple):
    """with_sharding_constraint honoring only axes present & divisible.

    Spec entries: None, an axis name, or a tuple of axes (sharded
    jointly). The BATCH_AXES sentinel resolves to the active strategy's
    batch axes; "model" entries are dropped under the fsdp strategy."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    axis_sizes = ctx["sizes"]
    entries = []
    for dim, want in zip(x.shape, spec):
        if want is None:
            entries.append(None)
            continue
        cands = want if isinstance(want, tuple) else (want,)
        if cands == BATCH_AXES:
            cands = ctx["batch_axes"]
        elif not ctx["tensor_ok"] and "model" in cands:
            cands = tuple(a for a in cands if a != "model")
        axes = tuple(a for a in cands if a in axis_sizes)
        size = math.prod(axis_sizes[a] for a in axes) if axes else 1
        if axes and size > 1 and dim % size == 0:
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


__all__ = ["constrain", "activation_mesh", "BATCH_AXES"]
