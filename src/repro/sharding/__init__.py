"""Mesh-axis sharding rules for params, batches and caches."""
from repro.sharding.rules import (Rules, batch_pspecs, cache_pspecs, dp_axes,
                                  named, param_pspecs)

__all__ = ["Rules", "param_pspecs", "batch_pspecs", "cache_pspecs", "named",
           "dp_axes"]
