"""Sharding rules: param/batch/cache pytrees -> PartitionSpecs on the mesh.

Axis roles on the production mesh (DESIGN.md §distribution):

    pod    pure data parallelism across pods (grad all-reduce crosses the
           inter-pod links once per step; params/state replicated per pod)
    data   batch parallelism + FSDP: weight matrices also shard their
           d_model-ish dimension here, so optimizer state divides by the
           full 256-way device count (ZeRO-3-flavoured storage; XLA
           re-gathers per layer)
    model  tensor parallelism: attention heads (or head_dim for MQA),
           MLP hidden, MoE experts (EP) or expert-hidden (TP), vocab

Rules are name+shape driven over the flattened param paths, with
divisibility guards: a dimension only shards if the mesh axis divides it
(e.g. gemma3's 4 heads can't split 16-way -> its 256-dim head_dim shards
instead; internvl's 92553 vocab stays replicated).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axsize(mesh, name) -> int:
    return mesh.shape[name]


def _div(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


class Rules:
    def __init__(self, mesh, strategy: str = "tp_sp"):
        self.mesh = mesh
        self.strategy = strategy
        self.model = _axsize(mesh, "model")
        self.data = _axsize(mesh, "data")

    # -- helpers -----------------------------------------------------------

    def m(self, dim: int):
        """'model' if divisible else None."""
        return "model" if _div(dim, self.model) else None

    def d(self, dim: int):
        return "data" if _div(dim, self.data) else None

    def dp(self, dim: int):
        """Full data-parallel axes tuple if divisible, else best effort."""
        axes = dp_axes(self.mesh)
        if self.strategy == "fsdp":
            axes = axes + ("model",)
            total = int(np.prod([_axsize(self.mesh, a) for a in axes]))
            if _div(dim, total):
                return axes
            axes = dp_axes(self.mesh)
        total = int(np.prod([_axsize(self.mesh, a) for a in axes]))
        if _div(dim, total):
            return axes
        if _div(dim, self.data):
            return ("data",)
        return None

    # -- parameter rules ----------------------------------------------------

    def param_spec(self, path: str, shape: tuple) -> P:
        """PartitionSpec for one parameter. ``path`` is '/'-joined keys with
        stacked layer-run axes already stripped by the caller."""
        name = path.split("/")[-1]
        nd = len(shape)

        if name == "embed":
            return P(self.m(shape[0]), self.d(shape[1]))
        if name == "lm_head":
            return P(self.d(shape[0]), self.m(shape[1]))
        if name == "pos_embed_dec":
            return P(None, self.d(shape[1]))

        # attention projections
        if name == "wq" and nd == 3:
            d, h, hd = shape
            if self.m(h):
                return P(self.d(d), "model", None)
            return P(self.d(d), None, self.m(hd))
        if name in ("wk", "wv") and nd == 3:
            d, kv, hd = shape
            if self.m(kv):
                return P(self.d(d), "model", None)
            return P(self.d(d), None, self.m(hd))
        if name == "wo" and nd == 3:
            h, hd, d = shape
            if self.m(h):
                return P("model", None, self.d(d))
            return P(None, self.m(hd), self.d(d))
        if name in ("bq", "bk", "bv") and nd == 2:
            h, hd = shape
            if self.m(h):
                return P("model", None)
            return P(None, self.m(hd))

        # MLA
        if name == "wq_a":
            return P(self.d(shape[0]), None)
        if name == "wq_b":
            return P(None, self.m(shape[1]), None)
        if name == "wkv_a":
            return P(self.d(shape[0]), None)
        if name in ("wk_b", "wv_b"):
            return P(None, self.m(shape[1]), None)

        # MoE (expert tensors are (E, D, F) / (E, F, D))
        if name == "router":
            return P(self.d(shape[0]), None)
        if re.search(r"moe/(w_gate|w_up)$", path) and nd == 3:
            e, d, f = shape
            if self.m(e):
                return P("model", self.d(d), None)
            return P(None, self.d(d), self.m(f))
        if re.search(r"moe/w_down$", path) and nd == 3:
            e, f, d = shape
            if self.m(e):
                return P("model", None, self.d(d))
            return P(None, self.m(f), self.d(d))

        # dense MLP / shared experts
        if name in ("w_gate", "w_up", "w_ff1") and nd == 2:
            return P(self.d(shape[0]), self.m(shape[1]))
        if name in ("w_down", "w_ff2") and nd == 2:
            return P(self.m(shape[0]), self.d(shape[1]))

        # recurrent families
        if name in ("w_in", "w_gate_in") and nd == 2:
            return P(self.d(shape[0]), self.m(shape[1]))
        if name in ("w_rg", "w_ig") and nd == 2:
            return P(self.m(shape[0]), None)
        if name == "w_out" and nd == 2:
            return P(self.m(shape[0]), self.d(shape[1]))
        if name in ("wq", "wk", "wv") and nd == 2:  # mlstm projections
            return P(self.d(shape[0]), self.m(shape[1]))
        if name == "w_if":
            return P(self.d(shape[0]), None)
        if name == "w_zifo":
            return P(self.d(shape[0]), self.m(shape[1]))
        if name == "r_zifo":
            return P(None, None, self.m(shape[2]))
        if name == "lam" or name == "skip":
            return P(self.m(shape[0]))
        if path.endswith("conv/w"):
            return P(None, self.m(shape[1]))
        if path.endswith("conv/b"):
            return P(self.m(shape[0]))

        # norms, biases, everything small: replicate
        return P(*([None] * nd))

    # -- batch / cache rules -------------------------------------------------

    def batch_spec(self, name: str, shape: tuple) -> P:
        nd = len(shape)
        b = self.dp(shape[0])
        if name in ("tokens", "labels", "mask"):
            if b is None and nd == 2 and shape[1] > 1:
                # long-context single-sequence: shard sequence instead
                return P(None, self.dp(shape[1]))
            return P(b, *([None] * (nd - 1)))
        if name in ("patch_embeds", "frames"):
            return P(b, None, None)
        return P(*([None] * nd))

    def cache_spec(self, path: str, shape: tuple) -> P:
        """Cache entries carry a leading stacked-layer axis L.

        KV caches (L, B, S, Kv, hd): batch over dp when divisible, else
        sequence over dp (context parallelism for the 500k cell); heads
        over model.
        """
        name = path.split("/")[-1]
        nd = len(shape)
        if name in ("k", "v") and nd == 5:
            L, B, S, kv, hd = shape
            b = self.dp(B)
            s = None if b else self.dp(S)
            return P(None, b, s, self.m(kv) if self.m(kv) else None,
                     None if self.m(kv) else self.m(hd))
        if name in ("k", "v") and nd == 4:  # unstacked
            B, S, kv, hd = shape
            b = self.dp(B)
            s = None if b else self.dp(S)
            return P(b, s, self.m(kv) if self.m(kv) else None,
                     None if self.m(kv) else self.m(hd))
        if name == "c_kv" and nd == 4:
            L, B, S, r = shape
            b = self.dp(B)
            s = None if b else self.dp(S)
            return P(None, b, s, None)
        if name == "k_rope" and nd == 4:
            L, B, S, r = shape
            b = self.dp(B)
            s = None if b else self.dp(S)
            return P(None, b, s, None)
        if name == "C" and nd == 5:  # mlstm matrix memory (L,B,H,dh,dh)
            return P(None, self.dp(shape[1]), self.m(shape[2]), None, None)
        if nd >= 2:
            b = self.dp(shape[1]) if nd >= 2 else None
            return P(None, b, *([None] * (nd - 2)))
        return P(*([None] * nd))


# ---------------------------------------------------------------------------
# tree-level API
# ---------------------------------------------------------------------------


_STACKED_PREFIXES = ("layers", "encoder", "cross", "mu", "nu")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(params, mesh, *, _strip=("mu/", "nu/")) -> object:
    """PartitionSpec tree matching ``params`` (works for optimizer moment
    trees too — moments shard like their parameters)."""
    rules = Rules(mesh)

    def one(path, leaf):
        p = _path_str(path)
        # optimizer state prefixes shard identically to the parameter
        for pre in ("mu/", "nu/"):
            if p.startswith(pre):
                p = p[len(pre):]
        p = re.sub(r"/(row|col|full)$", "", p)
        shape = tuple(leaf.shape)
        if p == "step" or not shape:
            return P()
        if re.fullmatch(r"(layers|encoder)/\d+/.*", p) or \
                p.startswith("cross/"):
            inner = tuple(rules.param_spec(p, shape[1:]))
            # factored moments may have dropped trailing dims vs the param
            return P(None, *inner[:len(shape) - 1])
        spec = tuple(rules.param_spec(p, shape))
        return P(*spec[:len(shape)])

    return jax.tree_util.tree_map_with_path(one, params)


def batch_pspecs(batch, mesh, strategy: str = "tp_sp"):
    rules = Rules(mesh, strategy)

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        spec = rules.batch_spec(name, tuple(leaf.shape))
        return P(*spec[:len(leaf.shape)])

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_pspecs(cache, mesh, strategy: str = "tp_sp"):
    rules = Rules(mesh, strategy)

    def one(path, leaf):
        spec = rules.cache_spec(_path_str(path), tuple(leaf.shape))
        return P(*spec[:len(leaf.shape)])

    return jax.tree_util.tree_map_with_path(one, cache)


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


__all__ = ["Rules", "param_pspecs", "batch_pspecs", "cache_pspecs", "named",
           "dp_axes"]
