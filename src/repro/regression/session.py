"""Per-tenant streaming regression-CP session over ``RegStreamState``.

Adds to the raw stream state the three per-tenant behaviours the serving
engine needs, all fixed-shape and vmappable:

* ``observe`` — price the incoming example first (smoothed online
  p-value of its *actual* label against the current window — the
  regression analogue of ``core.online.observe``, feeding the same
  exchangeability martingales), then learn it;
* ``observe_sliding`` — evict-if-full then observe: one sliding-window
  step with a traced per-tenant ``window``;
* ``intervals`` / ``pvalues`` — capacity-padded read paths. ``intervals``
  routes the fused distance-row + (a_i, b_i) update + critical-point
  computation through ``kernels.ops.interval_sweep`` (the Pallas kernel
  on TPU) and finishes with the shared ``regression.hull_sweep``; padded
  rows contribute neutral events, so results are bit-identical to
  ``regression.intervals_optimized`` on the live window (property-tested;
  the one caveat is an ``epsilon`` sitting exactly on the p == epsilon
  rank boundary, where f32 vs f64 threshold rounding may legitimately
  differ — the same measure-zero tie the batch tests dodge with
  irrational grid offsets).

Read paths require n >= k (the candidate's own k-NN needs k live rows);
early-stream outputs are well-shaped but degenerate, as in the batch path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.regression import BIG, _interval_ge, hull_sweep
from repro.kernels import ops as kops
from repro.regression import stream
from repro.regression.stream import RegStreamState
from repro.core.online import cshift

init = stream.init


def _ab_padded(state: RegStreamState, X_test, *, k):
    """Padded ``ab_optimized`` for a (m, p) query batch.

    Returns (a_vec (m, cap), b_vec (m, cap), a (m,), live (cap,)) with
    bits equal to ``regression.ab_optimized`` per live row/test point.
    """
    cap = state.capacity
    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    upd = a_prime + state.nbr_y[:, -1] / k

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, state.X), 0.0))
    enters = live[None, :] & (d < kth[None, :])
    a_vec = jnp.where(enters, upd[None, :], a_prime[None, :])
    b_vec = jnp.where(enters, -1.0 / k, 0.0)

    dm = jnp.where(live[None, :], d, BIG)
    _, idx = jax.lax.top_k(-dm, k)
    a = -jnp.sum(state.y[idx], axis=1) / k
    return a_vec, b_vec, a, live


def _observe(state: RegStreamState, x_new, y_new, tau, *, k):
    """Smoothed online p-value of (x_new, y_new), then learn it.

    The p-value tests the *observed label* against the current window
    (conformal test statistic for drift martingales): alpha_i = |a_i +
    b_i y|, alpha = |a + y|, smoothed rank with tie-break ``tau``. The
    distance row the learn step computes anyway (``stream.observe``'s
    second return) prices the point — scoring uses the pre-learn
    statistics, so one O(cap) row serves both.
    Precondition: n < capacity.
    """
    cap = state.capacity
    new_state, d_row = stream.observe(state, x_new, y_new, k=k)

    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    enters = live & (d_row < kth)  # d_row is BIG on inert rows
    a_vec = jnp.where(enters, a_prime + state.nbr_y[:, -1] / k, a_prime)
    b_vec = jnp.where(enters, -1.0 / k, 0.0)
    _, idx = jax.lax.top_k(-d_row, k)
    a = -jnp.sum(state.y[idx]) / k

    t = jnp.asarray(y_new, state.y.dtype)
    alphas = jnp.abs(a_vec + b_vec * t)
    alpha = jnp.abs(a + t)
    gt = jnp.sum(jnp.where(live, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live, alphas == alpha, False))
    # astype: no-op at f32/f64, pins sub-f32 dtypes (see core.online)
    p = ((gt + tau * (eq + 1.0)) / (state.n + 1.0)).astype(state.X.dtype)
    return new_state, p


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: Donating form of ``observe``: the (cap, cap) ``D`` row/column insert
#: updates in place instead of copying the matrix. The input state is
#: DELETED by the call. Numerics are identical to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _sliding_step(state: RegStreamState, x_new, y_new, tau, window, active,
                  *, k, evictable: bool = True, wmax: int | None = None):
    """One fused sliding-window tick: evict-if-full, observe, all gated.

    Regression counterpart of ``serving.session._sliding_step`` — the
    semantics of ``cond(evict_oldest) -> observe`` with an ``active``
    mask, restructured so the (cap, cap) matrix moves once per tick: a
    per-lane conditional compaction shift (a padded dynamic slice at
    offset s ∈ {0, 1}), the labeled list repair, then the observe core
    with arithmetically gated writes (inactive lanes rewrite their
    current values — masked state stays bitwise unchanged, p-value NaN).
    Bit-identical to the unfused form (tested). ``evictable=False``
    (static) drops the compaction for the grow-mode engine; ``wmax``
    (static, the sliding engine's window bound on occupancy) confines
    the whole tick to the ``[:wmax]`` block of every leaf — per-tick
    cost scales with the window, not the padded capacity.
    """
    cap = state.capacity
    if wmax is not None and wmax < cap:
        sub = RegStreamState(
            state.X[:wmax], state.y[:wmax], state.D[:wmax, :wmax],
            state.nbr_d[:wmax], state.nbr_y[:wmax], state.n)
        sub2, p = _sliding_step(sub, x_new, y_new, tau, window, active,
                                k=k, evictable=evictable)
        return RegStreamState(
            X=state.X.at[:wmax].set(sub2.X),
            y=state.y.at[:wmax].set(sub2.y),
            D=state.D.at[:wmax, :wmax].set(sub2.D),
            nbr_d=state.nbr_d.at[:wmax].set(sub2.nbr_d),
            nbr_y=state.nbr_y.at[:wmax].set(sub2.nbr_y),
            n=sub2.n), p
    act = jnp.asarray(active)
    if evictable:
        ev = act & (state.n >= window)
        s = ev.astype(jnp.int32)
        live = jnp.arange(cap) < state.n
        dcol = state.D[:, 0]
        affected = ev & live & (dcol <= state.nbr_d[:, -1])

        # conditional compaction: pad each leaf by one (the pad value IS
        # the compaction fill) and take one dynamic slice at offset s
        X1 = cshift(state.X, s, 0)
        y1 = cshift(state.y, s, 0)
        L1 = cshift(state.nbr_d, s, BIG)
        Ly1 = cshift(state.nbr_y, s, 0)
        Dp = jnp.pad(state.D, ((0, 1), (0, 1)), constant_values=BIG)
        D1 = jax.lax.dynamic_slice(Dp, (s, s), (cap, cap))
        aff1 = cshift(affected, s, False)
        es1 = cshift(dcol, s, BIG)
        n1 = state.n - s
        live1 = jnp.arange(cap) < n1
        nbr_d1, nbr_y1 = stream._drop_backfill_labeled(
            L1, Ly1, es1, live1[None, :], D1, y1, aff1, k=k)
    else:
        X1, y1, D1 = state.X, state.y, state.D
        nbr_d1, nbr_y1, n1 = state.nbr_d, state.nbr_y, state.n
        live1 = jnp.arange(cap) < n1

    # learn (mirrors stream._observe, writes gated on ``active``)
    idx = n1
    y_new = jnp.asarray(y_new, y1.dtype)
    d_row, nbr_d_m, nbr_y_m = kops.stream_update(
        X1, y1, nbr_d1, nbr_y1, x_new, y_new, n1, mode="reg")
    row = jnp.where(act, d_row, D1[idx, :])  # D symmetric: row == col
    D2 = D1.at[idx, :].set(row).at[:, idx].set(row)
    y2 = y1.at[idx].set(jnp.where(act, y_new, y1[idx]))
    own_neg, own_idx = jax.lax.top_k(-d_row, k)
    own_d = -own_neg
    own_y = y2[own_idx]
    own_y = jnp.where(own_d >= BIG, y_new, own_y)
    new_state = RegStreamState(
        X=X1.at[idx].set(jnp.where(act, x_new, X1[idx])),
        y=y2,
        D=D2,
        nbr_d=jnp.where(act, nbr_d_m.at[idx].set(own_d), nbr_d1),
        nbr_y=jnp.where(act, nbr_y_m.at[idx].set(own_y), nbr_y1),
        n=n1 + act,
    )

    # price the observed label against the pre-learn window (mirrors
    # ``_observe``'s p-value block bit-for-bit)
    kth = nbr_d1[:, -1]
    a_prime = y1 - jnp.sum(nbr_y1, axis=1) / k
    enters = live1 & (d_row < kth)
    a_vec = jnp.where(enters, a_prime + nbr_y1[:, -1] / k, a_prime)
    b_vec = jnp.where(enters, -1.0 / k, 0.0)
    a = -jnp.sum(y1[own_idx]) / k

    alphas = jnp.abs(a_vec + b_vec * y_new)
    alpha = jnp.abs(a + y_new)
    gt = jnp.sum(jnp.where(live1, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live1, alphas == alpha, False))
    p = ((gt + tau * (eq + 1.0)) / (n1 + 1.0)).astype(X1.dtype)
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=X1.dtype))
    return new_state, p


def _observe_sliding(state: RegStreamState, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never
    retrace). The fused ``_sliding_step`` with every lane active.
    """
    return _sliding_step(state, x_new, y_new, tau, window, True, k=k)


observe_sliding = functools.partial(
    jax.jit, static_argnames=("k",))(_observe_sliding)
#: Donating form of ``observe_sliding`` — same numerics, input deleted.
observe_sliding_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe_sliding)


def grow(state: RegStreamState, factor: int = 2) -> RegStreamState:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime (the capacity-doubling schedule). Not jittable.
    """
    cap = state.capacity
    extra = cap * (factor - 1)
    return RegStreamState(
        X=jnp.pad(state.X, ((0, extra), (0, 0))),
        y=jnp.pad(state.y, (0, extra)),
        D=jnp.pad(state.D, ((0, extra), (0, extra)), constant_values=BIG),
        nbr_d=jnp.pad(state.nbr_d, ((0, extra), (0, 0)),
                      constant_values=BIG),
        nbr_y=jnp.pad(state.nbr_y, ((0, extra), (0, 0))),
        n=state.n,
    )


@functools.partial(jax.jit, static_argnames=("k",))
def intervals(state: RegStreamState, X_test, *, k, epsilon):
    """Prediction intervals (m, 2) at miscoverage ``epsilon``.

    ``epsilon`` is traced (one compile serves every level — it only feeds
    the sweep threshold, and a traced f32 rounds identically to the
    embedded constant). Where the Pallas kernels are live (TPU, or
    interpret mode), the
    distance row + (a_i, b_i) update + critical points come fused from
    ``kops.interval_sweep``. Elsewhere the computation structurally
    mirrors ``regression.intervals_optimized`` (per-test ``lax.map``,
    vmapped ``_interval_ge``), so XLA emits the very same fused
    arithmetic and the results are bit-identical to the batch optimized
    path on the live window — the fully-batched form differs by ~1 ulp
    in the endpoints through different FMA contraction.
    """
    cap = state.capacity
    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    kth_label = state.nbr_y[:, -1]
    thresh = epsilon * (state.n + 1.0) - 1.0

    if kops.pallas_active(state.X.dtype):
        d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, state.X), 0.0))
        dm = jnp.where(live[None, :], d, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a_test = -jnp.sum(state.y[idx], axis=1) / k
        lo, hi = kops.interval_sweep(
            state.X, a_prime, kth, kth_label, live, X_test, a_test, k)

        def sweep(lo_r, hi_r):
            return jnp.stack(hull_sweep(lo_r, hi_r, lo_r > hi_r, thresh))

        return jax.vmap(sweep)(lo, hi)

    def per_test(x_t):
        d_t = jnp.sqrt(jnp.maximum(
            kops.sq_dists(x_t[None], state.X)[0], 0.0))
        enters = live & (d_t < kth)
        a_vec = jnp.where(enters, a_prime + kth_label / k, a_prime)
        b_vec = jnp.where(enters, -1.0 / k, 0.0)
        dm = jnp.where(live, d_t, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a = -jnp.sum(state.y[idx]) / k
        lo, hi = jax.vmap(_interval_ge, in_axes=(0, 0, None))(
            a_vec, b_vec, a)
        return jnp.stack(hull_sweep(lo, hi, (lo > hi) | ~live, thresh))

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k",))
def pvalues(state: RegStreamState, X_test, t_query, *, k):
    """Exact p-values (m, nq) at explicit query labels ``t_query``."""
    a_vec, b_vec, a, live = _ab_padded(state, X_test, k=k)
    ai = jnp.abs(a_vec[:, None, :] + b_vec[:, None, :]
                 * t_query[None, :, None])  # (m, nq, cap)
    at = jnp.abs(a[:, None] + t_query[None, :])  # (m, nq)
    cnt = jnp.sum(jnp.where(live[None, None, :], ai >= at[..., None], False),
                  axis=-1)
    return (cnt + 1.0) / (state.n + 1.0)


__all__ = ["RegStreamState", "init", "observe", "observe_donated",
           "observe_sliding", "observe_sliding_donated", "grow",
           "intervals", "pvalues"]
