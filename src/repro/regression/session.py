"""Per-tenant streaming regression-CP session over ``RegStreamState``.

Adds to the raw stream state the three per-tenant behaviours the serving
engine needs, all fixed-shape and vmappable:

* ``observe`` — price the incoming example first (smoothed online
  p-value of its *actual* label against the current window — the
  regression analogue of ``core.online.observe``, feeding the same
  exchangeability martingales), then learn it;
* ``observe_sliding`` — evict-if-full then observe: one sliding-window
  step with a traced per-tenant ``window``;
* ``intervals`` / ``pvalues`` — capacity-padded read paths. ``intervals``
  routes the fused distance-row + (a_i, b_i) update + critical-point
  computation through ``kernels.ops.interval_sweep`` (the Pallas kernel
  on TPU) and finishes with the shared ``regression.hull_sweep``; padded
  rows contribute neutral events, so results are bit-identical to
  ``regression.intervals_optimized`` on the live window (property-tested;
  the one caveat is an ``epsilon`` sitting exactly on the p == epsilon
  rank boundary, where f32 vs f64 threshold rounding may legitimately
  differ — the same measure-zero tie the batch tests dodge with
  irrational grid offsets).

Read paths require n >= k (the candidate's own k-NN needs k live rows);
early-stream outputs are well-shaped but degenerate, as in the batch path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.regression import BIG, _interval_ge, hull_sweep
from repro.kernels import ops as kops
from repro.regression import stream
from repro.regression.stream import RegStreamState

init = stream.init


def _ab_padded(state: RegStreamState, X_test, *, k):
    """Padded ``ab_optimized`` for a (m, p) query batch.

    Returns (a_vec (m, cap), b_vec (m, cap), a (m,), live (cap,)) with
    bits equal to ``regression.ab_optimized`` per live row/test point.
    """
    cap = state.capacity
    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    upd = a_prime + state.nbr_y[:, -1] / k

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, state.X), 0.0))
    enters = live[None, :] & (d < kth[None, :])
    a_vec = jnp.where(enters, upd[None, :], a_prime[None, :])
    b_vec = jnp.where(enters, -1.0 / k, 0.0)

    dm = jnp.where(live[None, :], d, BIG)
    _, idx = jax.lax.top_k(-dm, k)
    a = -jnp.sum(state.y[idx], axis=1) / k
    return a_vec, b_vec, a, live


@functools.partial(jax.jit, static_argnames=("k",))
def observe(state: RegStreamState, x_new, y_new, tau, *, k):
    """Smoothed online p-value of (x_new, y_new), then learn it.

    The p-value tests the *observed label* against the current window
    (conformal test statistic for drift martingales): alpha_i = |a_i +
    b_i y|, alpha = |a + y|, smoothed rank with tie-break ``tau``. The
    distance row the learn step computes anyway (``stream.observe``'s
    second return) prices the point — scoring uses the pre-learn
    statistics, so one O(cap) row serves both.
    Precondition: n < capacity.
    """
    cap = state.capacity
    new_state, d_row = stream.observe(state, x_new, y_new, k=k)

    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    enters = live & (d_row < kth)  # d_row is BIG on inert rows
    a_vec = jnp.where(enters, a_prime + state.nbr_y[:, -1] / k, a_prime)
    b_vec = jnp.where(enters, -1.0 / k, 0.0)
    _, idx = jax.lax.top_k(-d_row, k)
    a = -jnp.sum(state.y[idx]) / k

    t = jnp.asarray(y_new, state.y.dtype)
    alphas = jnp.abs(a_vec + b_vec * t)
    alpha = jnp.abs(a + t)
    gt = jnp.sum(jnp.where(live, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live, alphas == alpha, False))
    p = (gt + tau * (eq + 1.0)) / (state.n + 1.0)
    return new_state, p


@functools.partial(jax.jit, static_argnames=("k",))
def observe_sliding(state: RegStreamState, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never
    retrace). Under vmap the cond lowers to a select — both branches
    run, lanes that don't evict keep their state bitwise unchanged.
    """
    state = jax.lax.cond(
        state.n >= window,
        lambda s: stream.evict_oldest(s, k=k),
        lambda s: s,
        state,
    )
    return observe(state, x_new, y_new, tau, k=k)


def grow(state: RegStreamState, factor: int = 2) -> RegStreamState:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime (the capacity-doubling schedule). Not jittable.
    """
    cap = state.capacity
    extra = cap * (factor - 1)
    return RegStreamState(
        X=jnp.pad(state.X, ((0, extra), (0, 0))),
        y=jnp.pad(state.y, (0, extra)),
        D=jnp.pad(state.D, ((0, extra), (0, extra)), constant_values=BIG),
        nbr_d=jnp.pad(state.nbr_d, ((0, extra), (0, 0)),
                      constant_values=BIG),
        nbr_y=jnp.pad(state.nbr_y, ((0, extra), (0, 0))),
        n=state.n,
    )


@functools.partial(jax.jit, static_argnames=("k",))
def intervals(state: RegStreamState, X_test, *, k, epsilon):
    """Prediction intervals (m, 2) at miscoverage ``epsilon``.

    ``epsilon`` is traced (one compile serves every level — it only feeds
    the sweep threshold, and a traced f32 rounds identically to the
    embedded constant). Where the Pallas kernels are live (TPU, or
    interpret mode), the
    distance row + (a_i, b_i) update + critical points come fused from
    ``kops.interval_sweep``. Elsewhere the computation structurally
    mirrors ``regression.intervals_optimized`` (per-test ``lax.map``,
    vmapped ``_interval_ge``), so XLA emits the very same fused
    arithmetic and the results are bit-identical to the batch optimized
    path on the live window — the fully-batched form differs by ~1 ulp
    in the endpoints through different FMA contraction.
    """
    cap = state.capacity
    live = jnp.arange(cap) < state.n
    kth = state.nbr_d[:, -1]
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    kth_label = state.nbr_y[:, -1]
    thresh = epsilon * (state.n + 1.0) - 1.0

    if kops.pallas_active(state.X.dtype):
        d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, state.X), 0.0))
        dm = jnp.where(live[None, :], d, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a_test = -jnp.sum(state.y[idx], axis=1) / k
        lo, hi = kops.interval_sweep(
            state.X, a_prime, kth, kth_label, live, X_test, a_test, k)

        def sweep(lo_r, hi_r):
            return jnp.stack(hull_sweep(lo_r, hi_r, lo_r > hi_r, thresh))

        return jax.vmap(sweep)(lo, hi)

    def per_test(x_t):
        d_t = jnp.sqrt(jnp.maximum(
            kops.sq_dists(x_t[None], state.X)[0], 0.0))
        enters = live & (d_t < kth)
        a_vec = jnp.where(enters, a_prime + kth_label / k, a_prime)
        b_vec = jnp.where(enters, -1.0 / k, 0.0)
        dm = jnp.where(live, d_t, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a = -jnp.sum(state.y[idx]) / k
        lo, hi = jax.vmap(_interval_ge, in_axes=(0, 0, None))(
            a_vec, b_vec, a)
        return jnp.stack(hull_sweep(lo, hi, (lo > hi) | ~live, thresh))

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k",))
def pvalues(state: RegStreamState, X_test, t_query, *, k):
    """Exact p-values (m, nq) at explicit query labels ``t_query``."""
    a_vec, b_vec, a, live = _ab_padded(state, X_test, k=k)
    ai = jnp.abs(a_vec[:, None, :] + b_vec[:, None, :]
                 * t_query[None, :, None])  # (m, nq, cap)
    at = jnp.abs(a[:, None] + t_query[None, :])  # (m, nq)
    cnt = jnp.sum(jnp.where(live[None, None, :], ai >= at[..., None], False),
                  axis=-1)
    return (cnt + 1.0) / (state.n + 1.0)


__all__ = ["RegStreamState", "init", "observe", "observe_sliding", "grow",
           "intervals", "pvalues"]
