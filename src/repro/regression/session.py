"""Per-tenant streaming regression-CP session over ``RegStreamState``.

Adds to the raw stream state the three per-tenant behaviours the serving
engine needs, all fixed-shape and vmappable:

* ``observe`` — price the incoming example first (smoothed online
  p-value of its *actual* label against the current window — the
  regression analogue of ``core.online.observe``, feeding the same
  exchangeability martingales), then learn it;
* ``observe_sliding`` — evict-if-full then observe: one sliding-window
  step with a traced per-tenant ``window``. On the ring layout the
  evict half is a head advance + O(cap·k) list repair; the (cap, cap)
  ``D`` is only read (the backfill reductions) and written at one
  row + one column — never shifted or copied (``_sliding_step_compact``
  keeps the historic positional form as the bit-oracle);
* ``intervals`` / ``pvalues`` — capacity-padded read paths, computed on
  the ``arrival_view`` (an O(cap) gather into arrival order, so the
  historic linear-layout expressions — and their bits — are unchanged,
  equal-distance tie order included). ``intervals`` routes the fused
  distance-row + (a_i, b_i) update + critical-point computation through
  ``kernels.ops.interval_sweep`` (the Pallas kernel on TPU) and
  finishes with the shared ``regression.hull_sweep``; padded rows
  contribute neutral events, so results are bit-identical to
  ``regression.intervals_optimized`` on the live window (property-tested;
  the one caveat is an ``epsilon`` sitting exactly on the p == epsilon
  rank boundary, where f32 vs f64 threshold rounding may legitimately
  differ — the same measure-zero tie the batch tests dodge with
  irrational grid offsets).

Read paths require n >= k (the candidate's own k-NN needs k live rows);
early-stream outputs are well-shaped but degenerate, as in the batch path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.regression import BIG, _interval_ge, hull_sweep
from repro.kernels import ops as kops
from repro.regression import stream
from repro.regression.stream import RegStreamState, _mod_cap, _next_aid
from repro.core.online import (cshift, drop_backfill, ring_age, ring_live,
                               ring_slots)

init = stream.init


_arrival_stats = stream.arrival_stats


def _ab_padded(state: RegStreamState, X_test, *, k):
    """Padded ``ab_optimized`` for a (m, p) query batch.

    Operates on the arrival-ordered stats (rows in arrival order), so
    bits equal ``regression.ab_optimized`` per live row/test point.
    Returns (a_vec (m, cap), b_vec (m, cap), a (m,), live (cap,)).
    """
    Xg, yg, a_prime, upd, kth, _, live = _arrival_stats(state, k=k)

    d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, Xg), 0.0))
    enters = live[None, :] & (d < kth[None, :])
    a_vec = jnp.where(enters, upd[None, :], a_prime[None, :])
    b_vec = jnp.where(enters, -1.0 / k, 0.0)

    dm = jnp.where(live[None, :], d, BIG)
    _, idx = jax.lax.top_k(-dm, k)
    a = -jnp.sum(yg[idx], axis=1) / k
    return a_vec, b_vec, a, live


def _price(d_row, y_sel, y_new, tau, *, k, live, nbr_d, nbr_y, y, n):
    """Smoothed online p-value of label ``y_new`` against the pre-learn
    window statistics (alpha_i = |a_i + b_i y|, alpha = |a + y|,
    smoothed rank with tie-break ``tau``). Layout-free: per-slot scores
    masked by ``live``, integer rank counts, and the candidate's own
    ``a`` from the arrival-ordered top-k labels ``y_sel``.
    """
    kth = nbr_d[:, -1]
    a_prime = y - jnp.sum(nbr_y, axis=1) / k
    enters = live & (d_row < kth)  # d_row is BIG off the live window
    a_vec = jnp.where(enters, a_prime + nbr_y[:, -1] / k, a_prime)
    b_vec = jnp.where(enters, -1.0 / k, 0.0)
    a = -jnp.sum(y_sel) / k

    t = jnp.asarray(y_new, y.dtype)
    alphas = jnp.abs(a_vec + b_vec * t)
    alpha = jnp.abs(a + t)
    gt = jnp.sum(jnp.where(live, alphas > alpha, False))
    eq = jnp.sum(jnp.where(live, alphas == alpha, False))
    # astype: no-op at f32/f64, pins sub-f32 dtypes (see core.online)
    return ((gt + tau * (eq + 1.0)) / (n + 1.0)).astype(y.dtype)


def _observe(state: RegStreamState, x_new, y_new, tau, *, k):
    """Smoothed online p-value of (x_new, y_new), then learn it.

    The p-value tests the *observed label* against the current window
    (conformal test statistic for drift martingales). The distance row
    the learn step computes anyway (``stream.observe``'s second return)
    prices the point — scoring uses the pre-learn statistics, so one
    O(cap) row serves both.
    Precondition: n < capacity.
    """
    cap = state.capacity
    new_state, d_row = stream.observe(state, x_new, y_new, k=k)
    live = ring_live(cap, state.head, state.n, state.wrap)
    _, _, y_sel, _ = stream._own_list(state, d_row, state.y, y_new, k=k)
    p = _price(d_row, y_sel, y_new, tau, k=k, live=live,
               nbr_d=state.nbr_d, nbr_y=state.nbr_y, y=state.y,
               n=state.n)
    return new_state, p


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: Donating form of ``observe``: the (cap, cap) ``D`` row/column insert
#: updates in place instead of copying the matrix. The input state is
#: DELETED by the call. Numerics are identical to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _sliding_step(state: RegStreamState, x_new, y_new, tau, window, active,
                  *, k, evictable: bool = True, wmax: int | None = None):
    """One fused sliding-window tick: evict-if-full, observe, all gated.

    Regression counterpart of ``serving.session._sliding_step`` — the
    semantics of ``cond(evict_oldest) -> observe`` with an ``active``
    mask, on the ring layout: a gated head advance + the shared labeled
    list repair, then the observe core with arithmetically gated writes
    (inactive lanes rewrite their current values — masked state stays
    bitwise unchanged, p-value NaN). The (cap, cap) ``D`` is only read
    (one fused backfill-reduction pass) and written at one row + one
    column in place under donation. Bit-identical to the historic
    compaction form ``_sliding_step_compact`` (property-tested).
    ``evictable=False`` (static) drops the eviction machinery for the
    grow-mode engine; ``wmax`` (static, the sliding engine's window
    bound on occupancy) confines the ring to the ``[:wmax]`` block of
    every leaf — per-tick cost scales with the window, not the padded
    capacity.
    """
    cap = state.capacity
    # static block bound for the leaf slices; the traced modulus is the
    # state's ``wrap`` (engine invariant: wrap <= wmax)
    w = cap if wmax is None or wmax >= cap else wmax
    wrap = state.wrap
    # slot-space views confined to the ring block (pure reads)
    Xw, yw = state.X[:w], state.y[:w]
    Dw = state.D[:w, :w]
    aidw = state.aid[:w]
    head, n = state.head, state.n
    act = jnp.asarray(active)

    if evictable:
        ev = act & (n >= window)
        s = ev.astype(jnp.int32)
        dcol = Dw[:, head]
        head1 = _mod_cap(head + s, wrap)
        n1 = n - s
        live1 = ring_live(w, head1, n1, wrap)
        affected = ev & live1 & (dcol <= state.nbr_d[:w, -1])
        nbr_d1, nbr_y1, nbr_a1 = drop_backfill(
            state.nbr_d[:w], dcol, live1[None, :], Dw, affected, k=k,
            Ly=state.nbr_y[:w], La=state.nbr_a[:w], ys=yw, aid=aidw,
            age=ring_age(w, head1, wrap), slots=ring_slots(w, head1, wrap),
            aid0=aidw[head])
    else:
        head1, n1 = head, n
        nbr_d1, nbr_y1 = state.nbr_d[:w], state.nbr_y[:w]
        nbr_a1 = state.nbr_a[:w]
        live1 = ring_live(w, head1, n1, wrap)

    # learn (mirrors stream._observe, writes gated on ``active``)
    idx = _mod_cap(head1 + n1, wrap)
    y_new = jnp.asarray(y_new, yw.dtype)
    d_row, nbr_d_m, nbr_y_m = kops.stream_update(
        Xw, yw, nbr_d1, nbr_y1, x_new, y_new, n1, mode="reg", head=head1,
        wrap=wrap)
    row = jnp.where(act, d_row, Dw[idx, :])  # D symmetric: row == col
    # bit-neutral scheduling marker (see serving.session._sliding_step):
    # the in-place D update must depend on every repaired list (each
    # carries backfill reads of D) or XLA copies the donated (cap, cap)
    # buffer twice per tick. Distances are finite and >= 0 and labels
    # and ids finite, so the term is exactly +0.0
    row = row + (nbr_d1[0, 0]
                 + (nbr_y1[0, 0] + nbr_a1[0, 0]) * 0.0) * 0.0
    D2 = state.D.at[idx, :w].set(row).at[:w, idx].set(row)
    y2w = yw.at[idx].set(jnp.where(act, y_new, yw[idx]))
    sub = RegStreamState(Xw, yw, Dw, nbr_d1, nbr_y1, n1, head1, aidw,
                         wrap, nbr_a1)
    own_d, own_y, y_sel, own_a = stream._own_list(sub, d_row, y2w, y_new,
                                                  k=k)
    new_aid = _next_aid(aidw, head1, n1, wrap)
    enters = live1 & (d_row < nbr_d1[:, -1])
    nbr_a_m = stream._merge_aid(nbr_d1, nbr_a1,
                                jnp.where(enters, d_row, BIG), new_aid,
                                nbr_d_m)
    new_state = RegStreamState(
        X=state.X.at[idx].set(jnp.where(act, x_new, Xw[idx])),
        y=state.y.at[idx].set(jnp.where(act, y_new, yw[idx])),
        D=D2,
        nbr_d=state.nbr_d.at[:w].set(
            jnp.where(act, nbr_d_m.at[idx].set(own_d), nbr_d1)),
        nbr_y=state.nbr_y.at[:w].set(
            jnp.where(act, nbr_y_m.at[idx].set(own_y), nbr_y1)),
        n=n1 + act,
        head=head1,
        aid=state.aid.at[idx].set(
            jnp.where(act, new_aid, state.aid[idx])),
        wrap=wrap,
        nbr_a=state.nbr_a.at[:w].set(
            jnp.where(act, nbr_a_m.at[idx].set(own_a), nbr_a1)),
    )

    # price the observed label against the pre-learn window (mirrors
    # ``_observe``'s p-value block bit-for-bit)
    p = _price(d_row, y_sel, y_new, tau, k=k, live=live1,
               nbr_d=nbr_d1, nbr_y=nbr_y1, y=yw, n=n1)
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=Xw.dtype))
    return new_state, p


def _sliding_step_compact(state: RegStreamState, x_new, y_new, tau, window,
                          active, *, k, evictable: bool = True,
                          wmax: int | None = None):
    """Historic linear-layout sliding tick — the ring path's bit-oracle.

    Keeps arrival order positionally: eviction compacts every leaf down
    one row (and ``D`` one row AND one column) through a padded dynamic
    slice — the O(cap^2)-traffic form the ring layout replaces. Retained
    for the exactness property tests and as the benchmark baseline
    (``layout="compact"`` on the engine). Precondition: linear layout
    (``head == 0``), which this step preserves.
    """
    cap = state.capacity
    if wmax is not None and wmax < cap:
        sub = RegStreamState(
            state.X[:wmax], state.y[:wmax], state.D[:wmax, :wmax],
            state.nbr_d[:wmax], state.nbr_y[:wmax], state.n, state.head,
            state.aid[:wmax], jnp.minimum(state.wrap, wmax),
            state.nbr_a[:wmax])
        sub2, p = _sliding_step_compact(sub, x_new, y_new, tau, window,
                                        active, k=k, evictable=evictable)
        return RegStreamState(
            X=state.X.at[:wmax].set(sub2.X),
            y=state.y.at[:wmax].set(sub2.y),
            D=state.D.at[:wmax, :wmax].set(sub2.D),
            nbr_d=state.nbr_d.at[:wmax].set(sub2.nbr_d),
            nbr_y=state.nbr_y.at[:wmax].set(sub2.nbr_y),
            n=sub2.n, head=sub2.head,
            aid=state.aid.at[:wmax].set(sub2.aid),
            wrap=state.wrap,
            nbr_a=state.nbr_a.at[:wmax].set(sub2.nbr_a)), p
    act = jnp.asarray(active)
    aid = state.aid
    if evictable:
        ev = act & (state.n >= window)
        s = ev.astype(jnp.int32)
        live = jnp.arange(cap) < state.n
        dcol = state.D[:, 0]
        affected = ev & live & (dcol <= state.nbr_d[:, -1])

        # conditional compaction: pad each leaf by one (the pad value IS
        # the compaction fill) and take one dynamic slice at offset s
        X1 = cshift(state.X, s, 0)
        y1 = cshift(state.y, s, 0)
        L1 = cshift(state.nbr_d, s, BIG)
        Ly1 = cshift(state.nbr_y, s, 0)
        La1 = cshift(state.nbr_a, s, 0)
        aid1 = cshift(aid, s, 0)
        Dp = jnp.pad(state.D, ((0, 1), (0, 1)), constant_values=BIG)
        D1 = jax.lax.dynamic_slice(Dp, (s, s), (cap, cap))
        aff1 = cshift(affected, s, False)
        es1 = cshift(dcol, s, BIG)
        n1 = state.n - s
        live1 = jnp.arange(cap) < n1
        nbr_d1, nbr_y1, nbr_a1 = drop_backfill(
            L1, es1, live1[None, :], D1, aff1, k=k, Ly=Ly1, La=La1,
            ys=y1, aid=aid1, age=jnp.arange(cap, dtype=jnp.int32),
            slots=jnp.arange(cap, dtype=jnp.int32), aid0=aid[0])
    else:
        X1, y1, D1 = state.X, state.y, state.D
        nbr_d1, nbr_y1, n1, aid1 = (state.nbr_d, state.nbr_y, state.n,
                                    aid)
        nbr_a1 = state.nbr_a
        live1 = jnp.arange(cap) < n1

    # learn (mirrors stream._observe, writes gated on ``active``).
    # The clamp keeps an inactive lane at an exactly-full window in
    # bounds (idx == cap otherwise — XLA's pad+slice fusion reads the
    # pad fill there instead of clamping); the write is its own value,
    # so the clamp is bit-neutral wherever the step is defined
    idx = jnp.minimum(n1, cap - 1)
    y_new = jnp.asarray(y_new, y1.dtype)
    d_row, nbr_d_m, nbr_y_m = kops.stream_update(
        X1, y1, nbr_d1, nbr_y1, x_new, y_new, n1, mode="reg")
    row = jnp.where(act, d_row, D1[idx, :])  # D symmetric: row == col
    D2 = D1.at[idx, :].set(row).at[:, idx].set(row)
    y2 = y1.at[idx].set(jnp.where(act, y_new, y1[idx]))
    own_neg, own_idx = jax.lax.top_k(-d_row, k)
    own_d = -own_neg
    own_y = y2[own_idx]
    own_y = jnp.where(own_d >= BIG, y_new, own_y)
    new_aid = _next_aid(aid1, jnp.zeros((), jnp.int32), n1,
                        jnp.int32(cap))
    own_a = jnp.where(own_d >= BIG, 0, aid1[own_idx]).astype(jnp.int32)
    enters1 = live1 & (d_row < nbr_d1[:, -1])
    nbr_a_m = stream._merge_aid(nbr_d1, nbr_a1,
                                jnp.where(enters1, d_row, BIG), new_aid,
                                nbr_d_m)
    new_state = RegStreamState(
        X=X1.at[idx].set(jnp.where(act, x_new, X1[idx])),
        y=y2,
        D=D2,
        nbr_d=jnp.where(act, nbr_d_m.at[idx].set(own_d), nbr_d1),
        nbr_y=jnp.where(act, nbr_y_m.at[idx].set(own_y), nbr_y1),
        n=n1 + act,
        head=state.head,
        aid=aid1.at[idx].set(jnp.where(act, new_aid, aid1[idx])),
        wrap=state.wrap,
        nbr_a=jnp.where(act, nbr_a_m.at[idx].set(own_a), nbr_a1),
    )

    # price the observed label against the pre-learn window
    p = _price(d_row, y1[own_idx], y_new, tau, k=k, live=live1,
               nbr_d=nbr_d1, nbr_y=nbr_y1, y=y1, n=n1)
    p = jnp.where(act, p, jnp.asarray(jnp.nan, dtype=X1.dtype))
    return new_state, p


def _observe_sliding(state: RegStreamState, x_new, y_new, tau, window, *, k):
    """Evict-if-full then observe: one fixed-shape sliding-window step.

    ``window`` is a traced scalar (per-tenant window sizes never
    retrace). The fused ``_sliding_step`` with every lane active.
    """
    return _sliding_step(state, x_new, y_new, tau, window, True, k=k)


observe_sliding = functools.partial(
    jax.jit, static_argnames=("k",))(_observe_sliding)
#: Donating form of ``observe_sliding`` — same numerics, input deleted.
observe_sliding_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe_sliding)


def grow(state: RegStreamState, factor: int = 2) -> RegStreamState:
    """Double (by default) capacity host-side, preserving all live state.

    Shapes change, so jitted steps retrace — but only O(log n) times over
    a session's lifetime (the capacity-doubling schedule). The ring is
    normalized to linear order first (ring positions are modulus-bound,
    so they cannot survive a capacity change). Not jittable.
    """
    cap = state.capacity
    extra = cap * (factor - 1)
    state = stream.to_linear(state)
    return RegStreamState(
        X=jnp.pad(state.X, ((0, extra), (0, 0))),
        y=jnp.pad(state.y, (0, extra)),
        D=jnp.pad(state.D, ((0, extra), (0, extra)), constant_values=BIG),
        nbr_d=jnp.pad(state.nbr_d, ((0, extra), (0, 0)),
                      constant_values=BIG),
        nbr_y=jnp.pad(state.nbr_y, ((0, extra), (0, 0))),
        n=state.n,
        head=state.head,
        aid=jnp.pad(state.aid, (0, extra)),
        wrap=jnp.int32(cap * factor),
        nbr_a=jnp.pad(state.nbr_a, ((0, extra), (0, 0))),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def intervals(state: RegStreamState, X_test, *, k, epsilon):
    """Prediction intervals (m, 2) at miscoverage ``epsilon``.

    ``epsilon`` is traced (one compile serves every level — it only feeds
    the sweep threshold, and a traced f32 rounds identically to the
    embedded constant). The state is read through its ``arrival_view``
    (O(cap) gather; ``D`` untouched), after which the computation is the
    historic linear one. Where the Pallas kernels are live (TPU, or
    interpret mode), the
    distance row + (a_i, b_i) update + critical points come fused from
    ``kops.interval_sweep``. Elsewhere the computation structurally
    mirrors ``regression.intervals_optimized`` (per-test ``lax.map``,
    vmapped ``_interval_ge``), so XLA emits the very same fused
    arithmetic and the results are bit-identical to the batch optimized
    path on the live window — the fully-batched form differs by ~1 ulp
    in the endpoints through different FMA contraction.
    """
    Xg, yg, a_prime, upd, kth, kth_label, live = _arrival_stats(state,
                                                                k=k)
    thresh = epsilon * (state.n + 1.0) - 1.0

    if kops.pallas_active(state.X.dtype):
        d = jnp.sqrt(jnp.maximum(kops.sq_dists(X_test, Xg), 0.0))
        dm = jnp.where(live[None, :], d, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a_test = -jnp.sum(yg[idx], axis=1) / k
        lo, hi = kops.interval_sweep(
            Xg, a_prime, kth, kth_label, live, X_test, a_test, k)

        def sweep(lo_r, hi_r):
            return jnp.stack(hull_sweep(lo_r, hi_r, lo_r > hi_r, thresh))

        return jax.vmap(sweep)(lo, hi)

    def per_test(x_t):
        d_t = jnp.sqrt(jnp.maximum(
            kops.sq_dists(x_t[None], Xg)[0], 0.0))
        enters = live & (d_t < kth)
        # ``upd`` comes precomputed from the barriered stats block —
        # recomputing a_prime + kth_label/k here re-fuses with the map
        # body and rounds 1 ulp away from the batch path's bits
        a_vec = jnp.where(enters, upd, a_prime)
        b_vec = jnp.where(enters, -1.0 / k, 0.0)
        dm = jnp.where(live, d_t, BIG)
        _, idx = jax.lax.top_k(-dm, k)
        a = -jnp.sum(yg[idx]) / k
        lo, hi = jax.vmap(_interval_ge, in_axes=(0, 0, None))(
            a_vec, b_vec, a)
        return jnp.stack(hull_sweep(lo, hi, (lo > hi) | ~live, thresh))

    return jax.lax.map(per_test, X_test)


@functools.partial(jax.jit, static_argnames=("k",))
def pvalues(state: RegStreamState, X_test, t_query, *, k):
    """Exact p-values (m, nq) at explicit query labels ``t_query``."""
    a_vec, b_vec, a, live = _ab_padded(state, X_test, k=k)
    ai = jnp.abs(a_vec[:, None, :] + b_vec[:, None, :]
                 * t_query[None, :, None])  # (m, nq, cap)
    at = jnp.abs(a[:, None] + t_query[None, :])  # (m, nq)
    cnt = jnp.sum(jnp.where(live[None, None, :], ai >= at[..., None], False),
                  axis=-1)
    return (cnt + 1.0) / (state.n + 1.0)


__all__ = ["RegStreamState", "init", "observe", "observe_donated",
           "observe_sliding", "observe_sliding_donated", "grow",
           "intervals", "pvalues"]
