"""Exact incremental/decremental k-NN regression state (paper Section 8.1).

``core.regression.fit`` precomputes, per training point, the k nearest
neighbour labels (ordered nearest-first), the k-th neighbour distance and
label — the statistics behind the O(1)-per-point ``ab_optimized`` update.
This module maintains those statistics *online*: ``observe`` learns one
point and ``evict`` forgets one, both keeping every derived quantity
**bit-identical** to ``regression.fit`` refit-from-scratch on the live
window (property-tested in ``tests/test_regression_stream.py``).

The trick is the same as ``serving/session.py`` for classification: keep
the live pairwise-distance matrix ``D`` (one row+column per ``observe`` —
the row is needed for the online p-value anyway), so decremental removal
backfills k-best lists from stored exact distances instead of re-deriving
them. Bit-exactness additionally needs three invariants special to the
regression measure, where neighbour *labels* (not just distances) enter
the scores:

* ``nbr_d``/``nbr_y`` store each point's k nearest distances and labels in
  ``fit``'s exact order (ascending distance, ties toward the lower index:
  a new arrival carries the largest index, so it is inserted strictly
  below equal distances — a stable argsort with the candidate appended
  last reproduces ``top_k``'s tie rule);
* the label attached to a BIG (missing-neighbour) slot of row i is
  ``y_i`` — exactly what ``fit`` produces at window size n == k, where the
  only BIG entry in a row is its own masked diagonal;
* distance rows/columns are computed with the very ``kops.sq_dists``
  expression ``fit`` uses, which is bitwise row-decomposable and padding-
  invariant on the supported backends (checked by the property tests).

All arrays are capacity-padded and fixed-shape, so every update is one
jit-stable dispatch and vmaps across tenants (``repro.regression.engine``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.online import drop_backfill_core
from repro.core.regression import BIG, KnnRegState
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclass
class RegStreamState:
    """Capacity-padded streaming k-NN regression state.

    Rows ``[0, n)`` are live in arrival order. Inert rows hold zeros in
    ``X``/``y`` (zero rows keep ``sq_dists`` padding-invariant) and BIG in
    ``D``/``nbr_d``; ``D`` is BIG on the diagonal, mirroring ``fit``'s
    self-exclusion mask.
    """

    X: jnp.ndarray  # (cap, p)
    y: jnp.ndarray  # (cap,)
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere
    nbr_d: jnp.ndarray  # (cap, k) k nearest distances, ascending
    nbr_y: jnp.ndarray  # (cap, k) their labels, same order
    n: jnp.ndarray  # () live count

    def tree_flatten(self):
        return ((self.X, self.y, self.D, self.nbr_d, self.nbr_y,
                 self.n), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]

    @property
    def k(self) -> int:
        return self.nbr_d.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> RegStreamState:
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return RegStreamState(
        X=jnp.zeros((capacity, p), dtype=dtype),
        y=jnp.zeros((capacity,), dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
        nbr_d=jnp.full((capacity, k), BIG, dtype=dtype),
        nbr_y=jnp.zeros((capacity, k), dtype=dtype),
        n=jnp.zeros((), dtype=jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def state_view(state: RegStreamState, *, k) -> KnnRegState:
    """The capacity-padded ``KnnRegState`` this stream state encodes.

    Live rows carry exactly ``regression.fit``'s bits (once n >= k);
    inert rows are garbage and must be masked by the reader. Jitted on
    purpose: ``fit`` computes ``a_prime`` inside jit, and XLA's fused
    sum/divide/subtract rounds differently from the eager op-by-op
    dispatch — bit-parity needs the same compilation path.
    """
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    return KnnRegState(state.X, state.y, a_prime,
                       state.nbr_d[:, -1], state.nbr_y[:, -1])


def _observe(state: RegStreamState, x_new, y_new, *, k):
    """Learn one example in O(cap k): the paper's incremental update.

    Returns ``(new_state, d_row)`` — ``d_row`` is the (cap,) vector of
    distances from ``x_new`` to each live row (BIG on inert rows), for
    callers that price the point before learning it (``session.observe``).
    Precondition: n < capacity (callers grow or evict first).
    """
    idx = state.n
    y_new = jnp.asarray(y_new, state.y.dtype)

    # fused distance row + gated ordered merge into every live row's
    # (nbr_d, nbr_y) list — one Pallas pass on TPU; the CPU/f64 reference
    # is expression-identical to the historic inline code (strict d < kth
    # gate, stable-argsort insert-after-equals tie rule, BIG slots carry
    # the row's own label), so streaming bits vs ``fit`` are unchanged
    d_row, nbr_d, nbr_y = kops.stream_update(
        state.X, state.y, state.nbr_d, state.nbr_y, x_new, y_new,
        state.n, mode="reg")
    # one row + one column of D: under a donating jit these two updates
    # lower to in-place dynamic-update-slices — O(cap) HBM traffic, not
    # an O(cap^2) copy of the matrix
    D = state.D.at[idx, :].set(d_row).at[:, idx].set(d_row)

    # the new row's own list: top_k over its distance row (BIG at self),
    # exactly fit's per-row computation
    y2 = state.y.at[idx].set(y_new)
    own_neg, own_idx = jax.lax.top_k(-d_row, k)
    own_d = -own_neg
    own_y = y2[own_idx]
    # missing-neighbour slots carry the row's own label (fit convention:
    # at n == k the one BIG entry is the masked self-diagonal)
    own_y = jnp.where(own_d >= BIG, y_new, own_y)

    new_state = RegStreamState(
        X=state.X.at[idx].set(x_new),
        y=y2,
        D=D,
        nbr_d=nbr_d.at[idx].set(own_d),
        nbr_y=nbr_y.at[idx].set(own_y),
        n=state.n + 1,
    )
    return new_state, d_row


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: ``observe`` whose input state is donated: the capacity-padded buffers
#: (most importantly the (cap, cap) ``D``) are updated in place instead of
#: copied. The input state is DELETED by the call — reusing it afterwards
#: raises ``RuntimeError: Array has been deleted``. Numerics are identical
#: to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _evict(state: RegStreamState, i, *, k) -> RegStreamState:
    """Forget live row ``i`` in O(cap^2) worst case: decremental update.

    Only rows whose k-NN list contained the evicted point are touched;
    each is recomputed from the stored exact distances, so the result is
    bit-exact vs refitting on the remaining window. Rows above ``i`` are
    compacted down by one (arrival order preserved, so top_k's
    lower-index-first tie rule keeps matching ``fit`` on the window).
    ``i`` may be traced. Precondition: 0 <= i < n (callers guard; under
    vmap+select the skipped lanes compute discarded garbage).
    """
    cap = state.capacity
    i = jnp.asarray(i, jnp.int32)
    live = jnp.arange(cap) < state.n

    # rows whose list held the evicted point: d(r, i) <= kth. The evicted
    # index may sit anywhere, so on ties we cannot tell membership from
    # the distance alone — recompute conservatively (recompute is exact).
    dcol = state.D[:, i]
    affected = live & (dcol <= state.nbr_d[:, -1])

    # compact rows > i down by one (gather; index cap-1 maps to itself and
    # is overwritten by the inert fill below)
    perm = jnp.arange(cap) + (jnp.arange(cap) >= i)
    perm = jnp.minimum(perm, cap - 1)
    n2 = state.n - 1
    live2 = jnp.arange(cap) < n2

    Xs = jnp.where(live2[:, None], state.X[perm], 0.0)
    ys = jnp.where(live2, state.y[perm], 0.0)
    Ds = state.D[perm][:, perm]
    Ds = jnp.where(live2[:, None] & live2[None, :], Ds, BIG)
    nbr_ds = jnp.where(live2[:, None], state.nbr_d[perm], BIG)
    nbr_ys = jnp.where(live2[:, None], state.nbr_y[perm], 0.0)
    aff = live2 & affected[perm]

    # backfill affected rows: exact k-best straight from the stored
    # distances (the diagonal and inert entries are already BIG)
    neg, idxm = jax.lax.top_k(-Ds, k)
    rec_d = -neg
    rec_y = ys[idxm]
    rec_y = jnp.where(rec_d >= BIG, ys[:, None], rec_y)
    return RegStreamState(
        X=Xs, y=ys, D=Ds,
        nbr_d=jnp.where(aff[:, None], rec_d, nbr_ds),
        nbr_y=jnp.where(aff[:, None], rec_y, nbr_ys),
        n=n2,
    )


evict = functools.partial(jax.jit, static_argnames=("k",))(_evict)
#: Donating form of ``evict`` — same numerics, input state deleted.
evict_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict)


def _evict_oldest(state: RegStreamState, *, k) -> RegStreamState:
    """Sliding-window form: forget the oldest live point (row 0).

    Specialization of ``evict`` that skips the full top_k recompute:
    the evicted point has the LOWEST arrival index, so on distance ties
    it sorts first — if it is in a row's k-NN list at all it occupies
    the first slot holding its distance, and the repair is an O(k) drop
    + one backfill. The backfill value comes by multiset rank over the
    stored distances (see ``serving.session.evict_oldest``); its *label*
    is the (r+1)-th lowest-indexed candidate at that distance, where
    r counts the list's surviving occurrences of the value — exactly
    fit's ties-toward-lower-index order, so the result stays bit-exact
    vs refit (property-tested). Replaces an O(cap^2 log k) top_k with a
    few O(cap^2) masked reductions — the sliding-window hot path.
    Precondition: n >= 1 (guarded by callers; under vmap+select the n=0
    lanes compute garbage that the caller's select discards).
    """
    cap = state.capacity
    live = jnp.arange(cap) < state.n
    dcol = state.D[:, 0]
    kth = state.nbr_d[:, -1]
    affected = live & (dcol <= kth)

    def shift(a, fill):
        return jnp.concatenate([a[1:], jnp.full_like(a[:1], fill)], axis=0)

    Xs = shift(state.X, 0)
    ys = shift(state.y, 0)
    Ds = shift(state.D, BIG)
    Ds = jnp.concatenate(
        [Ds[:, 1:], jnp.full_like(Ds[:, :1], BIG)], axis=1)
    L = shift(state.nbr_d, BIG)
    Ly = shift(state.nbr_y, 0)
    aff = shift(affected, False)
    es = shift(dcol, BIG)

    n2 = state.n - 1
    live2 = jnp.arange(cap) < n2
    cand = live2[None, :]  # self-distances are BIG on the diagonal
    nbr_d2, nbr_y2 = _drop_backfill_labeled(L, Ly, es, cand, Ds, ys, aff,
                                            k=k)
    return RegStreamState(
        X=Xs, y=ys, D=Ds, nbr_d=nbr_d2, nbr_y=nbr_y2, n=n2)


def _drop_backfill_labeled(L, Ly, es, cand, Ds, ys, aff, *, k):
    """Repair each (distance, label) list flagged in ``aff``: the shared
    distance repair (``core.online.drop_backfill_core``) plus the label
    bookkeeping — the backfill point's label follows fit's ties-toward-
    lower-index order. Rows not flagged pass through untouched.
    """
    newL, pos0, cols, b, tprime, mprime = drop_backfill_core(
        L, es, cand, Ds, k=k)

    # the backfill label: among candidates at distance b (in index
    # order) skip the r occurrences the surviving list already holds —
    # they are the r lowest-indexed ones, fit's tie order
    r = jnp.where(b == tprime, mprime, 0)
    mask_b = cand & (Ds == b[:, None])
    csum = jnp.cumsum(mask_b.astype(jnp.int32), axis=1)
    pick = mask_b & (csum == r[:, None] + 1)
    yb = ys[jnp.argmax(pick, axis=1)]  # b >= BIG rows fixed up below

    Lyup = jnp.concatenate([Ly[:, 1:], Ly[:, :1]], axis=1)
    newLy = jnp.where(cols[None, :] < pos0[:, None], Ly,
                      jnp.where(cols[None, :] < k - 1, Lyup, yb[:, None]))
    # missing-neighbour slots carry the row's own label (fit convention)
    newLy = jnp.where(newL >= BIG, ys[:, None], newLy)
    return (jnp.where(aff[:, None], newL, L),
            jnp.where(aff[:, None], newLy, Ly))


evict_oldest = functools.partial(
    jax.jit, static_argnames=("k",))(_evict_oldest)
#: Donating form of ``evict_oldest`` — same numerics, input deleted.
evict_oldest_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict_oldest)


@functools.partial(jax.jit, static_argnames=("k", "capacity"))
def _replay(X, y, *, k, capacity):
    state = init(capacity, X.shape[1], k, dtype=X.dtype)

    def step(s, xy):
        s2, _ = observe(s, xy[0], xy[1], k=k)
        return s2, None

    state, _ = jax.lax.scan(step, state, (X, y))
    return state


def from_fit(X, y, *, k, capacity: int) -> RegStreamState:
    """Seed a streaming state from batch data by replaying ``observe``.

    One scanned jit (buffers donated across steps, no per-step host
    round-trip) — the incremental construction *is* the fit, bit-exactly,
    so no separate batch loader is needed.
    """
    return _replay(jnp.asarray(X), jnp.asarray(y), k=k,
                   capacity=int(capacity))


__all__ = ["RegStreamState", "init", "state_view", "observe",
           "observe_donated", "evict", "evict_donated", "evict_oldest",
           "evict_oldest_donated", "from_fit"]
