"""Exact incremental/decremental k-NN regression state (paper Section 8.1).

``core.regression.fit`` precomputes, per training point, the k nearest
neighbour labels (ordered nearest-first), the k-th neighbour distance and
label — the statistics behind the O(1)-per-point ``ab_optimized`` update.
This module maintains those statistics *online*: ``observe`` learns one
point and ``evict`` forgets one, both keeping every derived quantity
**bit-identical** to ``regression.fit`` refit-from-scratch on the live
window (property-tested in ``tests/test_regression_stream.py``).

The trick is the same as ``serving/session.py`` for classification: keep
the live pairwise-distance matrix ``D`` (one row+column per ``observe`` —
the row is needed for the online p-value anyway), so decremental removal
backfills k-best lists from stored exact distances instead of re-deriving
them. Bit-exactness additionally needs three invariants special to the
regression measure, where neighbour *labels* (not just distances) enter
the scores:

* ``nbr_d``/``nbr_y`` store each point's k nearest distances and labels in
  ``fit``'s exact order (ascending distance, ties toward the lower index:
  a new arrival carries the largest index, so it is inserted strictly
  below equal distances — a stable argsort with the candidate appended
  last reproduces ``top_k``'s tie rule);
* the label attached to a BIG (missing-neighbour) slot of row i is
  ``y_i`` — exactly what ``fit`` produces at window size n == k, where the
  only BIG entry in a row is its own masked diagonal;
* distance rows/columns are computed with the very ``kops.sq_dists``
  expression ``fit`` uses, which is bitwise row-decomposable and padding-
  invariant on the supported backends (checked by the property tests).

All arrays are capacity-padded and fixed-shape, so every update is one
jit-stable dispatch and vmaps across tenants (``repro.regression.engine``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.regression import BIG, KnnRegState
from repro.kernels import ops as kops


def _dist_row(x, X):
    """Euclidean distances from ``x`` to every row of ``X``.

    Must stay the exact expression ``regression._dists`` lowers to for one
    row — streaming bit-exactness vs ``fit`` rests on it.
    """
    return jnp.sqrt(jnp.maximum(kops.sq_dists(x[None], X)[0], 0.0))


@jax.tree_util.register_pytree_node_class
@dataclass
class RegStreamState:
    """Capacity-padded streaming k-NN regression state.

    Rows ``[0, n)`` are live in arrival order. Inert rows hold zeros in
    ``X``/``y`` (zero rows keep ``sq_dists`` padding-invariant) and BIG in
    ``D``/``nbr_d``; ``D`` is BIG on the diagonal, mirroring ``fit``'s
    self-exclusion mask.
    """

    X: jnp.ndarray  # (cap, p)
    y: jnp.ndarray  # (cap,)
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere
    nbr_d: jnp.ndarray  # (cap, k) k nearest distances, ascending
    nbr_y: jnp.ndarray  # (cap, k) their labels, same order
    n: jnp.ndarray  # () live count

    def tree_flatten(self):
        return ((self.X, self.y, self.D, self.nbr_d, self.nbr_y,
                 self.n), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]

    @property
    def k(self) -> int:
        return self.nbr_d.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32) -> RegStreamState:
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return RegStreamState(
        X=jnp.zeros((capacity, p), dtype=dtype),
        y=jnp.zeros((capacity,), dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
        nbr_d=jnp.full((capacity, k), BIG, dtype=dtype),
        nbr_y=jnp.zeros((capacity, k), dtype=dtype),
        n=jnp.zeros((), dtype=jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def state_view(state: RegStreamState, *, k) -> KnnRegState:
    """The capacity-padded ``KnnRegState`` this stream state encodes.

    Live rows carry exactly ``regression.fit``'s bits (once n >= k);
    inert rows are garbage and must be masked by the reader. Jitted on
    purpose: ``fit`` computes ``a_prime`` inside jit, and XLA's fused
    sum/divide/subtract rounds differently from the eager op-by-op
    dispatch — bit-parity needs the same compilation path.
    """
    a_prime = state.y - jnp.sum(state.nbr_y, axis=1) / k
    return KnnRegState(state.X, state.y, a_prime,
                       state.nbr_d[:, -1], state.nbr_y[:, -1])


@functools.partial(jax.jit, static_argnames=("k",))
def observe(state: RegStreamState, x_new, y_new, *, k):
    """Learn one example in O(cap k): the paper's incremental update.

    Returns ``(new_state, d_row)`` — ``d_row`` is the (cap,) vector of
    distances from ``x_new`` to each live row (BIG on inert rows), for
    callers that price the point before learning it (``session.observe``).
    Precondition: n < capacity (callers grow or evict first).
    """
    cap = state.capacity
    idx = state.n
    live = jnp.arange(cap) < state.n
    y_new = jnp.asarray(y_new, state.y.dtype)

    d = _dist_row(x_new, state.X)
    d_row = jnp.where(live, d, BIG)  # BIG at self (idx >= n) and inert
    D = state.D.at[idx, :].set(d_row).at[:, idx].set(d_row)

    # existing rows: the new point enters row i's k-NN list iff d < kth
    # (strict: ties keep the incumbent, whose index is lower — top_k's rule)
    enters = live & (d < state.nbr_d[:, -1])
    cand_d = jnp.where(enters, d, BIG)
    merged_d = jnp.concatenate([state.nbr_d, cand_d[:, None]], axis=1)
    merged_y = jnp.concatenate(
        [state.nbr_y, jnp.full((cap, 1), y_new, state.nbr_y.dtype)], axis=1)
    # stable sort with the candidate appended last == insert after equal
    # distances (the candidate's index is the largest) — fit's tie order
    order = jnp.argsort(merged_d, axis=1, stable=True)
    nbr_d = jnp.take_along_axis(merged_d, order, axis=1)[:, :k]
    nbr_y = jnp.take_along_axis(merged_y, order, axis=1)[:, :k]

    # the new row's own list: top_k over its distance row (BIG at self),
    # exactly fit's per-row computation
    y2 = state.y.at[idx].set(y_new)
    own_neg, own_idx = jax.lax.top_k(-d_row, k)
    own_d = -own_neg
    own_y = y2[own_idx]
    # missing-neighbour slots carry the row's own label (fit convention:
    # at n == k the one BIG entry is the masked self-diagonal)
    own_y = jnp.where(own_d >= BIG, y_new, own_y)
    nbr_y = jnp.where(nbr_d >= BIG, state.y[:, None], nbr_y)

    new_state = RegStreamState(
        X=state.X.at[idx].set(x_new),
        y=y2,
        D=D,
        nbr_d=nbr_d.at[idx].set(own_d),
        nbr_y=nbr_y.at[idx].set(own_y),
        n=state.n + 1,
    )
    return new_state, d_row


@functools.partial(jax.jit, static_argnames=("k",))
def evict(state: RegStreamState, i, *, k) -> RegStreamState:
    """Forget live row ``i`` in O(cap^2) worst case: decremental update.

    Only rows whose k-NN list contained the evicted point are touched;
    each is recomputed from the stored exact distances, so the result is
    bit-exact vs refitting on the remaining window. Rows above ``i`` are
    compacted down by one (arrival order preserved, so top_k's
    lower-index-first tie rule keeps matching ``fit`` on the window).
    ``i`` may be traced. Precondition: 0 <= i < n (callers guard; under
    vmap+select the skipped lanes compute discarded garbage).
    """
    cap = state.capacity
    i = jnp.asarray(i, jnp.int32)
    live = jnp.arange(cap) < state.n

    # rows whose list held the evicted point: d(r, i) <= kth. The evicted
    # index may sit anywhere, so on ties we cannot tell membership from
    # the distance alone — recompute conservatively (recompute is exact).
    dcol = state.D[:, i]
    affected = live & (dcol <= state.nbr_d[:, -1])

    # compact rows > i down by one (gather; index cap-1 maps to itself and
    # is overwritten by the inert fill below)
    perm = jnp.arange(cap) + (jnp.arange(cap) >= i)
    perm = jnp.minimum(perm, cap - 1)
    n2 = state.n - 1
    live2 = jnp.arange(cap) < n2

    Xs = jnp.where(live2[:, None], state.X[perm], 0.0)
    ys = jnp.where(live2, state.y[perm], 0.0)
    Ds = state.D[perm][:, perm]
    Ds = jnp.where(live2[:, None] & live2[None, :], Ds, BIG)
    nbr_ds = jnp.where(live2[:, None], state.nbr_d[perm], BIG)
    nbr_ys = jnp.where(live2[:, None], state.nbr_y[perm], 0.0)
    aff = live2 & affected[perm]

    # backfill affected rows: exact k-best straight from the stored
    # distances (the diagonal and inert entries are already BIG)
    neg, idxm = jax.lax.top_k(-Ds, k)
    rec_d = -neg
    rec_y = ys[idxm]
    rec_y = jnp.where(rec_d >= BIG, ys[:, None], rec_y)
    return RegStreamState(
        X=Xs, y=ys, D=Ds,
        nbr_d=jnp.where(aff[:, None], rec_d, nbr_ds),
        nbr_y=jnp.where(aff[:, None], rec_y, nbr_ys),
        n=n2,
    )


@functools.partial(jax.jit, static_argnames=("k",))
def evict_oldest(state: RegStreamState, *, k) -> RegStreamState:
    """Sliding-window form: forget the oldest live point (row 0)."""
    return evict(state, 0, k=k)


@functools.partial(jax.jit, static_argnames=("k", "capacity"))
def _replay(X, y, *, k, capacity):
    state = init(capacity, X.shape[1], k, dtype=X.dtype)

    def step(s, xy):
        s2, _ = observe(s, xy[0], xy[1], k=k)
        return s2, None

    state, _ = jax.lax.scan(step, state, (X, y))
    return state


def from_fit(X, y, *, k, capacity: int) -> RegStreamState:
    """Seed a streaming state from batch data by replaying ``observe``.

    One scanned jit (buffers donated across steps, no per-step host
    round-trip) — the incremental construction *is* the fit, bit-exactly,
    so no separate batch loader is needed.
    """
    return _replay(jnp.asarray(X), jnp.asarray(y), k=k,
                   capacity=int(capacity))


__all__ = ["RegStreamState", "init", "state_view", "observe", "evict",
           "evict_oldest", "from_fit"]
