"""Exact incremental/decremental k-NN regression state (paper Section 8.1).

``core.regression.fit`` precomputes, per training point, the k nearest
neighbour labels (ordered nearest-first), the k-th neighbour distance and
label — the statistics behind the O(1)-per-point ``ab_optimized`` update.
This module maintains those statistics *online*: ``observe`` learns one
point and ``evict`` forgets one, both keeping every derived quantity
**bit-identical** to ``regression.fit`` refit-from-scratch on the live
window (property-tested in ``tests/test_regression_stream.py``).

The trick is the same as ``serving/session.py`` for classification: keep
the live pairwise-distance matrix ``D`` (one row+column per ``observe`` —
the row is needed for the online p-value anyway), so decremental removal
backfills k-best lists from stored exact distances instead of re-deriving
them. Storage is the same **ring buffer**: ``head`` names the slot of the
oldest live point, the window occupies slots ``(head + i) % cap``, and
``evict_oldest`` is a head advance plus an O(cap·k) list repair — the
(cap, cap) ``D`` is never positionally compacted. ``aid`` stamps each
slot with a monotone arrival id; it is the tie-break key wherever
arrival order (not slot order) decides between equal distances.
Bit-exactness additionally needs three invariants special to the
regression measure, where neighbour *labels* (not just distances) enter
the scores:

* ``nbr_d``/``nbr_y`` store each point's k nearest distances and labels in
  ``fit``'s exact order (ascending distance, ties toward the *earliest
  arrival*: a new arrival is inserted strictly below equal distances — a
  stable argsort with the candidate appended last reproduces ``top_k``'s
  tie rule once rows are read in arrival order);
* the label attached to a BIG (missing-neighbour) slot of row i is
  ``y_i`` — exactly what ``fit`` produces at window size n == k, where the
  only BIG entry in a row is its own masked diagonal;
* distance rows/columns are computed with the very ``kops.sq_dists``
  expression ``fit`` uses, which is bitwise row-decomposable and padding-
  invariant on the supported backends (checked by the property tests).

Where a computation is arrival-order sensitive (the new point's own
top-k list, whose equal-distance neighbours must be taken oldest-first),
the (cap,) vectors are gathered through ``ring_slots`` into arrival
order first — an O(cap) gather, after which the historic linear-layout
expressions run unchanged and therefore produce the same bits.

All arrays are capacity-padded and fixed-shape, so every update is one
jit-stable dispatch and vmaps across tenants (``repro.regression.engine``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.online import (drop_backfill, next_aid as _next_aid,
                               ring_age, ring_live, ring_mod as _mod_cap,
                               ring_slots)
from repro.core.regression import BIG, KnnRegState
from repro.kernels import ops as kops


@jax.tree_util.register_pytree_node_class
@dataclass
class RegStreamState:
    """Capacity-padded streaming k-NN regression state (ring layout).

    Slots ``(head + i) % cap``, ``i in [0, n)`` are live in arrival
    order. Never-written slots hold zeros in ``X``/``y`` (zero rows keep
    ``sq_dists`` padding-invariant) and BIG in ``D``/``nbr_d``; ``D`` is
    BIG on the diagonal, mirroring ``fit``'s self-exclusion mask. Slots
    that have *left* the window may hold stale finite values — every
    reader masks by ring liveness (or gathers the live window into
    arrival order via ``arrival_view``), never by slot position.
    """

    X: jnp.ndarray  # (cap, p)
    y: jnp.ndarray  # (cap,)
    D: jnp.ndarray  # (cap, cap) live pairwise distances, BIG elsewhere
    nbr_d: jnp.ndarray  # (cap, k) k nearest distances, ascending
    nbr_y: jnp.ndarray  # (cap, k) their labels, same order
    n: jnp.ndarray  # () live count
    head: jnp.ndarray  # () slot of the oldest live point (ring start)
    aid: jnp.ndarray  # (cap,) per-slot arrival ids (monotone at insert)
    wrap: jnp.ndarray  # () ring modulus (<= cap; slots >= wrap inert)
    nbr_a: jnp.ndarray  # (cap, k) the neighbours' arrival ids (0 at BIG)

    def tree_flatten(self):
        return ((self.X, self.y, self.D, self.nbr_d, self.nbr_y,
                 self.n, self.head, self.aid, self.wrap,
                 self.nbr_a), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.D.shape[-1]

    @property
    def k(self) -> int:
        return self.nbr_d.shape[-1]


def init(capacity: int, p: int, k: int, dtype=jnp.float32,
         wrap: int | None = None) -> RegStreamState:
    """Fresh empty state. ``wrap`` (default: the capacity) is the ring
    modulus — a sliding engine whose window statically bounds occupancy
    confines the ring to the leading ``[:wrap]`` block of every leaf."""
    if capacity < k:
        raise ValueError(
            f"capacity {capacity} < k {k}: the k-best machinery (top_k) "
            "needs at least k rows")
    return RegStreamState(
        X=jnp.zeros((capacity, p), dtype=dtype),
        y=jnp.zeros((capacity,), dtype=dtype),
        D=jnp.full((capacity, capacity), BIG, dtype=dtype),
        nbr_d=jnp.full((capacity, k), BIG, dtype=dtype),
        nbr_y=jnp.zeros((capacity, k), dtype=dtype),
        n=jnp.zeros((), dtype=jnp.int32),
        head=jnp.zeros((), dtype=jnp.int32),
        aid=jnp.zeros((capacity,), dtype=jnp.int32),
        wrap=jnp.asarray(capacity if wrap is None else wrap, jnp.int32),
        nbr_a=jnp.zeros((capacity, k), dtype=jnp.int32),
    )


def _merge_aid(nbr_d_pre, nbr_a, cand_d, new_aid, merged_d):
    """Mirror the kernel's ordered k-best merge on the arrival-id lists.

    The kernel (``kops.stream_update``) merges the candidate into the
    distance/label lists; the id rider replays the same branch-free
    insert from the pre-merge distances: ``pos = #{j : L[j] <= c}``
    places the candidate strictly after equal values, every slot below
    keeps its id, the insert slot takes the new point's id, everything
    above shifts. BIG (missing-neighbour) slots carry the neutral id 0.
    """
    k = nbr_d_pre.shape[1]
    pos = jnp.sum((nbr_d_pre <= cand_d[:, None]).astype(jnp.int32),
                  axis=1, keepdims=True)
    cols = jnp.arange(k)[None, :]
    Ash = jnp.concatenate([nbr_a[:, :1], nbr_a[:, :k - 1]], axis=1)
    newA = jnp.where(cols < pos, nbr_a,
                     jnp.where(cols == pos,
                               jnp.asarray(new_aid, jnp.int32), Ash))
    return jnp.where(merged_d >= BIG, 0, newA)


def _arrival_leaves(state: RegStreamState):
    """(X, y, nbr_d, nbr_y) gathered into arrival order with the linear
    layout's inert fills (0 / 0 / BIG / 0) beyond ``n`` — bit-identical
    to the historic positional storage, stale slots scrubbed. O(cap·p)
    gathers; ``D`` is deliberately excluded (the read paths never touch
    it, and its gather is the O(cap^2) cost the ring layout avoids)."""
    cap = state.capacity
    slots = ring_slots(cap, state.head, state.wrap)
    live = jnp.arange(cap) < state.n
    X = jnp.where(live[:, None], state.X[slots], 0.0)
    y = jnp.where(live, state.y[slots], 0.0)
    nbr_d = jnp.where(live[:, None], state.nbr_d[slots], BIG)
    nbr_y = jnp.where(live[:, None], state.nbr_y[slots], 0.0)
    return X, y, nbr_d, nbr_y


def arrival_view(state: RegStreamState) -> RegStreamState:
    """The state with every O(cap) leaf in arrival order (head == 0).

    ``D`` is passed through untouched (still ring-indexed!) — callers of
    this view are the read paths, which never consult ``D``. For a full
    linear normalization including ``D`` use ``to_linear``."""
    X, y, nbr_d, nbr_y = _arrival_leaves(state)
    cap = state.capacity
    slots = ring_slots(cap, state.head, state.wrap)
    live = jnp.arange(cap) < state.n
    return RegStreamState(X, y, state.D, nbr_d, nbr_y, state.n,
                          jnp.zeros((), jnp.int32),
                          jnp.where(live, state.aid[slots], 0),
                          jnp.int32(cap),
                          jnp.where(live[:, None], state.nbr_a[slots], 0))


@jax.jit
def to_linear(state: RegStreamState) -> RegStreamState:
    """Full linear-layout normalization, ``D`` included (O(cap^2) gather).

    Leaf-for-leaf bit-identical (arrival ids included: the absolute
    counters are preserved, since the neighbour-id lists ``nbr_a``
    reference them by value) to the same stream served through the
    historic linear layout — the equivalence the exactness tests
    assert. Used by ``grow`` and the tests, never on the serving
    tick."""
    view = arrival_view(state)
    cap = state.capacity
    slots = ring_slots(cap, state.head, state.wrap)
    live = jnp.arange(cap) < state.n
    D = jnp.where(live[:, None] & live[None, :],
                  state.D[slots][:, slots], BIG)
    return RegStreamState(view.X, view.y, D, view.nbr_d, view.nbr_y,
                          state.n, view.head, view.aid, view.wrap,
                          view.nbr_a)


def arrival_stats(state: RegStreamState, *, k):
    """Arrival-ordered (X, y, a_prime, upd, kth, kth_label, live) — the
    one shared gather behind every regression read path.

    The per-row derived statistics are computed *in slot space* on the
    raw leaves — the exact expressions of the historic linear path and
    of ``fit`` — and only then gathered into arrival order. The
    optimization barrier between the arithmetic and the gather pins the
    fusion boundary: XLA compiles the reduce+divide+subtract chain in
    its own small computation (the shape in which its accumulation
    order matches ``fit``'s — a big consumer graph can re-vectorize the
    reduce and round odd lanes 1 ulp apart), and the gathers after the
    barrier are bit-preserving moves. This is what keeps the served
    reads bit-identical to the batch path regardless of the surrounding
    graph (session jit or the engine's mapped jit). Rows beyond ``n``
    carry the linear layout's inert fills.
    """
    cap = state.capacity
    a_prime_s = state.y - jnp.sum(state.nbr_y, axis=1) / k
    upd_s = a_prime_s + state.nbr_y[:, -1] / k
    a_prime_s, upd_s = jax.lax.optimization_barrier((a_prime_s, upd_s))
    slots = ring_slots(cap, state.head, state.wrap)
    live = jnp.arange(cap) < state.n
    X = jnp.where(live[:, None], state.X[slots], 0.0)
    y = jnp.where(live, state.y[slots], 0.0)
    a_prime = jnp.where(live, a_prime_s[slots], 0.0)
    upd = jnp.where(live, upd_s[slots], 0.0)
    kth = jnp.where(live, state.nbr_d[:, -1][slots], BIG)
    kth_label = jnp.where(live, state.nbr_y[:, -1][slots], 0.0)
    return X, y, a_prime, upd, kth, kth_label, live


@functools.partial(jax.jit, static_argnames=("k",))
def state_view(state: RegStreamState, *, k) -> KnnRegState:
    """The capacity-padded ``KnnRegState`` this stream state encodes.

    Rows come out in arrival order (ring gathered); live rows carry
    exactly ``regression.fit``'s bits (once n >= k); rows beyond ``n``
    are inert fills and must be masked by the reader. Jitted on
    purpose: ``fit`` computes ``a_prime`` inside jit, and XLA's fused
    sum/divide/subtract rounds differently from the eager op-by-op
    dispatch — bit-parity needs the same compilation path; see
    ``arrival_stats`` for why the stats are computed in slot space
    behind an optimization barrier.
    """
    X, y, a_prime, _, kth_d, kth_y, _ = arrival_stats(state, k=k)
    return KnnRegState(X, y, a_prime, kth_d, kth_y)


def _own_list(state: RegStreamState, d_row, y2, y_new, *, k):
    """The new point's own (distances, labels) k-NN list, plus the
    arrival-order top-k index set that produced it.

    ``fit`` breaks equal-distance ties toward the earliest arrival, so
    the top_k must run over the distance row in *arrival* order — under
    the ring layout that is a gather through ``ring_slots``, with labels
    masked to the linear path's inert 0 beyond ``n`` (garbage labels of
    stale slots must not leak into the degenerate n < k sums).
    Returns ``(own_d, own_y, y_sel, own_a)`` where ``y_sel`` are the
    selected *pre-learn* labels (the pricing path's ``a`` statistic) and
    ``own_a`` the selected neighbours' arrival ids (0 at BIG slots).
    """
    cap = state.capacity
    slots = ring_slots(cap, state.head, state.wrap)
    pos_live = jnp.arange(cap) < state.n
    # the explicit mask scrubs rank >= wrap alias positions; at ranks in
    # [n, wrap) the gathered row is already BIG, so this is bit-neutral
    d_arr = jnp.where(pos_live, d_row[slots], BIG)
    y_arr = jnp.where(pos_live, y2[slots], 0.0)
    y_pre = jnp.where(pos_live, state.y[slots], 0.0)
    a_arr = jnp.where(pos_live, state.aid[slots], 0)
    own_neg, own_idx = jax.lax.top_k(-d_arr, k)
    own_d = -own_neg
    own_y = y_arr[own_idx]
    # missing-neighbour slots carry the row's own label (fit convention:
    # at n == k the one BIG entry is the masked self-diagonal) and the
    # neutral arrival id 0
    own_y = jnp.where(own_d >= BIG, y_new, own_y)
    own_a = jnp.where(own_d >= BIG, 0, a_arr[own_idx]).astype(jnp.int32)
    return own_d, own_y, y_pre[own_idx], own_a


def _observe(state: RegStreamState, x_new, y_new, *, k):
    """Learn one example in O(cap k): the paper's incremental update.

    Returns ``(new_state, d_row)`` — ``d_row`` is the (cap,) vector of
    distances from ``x_new`` to each live slot (BIG elsewhere), for
    callers that price the point before learning it (``session.observe``).
    The new point lands at ring slot ``(head + n) % wrap``.
    Precondition: n < wrap (callers grow or evict first).
    """
    cap = state.capacity
    idx = _mod_cap(state.head + state.n, state.wrap)
    y_new = jnp.asarray(y_new, state.y.dtype)

    # fused distance row + gated ordered merge into every live row's
    # (nbr_d, nbr_y) list — one Pallas pass on TPU; the CPU/f64 reference
    # is expression-identical to the historic inline code (strict d < kth
    # gate, stable-argsort insert-after-equals tie rule, BIG slots carry
    # the row's own label), so streaming bits vs ``fit`` are unchanged
    d_row, nbr_d, nbr_y = kops.stream_update(
        state.X, state.y, state.nbr_d, state.nbr_y, x_new, y_new,
        state.n, mode="reg", head=state.head, wrap=state.wrap)
    new_aid = _next_aid(state.aid, state.head, state.n, state.wrap)
    live = ring_live(cap, state.head, state.n, state.wrap)
    enters = live & (d_row < state.nbr_d[:, -1])
    cand_d = jnp.where(enters, d_row, BIG)
    nbr_a = _merge_aid(state.nbr_d, state.nbr_a, cand_d, new_aid, nbr_d)
    # one row + one column of D: under a donating jit these two updates
    # lower to in-place dynamic-update-slices — O(cap) HBM traffic, not
    # an O(cap^2) copy of the matrix
    D = state.D.at[idx, :].set(d_row).at[:, idx].set(d_row)

    y2 = state.y.at[idx].set(y_new)
    own_d, own_y, _, own_a = _own_list(state, d_row, y2, y_new, k=k)

    new_state = RegStreamState(
        X=state.X.at[idx].set(x_new),
        y=y2,
        D=D,
        nbr_d=nbr_d.at[idx].set(own_d),
        nbr_y=nbr_y.at[idx].set(own_y),
        n=state.n + 1,
        head=state.head,
        aid=state.aid.at[idx].set(new_aid),
        wrap=state.wrap,
        nbr_a=nbr_a.at[idx].set(own_a),
    )
    return new_state, d_row


observe = functools.partial(jax.jit, static_argnames=("k",))(_observe)
#: ``observe`` whose input state is donated: the capacity-padded buffers
#: (most importantly the (cap, cap) ``D``) are updated in place instead of
#: copied. The input state is DELETED by the call — reusing it afterwards
#: raises ``RuntimeError: Array has been deleted``. Numerics are identical
#: to ``observe``.
observe_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_observe)


def _evict(state: RegStreamState, i, *, k) -> RegStreamState:
    """Forget the i-th *oldest* live point in O(cap^2) worst case.

    Only rows whose k-NN list contained the evicted point are touched;
    each is recomputed from the stored exact distances, so the result is
    bit-exact vs refitting on the remaining window. The general arbitrary
    -index form keeps the historic full recompute: the survivors are
    gathered into linear arrival order (one O(cap^2) permutation of
    ``D`` — arbitrary mid-window forgetting has no O(cap) repair), so
    the output is a normalized head == 0 state. ``i`` counts arrival
    rank (0 = oldest) and may be traced. Precondition: 0 <= i < n
    (callers guard; under vmap+select the skipped lanes compute
    discarded garbage).
    """
    cap = state.capacity
    i = jnp.asarray(i, jnp.int32)
    slot_i = _mod_cap(state.head + i, state.wrap)

    # rows whose list held the evicted point: d(r, i) <= kth. The evicted
    # point may sit anywhere in arrival order, so on ties we cannot tell
    # membership from the distance alone — recompute conservatively
    # (recompute is exact).
    dcol = state.D[:, slot_i]
    affected = (ring_live(cap, state.head, state.n, state.wrap)
                & (dcol <= state.nbr_d[:, -1]))

    # survivor slots in arrival order, rank i dropped (gather; the last
    # rank maps to itself and is overwritten by the inert fill below)
    ar = jnp.arange(cap, dtype=jnp.int32)
    ar = jnp.minimum(ar + (ar >= i), cap - 1)
    slots = ring_slots(cap, state.head, state.wrap)[ar]
    n2 = state.n - 1
    live2 = jnp.arange(cap) < n2

    Xs = jnp.where(live2[:, None], state.X[slots], 0.0)
    ys = jnp.where(live2, state.y[slots], 0.0)
    Ds = state.D[slots][:, slots]
    Ds = jnp.where(live2[:, None] & live2[None, :], Ds, BIG)
    nbr_ds = jnp.where(live2[:, None], state.nbr_d[slots], BIG)
    nbr_ys = jnp.where(live2[:, None], state.nbr_y[slots], 0.0)
    nbr_as = jnp.where(live2[:, None], state.nbr_a[slots], 0)
    aids = jnp.where(live2, state.aid[slots], 0)
    aff = live2 & affected[slots]

    # backfill affected rows: exact k-best straight from the stored
    # distances (the diagonal and inert entries are already BIG); rows
    # are now in arrival order, so top_k's lowest-index tie rule IS
    # fit's earliest-arrival rule
    neg, idxm = jax.lax.top_k(-Ds, k)
    rec_d = -neg
    rec_y = ys[idxm]
    rec_y = jnp.where(rec_d >= BIG, ys[:, None], rec_y)
    rec_a = jnp.where(rec_d >= BIG, 0, aids[idxm]).astype(jnp.int32)
    return RegStreamState(
        X=Xs, y=ys, D=Ds,
        nbr_d=jnp.where(aff[:, None], rec_d, nbr_ds),
        nbr_y=jnp.where(aff[:, None], rec_y, nbr_ys),
        n=n2,
        head=jnp.zeros((), jnp.int32),
        aid=aids,
        wrap=jnp.int32(cap),
        nbr_a=jnp.where(aff[:, None], rec_a, nbr_as),
    )


evict = functools.partial(jax.jit, static_argnames=("k",))(_evict)
#: Donating form of ``evict`` — same numerics, input state deleted.
evict_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict)


def _evict_oldest(state: RegStreamState, *, k) -> RegStreamState:
    """Sliding-window form: forget the oldest live point, O(cap).

    Specialization of ``evict`` that skips both the full top_k recompute
    *and* any positional movement: the evicted point has the EARLIEST
    arrival, so on distance ties it sorts first — if it is in a row's
    k-NN list at all it occupies the first slot holding its distance,
    and the repair is an O(k) drop + one backfill. The backfill value
    comes by multiset rank over the stored distances, and its *label*
    is the next-earliest-arrival candidate at that distance, arrival
    order read from the stored ``aid``s (``core.online.drop_backfill``)
    — exactly fit's ties-toward-earliest order, so the result stays
    bit-exact vs refit (property-tested). The ring head then advances:
    no leaf is shifted, the stale slot is simply masked out of every
    later read.
    Precondition: n >= 1 (guarded by callers; under vmap+select the n=0
    lanes compute garbage that the caller's select discards).
    """
    cap = state.capacity
    head = state.head
    dcol = state.D[:, head]
    kth = state.nbr_d[:, -1]
    head2 = _mod_cap(head + 1, state.wrap)
    n2 = state.n - 1
    live2 = ring_live(cap, head2, n2, state.wrap)  # survivors only
    affected = live2 & (dcol <= kth)

    cand = live2[None, :]  # self-distances are BIG on the diagonal
    nbr_d2, nbr_y2, nbr_a2 = drop_backfill(
        state.nbr_d, dcol, cand, state.D, affected, k=k,
        Ly=state.nbr_y, La=state.nbr_a, ys=state.y, aid=state.aid,
        age=ring_age(cap, head2, state.wrap),
        slots=ring_slots(cap, head2, state.wrap), aid0=state.aid[head])
    return RegStreamState(
        X=state.X, y=state.y, D=state.D, nbr_d=nbr_d2, nbr_y=nbr_y2,
        n=n2, head=head2, aid=state.aid, wrap=state.wrap, nbr_a=nbr_a2)


evict_oldest = functools.partial(
    jax.jit, static_argnames=("k",))(_evict_oldest)
#: Donating form of ``evict_oldest`` — same numerics, input deleted.
evict_oldest_donated = functools.partial(
    jax.jit, static_argnames=("k",), donate_argnums=(0,))(_evict_oldest)


@functools.partial(jax.jit, static_argnames=("k", "capacity"))
def _replay(X, y, *, k, capacity):
    state = init(capacity, X.shape[1], k, dtype=X.dtype)

    def step(s, xy):
        s2, _ = observe(s, xy[0], xy[1], k=k)
        return s2, None

    state, _ = jax.lax.scan(step, state, (X, y))
    return state


def from_fit(X, y, *, k, capacity: int) -> RegStreamState:
    """Seed a streaming state from batch data by replaying ``observe``.

    One scanned jit (buffers donated across steps, no per-step host
    round-trip) — the incremental construction *is* the fit, bit-exactly,
    so no separate batch loader is needed.
    """
    return _replay(jnp.asarray(X), jnp.asarray(y), k=k,
                   capacity=int(capacity))


__all__ = ["RegStreamState", "init", "state_view", "arrival_stats",
           "observe",
           "observe_donated", "evict", "evict_donated", "evict_oldest",
           "evict_oldest_donated", "from_fit", "arrival_view", "to_linear"]
