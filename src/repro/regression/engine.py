"""Micro-batching multi-tenant streaming regression-CP engine.

The regression counterpart of ``serving.engine.ServingEngine``: many
per-tenant ``RegStreamState``s stacked into one pytree (leading axis =
session slot), advanced by a single fixed-shape jitted ``vmap`` step per
tick, and served by a single vmapped dispatch that returns prediction
intervals for every tenant at once.

Usage::

    from repro.regression import RegressionServingEngine

    eng = RegressionServingEngine(n_sessions=64, capacity=256, dim=16,
                                  k=7, window=128)
    state = eng.init_state()
    for t in range(T):
        state, pvals = eng.observe(state, x_t, y_t, tau_t)  # (64,) smoothed
    # or: T ticks in ONE dispatch (xs: (T, 64, 16), ys/taus: (T, 64))
    state, pvals = eng.observe_many(state, xs, ys, taus)    # (T, 64)
    iv = eng.intervals(state, x_query, epsilon=0.1)  # (64, m, 2)

Per-session state is bit-identical to feeding that session's stream
through ``regression.stream`` alone, which in turn is bit-identical to
``regression.fit`` refit-from-scratch on the live window (tested); the
interval read path routes through the fused Pallas kernel on TPU. The
per-tick ``observe`` p-values (each tenant's observed label against its
current window) feed the same exchangeability martingales as the
classification engine — streaming drift detection for regression tenants.

As in ``serving.engine``, the observe path is O(cap) per tick: the
jitted step donates its input state (the (S, cap, cap) distance
matrices update in place — the input ``state`` is consumed; pass
``donate=False`` for copy semantics), and ``observe_many`` amortizes
dispatch overhead by scanning a whole chunk of ticks under one jit
(``observe`` is its T=1 case; both bit-neutral, property-tested).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import engine_utils
from repro.regression import session as sess_m
from repro.regression.stream import RegStreamState


class RegressionServingEngine:
    """Fixed-slot, fixed-shape multi-tenant regression-CP engine.

    Parameters
    ----------
    n_sessions: number of tenant slots (the micro-batch width).
    capacity:   per-session padded training capacity.
    dim:        feature dimension.
    k:          k-NN neighbourhood size (paper Section 8.1 measure).
    window:     sliding-window length (<= capacity); None => grow mode
                (capacity doubles when full instead of evicting).
    donate:     donate the input state to the jitted observe step (the
                O(cap) in-place path). The state passed to ``observe`` /
                ``observe_many`` is deleted by the call; reuse raises.
                ``False`` restores copy semantics (input stays valid).
    layout:     "ring" (default) — circular row indexing; a sliding tick
                evicts by advancing the per-session head pointer, so the
                (cap, cap) distance matrices are never shifted/copied.
                "compact" — the historic positional layout (O(cap^2)
                eviction traffic); kept as the benchmark baseline and
                the exactness oracle, bit-identical to "ring".
    instrument: attach telemetry (``repro.telemetry``): per-op latency
                histograms + trace records, and in-graph per-tick device
                counters (evictions / ring wraps / occupancy) folded
                into a lazy accumulator — drain with
                ``engine.telemetry.drain()``. Bit-identical to the
                uninstrumented engine (tested); ``metrics`` / ``tracer``
                / ``sync_timing`` as in ``serving.engine.ServingEngine``.
    shards:     partition the session axis across this many devices
                (``core.distributed.tenant_mesh``): state leaves get a
                tenant-sharded ``NamedSharding`` and every dispatch runs
                shard_map'd, one program per device with zero
                cross-device collectives — bit-identical to the
                single-device vmap (tested). ``n_sessions`` must divide
                evenly; pad with inactive lanes otherwise.
    """

    def __init__(self, *, n_sessions: int, capacity: int, dim: int, k: int,
                 window: int | None = None, dtype=jnp.float32,
                 donate: bool = True, layout: str = "ring",
                 instrument: bool = False, metrics=None, tracer=None,
                 sync_timing: bool = False, shards: int = 1):
        if window is not None and window > capacity:
            raise ValueError(f"window {window} exceeds capacity {capacity}")
        if window is not None and window < 1:
            raise ValueError("window must be >= 1")
        if capacity < k:
            raise ValueError(f"capacity {capacity} < k {k}")
        if layout not in ("ring", "compact"):
            raise ValueError(f"unknown layout {layout!r}")
        if shards > 1 and n_sessions % shards != 0:
            raise ValueError(
                f"n_sessions {n_sessions} not divisible by shards {shards};"
                " pad with inactive lanes"
                " (core.distributed.pad_tenant_count)")
        self.shards = shards
        self._mesh = None
        if shards > 1:
            from repro.core import distributed as dist
            self._mesh = dist.tenant_mesh(shards)
        self.n_sessions = n_sessions
        self.capacity = capacity
        self.dim = dim
        self.k = k
        self.window = window
        self.dtype = dtype
        self.donate = donate
        self.layout = layout
        # the fused sliding step: evict-if-full + observe + active mask
        # in one pass; grow mode (window=None) statically drops the
        # eviction machinery. A sliding window statically bounds
        # occupancy, so the tick runs on the [:window] block of every
        # leaf with ring modulus == window (cost scales with the window,
        # not the padded capacity) — observe_many verifies the
        # occupancy + ring-modulus invariants once per externally
        # supplied state.
        wmax = None if window is None else max(min(window, capacity), k)
        step_fn = (sess_m._sliding_step if layout == "ring"
                   else sess_m._sliding_step_compact)
        step = functools.partial(step_fn, k=k,
                                 evictable=window is not None, wmax=wmax)
        self._wmax = wmax
        self._w_checked = False
        self.telemetry = None
        if instrument:
            from repro.telemetry import EngineTelemetry
            self.telemetry = EngineTelemetry(
                engine="regression", metrics=metrics, tracer=tracer,
                sync=sync_timing,
                n_of=lambda s: s.n, head_of=lambda s: s.head,
                wrap_of=lambda s: s.wrap)
        vstep = jax.vmap(step, in_axes=(0, 0, 0, 0, 0, 0))
        chunk = engine_utils.scan_chunk(
            vstep, self.telemetry.stats_fn if instrument else None)
        # lax.map, not vmap: the scanned body keeps the exact per-session
        # graph, so served reads stay bit-identical to the single-session
        # path (vmap re-batches the distance GEMMs and count reductions,
        # which round differently at large capacities)
        pvals = lambda st, xt, tq: jax.lax.map(
            lambda args: sess_m.pvalues(args[0], args[1], tq, k=k),
            (st, xt))
        ivals = lambda st, xt, eps: jax.lax.map(
            lambda args: sess_m.intervals(args[0], args[1], k=k,
                                          epsilon=eps), (st, xt))
        if self._mesh is not None:
            from repro.core import distributed as dist
            chunk = dist.shard_tenant_chunk(chunk, self._mesh,
                                            with_stats=instrument)
            pvals = dist.shard_tenant_fn(pvals, self._mesh,
                                         (True, True, False))
            ivals = dist.shard_tenant_fn(ivals, self._mesh,
                                         (True, True, False))
        self._step_many = jax.jit(
            chunk, donate_argnums=(0,) if donate else ())
        self._pvalues = jax.jit(pvals)
        self._intervals = jax.jit(ivals)
        self._n_bound: int | None = None

    # -- state --------------------------------------------------------------

    def init_state(self) -> RegStreamState:
        """Stacked RegStreamState with a leading (n_sessions,) axis.

        Sliding engines confine every session's ring to the
        ``[:window]`` leaf block (``wrap == wmax``); grow mode uses the
        full capacity as the modulus (the ring never wraps there)."""
        one = sess_m.init(self.capacity, self.dim, self.k,
                          dtype=self.dtype, wrap=self._wmax)
        state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (self.n_sessions,) + a.shape),
            one)
        return self._shard_state(state)

    def _shard_state(self, state: RegStreamState) -> RegStreamState:
        """Lay the stacked state out tenant-sharded across the mesh."""
        if self._mesh is None:
            return state
        from repro.core import distributed as dist
        return dist.put_tenant_sharded(state, self._mesh)

    def taus(self, key) -> jnp.ndarray:
        """One tie-breaking uniform per session slot for this tick."""
        return jax.random.uniform(key, (self.n_sessions,), dtype=self.dtype)

    def _windows(self, state: RegStreamState) -> jnp.ndarray:
        cap = state.capacity
        w = cap + 1 if self.window is None else self.window  # +1: never evict
        return jnp.full((self.n_sessions,), w, dtype=jnp.int32)

    # -- serving ------------------------------------------------------------

    def observe(self, state: RegStreamState, x, y, tau, active=None):
        """One micro-batched tick: learn (x[s], y[s]) in every active slot.

        x: (S, dim); y: (S,); tau: (S,) tie-break uniforms; active: (S,)
        bool (default all). Returns (state, pvalues (S,)) — the smoothed
        online p-value of each observed label, NaN on inactive slots. In
        grow mode, auto-doubles capacity first if any session is full
        (host-side sync + retrace, O(log n) times total). The T=1 case
        of ``observe_many`` (bit-identical, tested); with ``donate=True``
        (default) the input ``state`` is consumed.
        """
        if active is None:
            active = jnp.ones((self.n_sessions,), dtype=bool)
        state, p = self._dispatch(
            state, x[None], y[None], tau[None], active[None], op="observe")
        return state, p[0]

    def observe_many(self, state: RegStreamState, xs, ys, taus,
                     active=None):
        """A chunk of T micro-batched ticks in ONE jitted dispatch.

        xs: (T, S, dim); ys: (T, S); taus: (T, S); active: (T, S) bool
        (default all). Returns (state, pvalues (T, S)) — tick t's row is
        bit-identical to calling ``observe`` T times (the chunk is a
        ``lax.scan`` over the same per-tick step; property-tested). In
        grow mode the whole chunk's worst-case occupancy is provisioned
        up front (capacity doubles until ``n + T <= cap``), so the scan
        never needs a mid-chunk host sync. With ``donate=True`` the
        input ``state`` is consumed.
        """
        if active is None:
            active = jnp.ones(xs.shape[:2], dtype=bool)
        return self._dispatch(state, xs, ys, taus, active,
                              op="observe_many")

    def _dispatch(self, state: RegStreamState, xs, ys, taus, active, *,
                  op: str):
        """The shared observe/observe_many dispatch (telemetry-aware)."""
        state = engine_utils.ensure_room(self, state, xs.shape[0],
                                         lambda s: s.n)
        engine_utils.check_window_occupancy(self, state, lambda s: s.n,
                                            lambda s: s.wrap)
        args = (state, xs, ys.astype(self.dtype), taus.astype(self.dtype),
                self._windows(state), active)
        if self.telemetry is None:
            return self._step_many(*args)
        T, S = xs.shape[:2]
        with self.telemetry.timed(op, signature=(xs.shape, self.capacity),
                                  ticks=T, tenants=S,
                                  capacity=self.capacity) as tm:
            state, (p, stats) = self._step_many(*args)
            tm.sync(p)
        self.telemetry.ticks.fold(stats)
        return state, p

    def lower_tick(self, ticks: int = 4):
        """Lower (but do NOT execute) a ``ticks``-long observe_many chunk.

        Returns the ``jax.stages.Lowered`` for the engine's compiled
        step on a zeros example batch — the artifact the static auditor
        (``repro.analysis.audit``) inspects for donation aliasing,
        collective-freedom and dense-materialization budgets. Tracing
        only: engine state and jit caches are untouched beyond the
        cache entry the first real tick would create anyway.
        """
        state = self.init_state()
        S, T = self.n_sessions, ticks
        xs = jnp.zeros((T, S, self.dim), self.dtype)
        ys = jnp.zeros((T, S), self.dtype)
        taus = jnp.zeros((T, S), self.dtype)
        active = jnp.ones((T, S), dtype=bool)
        return self._step_many.lower(state, xs, ys, taus,
                                     self._windows(state), active)

    def reset_occupancy(self) -> None:
        """Forget the host-side occupancy bound (grow mode) and the
        window-invariant check; the next ``observe`` re-syncs/re-checks
        from device."""
        self._n_bound = None
        self._w_checked = False

    def grow(self, state: RegStreamState, factor: int = 2) -> RegStreamState:
        """Double every session's capacity (host-side, preserves state).

        Session-level grow normalizes each ring to linear order with a
        full-capacity modulus; a sliding engine pins the modulus back to
        its window block (the normalized state fits it: head == 0,
        n <= window)."""
        grow_all = jax.vmap(functools.partial(sess_m.grow, factor=factor))
        if self.telemetry is not None:
            with self.telemetry.timed("grow", tenants=self.n_sessions,
                                      capacity=self.capacity * factor,
                                      signature=self.capacity):
                out = grow_all(state)
        else:
            out = grow_all(state)
        self.capacity = out.capacity
        if self._wmax is not None:
            out = RegStreamState(out.X, out.y, out.D, out.nbr_d, out.nbr_y,
                                 out.n, out.head, out.aid,
                                 jnp.full_like(out.wrap, self._wmax),
                                 out.nbr_a)
        return self._shard_state(out)

    def intervals(self, state: RegStreamState, X_test,
                  epsilon: float) -> jnp.ndarray:
        """Prediction intervals per session: (S, m, 2), one dispatch.

        X_test: (S, m, dim) per-session query batch, or (m, dim) broadcast
        to every session; ``epsilon`` is traced (no recompile per level).
        Inside the single jitted call the fused kernel (Pallas on TPU)
        computes distances + score updates + critical points; the hull
        sweep finishes per test point.
        """
        if X_test.ndim == 2:
            X_test = jnp.broadcast_to(
                X_test, (self.n_sessions,) + X_test.shape)
        eps = jnp.asarray(epsilon, self.dtype)
        if self.telemetry is None:
            return self._intervals(state, X_test, eps)
        with self.telemetry.timed("intervals",
                                  signature=(X_test.shape, self.capacity),
                                  tenants=self.n_sessions,
                                  capacity=self.capacity) as tm:
            return tm.sync(self._intervals(state, X_test, eps))

    def pvalues(self, state: RegStreamState, X_test,
                t_query) -> jnp.ndarray:
        """P-values at query labels per session: (S, m, nq), one dispatch."""
        if X_test.ndim == 2:
            X_test = jnp.broadcast_to(
                X_test, (self.n_sessions,) + X_test.shape)
        if self.telemetry is None:
            return self._pvalues(state, X_test, t_query)
        with self.telemetry.timed("pvalues",
                                  signature=(X_test.shape, self.capacity),
                                  tenants=self.n_sessions,
                                  capacity=self.capacity) as tm:
            return tm.sync(self._pvalues(state, X_test, t_query))

    # -- snapshot -----------------------------------------------------------

    def meta(self) -> dict[str, Any]:
        """JSON-serializable engine config, stored alongside snapshots."""
        return {
            "mode": "regression",
            "n_sessions": self.n_sessions,
            "capacity": self.capacity,
            "dim": self.dim,
            "k": self.k,
            "window": self.window,
            "dtype": jnp.dtype(self.dtype).name,
            "shards": self.shards,
        }

    @classmethod
    def from_meta(cls, meta: dict[str, Any]) -> "RegressionServingEngine":
        meta = dict(meta)
        mode = meta.pop("mode", "regression")
        if mode != "regression":
            raise ValueError(f"not a regression-engine meta: mode={mode!r}")
        meta.pop("n_labels", None)  # tolerate classification-era keys
        meta["dtype"] = jnp.dtype(meta.get("dtype", "float32"))
        # restore sharded only when this host can honour it
        shards = int(meta.pop("shards", 1))
        if (shards > 1 and shards <= jax.device_count()
                and meta["n_sessions"] % shards == 0):
            meta["shards"] = shards
        return cls(**meta)


__all__ = ["RegressionServingEngine"]
