"""Streaming full-CP regression (paper Section 8.1, served online).

The batch path in ``repro.core.regression`` fits once and predicts; this
package turns it into a streaming system with the paper's incremental &
decremental updates:

* ``stream``  — capacity-padded ``RegStreamState``: exact ``observe`` /
  ``evict`` that keep the per-point neighbour statistics (``a_prime``,
  ``kth_dist``, ``kth_label``) bit-identical to ``regression.fit`` on the
  live window, by maintaining the live pairwise-distance matrix;
* ``session`` — per-tenant sliding-window session (evict-if-full,
  capacity-doubling growth) + the padded read paths: prediction
  ``intervals`` and p-values, routed through the fused
  ``kernels/interval_sweep`` Pallas kernel on TPU;
* ``engine``  — ``RegressionServingEngine``: one vmapped jitted step
  advances every tenant, one vmapped dispatch serves every tenant's
  prediction intervals.
"""
from repro.regression.engine import RegressionServingEngine
from repro.regression.stream import RegStreamState

__all__ = ["RegressionServingEngine", "RegStreamState"]
