"""Feed-forward layers: gated MLP (SwiGLU/GeGLU) and token-choice MoE.

MoE uses sort-based capacity dispatch (no (T, E, C) one-hot tensors):

    1. top-k router -> (T*K,) flat expert assignments,
    2. stable argsort by expert id groups slots contiguously,
    3. position-in-group ranks computed with a cumsum over sorted ids;
       slots past the per-expert capacity C are dropped (standard
       token-choice overflow semantics),
    4. gather expert inputs to (E, C, D), run the batched expert FFN
       (one einsum over the expert dim -> shards cleanly as EP or TP),
    5. scatter-add weighted outputs back to token order.

Compute is C*E = K*capacity_factor*T expert-token FFNs — the compiled
FLOPs stay proportional to *active* parameters, which is what the roofline
table's MODEL_FLOPS/HLO_FLOPs column checks. Expert tensors are (E, D, F)
so the expert dim shards over "model" (EP, deepseek: 160 experts / 16) or
F shards over "model" (TP, mixtral: 8 experts < 16-way axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import act_fn, dense_init
from repro.sharding.activation import BATCH_AXES, constrain

_HIDDEN_TP = (BATCH_AXES, None, "model")  # MLP hidden shards over model


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(p, x, act: str = "silu"):
    x = constrain(x, (BATCH_AXES, None, None))  # SP all-gather
    g = act_fn(act)(constrain(
        jnp.einsum("bsd,df->bsf", x, p["w_gate"]), _HIDDEN_TP))
    u = constrain(jnp.einsum("bsd,df->bsf", x, p["w_up"]), _HIDDEN_TP)
    return jnp.einsum("bsf,fd->bsd", g * u, p["w_down"])


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, mo.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (mo.n_experts, d, mo.d_ff), dtype),
        "w_up": dense_init(ks[2], (mo.n_experts, d, mo.d_ff), dtype),
        "w_down": dense_init(ks[3], (mo.n_experts, mo.d_ff, d), dtype),
    }
    if mo.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, mo.d_ff * mo.n_shared_experts, dtype)
    return p


def moe_dense_mixture(p, x, cfg: ArchConfig):
    """Small-E MoE without dispatch: every token runs EVERY expert; the
    router's top-k mask weights the combine. E/K x more FLOPs than
    dispatch, but zero gather/scatter/sort collectives — at E = 8 on a
    256-chip mesh this trades a 732 s collective wall for 25 s of extra
    MXU time (EXPERIMENTS.md §Perf mixtral iteration 2). Outputs are
    exactly token-choice top-k (no capacity drops)."""
    mo = cfg.moe
    x = constrain(x, (BATCH_AXES, None, None))
    B, S, D = x.shape
    E, K = mo.n_experts, mo.n_experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # scatter normalized weights back to (B, S, E)
    combine = jnp.sum(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32)
        * top_p[..., None], axis=-2)  # (B, S, E)

    density = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                       axis=(0, 1, 2)) * E
    me = jnp.mean(probs, axis=(0, 1))
    aux = jnp.mean(density * me * E) * mo.router_aux_coef

    hid_spec = (None, BATCH_AXES, None, "model")
    g = act_fn(cfg.act)(constrain(
        jnp.einsum("bsd,edf->ebsf", x, p["w_gate"]), hid_spec))
    u = constrain(jnp.einsum("bsd,edf->ebsf", x, p["w_up"]), hid_spec)
    y = jnp.einsum("ebsf,efd->ebsd", g * u, p["w_down"])  # (E, B, S, D)
    out = jnp.einsum("ebsd,bse->bsd", y,
                     combine.astype(y.dtype))
    if mo.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg.act)
    return out, aux


def _dispatch_groups() -> int:
    """Number of dispatch groups = data-parallel shards of the active mesh
    (1 when no mesh context: tests/examples single-device path)."""
    from repro.sharding.activation import _ACTIVE

    ctx = _ACTIVE.get()
    if ctx is None:
        return 1
    import math

    axes = [a for a in ("pod", "data") if a in ctx["sizes"]]
    return math.prod(ctx["sizes"][a] for a in axes)


def moe(p, x, cfg: ArchConfig, decode: bool = False):
    """Token-choice top-k MoE. x: (B, S, D) -> (out, aux_loss).

    Dispatch is GROUP-LOCAL (groups = the data-parallel shards, the GShard
    formulation): each group's tokens route into per-group expert slots
    (G, E, C_g, D) whose G dim shards over data and E dim over model — so
    the sort/gather/scatter never crosses shards, expert compute is local,
    and the only cross-shard traffic is the output psum over "model"
    (+ the slot transport XLA derives). Per-group capacity C_g = T_g*K*cf/E
    (standard group-capacity semantics; with G=1 this reduces exactly to
    global dispatch, which is what the CPU tests exercise)."""
    mo = cfg.moe
    part = (mo.partition_decode or mo.partition) if decode \
        else mo.partition
    if part == "dense":
        return moe_dense_mixture(p, x, cfg)
    x = constrain(x, (BATCH_AXES, None, None))  # SP all-gather
    B, S, D = x.shape
    G = _dispatch_groups()
    if B % G:
        G = 1
    T = B * S // G  # tokens per group
    K = mo.n_experts_per_token
    E = mo.n_experts
    cap = max(1, int(T * K * mo.capacity_factor / E))

    xg = x.reshape(G, T, D)
    xg = constrain(xg, (BATCH_AXES, None, None))
    out_g, aux = _grouped_dispatch(p, xg, cfg, part, cap)
    out = out_g.reshape(B, S, D)
    if mo.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg.act)
    return out, aux


def _grouped_dispatch(p, xg, cfg: ArchConfig, part: str, cap: int):
    """xg: (G, T, D) group-sharded tokens -> (G, T, D), aux."""
    mo = cfg.moe
    G, T, D = xg.shape
    K, E = mo.n_experts_per_token, mo.n_experts

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (G, T, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                       axis=(0, 1, 2)) * E
    me = jnp.mean(probs, axis=(0, 1))
    aux = jnp.mean(density * me * E) * mo.router_aux_coef

    def one_group(xt, tp, te):
        return _dispatch_one(xt, tp, te, E, K, cap, xg.dtype)

    slot_tok, slot_w = jax.vmap(one_group)(xg, top_p, top_e)
    # (G, E*cap+1) each; expert inputs (G, E, cap, D)
    pad = jnp.zeros((G, 1, D), xg.dtype)
    xt_pad = jnp.concatenate([xg, pad], axis=1)
    x_exp = jnp.take_along_axis(
        xt_pad, slot_tok[:, :-1, None].astype(jnp.int32), axis=1)
    x_exp = x_exp.reshape(G, E, cap, D)

    exp_spec = ((BATCH_AXES, "model", None, None) if part == "ep"
                else (BATCH_AXES, None, None, None))
    hid_spec = ((BATCH_AXES, "model", None, None) if part == "ep"
                else (BATCH_AXES, None, None, "model"))
    x_exp = constrain(x_exp, exp_spec)

    g_ = act_fn(cfg.act)(constrain(
        jnp.einsum("gecd,edf->gecf", x_exp, p["w_gate"]), hid_spec))
    u = constrain(jnp.einsum("gecd,edf->gecf", x_exp, p["w_up"]), hid_spec)
    y_exp = constrain(
        jnp.einsum("gecf,efd->gecd", g_ * u, p["w_down"]), exp_spec)

    y_flat = y_exp.reshape(G, E * cap, D) * slot_w[:, :-1, None]
    out = jnp.zeros((G, T + 1, D), xg.dtype)
    out = jax.vmap(lambda o, st, yf: o.at[st].add(yf))(
        out, slot_tok[:, :-1].astype(jnp.int32), y_flat)[:, :T]
    return constrain(out, (BATCH_AXES, None, None)), aux


def _dispatch_one(xt, top_p, top_e, E, K, cap, dtype):
    """Per-group sort-based slot assignment. Returns (slot_tok, slot_w)
    each (E*cap + 1,) with the last entry the trash slot."""
    T = xt.shape[0]
    flat_e = top_e.reshape(-1)  # (T*K,)
    flat_w = top_p.reshape(-1).astype(dtype)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert bucket: global position minus prior-bucket sizes
    counts = jnp.bincount(flat_e, length=E)
    bucket_start = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * K) - bucket_start[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, E * cap)  # E*cap = trash
    slot_tok = jnp.full((E * cap + 1,), T, dtype=jnp.int32)
    slot_tok = slot_tok.at[slot].set(flat_tok[order].astype(jnp.int32))
    slot_w = jnp.zeros((E * cap + 1,), dtype).at[slot].set(flat_w[order])
    return slot_tok, slot_w


__all__ = ["init_mlp", "mlp", "init_moe", "moe", "moe_dense_mixture"]
