"""Top-level language models: decoder-only LM and encoder-decoder (audio).

Covers all ten assigned architectures through one code path driven by
``ArchConfig``:

* decoder-only (gemma3 / granite / qwen* / mixtral / deepseek / internvl2
  backbone / recurrentgemma / xlstm): token embed (+ optional stub patch
  embeds for the VLM), run-grouped layer stack, final norm, (tied) LM head.
* encoder-decoder (whisper): stub frame embeddings -> non-causal encoder;
  decoder = self-attn + cross-attn + FFN blocks with a separate cache.

Exposes the three lowered entry points of the dry-run: ``train_step_loss``
(the loss whose grad the launcher jits), ``prefill_logits`` and
``decode_step``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_m
from repro.models import blocks as blk
from repro.models import mlp as mlp_m
from repro.models.common import (apply_rope, dense_init, embed_init,
                                 rms_norm, sinusoidal_positions)
from repro.sharding.activation import BATCH_AXES, constrain

Z_LOSS_COEF = 1e-4


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ArchConfig) -> dict:
    dtype = _pdtype(cfg)
    ks = jax.random.split(key, 8)
    params = {
        # 1/sqrt(d) scale keeps tied-head logits ~unit variance at init
        # (gemma-style input embed_scale multiplies sqrt(d) back on lookup)
        "embed": embed_init(ks[0], (cfg.padded_vocab_size, cfg.d_model),
                            dtype) * (cfg.d_model ** -0.5),
        "layers": blk.init_layer_stack(ks[1], cfg, dtype),
        "final_norm": blk._norm_params(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            ks[2], (cfg.d_model, cfg.padded_vocab_size), dtype)
    if cfg.is_encoder_decoder:
        params["encoder"] = init_encoder(ks[3], cfg, dtype)
        params["cross"] = init_cross_stack(ks[4], cfg, dtype)
        # learned decoder positions sized for the largest assigned shape
        # (32k prefill/decode) — the backbone spec governs, not whisper's
        # 448-token context
        params["pos_embed_dec"] = embed_init(
            ks[5], (32_768, cfg.d_model), dtype) * 0.02
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ArchConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    # batch over (pod, data); sequence over data when batch can't shard
    x = constrain(x, (BATCH_AXES, None, None))
    return x


def lm_logits(params, cfg: ArchConfig, x):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.padded_vocab_size != cfg.vocab_size:
        # pad ids exist only to make the vocab shardable; never predicted
        pad_mask = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                           logits)
    # keep the f32-bound logits vocab-sharded: without this constraint the
    # partitioner can replicate the (B, S, V) tensor (13+ GiB/device at 50k
    # vocab before the CE reduce) — see EXPERIMENTS.md §Perf iteration 0
    return constrain(logits, (BATCH_AXES, None, "model"))


# ---------------------------------------------------------------------------
# decoder-only forward
# ---------------------------------------------------------------------------


def hidden_forward(params, cfg: ArchConfig, batch, *,
                   want_states: bool = False):
    """Trunk only: embed -> layer stack -> final norm. Returns (h, aux,
    states) with h: (B, S, D)."""
    x = embed_tokens(params, cfg, batch["tokens"])
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux, states = blk.apply_stack_full(
        params["layers"], x, cfg, positions, want_states=want_states)
    x = blk.apply_norm(params["final_norm"], x, cfg)
    return x, aux, states


def forward(params, cfg: ArchConfig, batch, *, want_states: bool = False):
    """batch: {"tokens": (B, S_txt)} (+ "patch_embeds" (B, Np, D) for vlm).

    Returns (logits (B, S, V), aux, states).
    """
    x, aux, states = hidden_forward(params, cfg, batch,
                                    want_states=want_states)
    return lm_logits(params, cfg, x), aux, states


def cross_entropy(logits, labels, mask=None):
    """Token CE with z-loss. Vocab-shard-friendly: the gold logit comes from
    an iota==label masked reduce (partitions as a local reduce + tiny
    all-reduce) instead of take_along_axis (which would all-gather the f32
    logits across the vocab shards — a 13 GiB/device temp at 50k vocab)."""
    logits_f = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits_f, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, len(logits.shape) - 1)
    gold = jnp.sum(
        jnp.where(vocab_ids == labels[..., None], logits_f, 0.0), axis=-1)
    nll = lse - gold
    z = Z_LOSS_COEF * lse ** 2
    per_tok = nll + z
    if mask is None:
        return jnp.mean(per_tok)
    mask = mask.astype(jnp.float32)
    return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# past this many logit elements, the loss runs in sequence chunks so the
# f32 (B, S, V) tensor never materializes (~1.6 GiB/device at 4k x 48k)
_CE_CHUNK_LIMIT = 64 * 1024 * 1024
_CE_CHUNK = 512


def chunked_cross_entropy(params, cfg: ArchConfig, h, labels, mask=None):
    """CE computed per sequence chunk; exact same value as the dense path."""
    B, S, D = h.shape
    c = min(_CE_CHUNK, S)
    pad = (-S) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nc = (S + pad) // c
    hc = jnp.moveaxis(h.reshape(B, nc, c, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, c), 1, 0)

    def chunk(carry, inp):
        tot, cnt = carry
        hx, lx, mx = inp
        logits = lm_logits(params, cfg, hx).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        vocab_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(
            jnp.where(vocab_ids == lx[..., None], logits, 0.0), axis=-1)
        per_tok = (lse - gold + Z_LOSS_COEF * lse ** 2) * mx
        return (tot + jnp.sum(per_tok), cnt + jnp.sum(mx)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def train_step_loss(params, cfg: ArchConfig, batch):
    """Scalar loss for one batch; grads of this are the train step."""
    if cfg.is_encoder_decoder:
        logits, aux = forward_encdec(params, cfg, batch)
        return cross_entropy(logits, batch["labels"],
                             batch.get("mask")) + aux
    h, aux, _ = hidden_forward(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        # patch positions carry no next-token loss
        npz = batch["patch_embeds"].shape[1]
        h = h[:, npz:]
    if h.shape[0] * h.shape[1] * cfg.padded_vocab_size > _CE_CHUNK_LIMIT:
        return chunked_cross_entropy(params, cfg, h, labels, mask) + aux
    return cross_entropy(lm_logits(params, cfg, h), labels, mask) + aux


# ---------------------------------------------------------------------------
# decoding
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    caches = blk.init_stack_cache(cfg, batch, max_len, _dtype(cfg))
    if cfg.is_encoder_decoder:
        n_enc = cfg.n_frontend_tokens or 1500
        kv_shape = (cfg.n_layers, batch, n_enc, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
        cross = {"k": jnp.zeros(kv_shape, _dtype(cfg)),
                 "v": jnp.zeros(kv_shape, _dtype(cfg))}
        return {"self": caches, "cross": cross}
    return {"self": caches}


def decode_step(params, cfg: ArchConfig, tokens, cache, index):
    """One new token against a filled cache. tokens: (B, 1) int32.

    Returns (logits (B, 1, V), new_cache).
    """
    x = embed_tokens(params, cfg, tokens)
    if cfg.is_encoder_decoder:
        x = x + params["pos_embed_dec"][index][None, None, :].astype(x.dtype)
        x, new_self = decode_encdec_body(params, cfg, x, cache, index)
        new_cache = {"self": new_self, "cross": cache["cross"]}
    else:
        x, new_self = blk.apply_stack_decode(
            params["layers"], x, cfg, cache["self"], index)
        new_cache = {"self": new_self}
    x = blk.apply_norm(params["final_norm"], x, cfg)
    return lm_logits(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# encoder-decoder (whisper-style; conv/audio frontend is a stub: the batch
# carries precomputed frame embeddings)
# ---------------------------------------------------------------------------


def init_encoder(key, cfg: ArchConfig, dtype) -> list:
    enc_cfg = cfg.replace(layer_pattern=("attn",) * cfg.n_encoder_layers,
                          n_layers=cfg.n_encoder_layers)
    return blk.init_layer_stack(key, enc_cfg, dtype)


def init_cross_stack(key, cfg: ArchConfig, dtype) -> dict:
    """Per-decoder-layer cross-attention params, stacked."""
    def one(k):
        ks = jax.random.split(k, 2)
        return {"ln": blk._norm_params(cfg, dtype),
                "attn": attn_m.init_attention(ks[0], cfg, dtype)}

    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(one)(keys)


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, T, D) stub embeddings -> encoder output (B, T, D)."""
    x = frames.astype(_dtype(cfg))
    T = x.shape[1]
    x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)[None]
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x, _, _ = blk.apply_stack_full(params["encoder"], x, cfg, positions,
                                   causal=False)
    return x


def _cross_attention(p, x, k, v, cfg: ArchConfig):
    """x: (B, Sq, D) queries; k/v: (B, Skv, Kv, hd) from the encoder."""
    from repro.kernels import ops as kops

    h = blk.apply_norm(p["ln"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    if cfg.qkv_bias:
        q = q + p["attn"]["bq"]
    out = kops.flash_attention(q, k, v, causal=False)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])


def _cross_kv(p, enc_out, cfg: ArchConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["attn"]["wv"])
    if cfg.qkv_bias:
        k = k + p["attn"]["bk"]
        v = v + p["attn"]["bv"]
    return k, v


def forward_encdec(params, cfg: ArchConfig, batch):
    """Full teacher-forced encoder-decoder pass (train/prefill)."""
    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    B, S, _ = x.shape
    x = x + params["pos_embed_dec"][:S][None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    # decoder: self-attn block then cross-attn, per layer (scanned)
    def body(h, layer_in):
        self_p, cross_p = layer_in
        h, _, _ = blk.apply_block_full(self_p, h, cfg, "attn", positions)
        k, v = _cross_kv(cross_p, enc_out, cfg)
        h = _cross_attention(cross_p, h, k, v, cfg)
        return h, None

    assert len(params["layers"]) == 1, "encdec decoder must be one run"
    x, _ = jax.lax.scan(
        blk._remat(body, cfg), x,
        (params["layers"][0], params["cross"]))
    x = blk.apply_norm(params["final_norm"], x, cfg)
    return lm_logits(params, cfg, x), jnp.zeros((), jnp.float32)


def prefill_cross_cache(params, cfg: ArchConfig, frames):
    """Encoder pass + per-layer cross K/V (the decode-time constant)."""
    enc_out = encode(params, cfg, frames)
    k, v = jax.vmap(lambda p: _cross_kv(p, enc_out, cfg))(params["cross"])
    return {"k": k, "v": v}  # stacked (L, B, T, Kv, hd)


def decode_encdec_body(params, cfg: ArchConfig, x, cache, index):
    def body(h, layer_in):
        self_p, cross_p, self_c, ck, cv = layer_in
        h, c2 = blk.apply_block_decode(self_p, h, cfg, "attn", self_c, index)
        h = _cross_attention(cross_p, h, ck, cv, cfg)
        return h, c2

    x, new_self = jax.lax.scan(
        body, x,
        (params["layers"][0], params["cross"],
         cache["self"][0], cache["cross"]["k"], cache["cross"]["v"]))
    return x, [new_self]


__all__ = ["init_lm", "forward", "forward_encdec", "train_step_loss",
           "cross_entropy", "init_cache", "decode_step",
           "prefill_cross_cache", "encode", "embed_tokens", "lm_logits"]
