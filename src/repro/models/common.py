"""Shared model-building primitives: init, norms, rotary embeddings, acts.

Parameters are plain nested dicts of jnp arrays (no framework dependency) so
the same trees flow through pjit sharding rules, the checkpointer, and the
optimizer without adapters. Initializers take an explicit PRNG key path via
``fold_in`` so layer stacking (vmap'd init) stays deterministic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Param = jnp.ndarray


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (scale defaults to 1/sqrt(fan_in))."""
    import math

    fan_in = math.prod(shape[:-1]) if len(shape) >= 2 else (
        shape[0] if shape else 1)
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6, *, offset: float = 0.0):
    """RMSNorm in f32 accumulation; gemma-style (1 + w) via offset=1."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (offset + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    """Inverse frequencies for RoPE, (head_dim // 2,) f32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotate pairs (x[..., ::2], x[..., 1::2]).

    x: (B, S, H, D); positions: (B, S) int32. f32 math, cast back.
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, d/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int):
    """Standard sin/cos table (n_pos, dim) — whisper encoder positions."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2.0 * idx / dim))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def softcap(logits, cap: float | None):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


__all__ = ["dense_init", "embed_init", "rms_norm", "layer_norm", "act_fn",
           "rope_frequencies", "apply_rope", "sinusoidal_positions",
           "softcap"]
