"""Transformer-family block assembly and the run-grouped layer stack.

A model is a sequence of layers, each of one *kind*:

    attn            full-attention transformer block (+ MoE if configured)
    attn_local      sliding-window attention block
    dense_ffn_attn  attention + dense FFN even in MoE models (deepseek L0)
    rglru           Griffin recurrent block + MLP
    mlstm / slstm   xLSTM blocks (self-contained, no separate FFN)

Consecutive layers of the same kind form a *run*; a run's parameters are
stacked on a leading axis and applied with lax.scan (remat'd per layer).
This keeps compile time O(#runs), not O(#layers) — gemma3's 5-local:1-global
pattern becomes alternating scans of 5 and 1; granite's 88 identical layers
one scan of 88. Caches stack the same way and thread through the scan as
per-layer xs/ys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_m
from repro.models import mlp as mlp_m
from repro.models import recurrent as rec_m
from repro.models.common import layer_norm, rms_norm
from repro.sharding.activation import (BATCH_AXES, constrain,
                                       grad_compressed_boundary)

ATTN_KINDS = ("attn", "attn_local", "dense_ffn_attn")

# Megatron-style sequence parallelism: the residual stream between blocks
# lives sharded (batch x dp, seq x model); XLA inserts the all-gather before
# attention/MLP and the reduce-scatter after. This is what keeps the
# 88-layer scan's saved carries at S/tp instead of S per device
# (EXPERIMENTS.md §Dry-run: 200 GiB -> single-digit GiB on granite-34b).
SP_SPEC = (BATCH_AXES, "model", None)


def _norm_params(cfg: ArchConfig, dtype):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    w = (jnp.zeros if cfg.rms_offset else jnp.ones)((cfg.d_model,), dtype)
    return {"w": w}


def apply_norm(p, x, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps, offset=cfg.rms_offset)


# ---------------------------------------------------------------------------
# per-kind init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ArchConfig, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": _norm_params(cfg, dtype)}
    if kind in ATTN_KINDS:
        if cfg.mla is not None:
            p["attn"] = attn_m.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_m.init_attention(ks[0], cfg, dtype)
        p["ln2"] = _norm_params(cfg, dtype)
        if cfg.moe.n_experts and kind != "dense_ffn_attn":
            p["moe"] = mlp_m.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_m.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.post_norms:
            p["post_attn"] = _norm_params(cfg, dtype)
            p["post_mlp"] = _norm_params(cfg, dtype)
    elif kind == "rglru":
        p["rec"] = rec_m.init_rglru_block(ks[0], cfg, dtype)
        p["ln2"] = _norm_params(cfg, dtype)
        p["mlp"] = mlp_m.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "mlstm":
        p["block"] = rec_m.init_mlstm_block(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["block"] = rec_m.init_slstm_block(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype):
    if kind in ATTN_KINDS:
        if cfg.mla is not None:
            return attn_m.init_mla_cache(cfg, batch, max_len, dtype)
        eff = min(max_len, cfg.window) if kind == "attn_local" and cfg.window \
            else max_len
        # sliding-window layers never need more than `window` cache slots;
        # keep full length for simplicity of indexing (ring buffers are a
        # perf iteration, EXPERIMENTS.md §Perf)
        del eff
        return attn_m.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return rec_m.init_rglru_state(cfg, batch, dtype)
    if kind == "mlstm":
        return rec_m.init_mlstm_state(cfg, batch, dtype)
    if kind == "slstm":
        return rec_m.init_slstm_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# per-kind apply
# ---------------------------------------------------------------------------


def _attn_kwargs(cfg: ArchConfig, kind: str):
    window = cfg.window if kind == "attn_local" else 0
    theta = cfg.rope_theta_local if kind == "attn_local" else cfg.rope_theta
    return window, theta


def apply_block_full(p, x, cfg: ArchConfig, kind: str, positions,
                     causal: bool = True):
    """Train/prefill block application. Returns (x, aux, state_out)."""
    aux = jnp.zeros((), jnp.float32)
    state_out = None
    x = constrain(x, SP_SPEC)
    # bf16 + SP-layout pinned cotangent at the block boundary
    # (EXPERIMENTS.md §Perf granite iteration 3)
    x = grad_compressed_boundary(x, SP_SPEC)
    if kind in ATTN_KINDS:
        window, theta = _attn_kwargs(cfg, kind)
        h = apply_norm(p["ln1"], x, cfg)
        if cfg.mla is not None:
            a = attn_m.mla_full(p["attn"], h, cfg, positions=positions,
                                theta=theta)
        else:
            a = attn_m.attention_full(p["attn"], h, cfg, positions=positions,
                                      window=window, causal=causal,
                                      theta=theta)
        if cfg.post_norms:
            a = apply_norm(p["post_attn"], a, cfg)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg)
        if "moe" in p:
            f, aux = mlp_m.moe(p["moe"], h, cfg)
        else:
            f = mlp_m.mlp(p["mlp"], h, cfg.act)
        if cfg.post_norms:
            f = apply_norm(p["post_mlp"], f, cfg)
        x = x + f
    elif kind == "rglru":
        h = apply_norm(p["ln1"], x, cfg)
        r, state_out = rec_m.rglru_block_full(p["rec"], h, cfg)
        x = x + r
        h = apply_norm(p["ln2"], x, cfg)
        x = x + mlp_m.mlp(p["mlp"], h, cfg.act)
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x, cfg)
        r, state_out = rec_m.mlstm_block_full(p["block"], h, cfg)
        x = x + r
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x, cfg)
        r, state_out = rec_m.slstm_block_full(p["block"], h, cfg)
        x = x + r
    else:
        raise ValueError(kind)
    x = constrain(x, SP_SPEC)  # reduce-scatter back to the SP layout
    return x, aux, state_out


def apply_block_decode(p, x, cfg: ArchConfig, kind: str, cache, index):
    """One-token decode. Returns (x, new_cache)."""
    if kind in ATTN_KINDS:
        window, theta = _attn_kwargs(cfg, kind)
        h = apply_norm(p["ln1"], x, cfg)
        if cfg.mla is not None:
            a, cache = attn_m.mla_decode(p["attn"], h, cfg, cache, index,
                                         theta=theta)
        else:
            a, cache = attn_m.attention_decode(p["attn"], h, cfg, cache,
                                               index, window=window,
                                               theta=theta)
        if cfg.post_norms:
            a = apply_norm(p["post_attn"], a, cfg)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg)
        if "moe" in p:
            f, _ = mlp_m.moe(p["moe"], h, cfg, decode=True)
        else:
            f = mlp_m.mlp(p["mlp"], h, cfg.act)
        if cfg.post_norms:
            f = apply_norm(p["post_mlp"], f, cfg)
        x = x + f
    elif kind == "rglru":
        h = apply_norm(p["ln1"], x, cfg)
        r, cache = rec_m.rglru_block_step(p["rec"], h, cfg, cache)
        x = x + r
        h = apply_norm(p["ln2"], x, cfg)
        x = x + mlp_m.mlp(p["mlp"], h, cfg.act)
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x, cfg)
        r, cache = rec_m.mlstm_block_step(p["block"], h, cfg, cache)
        x = x + r
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x, cfg)
        r, cache = rec_m.slstm_block_step(p["block"], h, cfg, cache)
        x = x + r
    else:
        raise ValueError(kind)
    return x, cache


# ---------------------------------------------------------------------------
# runs: group consecutive identical kinds, scan each group
# ---------------------------------------------------------------------------


def pattern_runs(pattern) -> list[tuple[str, int]]:
    runs = []
    for kind in pattern:
        if runs and runs[-1][0] == kind:
            runs[-1] = (kind, runs[-1][1] + 1)
        else:
            runs.append((kind, 1))
    return runs


def init_layer_stack(key, cfg: ArchConfig, dtype) -> list:
    """Per-run stacked params (leading axis = run length). The run *kinds*
    are static — recovered from ``pattern_runs(cfg.pattern)`` at apply time —
    so the returned list is a pure array pytree (jit/grad/checkpoint safe)."""
    stacks = []
    layer_idx = 0
    for kind, length in pattern_runs(cfg.pattern):
        keys = jax.random.fold_in(key, layer_idx)
        run_keys = jax.random.split(keys, length)
        params = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype))(run_keys)
        stacks.append(params)
        layer_idx += length
    return stacks


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def apply_stack_full(stacks, x, cfg: ArchConfig, positions,
                     causal: bool = True, want_states: bool = False):
    """Apply all runs (train/prefill). Returns (x, aux_total, states)."""
    aux_total = jnp.zeros((), jnp.float32)
    states = []
    for (kind, length), run_params in zip(pattern_runs(cfg.pattern), stacks):

        def body(carry, layer_params, kind=kind):
            h, aux = carry
            h2, a, st = apply_block_full(layer_params, h, cfg, kind,
                                         positions, causal)
            out = st if want_states else None
            return (h2, aux + a), out

        if cfg.scan_layers:
            (x, aux_total), st_stack = jax.lax.scan(
                _remat(body, cfg), (x, aux_total), run_params)
        else:
            # unrolled (dry-run accounting mode): XLA cost_analysis counts
            # while-loop bodies once, so faithful FLOP/collective totals
            # need every layer in the entry computation
            outs = []
            for i in range(length):
                layer = jax.tree.map(lambda a: a[i], run_params)
                (x, aux_total), st = _remat(body, cfg)((x, aux_total), layer)
                outs.append(st)
            st_stack = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                        if want_states else None)
        states.append(st_stack)
    return x, aux_total, states


def apply_stack_decode(stacks, x, cfg: ArchConfig, caches, index):
    """One-token decode through all runs. caches: list aligned with stacks."""
    new_caches = []
    for (kind, _), run_params, cache in zip(
            pattern_runs(cfg.pattern), stacks, caches):

        def body(h, layer_in, kind=kind):
            layer_params, layer_cache = layer_in
            h2, c2 = apply_block_decode(layer_params, h, cfg, kind,
                                        layer_cache, index)
            return h2, c2

        x, c_out = jax.lax.scan(body, x, (run_params, cache))
        new_caches.append(c_out)
    return x, new_caches


def init_stack_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Stacked caches, one entry per run."""
    caches = []
    for kind, length in pattern_runs(cfg.pattern):
        one = init_block_cache(cfg, kind, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (length,) + a.shape), one))
    return caches


__all__ = ["init_block", "apply_block_full", "apply_block_decode",
           "pattern_runs", "init_layer_stack", "apply_stack_full",
           "apply_stack_decode", "init_stack_cache", "init_block_cache",
           "apply_norm", "ATTN_KINDS"]
