"""Attention layers: GQA/MQA (+qk-norm, bias, sliding window, softcap), MLA.

Three compute paths, selected by workload:

* full-sequence (train/prefill): ``repro.kernels.ops.flash_attention`` — the
  Pallas TPU kernel on device, a chunked online-softmax scan in pure jnp
  elsewhere (keeps 32k+ prefill memory bounded at compile time too).
* decode: one query position against a preallocated KV cache ring
  (dense masked einsum — memory-bound, no kernel needed).
* MLA (DeepSeek-V2): low-rank KV. Train uses the unabsorbed form (standard
  MHA over decompressed K/V); decode uses the absorbed form, attending in
  the 512-dim latent space so the cache is (kv_lora + rope) per token —
  the architecture's reason to exist.

Caches are plain dicts of arrays so they shard/checkpoint like params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models.common import apply_rope, dense_init, rms_norm, softcap
from repro.sharding.activation import BATCH_AXES, constrain

NEG_INF = -1e30

# tensor-parallel layouts: heads shard over "model" (falling back to nothing
# when the head count doesn't divide — MQA K/V stay replicated, the standard
# Megatron treatment). These constraints are what stop the partitioner from
# keeping sequence sharding through attention and replicating the weights
# instead (EXPERIMENTS.md §Perf iteration 1).
_HEADS_TP = (BATCH_AXES, None, "model", None)


@jax.custom_vjp
def _barrier(x):
    """Differentiable ``optimization_barrier``.

    jax 0.4.x ships no differentiation rule for the primitive; the intent
    (stop fusion across the gather boundary) applies to the backward
    reduce-scatter just the same, so the VJP barriers the cotangent.
    """
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, kv, hd), dtype),
        "wv": dense_init(ks[2], (d, kv, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h, qk), dtype),
        "wkv_a": dense_init(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h, m.qk_nope_head_dim),
                           dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h, m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (h, m.v_head_dim, d), dtype),
    }


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# standard GQA attention
# ---------------------------------------------------------------------------


def _project_qkv(p, x, cfg: ArchConfig, positions, theta):
    # explicit Megatron-SP all-gather: replicate the sequence dim BEFORE the
    # projections so the einsums keep the *weights* sharded (backward of
    # this gather is the reduce-scatter; without it the partitioner gathers
    # the weights instead and all-reduces full f32 weight grads). The
    # optimization barrier stops the norm's f32 internals from fusing
    # across the boundary — the gather must move bf16, not f32
    # (EXPERIMENTS.md §Perf granite iteration 3).
    x = _barrier(constrain(x, (BATCH_AXES, None, None)))
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), _HEADS_TP)
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), _HEADS_TP)
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), _HEADS_TP)
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attention_full(p, x, cfg: ArchConfig, *, positions, window: int = 0,
                   causal: bool = True, theta: float = 10_000.0):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    out = kops.flash_attention(
        q, k, v, causal=causal, window=window or None,
        softcap=cfg.attn_logit_softcap or None)
    out = constrain(out, _HEADS_TP)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p, x, cfg: ArchConfig, cache: dict, index,
                     *, window: int = 0, theta: float = 10_000.0):
    """One-token decode. x: (B, 1, D); cache k/v: (B, S_max, Kv, hd).

    Returns (out (B,1,D), new_cache). ``index`` is the number of tokens
    already in the cache (the new token's position).
    """
    B, _, _ = x.shape
    S_max = cache["k"].shape[1]
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, pos, theta)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))

    h, kv = cfg.n_heads, cfg.n_kv_heads
    rep = h // kv
    hd = cfg.resolved_head_dim
    qh = q.reshape(B, kv, rep, hd)  # fold group into q
    logits = jnp.einsum("bgrk,bsgk->bgrs", qh.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * (hd ** -0.5)
    logits = softcap(logits, cfg.attn_logit_softcap or None)
    kpos = jnp.arange(S_max)
    mask = kpos <= index
    if window:
        mask &= kpos > index - window
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bsgk->bgrk", probs, v_cache.astype(jnp.float32))
    out = out.reshape(B, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_full(p, x, cfg: ArchConfig, *, positions,
             theta: float = 10_000.0):
    """Unabsorbed MLA for train/prefill: decompress K/V, run standard MHA."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    x = constrain(x, (BATCH_AXES, None, None))  # SP all-gather (see above)
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(ckv_full[..., :m.kv_lora_rank], p["kv_norm"],
                    cfg.norm_eps)
    k_rope = apply_rope(ckv_full[:, :, None, m.kv_lora_rank:], positions,
                        theta)  # (B,S,1,rope) shared across heads
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])

    qk = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope,
                                  (B, S, h, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # pad v head dim up to qk head dim for the shared kernel, then slice
    pad = qk.shape[-1] - v.shape[-1]
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = kops.flash_attention(qk, kk, vp, causal=True, scale=scale)
    out = out[..., :m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(p, x, cfg: ArchConfig, cache: dict, index,
               *, theta: float = 10_000.0):
    """Absorbed MLA decode: attend in the kv_lora latent space."""
    m = cfg.mla
    B = x.shape[0]
    S_max = cache["c_kv"].shape[1]
    pos = jnp.full((B, 1), index, dtype=jnp.int32)

    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])  # (B,1,H,nope+rope)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], pos, theta)
    # absorb wk_b into the query: q_c = q_nope @ wk_b^T -> latent space
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])  # (B,1,H,rank)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_new = rms_norm(ckv_full[..., :m.kv_lora_rank], p["kv_norm"],
                     cfg.norm_eps)
    kr_new = apply_rope(ckv_full[:, :, None, m.kv_lora_rank:], pos,
                        theta)[:, :, 0, :]
    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, index, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, index, 0))

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bshr,btr->bhst", q_c.astype(jnp.float32),
                         c_cache.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                           kr_cache.astype(jnp.float32))) * scale
    mask = jnp.arange(S_max) <= index
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out_c = jnp.einsum("bhst,btr->bshr", probs,
                       c_cache.astype(jnp.float32))  # (B,1,H,rank)
    out = jnp.einsum("bshr,rhk->bshk", out_c.astype(x.dtype), p["wv_b"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {
        "c_kv": c_cache, "k_rope": kr_cache}


__all__ = ["init_attention", "init_mla", "init_kv_cache", "init_mla_cache",
           "attention_full", "attention_decode", "mla_full", "mla_decode"]
