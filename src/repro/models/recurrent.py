"""Recurrent / SSM-family blocks: RG-LRU (RecurrentGemma), mLSTM, sLSTM.

TPU adaptation notes (DESIGN.md §hardware):

* RG-LRU is a *diagonal* linear recurrence -> chunked evaluation: lax.scan
  over chunks carrying the hidden state, jax.lax.associative_scan within a
  chunk. Memory stays O(B * chunk * W) while the sequential depth drops from
  S to S/chunk (the Griffin paper's own TPU strategy).
* mLSTM uses the stabilized *chunkwise* form: intra-chunk quadratic matmuls
  (MXU-friendly) + an inter-chunk (C, n, m) carry — the linear-attention
  trick that makes the 500k-token cell sub-quadratic.
* sLSTM has a non-linear state->gate dependency, so it is inherently
  sequential: lax.scan over time with per-head recurrent matrices. This is
  the architecture's own constraint, not an implementation shortcut.

All blocks expose (full-sequence, decode-step) pairs with a carried state
dict, mirroring the KV-cache interface of the attention layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init

_CHUNK = 256


# ---------------------------------------------------------------------------
# temporal (causal, depthwise) conv1d
# ---------------------------------------------------------------------------


def init_conv1d(key, width: int, channels: int, dtype) -> dict:
    return {
        "w": dense_init(key, (width, channels), dtype, scale=width ** -0.5),
        "b": jnp.zeros((channels,), dtype),
    }


def conv1d_full(p, x):
    """Causal depthwise conv. x: (B, S, C)."""
    width = p["w"].shape[0]
    out = jnp.zeros_like(x)
    for i in range(width):
        shift = width - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * p["w"][i]
    return out + p["b"]


def conv1d_step(p, x_t, state):
    """x_t: (B, 1, C); state: (B, width-1, C) past inputs."""
    width = p["w"].shape[0]
    window = jnp.concatenate([state, x_t], axis=1)  # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", window, p["w"]) + p["b"]
    return y[:, None, :], window[:, -(width - 1):, :]


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


def init_rglru_block(key, cfg: ArchConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 8)
    # Lambda init so a = sigmoid(lam)^c covers [0.9, 0.999] (Griffin init)
    c = 8.0
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / c) / (1.0 - u ** (1.0 / c)))
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),  # recurrent branch in-proj
        "w_gate_in": dense_init(ks[1], (d, w), dtype),  # gate branch in-proj
        "conv": init_conv1d(ks[2], cfg.conv1d_width, w, dtype),
        "w_rg": dense_init(ks[3], (w, w), dtype),  # recurrence gate
        "b_rg": jnp.zeros((w,), dtype),
        "w_ig": dense_init(ks[4], (w, w), dtype),  # input gate
        "b_ig": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d), dtype),
    }


def _rglru_scan(log_a, gx, h0):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + gx_t, chunked.

    log_a, gx: (B, S, W); h0: (B, W). Returns (h_seq, h_last)."""
    B, S, W = gx.shape
    chunk = min(_CHUNK, S)
    pad = (-S) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    n_chunks = (S + pad) // chunk
    la = log_a.reshape(B, n_chunks, chunk, W).transpose(1, 0, 2, 3)
    gg = gx.reshape(B, n_chunks, chunk, W).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        la_c, g_c = inp  # (B, chunk, W)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 + a2, b2 + jnp.exp(a2) * b1

        la_cum, b_cum = jax.lax.associative_scan(op, (la_c, g_c), axis=1)
        h_seq = jnp.exp(la_cum) * h[:, None, :] + b_cum
        return h_seq[:, -1, :], h_seq

    h_last, hs = jax.lax.scan(chunk_step, h0, (la, gg))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, W)[:, :S]
    return hs, h_last


def rglru_block_full(p, x, cfg: ArchConfig, state=None):
    """Full-sequence Griffin recurrent block. x: (B, S, D)."""
    B, S, _ = x.shape
    w = cfg.lru_width
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]))
    u_raw = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    u = conv1d_full(p["conv"], u_raw)

    r = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", u, p["w_rg"]) + p["b_rg"])
        .astype(jnp.float32))
    i = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", u, p["w_ig"]) + p["b_ig"])
        .astype(jnp.float32))
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"])[None, None, :] * r  # (B,S,W) f32
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gx = beta * (i * u.astype(jnp.float32))
    h0 = (state["h"] if state is not None
          else jnp.zeros((B, w), jnp.float32))
    hs, h_last = _rglru_scan(log_a, gx, h0)
    y = jnp.einsum("bsw,wd->bsd", (hs.astype(x.dtype) * gate), p["w_out"])
    # conv state for a subsequent decode phase: last width-1 raw inputs
    cw = cfg.conv1d_width - 1
    conv_state = jnp.pad(u_raw, ((0, 0), (cw, 0), (0, 0)))[:, S:S + cw]
    return y, {"h": h_last, "conv": conv_state}


def init_rglru_state(cfg: ArchConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width),
                          dtype),
    }


def rglru_block_step(p, x_t, cfg: ArchConfig, state):
    """One decode step. x_t: (B, 1, D); state: {"h", "conv"}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x_t, p["w_gate_in"]))
    u = jnp.einsum("bsd,dw->bsw", x_t, p["w_in"])
    u, conv_state = conv1d_step(p["conv"], u, state["conv"])

    r = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", u, p["w_rg"]) + p["b_rg"])
        .astype(jnp.float32))
    i = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", u, p["w_ig"]) + p["b_ig"])
        .astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    h = (a[:, 0] * state["h"]
         + (beta * (i * u.astype(jnp.float32)))[:, 0])
    y = jnp.einsum("bsw,wd->bsd", (h[:, None].astype(x_t.dtype) * gate),
                   p["w_out"])
    return y, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block), stabilized chunkwise form
# ---------------------------------------------------------------------------


def init_mlstm_block(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    ks = jax.random.split(key, 10)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype),
        "w_gate": dense_init(ks[1], (d, di), dtype),
        "conv": init_conv1d(ks[2], cfg.conv1d_width, di, dtype),
        "wq": dense_init(ks[3], (di, di), dtype),
        "wk": dense_init(ks[4], (di, di), dtype),
        "wv": dense_init(ks[5], (di, di), dtype),
        "w_if": dense_init(ks[6], (di, 2 * nh), jnp.float32),
        "b_if": jnp.concatenate([
            jnp.zeros((nh,), jnp.float32),  # input gate bias
            jnp.linspace(3.0, 6.0, nh)]),  # forget gate bias (open)
        "skip": jnp.ones((di,), dtype),  # learnable conv skip scale
        "w_down": dense_init(ks[7], (di, d), dtype),
    }


def _mlstm_chunk(q, k, v, log_i, log_f, carry):
    """One stabilized chunk. q/k/v: (B, H, L, Dh); gates: (B, H, L).

    carry: (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)). Returns (h, new_carry).
    """
    B, H, L, Dh = q.shape
    scale = Dh ** -0.5
    b = jnp.cumsum(log_f, axis=-1)  # (B,H,L) inclusive cumulative log f
    C_p, n_p, m_p = carry

    # intra-chunk log weights D[t,s] = b_t - b_s + log_i_s  (s <= t)
    Dm = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(mask, Dm, -jnp.inf)
    m_intra = jnp.max(Dm, axis=-1)  # (B,H,L)
    m_inter = b + m_p[..., None]
    m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)

    S = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale  # (B,H,L,L)
    W = jnp.exp(Dm - m_t[..., None])
    h_num = jnp.einsum("bhts,bhsd->bhtd", S * W, v)
    n_vec = jnp.einsum("bhts,bhsd->bhtd", W, k)

    inter_w = jnp.exp(m_inter - m_t)[..., None]
    h_num = h_num + inter_w * jnp.einsum("bhde,bhte->bhtd", C_p, q) * scale
    n_vec = n_vec + inter_w * n_p[..., None, :]

    qn = jnp.einsum("bhtd,bhtd->bht", q, n_vec) * scale
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h = h_num / denom[..., None]

    # carry update
    bL = b[..., -1]  # (B,H)
    m_new = jnp.maximum(bL + m_p, jnp.max(bL[..., None] - b + log_i, axis=-1))
    w_s = jnp.exp(bL[..., None] - b + log_i - m_new[..., None])  # (B,H,L)
    C_new = (jnp.exp(bL + m_p - m_new)[..., None, None] * C_p
             + jnp.einsum("bhs,bhsd,bhse->bhde", w_s, v, k))
    n_new = (jnp.exp(bL + m_p - m_new)[..., None] * n_p
             + jnp.einsum("bhs,bhsd->bhd", w_s, k))
    return h, (C_new, n_new, m_new)


def mlstm_block_full(p, x, cfg: ArchConfig, state=None):
    """Full-sequence mLSTM block. x: (B, S, D)."""
    B, S, d = x.shape
    di = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dh = di // nh

    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_gate"])
    xc = jax.nn.silu(conv1d_full(p["conv"], up))

    def heads(t):
        return t.reshape(B, S, nh, dh).transpose(0, 2, 1, 3)

    q = heads(jnp.einsum("bse,ef->bsf", xc, p["wq"])).astype(jnp.float32)
    k = heads(jnp.einsum("bse,ef->bsf", xc, p["wk"])).astype(jnp.float32)
    v = heads(jnp.einsum("bse,ef->bsf", up, p["wv"])).astype(jnp.float32)
    gif = jnp.einsum("bse,eg->bsg", xc.astype(jnp.float32), p["w_if"]) \
        + p["b_if"]
    log_i = gif[..., :nh].transpose(0, 2, 1)  # (B,H,S) pre-activations
    log_f = jax.nn.log_sigmoid(gif[..., nh:]).transpose(0, 2, 1)

    chunk = min(_CHUNK, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
    nc = (S + pad) // chunk

    def reshape_chunks(t, feat):
        if feat:
            return t.reshape(B, nh, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
        return t.reshape(B, nh, nc, chunk).transpose(2, 0, 1, 3)

    if state is None:
        carry = (jnp.zeros((B, nh, dh, dh), jnp.float32),
                 jnp.zeros((B, nh, dh), jnp.float32),
                 jnp.full((B, nh), -1e30, jnp.float32))
    else:
        carry = (state["C"], state["n"], state["m"])

    def step(c, inp):
        qc, kc, vc, lic, lfc = inp
        h, c2 = _mlstm_chunk(qc, kc, vc, lic, lfc, c)
        return c2, h

    carry, hs = jax.lax.scan(
        step, carry,
        (reshape_chunks(q, True), reshape_chunks(k, True),
         reshape_chunks(v, True), reshape_chunks(log_i, False),
         reshape_chunks(log_f, False)))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(B, nh, nc * chunk, dh)
    hs = hs[:, :, :S].transpose(0, 2, 1, 3).reshape(B, S, di)

    out = (hs.astype(x.dtype) + p["skip"] * xc) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", out, p["w_down"])
    cw = cfg.conv1d_width - 1
    conv_state = jnp.pad(up, ((0, 0), (cw, 0), (0, 0)))[:, S:S + cw]
    return y, {"C": carry[0], "n": carry[1], "m": carry[2],
               "conv": conv_state}


def init_mlstm_state(cfg: ArchConfig, batch: int, dtype):
    di = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dh = di // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, di), dtype),
    }


def mlstm_block_step(p, x_t, cfg: ArchConfig, state):
    """One decode step with O(1) state. x_t: (B, 1, D)."""
    B, _, d = x_t.shape
    di = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dh = di // nh

    up = jnp.einsum("bsd,de->bse", x_t, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x_t, p["w_gate"])
    uc, conv_state = conv1d_step(p["conv"], up, state["conv"])
    xc = jax.nn.silu(uc)

    def heads(t):
        return t.reshape(B, nh, dh)

    q = heads(jnp.einsum("bse,ef->bsf", xc, p["wq"])[:, 0]).astype(jnp.float32)
    k = heads(jnp.einsum("bse,ef->bsf", xc, p["wk"])[:, 0]).astype(jnp.float32)
    v = heads(jnp.einsum("bse,ef->bsf", up, p["wv"])[:, 0]).astype(jnp.float32)
    gif = jnp.einsum("be,eg->bg", xc[:, 0].astype(jnp.float32), p["w_if"]) \
        + p["b_if"]
    log_i = gif[:, :nh]
    log_f = jax.nn.log_sigmoid(gif[:, nh:])

    C_p, n_p, m_p = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(log_f + m_p, log_i)
    fw = jnp.exp(log_f + m_p - m_new)[..., None]
    iw = jnp.exp(log_i - m_new)[..., None]
    C = fw[..., None] * C_p + iw[..., None] * jnp.einsum(
        "bhd,bhe->bhde", v, k)
    n = fw * n_p + iw * k
    scale = dh ** -0.5
    h_num = jnp.einsum("bhde,bhe->bhd", C, q) * scale
    qn = jnp.einsum("bhd,bhd->bh", q, n) * scale
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = (h_num / denom[..., None]).reshape(B, 1, di)

    out = (h.astype(x_t.dtype) + p["skip"] * xc) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", out, p["w_down"])
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block) — inherently sequential
# ---------------------------------------------------------------------------


def init_slstm_block(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = int(d * cfg.slstm_proj_factor) * 2
    ks = jax.random.split(key, 8)
    return {
        "w_zifo": dense_init(ks[0], (d, 4 * d), dtype),
        # per-head recurrent matrices (block-diagonal recurrence)
        "r_zifo": dense_init(ks[1], (nh, dh, 4 * dh), dtype,
                             scale=dh ** -0.5),
        "b_zifo": jnp.concatenate([
            jnp.zeros((2 * d,), jnp.float32),
            jnp.ones((d,), jnp.float32) * 3.0,  # forget bias open
            jnp.zeros((d,), jnp.float32)]),
        "w_ff1": dense_init(ks[2], (d, dff), dtype),
        "w_ff2": dense_init(ks[3], (dff // 2, d), dtype),
    }


def _slstm_gates(p, x_t, h_prev, nh, dh):
    """x_t: (B, D); h_prev: (B, H, Dh) -> z, i~, f~, o~ each (B, H, Dh)."""
    B, d = x_t.shape
    wx = jnp.einsum("bd,de->be", x_t, p["w_zifo"])  # (B, 4D)
    rh = jnp.einsum("bhd,hde->bhe", h_prev, p["r_zifo"])  # (B, H, 4Dh)
    wx = wx.reshape(B, 4, nh, dh).transpose(0, 2, 1, 3)  # (B,H,4,Dh)
    rh = rh.reshape(B, nh, 4, dh)
    g = (wx + rh).astype(jnp.float32).transpose(0, 2, 1, 3) \
        + p["b_zifo"].reshape(4, nh, dh)
    return g[:, 0], g[:, 1], g[:, 2], g[:, 3]  # (B,H,Dh) each


def _slstm_step(p, x_t, st, nh, dh):
    c, n, h, m = st
    z, it, ft, ot = _slstm_gates(p, x_t, h, nh, dh)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(ot)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def init_slstm_state(cfg: ArchConfig, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 1e30}


def slstm_block_full(p, x, cfg: ArchConfig, state=None):
    """Sequential scan over time. x: (B, S, D)."""
    B, S, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    if state is None:
        st = init_slstm_state(cfg, B)
    else:
        st = state
    init = (st["c"], st["n"], st["h"], st["m"])

    def step(carry, x_t):
        new = _slstm_step(p, x_t, carry, nh, dh)
        return new, new[2]

    (c, n, h, m), hs = jax.lax.scan(step, init, x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    # GLU feed-forward (proj factor 4/3, paired gates)
    ff = jnp.einsum("bsd,de->bse", hs, p["w_ff1"])
    u, g = jnp.split(ff, 2, axis=-1)
    y = jnp.einsum("bse,ed->bsd", u * jax.nn.gelu(g), p["w_ff2"])
    return y, {"c": c, "n": n, "h": h, "m": m}


def slstm_block_step(p, x_t, cfg: ArchConfig, state):
    B = x_t.shape[0]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    st = (state["c"], state["n"], state["h"], state["m"])
    c, n, h, m = _slstm_step(p, x_t[:, 0], st, nh, dh)
    hs = h.reshape(B, 1, cfg.d_model).astype(x_t.dtype)
    ff = jnp.einsum("bsd,de->bse", hs, p["w_ff1"])
    u, g = jnp.split(ff, 2, axis=-1)
    y = jnp.einsum("bse,ed->bsd", u * jax.nn.gelu(g), p["w_ff2"])
    return y, {"c": c, "n": n, "h": h, "m": m}


__all__ = [
    "init_conv1d", "conv1d_full", "conv1d_step",
    "init_rglru_block", "rglru_block_full", "rglru_block_step",
    "init_rglru_state",
    "init_mlstm_block", "mlstm_block_full", "mlstm_block_step",
    "init_mlstm_state",
    "init_slstm_block", "slstm_block_full", "slstm_block_step",
    "init_slstm_state",
]
