"""Optimized-HLO analysis: collective bytes + HBM traffic, while-trip aware.

``compiled.cost_analysis()`` has no collective term and counts while bodies
once, so both remaining roofline terms are recovered from the HLO text:

* The module is split into named computations; ``while`` ops link body and
  condition computations, whose trip count is read from the loop bound
  constant in the condition (scan lowering: induction 0..N, direction=LT).
* Multiplicities propagate: ops inside a while body executing N times under
  a body executing M times count N*M.
* Collective bytes: result sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (and their -start forms),
  x multiplicity. Per-device quantities (post-SPMD HLO).
* HBM bytes: per top-level op, operand+result sizes (a post-fusion traffic
  model: fusion internals live in registers/VMEM, the fusion op's operands
  and results are the HBM transfers). Free ops (bitcast, tuple, gte,
  parameter) skipped; computations referenced by fusion ``calls=`` skipped.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "partition-id", "replica-id", "after-all", "add-dependency",
    "opt-barrier", "domain", "get-dimension-size",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type may be a tuple containing /*index=N*/ comments — allow
# anything up to the closing paren (tuple types never nest parens)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[^\]]*\]\S*)\s+"
    r"([\w\-]+)[(.]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")
# a param type is a paren tuple, an array type (whose dims contain
# commas — `f32[8,8]{1,0}` must not be cut at the first comma), or a
# bare scalar token
_PARAM_SIG_RE = re.compile(
    r"(\w[\w.\-]*):\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?)|(?:[^,)]+))")


def _bytes_of_type(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)  # %name -> bytes
    params: list = field(default_factory=list)  # ordered (%name, bytes)
    whiles: list = field(default_factory=list)  # (body, cond) comp names
    fusion_calls: set = field(default_factory=set)
    max_int_constant: int = 0


def parse_module(hlo_text: str) -> dict:
    comps: dict = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and ("->" in line or line.endswith("{")):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            # parameter types from the signature (ordered)
            sig = line[line.index("("):]
            for pm in _PARAM_SIG_RE.finditer(sig):
                pname = "%" + pm.group(1)
                pbytes = _bytes_of_type(pm.group(2))
                cur.defs[pname] = pbytes
                cur.params.append((pname, pbytes))
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, type_str, kind = md.groups()
        rb = _bytes_of_type(type_str)
        cur.defs[name] = rb
        # operand names: every %ref before the first attribute assignment
        tail = line[md.end():]
        attr_cut = re.split(r",\s*\w+=", tail, maxsplit=1)[0]
        operands = re.findall(r"%[\w.\-]+", attr_cut)
        op = Op(name, kind, rb, operands, line)
        cur.ops.append(op)
        for cm in re.finditer(r"constant\((\d+)\)", line):
            cur.max_int_constant = max(cur.max_int_constant,
                                       int(cm.group(1)))
        if kind == "while":
            attrs = dict(
                (k, v) for k, v in re.findall(
                    r"(body|condition)=(%[\w.\-]+)", line))
            if "body" in attrs and "condition" in attrs:
                mt = _TRIP_RE.search(line)
                trip = int(mt.group(1)) if mt else None
                cur.whiles.append((attrs["body"], attrs["condition"], trip))
        if kind == "fusion" or "calls=" in line:
            for m2 in re.finditer(r"calls=(%[\w.\-]+)", line):
                cur.fusion_calls.add(m2.group(1))
        for m2 in re.finditer(r"to_apply=(%[\w.\-]+)", line):
            cur.fusion_calls.add(m2.group(1))
    return comps


def _entry_name(comps: dict, hlo_text: str) -> str:
    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", hlo_text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def _trip_count(comps: dict, cond_name: str) -> int:
    """Max-int-constant HEURISTIC trip count — the fallback when a while
    op carries no ``known_trip_count`` metadata. It misreads loop bounds
    when the condition computation holds unrelated large constants, so
    ``computation_multiplicities`` prefers the metadata everywhere and
    counts every fallback in ``trip_fallbacks`` (surfaced by the audit
    report as a parser-confidence warning)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = cond.max_int_constant
    # the bound constant may sit in a fused compare computation
    for sub in cond.fusion_calls:
        if sub in comps:
            best = max(best, comps[sub].max_int_constant)
    return max(best, 1)


def computation_multiplicities(hlo_text: str) -> dict:
    """{computation_name: times executed per step} via while nesting.

    Returns ``{"comps", "mult", "entry", "trip_fallbacks"}`` —
    ``trip_fallbacks`` counts while ops whose trip count came from the
    max-int-constant heuristic instead of ``known_trip_count`` metadata
    (0 means every multiplicity is exact)."""
    comps = parse_module(hlo_text)
    entry = _entry_name(comps, hlo_text)
    mult: dict = defaultdict(float)
    seen_stack = []
    fallbacks = [0]

    def visit(name: str, m: float):
        if name not in comps or name in seen_stack:
            return
        mult[name] += m
        seen_stack.append(name)
        comp = comps[name]
        for body, cond, trip in comp.whiles:
            if trip is None:
                fallbacks[0] += 1
                trip = _trip_count(comps, cond)
            visit(body, m * trip)
            visit(cond, m * (trip + 1))
        seen_stack.pop()

    visit(entry, 1.0)
    return {"comps": comps, "mult": dict(mult), "entry": entry,
            "trip_fallbacks": fallbacks[0]}


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind collective result bytes per device, while-trip weighted."""
    info = computation_multiplicities(hlo_text)
    comps, mult = info["comps"], info["mult"]
    out: dict = defaultdict(float)
    for cname, m in mult.items():
        for op in comps[cname].ops:
            kind = op.kind
            if kind.endswith("-done"):
                continue
            for c in COLLECTIVES:
                if kind == c or kind == c + "-start":
                    out[c] += m * op.result_bytes
                    break
    return {k: float(v) for k, v in out.items()}


def _fusion_traffic(op: Op, comp: Computation, comps: dict) -> float:
    """Operand+result bytes for a fusion, with dynamic-(update-)slice
    awareness: a fusion that slices a big buffer only reads the slice; one
    that updates in place only writes the update (XLA aliases the buffer)."""
    called_name = None
    m = re.search(r"calls=(%[\w.\-]+)", op.line)
    if m:
        called_name = m.group(1)
    called = comps.get(called_name)
    if called is None:
        b = op.result_bytes
        for o in op.operands:
            b += comp.defs.get(o, 0)
        return b

    # which internal params are consumed by dynamic-slice / DUS?
    sliced_param_read = {}
    dus_write = None
    for iop in called.ops:
        if iop.kind == "dynamic-slice" and iop.operands:
            sliced_param_read[iop.operands[0]] = iop.result_bytes
        if iop.kind == "dynamic-update-slice" and len(iop.operands) >= 2:
            sliced_param_read[iop.operands[0]] = 0  # aliased in-place read
            dus_write = called.defs.get(iop.operands[1], iop.result_bytes)

    b = dus_write if dus_write is not None else op.result_bytes
    for i, o in enumerate(op.operands):
        pname = called.params[i][0] if i < len(called.params) else None
        if pname is not None and pname in sliced_param_read:
            b += sliced_param_read[pname]
        else:
            b += comp.defs.get(o, 0)
    return b


# chunked-attention score-tile signature: f32 rank>=2 tensors whose two
# trailing dims are the (block_q, block_k) tile of kernels/ref.py's
# chunked_attention. On the CPU container these tiles hit HBM every
# (q-block, kv-block) step; the TPU target runs the Pallas flash kernel
# (kernels/flash_attention.py) where they live in VMEM scratch — so the
# roofline's memory term subtracts them (EXPERIMENTS.md §Roofline note).
_FLASH_TILE_RE = re.compile(r"f32\[[\d,]*1024,1024\]")


def _is_flash_tile(line: str) -> bool:
    return bool(_FLASH_TILE_RE.search(line.split(" = ")[-1][:60]))


def hbm_bytes(hlo_text: str, flash_adjusted: bool = False) -> float:
    """Post-fusion HBM traffic model: operand+result bytes of every counted
    top-level op, while-trip weighted. Per device.

    flash_adjusted=True removes traffic of ops *producing* attention score
    tiles (see _FLASH_TILE_RE) — the VMEM-resident tiles of the TPU
    flash-attention kernel that the CPU stand-in materializes."""
    info = computation_multiplicities(hlo_text)
    comps, mult = info["comps"], info["mult"]
    total = 0.0
    for cname, m in mult.items():
        comp = comps[cname]
        tile_defs = set()
        if flash_adjusted:
            for op in comp.ops:
                if _is_flash_tile(op.line):
                    tile_defs.add(op.name)
        for op in comp.ops:
            if op.kind in _FREE_OPS or op.kind == "while":
                continue
            if flash_adjusted and op.name in tile_defs:
                continue  # tile producer: VMEM-resident on the TPU target
            if op.kind == "fusion":
                b = _fusion_traffic(op, comp, comps)
                if flash_adjusted:  # tile operands also stay in VMEM
                    for o in op.operands:
                        if o in tile_defs:
                            b = max(0.0, b - comp.defs.get(o, 0))
                total += m * b
                continue
            if op.kind == "dynamic-slice":
                total += m * 2 * op.result_bytes
                continue
            if op.kind == "dynamic-update-slice":
                upd = comp.defs.get(op.operands[1], 0) \
                    if len(op.operands) >= 2 else 0
                total += m * 2 * upd
                continue
            b = op.result_bytes
            for o in op.operands:
                if flash_adjusted and o in tile_defs:
                    continue
                b += comp.defs.get(o, 0)
            total += m * b
    return total


def dense_materializations(hlo_text: str, min_bytes: int) -> list:
    """Ops that *write* >= ``min_bytes`` of fresh output, per execution.

    The serving engines' ring-layout acceptance check: a sliding tick
    must never shift/copy/rebuild a (cap, cap) buffer — the only allowed
    big-result ops are parameters/plumbing, in-place
    dynamic-update-slice chains (XLA aliases those with the donated
    input, so they write only the updated row/column), and staged
    *reduce operands* (a big fused mask/key buffer whose only consumers
    are reductions collapsing it to O(cap) — read-side scratch the CPU
    backend sometimes declines to fuse into a second reduce, not a copy
    of state). Everything else producing a result of at least
    ``min_bytes`` — pads, concatenates, slices, gathers, copies, and
    fusions that neither root in a dynamic-update-slice nor feed only
    reductions — is reported with its while-trip multiplicity, so a
    caller can assert that nothing big materializes *per tick*
    (multiplicity > 1) while tolerating one-time setup at the entry.

    Returns a list of dicts: {computation, mult, kind, name, bytes}.
    """
    info = computation_multiplicities(hlo_text)
    comps, mult = info["comps"], info["mult"]
    fusion_called: set = set()
    for comp in comps.values():
        fusion_called |= comp.fusion_calls
    out = []
    for cname, m in mult.items():
        if cname in fusion_called:
            continue  # fusion internals live in registers/VMEM
        comp = comps[cname]
        # name -> consuming ops, built once per computation (the former
        # per-candidate rescan made this O(ops^2) on engine modules)
        consumers_of: dict = defaultdict(list)
        for o in comp.ops:
            for ref in o.operands:
                consumers_of[ref].append(o)

        def reduce_rooted(op):
            if op.kind in ("reduce", "reduce-window"):
                return True
            if op.kind != "fusion":
                return False
            called = re.search(r"calls=(%[\w.\-]+)", op.line)
            body = comps.get(called.group(1)) if called else None
            return body is not None and any(
                o.kind in ("reduce", "reduce-window") for o in body.ops)

        for op in comp.ops:
            if op.kind in _FREE_OPS or op.kind == "while":
                continue
            if op.result_bytes < min_bytes:
                continue
            if op.kind == "dynamic-update-slice":
                continue  # in-place: writes only the update operand
            if op.kind == "fusion":
                called = re.search(r"calls=(%[\w.\-]+)", op.line)
                body = comps.get(called.group(1)) if called else None
                if body is not None and any(
                        o.kind == "dynamic-update-slice"
                        for o in body.ops):
                    continue  # DUS-rooted fusion: aliased in place
            consumers = consumers_of.get(op.name, [])
            if consumers and all(reduce_rooted(o) for o in consumers):
                continue  # reduce staging: collapsed to O(cap) in place
            out.append({"computation": cname, "mult": float(m),
                        "kind": op.kind, "name": op.name,
                        "bytes": op.result_bytes, "line": op.line.strip()})
    return out


# ---------------------------------------------------------------------------
# compiled-module invariants (consumed by repro.analysis.audit)
# ---------------------------------------------------------------------------

# one level of brace nesting: entries look like `{0}: (0, {}, may-alias)`
_ALIAS_ATTR_RE = re.compile(
    r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}")
_ALIAS_ENTRY_RE = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+)")


def input_output_aliases(hlo_text: str) -> dict:
    """Donation aliasing from the HloModule header.

    Returns ``{output_index_path: param_number}`` where the key is the
    (possibly empty) tuple index of the aliased output in the entry
    result — e.g. ``{(0,): 0, (1,): 1}`` for a jit whose first two
    outputs alias (reuse the buffers of) entry parameters 0 and 1.
    Empty dict when the module declares no aliasing (nothing donated,
    or every donation was dropped — the donation-leak signal)."""
    m = _ALIAS_ATTR_RE.search(hlo_text)
    if not m:
        return {}
    out = {}
    for idx, param in _ALIAS_ENTRY_RE.findall(m.group(1)):
        key = tuple(int(x) for x in idx.replace(",", " ").split())
        out[key] = int(param)
    return out


def big_copies(hlo_text: str, min_bytes: int,
               min_mult: float = 0.0) -> list:
    """``copy``/``copy-start`` ops writing >= ``min_bytes``, with their
    while-trip multiplicity and source line.

    A donated buffer that really updates in place never shows a
    full-size copy of itself; XLA reintroducing one (e.g. a scheduling
    change that makes the in-place write clobber a pending read) is the
    regression class PR 5's marker eliminated — this is its detector.
    """
    info = computation_multiplicities(hlo_text)
    comps, mult = info["comps"], info["mult"]
    out = []
    for cname, m in mult.items():
        if m < min_mult:
            continue
        for op in comps[cname].ops:
            if op.kind not in ("copy", "copy-start"):
                continue
            if op.result_bytes < min_bytes:
                continue
            out.append({"computation": cname, "mult": float(m),
                        "kind": op.kind, "name": op.name,
                        "bytes": op.result_bytes, "line": op.line.strip()})
    return out


def count_ops(hlo_text: str) -> dict:
    """Census of interesting ops (while-trip weighted)."""
    info = computation_multiplicities(hlo_text)
    comps, mult = info["comps"], info["mult"]
    counts: dict = defaultdict(float)
    interesting = COLLECTIVES + (
        "fusion", "dot", "convolution", "while", "custom-call",
        "dynamic-update-slice", "copy", "transpose")
    for cname, m in mult.items():
        for op in comps[cname].ops:
            for k in interesting:
                if op.kind == k or op.kind == k + "-start":
                    counts[k] += m
                    break
    return {k: float(v) for k, v in counts.items()}


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(global_flops: float, device_hbm_bytes: float,
                   coll_bytes: dict, n_chips: int) -> dict:
    """Three per-step roofline times in seconds.

    global_flops: whole-program (jaxpr counter); divided across chips.
    device_hbm_bytes / coll_bytes: already per-device (post-SPMD HLO).
    All-reduce moves ~2x the buffer on a ring; others ~1x.
    """
    t_compute = global_flops / (n_chips * PEAK_FLOPS_BF16)
    t_memory = device_hbm_bytes / HBM_BW
    cb = 0.0
    for kind, b in coll_bytes.items():
        cb += (2.0 if kind == "all-reduce" else 1.0) * b
    t_coll = cb / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}


def model_flops_per_step(n_active_params: int, tokens_per_step: int,
                         kind: str = "train") -> float:
    """6ND for train (fwd+bwd), 2ND for inference forward."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_active_params * tokens_per_step


__all__ = ["collective_bytes", "hbm_bytes", "count_ops",
           "computation_multiplicities", "dense_materializations",
           "input_output_aliases", "big_copies",
           "roofline_terms", "model_flops_per_step", "PEAK_FLOPS_BF16",
           "HBM_BW", "ICI_BW"]
