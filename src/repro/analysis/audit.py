"""Compiled-artifact invariant auditor: static gates over jaxprs + HLO.

``python -m repro.analysis.audit`` traces and compiles every engine
configuration in the serving matrix (classification/regression x
grow/sliding x ring/compact x shards 1/8) plus the registry measures
(knn, simplified_knn, kde, lssvm, bootstrap, knn_regression) and runs a
registered suite of checkers against the *artifacts* — no tick is
executed (the retrace auditor alone runs a tiny scripted lifecycle,
since retracing is a runtime property). It emits a JSON report with
per-check pass/fail and the offending HLO op lines, and exits nonzero
on any violation; CI runs it as a blocking gate.

Checkers (name -> invariant -> introducing PR):

* ``donation-alias`` — every donated state leaf must alias an output in
  the compiled module (``input_output_alias`` header) and no per-tick
  full-leaf ``copy``/``copy-start`` may touch the donated buffers. This
  is the O(cap) in-place distance-matrix contract of PR 3, and the
  double-copy regression class PR 5's scheduling marker eliminated.
* ``collective-freedom`` — ``collective_bytes == 0`` for every
  shard_map'd tick: PR 8's tenant-sharded dispatch is embarrassingly
  parallel by construction, so any collective is a lowering bug.
* ``dense-budget`` — declarative per-target byte budgets on fresh
  per-tick materializations (``dense_materializations`` with
  ``mult > 1``): ring layouts budget ZERO full-size writes (PR 5's
  O(cap)-eviction claim); the compact sliding layout carries a
  documented waiver (it IS the O(cap^2) baseline/oracle).
* ``retrace`` — a scripted session lifecycle (observe, observe_many,
  read path, then the identical lifecycle again) must add zero
  compilations on the repeat pass, and the first pass must stay within
  the declared shape-bucket budget (PR 1's no-retrace-as-windows-slide
  contract; ``jax.monitoring`` compile events are recorded as a
  secondary signal).
* ``source-lint`` — AST pass over ``src/`` (``repro.analysis.lint``):
  keyed randomness only (PR 4), no host syncs in jit-reachable helpers,
  no Python loops over the tenant axis in engine modules (PR 1-3), and
  ``_donated``/``donate=False`` copy-semantics consistency (PR 3).

Known waiver: at ONE tenant lane per device (``n_sessions == shards``)
XLA-CPU reintroduces a per-tick double copy of the donated (1, cap,
cap) distance carry — a degenerate-batch scheduling artifact, not a
code regression (>= 2 lanes/device compiles clean; real deployments
batch many lanes per shard). The audit matrix therefore uses >= 2
lanes per device; keep fleets above one lane per shard.

IMPORTANT: this module must stay importable WITHOUT importing jax —
``main()`` re-execs with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` (CPU hosts only) before jax first loads so the sharded
targets can compile. Everything jax-touching imports lazily.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import dataclass, field

from repro.analysis import hlo as hlo_m
from repro.analysis import lint as lint_m

_REEXEC_SENTINEL = "REPRO_AUDIT_REEXEC"

#: engine-matrix shape: >= 2 tenant lanes per device at max shards (see
#: the lanes-per-device waiver in the module docstring)
_S, _CAP, _DIM, _K, _CHUNK = 16, 32, 4, 3, 4

MEASURES = ("knn", "simplified_knn", "kde", "lssvm", "bootstrap",
            "knn_regression")


@dataclass
class AuditTarget:
    """One audited configuration with its declarative budgets."""

    name: str
    kind: str                    # "engine" | "measure"
    family: str = ""             # classification | regression
    mode: str = ""               # sliding | grow
    layout: str = "ring"
    shards: int = 1
    measure: str = ""
    n_sessions: int = _S
    capacity: int = _CAP
    dim: int = _DIM
    k: int = _K
    window: int | None = _CAP
    chunk: int = _CHUNK
    donate: bool = True
    # budgets: a non-empty waiver string replaces the zero budget
    dense_waiver: str = ""
    copy_waiver: str = ""
    max_collective_bytes: float = 0.0
    retrace_budget: dict = field(
        default_factory=lambda: {"step": 2, "read": 1})

    def describe(self) -> dict:
        d = {"name": self.name, "kind": self.kind, "shards": self.shards}
        if self.kind == "engine":
            d.update(family=self.family, mode=self.mode,
                     layout=self.layout, n_sessions=self.n_sessions,
                     capacity=self.capacity, donate=self.donate)
        else:
            d["measure"] = self.measure
        return d


# ---------------------------------------------------------------------------
# the invariants as pure functions over HLO text (single definitions —
# tests/test_ring_layout.py and tests/test_distributed.py consume THESE)
# ---------------------------------------------------------------------------


def dense_tick_violations(hlo_text: str, min_bytes: int) -> list:
    """Fresh writes >= min_bytes that execute once PER TICK (mult > 1).

    The PR 5 ring-layout invariant: a sliding tick never shifts /
    copies / rebuilds a (cap, cap)-sized buffer. One-time (mult == 1)
    setup at the entry is tolerated."""
    return [d for d in hlo_m.dense_materializations(hlo_text, min_bytes)
            if d["mult"] > 1]


def collective_violations(hlo_text: str) -> list:
    """Collective ops (any multiplicity) with their source lines."""
    info = hlo_m.computation_multiplicities(hlo_text)
    out = []
    for cname, m in info["mult"].items():
        for op in info["comps"][cname].ops:
            kind = op.kind[:-len("-start")] \
                if op.kind.endswith("-start") else op.kind
            if kind in hlo_m.COLLECTIVES:
                out.append({"computation": cname, "mult": float(m),
                            "kind": op.kind, "name": op.name,
                            "bytes": op.result_bytes,
                            "line": op.line.strip()})
    return out


def alias_violations(hlo_text: str, expected_aliases: int) -> list:
    """Donated-buffer leaks: fewer aliased params than donated leaves."""
    aliases = hlo_m.input_output_aliases(hlo_text)
    if len(aliases) >= expected_aliases:
        return []
    return [{"kind": "missing-alias",
             "line": f"input_output_alias covers "
                     f"{len(aliases)}/{expected_aliases} donated state "
                     f"leaves: {sorted(aliases.values())}"}]


# ---------------------------------------------------------------------------
# artifacts (lazily traced/compiled, shared across checkers)
# ---------------------------------------------------------------------------


class Artifact:
    """Compiled view of one target. Nothing here executes a tick."""

    def __init__(self, target: AuditTarget):
        self.target = target
        self._engine = None
        self._hlo = None
        self._n_leaves = None

    def build_engine(self, **overrides):
        t = self.target
        kw = dict(n_sessions=t.n_sessions, capacity=t.capacity,
                  dim=t.dim, k=t.k,
                  window=t.window if t.mode == "sliding" else None,
                  layout=t.layout, donate=t.donate, shards=t.shards)
        kw.update(overrides)
        if t.family == "classification":
            from repro.serving.engine import ServingEngine
            return ServingEngine(n_labels=2, **kw)
        from repro.regression.engine import RegressionServingEngine
        return RegressionServingEngine(**kw)

    def engine(self):
        if self._engine is None:
            self._engine = self.build_engine()
        return self._engine

    def n_state_leaves(self) -> int:
        if self._n_leaves is None:
            import jax
            self._n_leaves = len(
                jax.tree_util.tree_leaves(self.engine().init_state()))
        return self._n_leaves

    def hlo(self) -> str:
        """Optimized HLO of the compiled observe_many tick (engine
        targets) or of the jitted p-value read path (measure targets)."""
        if self._hlo is None:
            if self.target.kind == "engine":
                lowered = self.engine().lower_tick(self.target.chunk)
                self._hlo = lowered.compile().as_text()
            else:
                self._hlo = _measure_hlo(self.target)
        return self._hlo

    def big_bytes(self) -> int:
        """Per-device full-size (lanes, cap, cap) f32 leaf bytes — the
        threshold above which a fresh write counts as 'dense'."""
        t = self.target
        lanes = t.n_sessions // t.shards
        return lanes * t.capacity * t.capacity * 4

    def trip_fallbacks(self) -> int:
        return hlo_m.computation_multiplicities(
            self.hlo())["trip_fallbacks"]


def _measure_hlo(t: AuditTarget) -> str:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import registry

    rng = np.random.default_rng(0)
    n = 24
    X = jnp.asarray(rng.normal(size=(n, t.dim)), jnp.float32)
    hp: dict = {}
    if t.measure == "knn_regression":
        y = jnp.asarray(rng.normal(size=n), jnp.float32)
        hp = {"k": t.k, "t_query": np.linspace(-1.0, 1.0, 5)}
    else:
        y = jnp.asarray(rng.integers(0, 2, size=n), jnp.int32)
        if t.measure in ("knn", "simplified_knn"):
            hp = {"k": t.k}
    cp = registry.ConformalPredictor(t.measure, **hp).fit(X, y)
    Xq = X[:4]
    fn = lambda st, q: cp.spec.pvalues(st, cp._ctx, q, cp.hp)
    return jax.jit(fn).lower(cp._state, Xq).compile().as_text()


# ---------------------------------------------------------------------------
# checker registry
# ---------------------------------------------------------------------------

CHECKERS: dict = {}


def checker(name: str):
    def deco(fn):
        CHECKERS[name] = fn
        return fn
    return deco


def _result(name, target, status, violations=None, info=None) -> dict:
    return {"check": name, "target": target.name if target else "src",
            "status": status, "violations": violations or [],
            "info": info or {}}


@checker("donation-alias")
def check_donation(target: AuditTarget, art: Artifact) -> dict:
    if target.kind != "engine":
        return _result("donation-alias", target, "skipped",
                       info={"reason": "nothing donated on the "
                                       "registry read path"})
    if not target.donate:
        return _result("donation-alias", target, "skipped",
                       info={"reason": "donate=False copy semantics"})
    text = art.hlo()
    vs = alias_violations(text, art.n_state_leaves())
    info = {"aliased": len(hlo_m.input_output_aliases(text)),
            "state_leaves": art.n_state_leaves()}
    if target.copy_waiver:
        info["copy_waiver"] = target.copy_waiver
    else:
        copies = hlo_m.big_copies(text, art.big_bytes(), min_mult=1.5)
        vs += copies
        info["per_tick_big_copies"] = len(copies)
    return _result("donation-alias", target,
                   "fail" if vs else "pass", vs, info)


@checker("collective-freedom")
def check_collectives(target: AuditTarget, art: Artifact) -> dict:
    text = art.hlo()
    cb = hlo_m.collective_bytes(text)
    total = sum(cb.values())
    vs = collective_violations(text) \
        if total > target.max_collective_bytes else []
    return _result("collective-freedom", target,
                   "fail" if vs else "pass", vs,
                   {"collective_bytes": cb, "shards": target.shards})


@checker("dense-budget")
def check_dense(target: AuditTarget, art: Artifact) -> dict:
    text = art.hlo()
    info = {"min_bytes": art.big_bytes(),
            "trip_fallbacks": art.trip_fallbacks()}
    vs = dense_tick_violations(text, art.big_bytes())
    if target.dense_waiver:
        info.update(waiver=target.dense_waiver, measured=len(vs))
        return _result("dense-budget", target, "waived", [], info)
    return _result("dense-budget", target, "fail" if vs else "pass",
                   vs, info)


@checker("retrace")
def check_retrace(target: AuditTarget, art: Artifact) -> dict:
    if target.kind != "engine":
        return _result("retrace", target, "skipped",
                       info={"reason": "registry predictors are the "
                                       "exact-shape API (one retrace "
                                       "per size by design)"})
    if target.shards > 1:
        return _result("retrace", target, "skipped",
                       info={"reason": "lifecycle executed on the "
                                       "shards=1 twin (same step fn)"})
    import jax
    import jax.numpy as jnp

    compile_events = [0]

    def _listener(event: str, **kw):
        if "compil" in event:
            compile_events[0] += 1

    try:
        jax.monitoring.register_event_listener(_listener)
        have_monitor = True
    except Exception:  # pragma: no cover - older jax
        have_monitor = False

    eng = art.build_engine()  # fresh engine: empty jit caches
    t = target

    def lifecycle(state):
        for i in range(3):
            x = jnp.full((t.n_sessions, t.dim), 0.1 * (i + 1),
                         jnp.float32)
            y = (jnp.zeros((t.n_sessions,), jnp.int32)
                 if t.family == "classification"
                 else jnp.zeros((t.n_sessions,), jnp.float32))
            tau = jnp.full((t.n_sessions,), 0.5, jnp.float32)
            state, _ = eng.observe(state, x, y, tau)
        xs = jnp.zeros((t.chunk, t.n_sessions, t.dim), jnp.float32)
        ys = (jnp.zeros((t.chunk, t.n_sessions), jnp.int32)
              if t.family == "classification"
              else jnp.zeros((t.chunk, t.n_sessions), jnp.float32))
        ts = jnp.full((t.chunk, t.n_sessions), 0.5, jnp.float32)
        state, _ = eng.observe_many(state, xs, ys, ts)
        xq = jnp.zeros((2, t.dim), jnp.float32)
        if t.family == "classification":
            eng.predict(state, xq)
        else:
            eng.intervals(state, xq, epsilon=0.1)
        return state

    def caches():
        read = (eng._predict if t.family == "classification"
                else eng._intervals)
        return {"step": eng._step_many._cache_size(),
                "read": read._cache_size()}

    state = lifecycle(eng.init_state())
    first = caches()
    events_first = compile_events[0]
    lifecycle(state)  # identical shapes: must add ZERO compilations
    second = caches()
    events_second = compile_events[0] - events_first

    vs = []
    for key, budget in t.retrace_budget.items():
        if first[key] > budget:
            vs.append({"kind": "retrace-budget", "op": key,
                       "line": f"{key}: {first[key]} compiled "
                               f"shape-buckets > budget {budget}"})
        if second[key] != first[key]:
            vs.append({"kind": "steady-state-retrace", "op": key,
                       "line": f"{key}: repeat lifecycle recompiled "
                               f"({first[key]} -> {second[key]})"})
    info = {"first_pass": first, "second_pass": second,
            "budget": t.retrace_budget}
    if have_monitor:
        info["monitoring_compile_events"] = {
            "first_pass": events_first, "second_pass": events_second}
    return _result("retrace", target, "fail" if vs else "pass", vs, info)


def check_source_lint(src_root: str) -> dict:
    vs = [v.as_dict() for v in lint_m.lint_tree(src_root)]
    return {"check": "source-lint", "target": "src",
            "status": "fail" if vs else "pass", "violations": vs,
            "info": {"rules": list(lint_m.RULE_NAMES),
                     "root": src_root}}


# ---------------------------------------------------------------------------
# the audited matrix
# ---------------------------------------------------------------------------


def engine_matrix(max_shards: int, quick: bool = False) -> list:
    """Engine targets: family x mode x layout x shards."""
    targets = []
    shard_grid = (1,) if max_shards < 8 else (1, 8)
    for family in ("classification", "regression"):
        for mode in ("sliding", "grow"):
            for layout in ("ring", "compact"):
                for shards in shard_grid:
                    if quick and (mode, layout) == ("grow", "compact"):
                        continue
                    if quick and shards > 1:
                        continue
                    t = AuditTarget(
                        name=f"{family}-{mode}-{layout}-s{shards}",
                        kind="engine", family=family, mode=mode,
                        layout=layout, shards=shards)
                    if mode == "sliding" and layout == "compact":
                        t.dense_waiver = (
                            "compact positional layout IS the O(cap^2) "
                            "compaction baseline (PR 5 oracle)")
                        t.copy_waiver = t.dense_waiver
                    targets.append(t)
    return targets


def measure_matrix(quick: bool = False) -> list:
    names = ("knn", "lssvm", "bootstrap") if quick else MEASURES
    return [AuditTarget(name=f"measure-{m}", kind="measure", measure=m,
                        donate=False)
            for m in names]


def run_audit(max_shards: int = 8, checks=None, quick: bool = False,
              src_root: str | None = None) -> dict:
    """Run the checker suite over the matrix; returns the JSON report."""
    import jax

    t0 = time.time()
    max_shards = min(max_shards, jax.device_count())
    if src_root is None:
        src_root = os.path.join(
            os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))))
    targets = engine_matrix(max_shards, quick) + measure_matrix(quick)
    selected = set(checks) if checks else set(CHECKERS) | {"source-lint"}

    results = []
    if "source-lint" in selected:
        results.append(check_source_lint(src_root))
    for t in targets:
        if t.kind == "measure" and t.measure == "bootstrap":
            # host-side numpy measure: no jitted artifact to audit; its
            # keyed-draw invariant is covered by source-lint
            for name in CHECKERS:
                if name in selected:
                    results.append(_result(
                        name, t, "skipped",
                        info={"reason": "host-side measure (keyed "
                                        "draws gated by source-lint)"}))
            continue
        art = Artifact(t)
        for name, fn in CHECKERS.items():
            if name in selected:
                results.append(fn(t, art))

    summary = {"pass": 0, "fail": 0, "waived": 0, "skipped": 0}
    for r in results:
        summary[r["status"]] += 1
    summary["trip_fallbacks"] = sum(
        r["info"].get("trip_fallbacks", 0) for r in results)

    from repro.kernels import ops as ops_m
    report = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "route": ops_m.active_route(),
        "matrix": {"engine_targets": sum(
                       1 for t in targets if t.kind == "engine"),
                   "measure_targets": sum(
                       1 for t in targets if t.kind == "measure"),
                   "max_shards": max_shards, "quick": quick},
        "targets": [t.describe() for t in targets],
        "checks": results,
        "summary": summary,
        "elapsed_s": round(time.time() - t0, 3),
        "ok": summary["fail"] == 0,
    }
    return report


def format_summary(report: dict) -> str:
    s = report["summary"]
    lines = [f"audit: {s['pass']} pass, {s['fail']} fail, "
             f"{s['waived']} waived, {s['skipped']} skipped "
             f"({report['matrix']['engine_targets']} engine + "
             f"{report['matrix']['measure_targets']} measure targets, "
             f"max_shards={report['matrix']['max_shards']}, "
             f"{report['elapsed_s']:.1f}s)"]
    if s.get("trip_fallbacks"):
        lines.append(f"  warning: {s['trip_fallbacks']} while op(s) "
                     f"missing known_trip_count metadata (heuristic "
                     f"trip counts)")
    for r in report["checks"]:
        if r["status"] != "fail":
            continue
        lines.append(f"  FAIL {r['check']} @ {r['target']}")
        for v in r["violations"][:4]:
            lines.append(f"    {v.get('line', v)}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _maybe_reexec(args, argv) -> None:
    """Re-exec with 8 virtual CPU devices so sharded targets compile.

    Only when: sharded targets requested, jax not yet imported, no
    device-count flag present, and the platform is (defaulting to) CPU —
    never override a real accelerator topology."""
    if args.no_reexec or args.max_shards <= 1:
        return
    if _REEXEC_SENTINEL in os.environ or "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    if os.environ.get("JAX_PLATFORMS", "cpu") != "cpu":
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count="
                f"{args.max_shards}").strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ[_REEXEC_SENTINEL] = "1"
    os.execv(sys.executable,
             [sys.executable, "-m", "repro.analysis.audit"] + list(argv))


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="static invariant audit over the compiled engine "
                    "matrix (see module docstring)")
    ap.add_argument("--out", default="audit_report.json",
                    help="JSON report path")
    ap.add_argument("--max-shards", type=int, default=8,
                    help="audit sharded targets up to this shard count "
                         "(clamped to jax.device_count())")
    ap.add_argument("--quick", action="store_true",
                    help="reduced matrix (CI smoke / unit tests)")
    ap.add_argument("--checks", default="",
                    help="comma-separated checker subset "
                         f"(default: all of {sorted(CHECKERS) if CHECKERS else ''} + source-lint)")
    ap.add_argument("--no-reexec", action="store_true",
                    help="never re-exec for virtual devices; sharded "
                         "targets are clamped to the devices present")
    ap.add_argument("--print", dest="print_json", action="store_true",
                    help="dump the full JSON report to stdout")
    args = ap.parse_args(argv)

    _maybe_reexec(args, argv)

    checks = [c for c in args.checks.split(",") if c] or None
    report = run_audit(max_shards=args.max_shards, checks=checks,
                       quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(format_summary(report))
    print(f"report -> {args.out}")
    if args.print_json:
        print(json.dumps(report, indent=1, sort_keys=True))
    return 0 if report["ok"] else 1


__all__ = ["AuditTarget", "Artifact", "CHECKERS", "MEASURES",
           "engine_matrix", "measure_matrix", "run_audit",
           "dense_tick_violations", "collective_violations",
           "alias_violations", "format_summary", "main"]


if __name__ == "__main__":
    raise SystemExit(main())
