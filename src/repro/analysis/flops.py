"""Exact jaxpr-level FLOP accounting (scan-aware).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scanned program (layer stacks, attention chunk loops, remat backward scans)
under-reports by the trip count. This counter walks the closed jaxpr of the
*exact function that gets lowered* and multiplies scan bodies by their
static ``length`` — including the rematerialized forward inside the backward
scan, so the MODEL_FLOPS/HLO_FLOPs column genuinely reflects remat waste.

Conventions (matching XLA's counter where it is correct):
* dot_general: 2 * batch * M * N * K
* elementwise / select / compare: 1 flop per output element
* transcendental (exp/log/tanh/erf/logistic/sin/cos/rsqrt/sqrt): 1 per elem
  (reported separately too)
* reductions: 1 flop per *input* element
* data movement (reshape/broadcast/slice/gather/scatter/convert/...): 0

Counts are GLOBAL (unsharded program semantics): divide by chip count for
per-chip roofline time.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import numpy as np
from jax import core

_ZERO_COST = {
    "reshape", "broadcast_in_dim", "squeeze", "transpose", "slice",
    "dynamic_slice", "dynamic_update_slice", "gather", "concatenate",
    "convert_element_type", "bitcast_convert_type", "pad", "rev", "iota",
    "copy", "stop_gradient", "device_put", "split", "squeeze",
    "empty", "broadcast", "expand_dims", "real", "imag",
    "shard_to_full", "full_to_shard", "sharding_constraint",
    "partition_id", "axis_index", "pvary",
}

_TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "sin", "cos", "erf", "erfc",
    "logistic", "rsqrt", "sqrt", "pow", "cbrt", "exp2", "atan2", "digamma",
    "lgamma",
}

_REDUCERS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "reduce_precision", "cumsum",
    "cumlogsumexp", "cummax", "cummin", "cumprod",
}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:  # noqa: BLE001
        return 0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    bsize = math.prod(lhs.shape[d] for d in lb) if lb else 1
    ksize = math.prod(lhs.shape[d] for d in lc) if lc else 1
    msize = math.prod(
        lhs.shape[d] for d in range(len(lhs.shape)) if d not in lc + lb)
    nsize = math.prod(
        rhs.shape[d] for d in range(len(rhs.shape)) if d not in rc + rb)
    return 2.0 * bsize * msize * nsize * ksize


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * out_elems * (kernel spatial * in_features / groups)
    groups = eqn.params.get("feature_group_count", 1)
    kernel_elems = math.prod(rhs.shape[:-1])  # spatial x in_features
    return 2.0 * _size(out) * kernel_elems / max(groups, 1)


def count_jaxpr(jaxpr, mult: float = 1.0, acc=None) -> dict:
    """Recursively accumulate {"flops", "transcendental"} over a Jaxpr."""
    if acc is None:
        acc = {"flops": 0.0, "transcendental": 0.0}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            acc["flops"] += mult * _conv_flops(eqn)
        elif name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            count_jaxpr(inner, mult * eqn.params["length"], acc)
        elif name == "while":
            # only bounded fori-style whiles appear (rare); count body once
            count_jaxpr(eqn.params["body_jaxpr"].jaxpr, mult, acc)
        elif name == "cond":
            branches = eqn.params["branches"]
            sub = [count_jaxpr(b.jaxpr, 1.0) for b in branches]
            worst = max(s["flops"] for s in sub)
            acc["flops"] += mult * worst
        elif _subjaxprs(eqn):
            # pjit / remat2 / custom_{jvp,vjp}_call / closed_call / shard_map
            # and anything else carrying sub-jaxprs: recurse x1
            for inner in _subjaxprs(eqn):
                count_jaxpr(inner, mult, acc)
        elif name in _ZERO_COST:
            pass
        elif name in _REDUCERS or name.startswith("reduce_"):
            acc["flops"] += mult * sum(_size(v.aval) for v in eqn.invars[:1])
        elif name == "sort":
            n = _size(eqn.invars[0].aval)
            acc["flops"] += mult * n * max(math.log2(max(n, 2)), 1.0)
        elif name in _TRANSCENDENTAL:
            n = sum(_size(v.aval) for v in eqn.outvars)
            acc["flops"] += mult * n
            acc["transcendental"] += mult * n
        else:
            # elementwise & everything else: 1 flop per output element
            acc["flops"] += mult * sum(_size(v.aval) for v in eqn.outvars)
    return acc


def _subjaxprs(eqn) -> list:
    """Raw Jaxprs carried in an eqn's params (jaxpr / call_jaxpr / ...)."""
    out = []
    for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                "fun_jaxpr"):
        v = eqn.params.get(key)
        if v is None:
            continue
        out.append(getattr(v, "jaxpr", v))
    return out


def flops_of(fn, *args) -> dict:
    """Trace ``fn`` abstractly and count. args may be ShapeDtypeStructs."""
    closed = jax.make_jaxpr(fn)(*args)
    return count_jaxpr(closed.jaxpr)


__all__ = ["count_jaxpr", "flops_of"]
