"""Source-invariant lint: AST checks the compiled-artifact auditor runs
over ``src/`` without executing (or even importing) any of it.

Each rule encodes a structural invariant the serving stack's tests and
benches rely on but that HLO-level checks cannot see:

* ``unkeyed-randomness`` — every random draw in ``src/`` must be keyed
  (``np.random.default_rng(seed)`` / ``jax.random.PRNGKey``): module-
  level ``np.random.*`` draws and stdlib ``random`` calls are hash-order
  / process-global state, the exact bug class PR 4 removed from the
  bootstrap measure.
* ``host-sync-in-jit`` — functions reachable from a ``jax.jit`` wrapping
  in the same module must not call ``time.time``/``time.perf_counter``,
  ``.item()``, ``np.asarray``, or ``.block_until_ready()``: under trace
  these either fail or silently force a device sync per call.
* ``tenant-python-loop`` — the engine modules (``serving/engine.py``,
  ``regression/engine.py``) must never loop Python-side over the tenant
  axis; the one-dispatch-per-tick contract (PR 1-3) is the whole point.
* ``donate-inconsistent`` — every ``*_donated`` jit variant
  (``donate_argnums``) must sit next to a same-named plain variant of
  the same function (the copy-semantics escape hatch), and any other
  ``donate_argnums`` in the serving/regression/core layers must be
  conditioned on a ``donate`` flag (the engines' ``donate=False``
  contract).
* ``swallowed-exception`` — the durability layers (``serving/``,
  ``checkpoint/``, ``robustness/``) must never silently eat an error:
  a bare ``except:`` or a handler whose whole body is ``pass`` /
  ``...`` / ``continue`` hides exactly the I/O failures the chaos
  suite injects (a swallowed write error becomes a half-written
  snapshot that only surfaces at restore time). Handlers must re-raise,
  bind/record the exception, or fall back explicitly.

Lines carrying ``# audit: allow`` are exempt (one escape hatch, visible
in review). Pure stdlib — importable before jax, usable in CI without a
device.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass

_PRAGMA = "# audit: allow"

#: numpy.random constructors that take (or carry) an explicit seed —
#: everything else on the module-level RNG is an unkeyed draw
_KEYED_NP_RANDOM = {"default_rng", "RandomState", "Generator",
                    "SeedSequence", "PCG64", "Philox", "bit_generator"}

_HOST_SYNC_ATTRS = {"item", "block_until_ready"}
_TIME_FNS = {"time", "perf_counter", "monotonic", "process_time"}

#: modules whose For/While loops must not range over the tenant axis
_ENGINE_MODULES = (os.path.join("serving", "engine.py"),
                   os.path.join("regression", "engine.py"))

#: layers where donate_argnums must follow the _donated / flag contract
_DONATE_SCOPED = (os.path.join("repro", "serving"),
                  os.path.join("repro", "regression"),
                  os.path.join("repro", "core"))


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


def _allowed(src_lines: list, lineno: int) -> bool:
    if 1 <= lineno <= len(src_lines):
        return _PRAGMA in src_lines[lineno - 1]
    return False


def _attr_chain(node: ast.AST) -> list:
    """['np', 'random', 'default_rng'] for np.random.default_rng."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _numpy_aliases(tree: ast.Module) -> set:
    """Names this module binds to the numpy package (np, numpy, ...)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    out.add(a.asname or "numpy")
    return out


def _stdlib_random_aliases(tree: ast.Module) -> set:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random":
                    out.add(a.asname or "random")
    return out


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit / jit, or functools.partial(jax.jit, ...)."""
    chain = _attr_chain(node)
    if chain and chain[-1] == "jit":
        return True
    if isinstance(node, ast.Call):
        c = _attr_chain(node.func)
        if c and c[-1] == "partial" and node.args:
            return _is_jit_expr(node.args[0])
    return False


def _jit_call_kwargs(node: ast.Call) -> dict:
    """kwargs across a partial(jax.jit, ...)(fn) or jax.jit(fn, ...)."""
    kws = {k.arg: k.value for k in node.keywords if k.arg}
    if isinstance(node.func, ast.Call):  # the partial(...) call itself
        kws.update({k.arg: k.value
                    for k in node.func.keywords if k.arg})
    return kws


def _jit_wrapped_names(tree: ast.Module) -> set:
    """Module-level function names handed to a jit wrapping."""
    roots = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jit_expr(dec):
                    roots.add(node.name)
        if isinstance(node, ast.Call) and _is_jit_expr(node.func):
            for a in node.args:
                if isinstance(a, ast.Name):
                    roots.add(a.id)
                # jax.jit(functools.partial(fn, ...)) / partial forms
                if isinstance(a, ast.Call) and a.args and \
                        isinstance(a.args[0], ast.Name):
                    roots.add(a.args[0].id)
    return roots


def _reachable_from(roots: set, funcs: dict) -> set:
    """Transitive closure over same-module Name calls."""
    seen = set()
    todo = [r for r in roots if r in funcs]
    while todo:
        name = todo.pop()
        if name in seen:
            continue
        seen.add(name)
        for node in ast.walk(funcs[name]):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in funcs:
                todo.append(node.func.id)
    return seen


def _lint_randomness(path, tree, lines, out):
    np_names = _numpy_aliases(tree)
    rnd_names = _stdlib_random_aliases(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if len(chain) >= 3 and chain[0] in np_names \
                and chain[1] == "random" \
                and chain[2] not in _KEYED_NP_RANDOM:
            if not _allowed(lines, node.lineno):
                out.append(Violation(
                    "unkeyed-randomness", path, node.lineno,
                    f"module-level numpy RNG draw "
                    f"{'.'.join(chain)}(); key it via "
                    f"np.random.default_rng(seed)"))
        if len(chain) == 2 and chain[0] in rnd_names:
            if not _allowed(lines, node.lineno):
                out.append(Violation(
                    "unkeyed-randomness", path, node.lineno,
                    f"stdlib random call {'.'.join(chain)}() uses "
                    f"process-global state; use a keyed generator"))


def _lint_host_sync(path, tree, lines, out):
    np_names = _numpy_aliases(tree)
    funcs = {n.name: n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    jitted = _reachable_from(_jit_wrapped_names(tree), funcs)
    for fname in sorted(jitted):
        for node in ast.walk(funcs[fname]):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            bad = None
            if len(chain) == 2 and chain[0] == "time" \
                    and chain[1] in _TIME_FNS:
                bad = f"wall-clock read {'.'.join(chain)}()"
            elif chain and chain[-1] in _HOST_SYNC_ATTRS:
                bad = f".{chain[-1]}() host sync"
            elif len(chain) == 2 and chain[0] in np_names \
                    and chain[1] == "asarray":
                bad = "np.asarray (device->host transfer)"
            if bad and not _allowed(lines, node.lineno):
                out.append(Violation(
                    "host-sync-in-jit", path, node.lineno,
                    f"{bad} inside jit-reachable helper {fname}()"))


def _lint_tenant_loops(path, tree, lines, out):
    if not path.replace("\\", "/").endswith(
            tuple(p.replace(os.sep, "/") for p in _ENGINE_MODULES)):
        return
    for node in ast.walk(tree):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        probe = node.iter if isinstance(node, ast.For) else node.test
        names = {n.id for n in ast.walk(probe) if isinstance(n, ast.Name)}
        attrs = {n.attr for n in ast.walk(probe)
                 if isinstance(n, ast.Attribute)}
        if ("n_sessions" in names | attrs or "sessions" in names) \
                and not _allowed(lines, node.lineno):
            out.append(Violation(
                "tenant-python-loop", path, node.lineno,
                "Python loop over the tenant axis in an engine module; "
                "ticks must stay one vmapped/shard_map'd dispatch"))


def _lint_donate(path, tree, lines, out):
    norm = path.replace("\\", "/")
    if not any(s.replace(os.sep, "/") in norm for s in _DONATE_SCOPED):
        return
    # module-level jit assignments: name -> (wrapped fn name, kwargs)
    assigns = {}
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and _is_jit_expr(node.value.func)):
            continue
        inner = None
        if node.value.args and isinstance(node.value.args[0], ast.Name):
            inner = node.value.args[0].id
        assigns[node.targets[0].id] = (
            inner, _jit_call_kwargs(node.value), node.lineno)
    for name, (inner, kws, lineno) in assigns.items():
        if "donate_argnums" not in kws:
            continue
        if not name.endswith("_donated"):
            if _allowed(lines, lineno):
                continue
            out.append(Violation(
                "donate-inconsistent", path, lineno,
                f"{name} donates its input without the _donated naming "
                f"contract (callers can't see the copy-semantics "
                f"change)"))
            continue
        base = name[:-len("_donated")]
        plain = assigns.get(base)
        if plain is None or plain[0] != inner \
                or "donate_argnums" in plain[1]:
            if not _allowed(lines, lineno):
                out.append(Violation(
                    "donate-inconsistent", path, lineno,
                    f"{name} has no plain copy-semantics twin "
                    f"{base} wrapping the same function"))
    # donate_argnums anywhere else in scope must be flag-conditioned
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_jit_expr(node.func)):
            continue
        kws = _jit_call_kwargs(node)
        if "donate_argnums" not in kws:
            continue
        expr = kws["donate_argnums"]
        at_module_level = any(
            isinstance(n, ast.Assign) and n.value is node
            for n in tree.body)
        if at_module_level:
            continue  # the _donated contract above covers these
        names = {x.id for x in ast.walk(expr) if isinstance(x, ast.Name)}
        attrs = {x.attr for x in ast.walk(expr)
                 if isinstance(x, ast.Attribute)}
        if not any("donate" in s for s in names | attrs) \
                and not _allowed(lines, node.lineno):
            out.append(Violation(
                "donate-inconsistent", path, node.lineno,
                "donate_argnums not conditioned on a donate flag; the "
                "engines' donate=False contract must stay honest"))


#: layers where an except handler may not silently swallow the error
_SWALLOW_SCOPED = (os.path.join("repro", "serving"),
                   os.path.join("repro", "checkpoint"),
                   os.path.join("repro", "robustness"))

#: handler bodies that discard the exception without a trace
_SWALLOW_STMTS = (ast.Pass, ast.Continue)


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body does nothing with the error."""
    for stmt in handler.body:
        if isinstance(stmt, _SWALLOW_STMTS):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(
                stmt.value, ast.Constant):  # `...` or a bare docstring
            continue
        return False
    return True


def _lint_swallowed(path, tree, lines, out):
    norm = path.replace("\\", "/")
    if not any(s.replace(os.sep, "/") in norm for s in _SWALLOW_SCOPED):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None and not _allowed(lines, node.lineno):
            out.append(Violation(
                "swallowed-exception", path, node.lineno,
                "bare except: in a durability layer catches "
                "KeyboardInterrupt/SystemExit and hides injected I/O "
                "faults; catch a concrete exception type"))
            continue
        if _swallows(node) and not _allowed(lines, node.lineno):
            out.append(Violation(
                "swallowed-exception", path, node.lineno,
                "except handler silently discards the error; re-raise, "
                "record it, or fall back explicitly (# audit: allow to "
                "opt out)"))


_RULES = (_lint_randomness, _lint_host_sync, _lint_tenant_loops,
          _lint_donate, _lint_swallowed)

RULE_NAMES = ("unkeyed-randomness", "host-sync-in-jit",
              "tenant-python-loop", "donate-inconsistent",
              "swallowed-exception")


def lint_paths(paths) -> list:
    """Run every rule over the given .py files; list of Violations."""
    out: list = []
    for path in paths:
        with open(path, encoding="utf-8") as f:
            src = f.read()
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:  # surfaced, not swallowed
            out.append(Violation("parse-error", path, e.lineno or 0,
                                 str(e)))
            continue
        lines = src.splitlines()
        for rule in _RULES:
            rule(path, tree, lines, out)
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_tree(root: str) -> list:
    """Lint every .py file under ``root`` (normally ``src/``)."""
    paths = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                paths.append(os.path.join(dirpath, fn))
    return lint_paths(paths)


__all__ = ["Violation", "lint_paths", "lint_tree", "RULE_NAMES"]
