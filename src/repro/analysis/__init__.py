"""Compiled-artifact analysis: HLO collective census + roofline terms."""
