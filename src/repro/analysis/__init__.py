"""Compiled-artifact analysis: HLO census, roofline terms, static audit.

Submodules (all importable without jax except where noted):

* ``hlo`` — optimized-HLO text parser: collective census, dense
  materializations, input/output aliasing, big-copy detection.
* ``flops`` — jaxpr flop counting / roofline terms (imports jax).
* ``lint`` — AST source-invariant lint (pure stdlib, jax-free).
* ``audit`` — the invariant auditor CLI over the engine matrix
  (``python -m repro.analysis.audit``; imports jax lazily).
"""
