"""Fitted per-(op, capacity-bucket) latency model, and the tuning APIs.

The trace records (``telemetry.tracer``) carry everything a serving
cost model needs: op kind, capacity bucket (the retrace granularity),
chunk length (``ticks``), wall time, and the compile-vs-steady flag
that keeps compilation out of the steady-state fit.

Model: for each (op, cap_bucket) group of *steady* records,

    wall_s  ~=  a  +  b * ticks

by least squares — ``a`` is the fixed per-dispatch overhead (host
round-trip, buffer shuffling), ``b`` the marginal per-tick cost. Ops
without a ``ticks`` axis (predict / intervals / snapshot) degenerate to
``a = median(wall_s), b = 0``. The fit is tiny on purpose: two
parameters per group is enough to answer the two tuning questions the
serving stack hand-tunes today, and few enough to be identifiable from
a short trace.

``suggest_chunk(op, bucket, overhead_frac)`` inverts the model: the
amortized per-tick cost of a T-chunk is ``a/T + b``, so the smallest
chunk whose dispatch-overhead share is <= ``overhead_frac`` is

    T  >=  a * (1 - f) / (b * f).

``suggest_buckets(...)`` replaces the hand-picked power-of-two capacity
buckets: fit ``b(bucket) ~ c * bucket^alpha`` (log-log least squares
across fitted buckets), then space boundaries geometrically in *cost*
— each bucket's top-vs-bottom cost ratio <= ``cost_ratio`` — i.e. a
capacity growth factor of ``cost_ratio ** (1/alpha)``. Sub-linear cost
scaling (alpha < 1, the dispatch-bound regime) yields coarser buckets
(fewer retraces for the same padding waste); super-linear scaling
yields finer ones.

The model persists as JSON (``save``/``load``/``to_json``) and the
round-trip is bitwise: parameters are Python floats, which
``json`` serializes via shortest-round-trip repr.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Iterable

MODEL_VERSION = 1

# ops whose cost scales with a ticks axis (the chunked observe path)
_TICKED_OPS = ("observe", "observe_many")


def _fit_affine(ticks: list[float], walls: list[float]) -> tuple[float,
                                                                 float]:
    """Least-squares wall ~= a + b*ticks, clamped to a, b >= 0."""
    n = len(ticks)
    mt = sum(ticks) / n
    mw = sum(walls) / n
    sxx = sum((t - mt) ** 2 for t in ticks)
    if sxx == 0.0:  # a single chunk length observed: all cost marginal
        return 0.0, mw / mt if mt else mw
    sxy = sum((t - mt) * (w - mw) for t, w in zip(ticks, walls))
    b = max(sxy / sxx, 0.0)
    a = max(mw - b * mt, 0.0)
    return a, b


class CostModel:
    """Per-(engine, op, cap_bucket) affine latency model.

    ``entries`` maps (engine, op, cap_bucket) -> {"a", "b", "n"}:
    dispatch overhead seconds, marginal per-tick seconds, sample count.
    ``engine`` may be "" when the trace did not label one.
    """

    def __init__(self, entries: dict[tuple[str, str, int],
                                     dict[str, float]] | None = None,
                 meta: dict[str, Any] | None = None):
        self.entries = dict(entries or {})
        self.meta = dict(meta or {})

    # -- fitting -------------------------------------------------------------

    @classmethod
    def fit(cls, records: Iterable[dict[str, Any]],
            **meta: Any) -> "CostModel":
        """Fit from trace records (steady only; compile records and
        zero-wall synthetic records are excluded)."""
        groups: dict[tuple[str, str, int], list[tuple[float, float]]] = {}
        for rec in records:
            if rec.get("compile") or rec["wall_s"] <= 0.0:
                continue
            key = (rec.get("engine", ""), rec["op"],
                   int(rec.get("cap_bucket", 0)))
            wall = float(rec.get("dispatch_s") or rec["wall_s"])
            groups.setdefault(key, []).append(
                (float(rec.get("ticks", 1)), wall))
        entries = {}
        for key, samples in groups.items():
            ticks = [t for t, _ in samples]
            walls = [w for _, w in samples]
            if key[1] in _TICKED_OPS:
                a, b = _fit_affine(ticks, walls)
            else:
                a, b = sorted(walls)[len(walls) // 2], 0.0
            entries[key] = {"a": a, "b": b, "n": float(len(samples))}
        return cls(entries, meta)

    # -- lookup --------------------------------------------------------------

    def _entry(self, op: str, cap_bucket: int | None,
               engine: str | None) -> dict[str, float] | None:
        """Exact match first, then nearest bucket (log distance), then
        any engine with that op."""
        cands = [(e, o, c) for (e, o, c) in self.entries
                 if o == op and (engine is None or e == engine)]
        if not cands:
            cands = [(e, o, c) for (e, o, c) in self.entries if o == op]
        if not cands:
            return None
        if cap_bucket is None:
            return self.entries[max(cands, key=lambda k: k[2])]
        best = min(cands, key=lambda k: abs(
            math.log(max(k[2], 1)) - math.log(max(cap_bucket, 1))))
        return self.entries[best]

    def predict(self, op: str, *, ticks: int = 1,
                cap_bucket: int | None = None,
                engine: str | None = None) -> float:
        """Modeled wall seconds of one dispatch."""
        e = self._entry(op, cap_bucket, engine)
        if e is None:
            raise KeyError(f"no fitted entry for op {op!r}")
        return e["a"] + e["b"] * ticks

    # -- tuning --------------------------------------------------------------

    def suggest_chunk(self, op: str = "observe_many", *,
                      cap_bucket: int | None = None,
                      engine: str | None = None,
                      overhead_frac: float = 0.05,
                      max_chunk: int = 1024) -> int:
        """Smallest observe_many chunk whose per-tick dispatch-overhead
        share is <= ``overhead_frac`` under the fitted model.

        Replaces the hand-tuned serving constant (chunk=64 in the
        benches). Falls back to the plain-``observe`` fit when the
        trace never chunked, and to ``max_chunk`` when the marginal
        cost is unresolvable (b == 0: overhead is everything, so chunk
        as much as latency tolerates).
        """
        if not 0.0 < overhead_frac < 1.0:
            raise ValueError("overhead_frac must be in (0, 1)")
        e = self._entry(op, cap_bucket, engine)
        if e is None or (e["b"] == 0.0 and e["a"] == 0.0):
            e = self._entry("observe", cap_bucket, engine)
        if e is None:
            raise KeyError(f"no fitted entry for op {op!r} / 'observe'")
        a, b = e["a"], e["b"]
        if b <= 0.0:
            return max_chunk
        t = a * (1.0 - overhead_frac) / (b * overhead_frac)
        return int(min(max(math.ceil(t), 1), max_chunk))

    def fit_capacity_scaling(self, op: str = "observe_many", *,
                             engine: str | None = None) -> tuple[float,
                                                                 float]:
        """(c, alpha) of per-tick cost ~ c * bucket^alpha across fitted
        buckets (log-log LS). Falls back to alpha=1 (linear — the
        memory-traffic model of the O(cap) tick) with fewer than two
        distinct buckets."""
        pts = [(c, e["a"] + e["b"]) if e["b"] == 0.0 else (c, e["b"])
               for (eng, o, c), e in self.entries.items()
               if o == op and c > 0 and (engine is None or eng == engine)]
        pts = [(c, v) for c, v in pts if v > 0.0]
        if len({c for c, _ in pts}) < 2:
            if not pts:
                return 0.0, 1.0
            c0, v0 = pts[0]
            return v0 / c0, 1.0
        lx = [math.log(c) for c, _ in pts]
        ly = [math.log(v) for _, v in pts]
        n = len(pts)
        mx, my = sum(lx) / n, sum(ly) / n
        sxx = sum((x - mx) ** 2 for x in lx)
        sxy = sum((x - mx) * (y - my) for x, y in zip(lx, ly))
        alpha = sxy / sxx
        c = math.exp(my - alpha * mx)
        return c, alpha

    def suggest_buckets(self, *, cap_min: int, cap_max: int,
                        op: str = "observe_many",
                        engine: str | None = None,
                        cost_ratio: float = 2.0) -> list[int]:
        """Capacity-bucket boundaries spaced geometrically in *cost*.

        Each bucket's top-to-bottom modeled cost ratio is at most
        ``cost_ratio`` (2.0 reproduces the hand-tuned power-of-two
        scheme exactly when cost scales linearly with capacity). The
        boundaries are what the engine pool should retrace at; the last
        one always covers ``cap_max``.
        """
        if cap_min < 1 or cap_max < cap_min:
            raise ValueError(f"bad capacity range [{cap_min}, {cap_max}]")
        if cost_ratio <= 1.0:
            raise ValueError("cost_ratio must be > 1")
        _, alpha = self.fit_capacity_scaling(op, engine=engine)
        # clamp: a near-flat fit would put every capacity in one bucket
        # (growth factor -> inf) and a wildly super-linear one would
        # bucket per-capacity; both are fit noise at small trace sizes
        alpha = min(max(alpha, 0.25), 4.0)
        growth = cost_ratio ** (1.0 / alpha)
        growth = min(max(growth, 1.189), 16.0)  # >= 2**(1/4) per bucket
        bounds = [int(cap_min)]
        while bounds[-1] < cap_max:
            nxt = max(int(math.ceil(bounds[-1] * growth)), bounds[-1] + 1)
            bounds.append(min(nxt, int(cap_max)))
        return bounds

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> dict[str, Any]:
        return {
            "version": MODEL_VERSION,
            "meta": self.meta,
            "entries": [
                {"engine": e, "op": o, "cap_bucket": c, **params}
                for (e, o, c), params in sorted(self.entries.items())
            ],
        }

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "CostModel":
        if d.get("version") != MODEL_VERSION:
            raise ValueError(f"cost model version {d.get('version')} != "
                             f"{MODEL_VERSION}")
        entries = {}
        for e in d["entries"]:
            entries[(e["engine"], e["op"], int(e["cap_bucket"]))] = {
                "a": float(e["a"]), "b": float(e["b"]),
                "n": float(e["n"])}
        return cls(entries, d.get("meta"))

    def save(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CostModel":
        with open(path) as f:
            return cls.from_json(json.load(f))


def fit_cost_model(records: Iterable[dict[str, Any]],
                   **meta: Any) -> CostModel:
    """Module-level alias for ``CostModel.fit``."""
    return CostModel.fit(records, **meta)


__all__ = ["MODEL_VERSION", "CostModel", "fit_cost_model"]
