"""Low-overhead observability for the CP serving stack.

Four pieces, composable and individually optional:

* ``metrics``  — process-wide registry of counters / gauges /
  fixed-bucket latency histograms (p50/p99) with plain-text and JSON
  export. No external deps, no background threads.
* ``tracer``   — JSONL per-op trace recorder (one record per engine
  dispatch: op kind, tenant count, capacity bucket, wall time,
  compile-vs-steady flag). The recorded file doubles as the input
  format for the trace-replay benchmark harness (ROADMAP item).
* ``device``   — in-graph per-tick counters (evictions, ring wraps,
  occupancy) carried alongside engine state and drained to host
  metrics without breaking buffer donation or bit-exactness.
* ``validity`` — online CP correctness monitors: rolling empirical
  coverage vs 1-eps, a vectorized p-value-uniformity (ECDF/KS)
  statistic, and the exchangeability drift martingales, all surfaced
  as metrics instead of one-shot prints.
* ``loadgen``  — synthetic trace generators (steady / bursty /
  diurnal / zipf-tenant-skewed) emitting the same schema the tracer
  records, so generated and recorded traces are interchangeable
  replay inputs.
* ``replay``   — drive either serving engine from a trace, preserving
  (or compressing) inter-arrival timing; reports p50/p99 per-op
  latency, steps/s, queue depth and SLO-violation fraction.
* ``costmodel``— per-(op, capacity-bucket) latency model fitted from
  any trace; ``suggest_chunk`` / ``suggest_buckets`` replace the
  hand-tuned observe_many chunk size and power-of-two bucketing.

The engines accept ``instrument=True`` (plus optional ``metrics=`` /
``tracer=``) and stay bit-identical to the uninstrumented path — the
device stats only *read* the cheap integer bookkeeping leaves
(``n``/``head``/``wrap``), never the float state (property-tested).
"""
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, get_registry,
                                     set_registry)
from repro.telemetry.tracer import (OP_KINDS, TRACE_SCHEMA, Tracer,
                                    capacity_bucket, iter_trace,
                                    read_trace, validate_record,
                                    validate_trace_file, write_trace)
from repro.telemetry.device import TickStats, make_chunk_stats_fn
from repro.telemetry.hooks import EngineTelemetry
from repro.telemetry.validity import (CoverageMonitor, DriftMonitor,
                                      UniformityMonitor)
from repro.telemetry.costmodel import CostModel, fit_cost_model
from repro.telemetry import loadgen
from repro.telemetry.replay import ReplayResult, calibrate_engine, replay

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "set_registry",
    "OP_KINDS", "TRACE_SCHEMA", "Tracer", "capacity_bucket", "iter_trace",
    "read_trace", "validate_record", "validate_trace_file", "write_trace",
    "TickStats", "make_chunk_stats_fn", "EngineTelemetry",
    "CoverageMonitor", "DriftMonitor", "UniformityMonitor",
    "CostModel", "fit_cost_model", "loadgen",
    "ReplayResult", "calibrate_engine", "replay",
]
