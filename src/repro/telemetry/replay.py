"""Trace replay harness: re-drive the serving engines from a trace.

The decoding half of the tracer: a recorded (``serve.py --trace-out``)
or generated (``telemetry.loadgen``) JSONL trace is replayed against
either serving engine — classification (``repro.serving``) or
regression (``repro.regression``) — preserving the trace's
inter-arrival timing (or compressing it via ``speedup``), and reporting
what the ROADMAP's load story needs: p50/p99 per-op latency
(device-true — the engines run with ``sync_timing=True``), session
steps/s, queue depth, and the SLO-violation fraction, all through the
ordinary ``MetricsRegistry``.

Semantics
---------
* A record's ``t`` is its *arrival* on the trace clock; replay arrival
  is ``t / speedup``. The loop sleeps until a batch's last arrival,
  dispatches synchronously, and measures each record's **sojourn**
  (completion - arrival): queueing delay during bursts shows up in the
  p99 exactly as it would in a live server. ``speedup=inf`` drops the
  clock entirely (every op back-to-back): sojourns then equal service
  times and queue depth degenerates to the remaining backlog — the
  right mode for determinism tests and CI, documented as such.
* Replayed traffic is synthesized deterministically from ``(seed,
  record seq, tick)`` — same trace + same seed => bit-identical final
  engine state, independent of wall-clock jitter and of the
  ``chunk`` coalescing below (chunking is bit-neutral by the engines'
  observe_many property).
* ``chunk=N`` coalesces runs of consecutive single-tick ``observe``
  records into one ``observe_many`` dispatch of up to N ticks — the
  knob ``costmodel.suggest_chunk`` tunes. Records keep their own
  arrival times, so batching's latency cost (early arrivals wait for
  the batch to fill) is measured, not hidden.
* Ops with no engine counterpart on the vmapped path (``fit``,
  ``evict`` — eviction is the sliding window's job — ``grow``,
  ``snapshot_*``) are skipped and counted in
  ``replay_skipped_ops_total``. Read ops map onto the engine's read
  path (classification: ``predict``; regression: ``intervals``).

Fault schedule (tracer schema v3, ``robustness.faults``)
--------------------------------------------------------
* ``duplicate_arrival`` records are at-least-once re-deliveries of an
  earlier event id: replay dedups them at ingest
  (``replay_duplicates_dropped_total``) — the surviving stream is the
  trace minus its duplicates, so the final state is bit-identical to
  replaying the never-duplicated trace.
* ``delay_s`` shifts a record's arrival to ``t + delay_s`` (the
  injected dispatch delay); batches wait for their latest member.
* Traffic value faults (``fault.kind`` in ``VALUE_FAULTS``) corrupt
  that record's synthesized tick for ``fault["tenant"]`` — what the
  ``guard=True`` admission check is there to catch.

Overload controls
-----------------
``shed_depth=N`` enables queue-depth load shedding: when the backlog
exceeds N, arriving READ ops are shed (counted per op in
``replay_shed_ops_total``, never dispatched); past ``2 * N`` observes
are DEFERRED (``replay_deferred_observes_total``) into a pending queue
flushed every ``defer_flush`` ticks, before any dispatched read (reads
see all prior writes), and at end of trace. Observe order is
preserved, so the final engine state stays bit-identical to the
unshed replay; deferred records pay their true (larger) sojourn.
"""
from __future__ import annotations

import io
import math
import time
from typing import Any, Iterable

import numpy as np

from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.tracer import Tracer

_DRIVE_OPS = frozenset({"observe", "observe_many"})
_READ_OPS = frozenset({"predict", "intervals", "pvalues"})


class ReplayResult:
    """Outcome of one replay: the report dict, final engine state, and
    the engine/metrics that produced it (for determinism checks and
    follow-up reads). Sharded replays (``shards > 1``) concatenate the
    per-shard states back into the full (S, ...) tree — bit-identical
    to the unsharded replay's state — and ``engine`` holds the list of
    per-shard engines."""

    def __init__(self, report: dict[str, Any], state, engine, metrics):
        self.report = report
        self.state = state
        self.engine = engine
        self.metrics = metrics


def _make_engine(kind: str, *, tenants, capacity, window, dim, k,
                 n_labels, metrics, tracer):
    if kind == "regression":
        from repro.regression import RegressionServingEngine
        return RegressionServingEngine(
            n_sessions=tenants, capacity=capacity, dim=dim, k=k,
            window=window, instrument=True, metrics=metrics,
            tracer=tracer, sync_timing=True)
    from repro.serving import ServingEngine
    return ServingEngine(
        n_sessions=tenants, capacity=capacity, dim=dim, k=k,
        n_labels=n_labels, window=window, instrument=True,
        metrics=metrics, tracer=tracer, sync_timing=True)


def _tick_traffic(seed: int, seq: int, tick: int, S: int, dim: int,
                  kind: str):
    """One tick of deterministic synthetic traffic for record ``seq``."""
    rng = np.random.default_rng((seed, seq, tick))
    x = rng.standard_normal((S, dim)).astype(np.float32)
    if kind == "regression":
        y = rng.standard_normal(S).astype(np.float32)
    else:
        y = (rng.random(S) < 0.5).astype(np.int32)
    tau = rng.random(S).astype(np.float32)
    return x, y, tau


def _plan_batches(records: list[dict[str, Any]],
                  chunk: int | None) -> list[list[int]]:
    """Group record indices into dispatch batches.

    Read ops and multi-tick observe_many records dispatch alone;
    consecutive single-tick observes coalesce up to ``chunk``.
    """
    batches: list[list[int]] = []
    run: list[int] = []
    for i, rec in enumerate(records):
        single_obs = rec["op"] == "observe" and rec.get("ticks", 1) == 1
        if chunk and chunk > 1 and single_obs:
            run.append(i)
            if len(run) >= chunk:
                batches.append(run)
                run = []
            continue
        if run:
            batches.append(run)
            run = []
        batches.append([i])
    if run:
        batches.append(run)
    return batches


def replay(records: Iterable[dict[str, Any]], *,
           engine: str = "classification", dim: int = 8, k: int = 7,
           n_labels: int = 2, capacity: int | None = None,
           window: int | None = None, speedup: float = math.inf,
           seed: int = 0, slo_s: float | None = None,
           chunk: int | None = None, eps: float = 0.1,
           metrics: MetricsRegistry | None = None,
           tracer: Tracer | None = None, shards: int = 1,
           shed_depth: int | None = None, defer_flush: int = 64,
           guard: bool = False) -> ReplayResult:
    """Replay a trace against one engine; see module doc for semantics.

    ``records`` may be a list or a generator (``tracer.iter_trace``);
    geometry defaults come from the trace (``tenants`` / ``capacity``
    maxima), overridable per argument. ``slo_s`` is the default latency
    objective; a record's own ``slo_s`` field wins. Returns a
    ``ReplayResult`` whose ``report`` carries p50/p99 per op, steps/s,
    queue depth, and the SLO-violation fraction.

    ``shards > 1`` partitions the tenant axis into contiguous groups,
    replays each against its own engine with its own metrics registry
    (the multi-process collection shape), and merges the per-shard
    registries into one report via ``MetricsRegistry.merge``. Traffic
    is still synthesized at full width and sliced per shard, and the
    trace's ``active`` masks partition with the tenants, so the
    concatenated final state is bit-identical to the unsharded replay
    (tested). The report gains ``shards`` and ``per_shard`` (tenants,
    session steps, occupancy per shard).

    ``shed_depth`` / ``defer_flush`` enable load shedding and
    ``guard=True`` wraps every shard engine in a
    ``robustness.guard.TickGuard`` (admission + quarantine; the
    report gains a merged ``guard`` section) — module doc for both.
    """
    if speedup <= 0:
        raise ValueError("speedup must be > 0 (math.inf compresses)")
    metrics = metrics if metrics is not None else MetricsRegistry()
    all_recs = list(records)
    def _is_dup(r):
        return r.get("fault", {}).get("kind") == "duplicate_arrival"

    n_dups = sum(1 for r in all_recs if _is_dup(r))
    if n_dups:  # at-least-once delivery: drop re-delivered event ids
        metrics.counter("replay_duplicates_dropped_total").inc(n_dups)
        all_recs = [r for r in all_recs if not _is_dup(r)]
    played = [r for r in all_recs if r["op"] in _DRIVE_OPS | _READ_OPS]
    for r in all_recs:
        if r["op"] not in _DRIVE_OPS | _READ_OPS:
            metrics.counter("replay_skipped_ops_total", op=r["op"]).inc()
    if not played:
        raise ValueError("trace contains no replayable ops")

    S = max(int(r.get("tenants", 1)) for r in played)
    if not 1 <= shards <= S:
        raise ValueError(f"shards {shards} outside [1, tenants={S}]")
    cap = capacity or max((int(r.get("capacity", 0)) for r in played),
                          default=0) or 128
    cap = max(cap, k + 1)
    window = window if window is not None else max(k, cap // 2)
    cuts = [S * i // shards for i in range(shards + 1)]
    shard_metrics = ([metrics] if shards == 1
                     else [MetricsRegistry() for _ in range(shards)])
    engs = [_make_engine(engine, tenants=cuts[i + 1] - cuts[i],
                         capacity=cap, window=window, dim=dim, k=k,
                         n_labels=n_labels, metrics=shard_metrics[i],
                         tracer=tracer)
            for i in range(shards)]
    drivers: list[Any] = engs
    if guard:
        from repro.robustness.guard import TickGuard
        drivers = [TickGuard(engs[i], metrics=shard_metrics[i])
                   for i in range(shards)]
    batches = _plan_batches(played, chunk)

    # ---- compile warmup: one throwaway dispatch per distinct shape ---------
    # signature so every timed dispatch below is steady-state. Warmup
    # traffic comes from a disjoint seq namespace; the warmed state is
    # discarded (the engines donate their inputs, so we chain through).
    tick_counts = sorted({
        sum(played[i].get("ticks", 1) for i in b)
        for b in batches if played[b[0]]["op"] in _DRIVE_OPS})
    warm_reads = any(played[b[0]]["op"] in _READ_OPS for b in batches)
    for si, eng in enumerate(engs):
        lo, hi = cuts[si], cuts[si + 1]
        warm_state = eng.init_state()
        for wi, T in enumerate(tick_counts):
            xs, ys, taus = _stack_ticks(
                [(10 ** 9 + wi, j) for j in range(T)], seed, S, dim,
                engine)
            warm_state, _ = drivers[si].observe_many(
                warm_state, xs[:, lo:hi], ys[:, lo:hi], taus[:, lo:hi])
        if warm_reads:
            _read(eng, warm_state, engine, seed, 10 ** 9, dim, eps)
        del warm_state
        eng.reset_occupancy()
        if eng.telemetry is not None:  # keep warmup out of the tick stats
            eng.telemetry.ticks.reset()

    states = [eng.init_state() for eng in engs]
    arrivals = ([0.0] * len(played) if math.isinf(speedup)
                else [(r["t"] + r.get("delay_s", 0.0)) / speedup
                      for r in played])
    qhist = metrics.histogram(
        "replay_queue_depth",
        bounds=tuple(float(2 ** e) for e in range(0, 17)))
    slo_total = 0
    slo_checked = 0
    ticks_total = 0
    steps_total = 0
    arrived_ptr = 0
    completed = 0
    shed_total = 0
    deferred_total = 0
    pending: list[list[int]] = []  # deferred observe batches, in order
    pending_ticks = 0
    t0 = time.perf_counter()

    def _account(batch, done, service):
        nonlocal slo_total, slo_checked, completed
        for i in batch:
            rec = played[i]
            sojourn = (service if math.isinf(speedup)
                       else done - arrivals[i])
            metrics.histogram("replay_sojourn_s", op=rec["op"]).observe(
                sojourn)
            metrics.counter("replay_ops_total", op=rec["op"]).inc()
            slo = rec.get("slo_s", slo_s)
            if slo is not None:
                slo_checked += 1
                if sojourn > slo:
                    slo_total += 1
        completed += len(batch)

    def _dispatch_observes(batch):
        nonlocal ticks_total, steps_total
        keys = [(played[i]["seq"], j) for i in batch
                for j in range(played[i].get("ticks", 1))]
        xs, ys, taus = _stack_ticks(keys, seed, S, dim, engine)
        _corrupt_batch(xs, ys, taus, [played[i] for i in batch],
                       engine, n_labels)
        active = _stack_active(
            [played[i] for i in batch], S)
        for si in range(shards):
            lo, hi = cuts[si], cuts[si + 1]
            states[si], _p = drivers[si].observe_many(
                states[si], xs[:, lo:hi], ys[:, lo:hi],
                taus[:, lo:hi], active=active[:, lo:hi])
        ticks_total += len(keys)
        steps_total += int(active.sum())

    def _flush_pending():
        """Dispatch the deferred observe batches (original batch
        shapes, original order: bit-identical final state)."""
        nonlocal pending, pending_ticks
        if not pending:
            return
        d0 = time.perf_counter()
        for pb in pending:
            _dispatch_observes(pb)
        done = time.perf_counter() - t0
        service = time.perf_counter() - d0
        for pb in pending:
            _account(pb, done, service)
        pending = []
        pending_ticks = 0

    for batch in batches:
        recs = [played[i] for i in batch]
        op = recs[0]["op"]
        if not math.isinf(speedup):
            # wait for the batch's LATEST member (injected delay_s can
            # put it after the batch-closing record)
            last_arr = max(arrivals[i] for i in batch)
            wait = last_arr - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
        now = time.perf_counter() - t0
        while arrived_ptr < len(played) and arrivals[arrived_ptr] <= now:
            arrived_ptr += 1
        backlog = max(arrived_ptr, batch[-1] + 1) - completed
        qhist.observe(backlog)

        if op in _DRIVE_OPS:
            if shed_depth is not None and backlog > 2 * shed_depth:
                pending.append(batch)
                pending_ticks += sum(played[i].get("ticks", 1)
                                     for i in batch)
                deferred_total += len(batch)
                metrics.counter("replay_deferred_observes_total").inc(
                    len(batch))
                if pending_ticks >= defer_flush:
                    _flush_pending()
                continue
            _flush_pending()  # observes stay in arrival order
            d0 = time.perf_counter()
            _dispatch_observes(batch)
            done = time.perf_counter() - t0
            _account(batch, done, time.perf_counter() - d0)
        else:
            if shed_depth is not None and backlog > shed_depth:
                # shed reads first: cheaper to drop, no state impact
                shed_total += len(batch)
                metrics.counter("replay_shed_ops_total", op=op).inc(
                    len(batch))
                completed += len(batch)
                continue
            _flush_pending()  # a served read sees all prior writes
            d0 = time.perf_counter()
            for si, eng in enumerate(engs):
                _read(eng, states[si], engine, seed, recs[0]["seq"], dim,
                      eps)
            done = time.perf_counter() - t0
            _account(batch, done, time.perf_counter() - d0)
    _flush_pending()
    wall = time.perf_counter() - t0

    # ---- per-shard accounting + registry merge -----------------------------
    per_shard = []
    for si, eng in enumerate(engs):
        tot = eng.telemetry.ticks.drain() if eng.telemetry else {}
        ticks_si = tot.get("ticks", 0)
        per_shard.append({
            "shard": si,
            "tenants": cuts[si + 1] - cuts[si],
            "session_steps": ticks_si,
            "occupancy_mean": (tot.get("occupancy_sum", 0) / ticks_si
                               if ticks_si else math.nan),
            "occupancy_max": tot.get("occupancy_max", 0),
        })
    if shards > 1:
        for sm in shard_metrics:
            metrics.merge(sm)

    # ---- report ------------------------------------------------------------
    engine_label = ("regression" if engine == "regression"
                    else "classification")
    per_op: dict[str, dict[str, float]] = {}
    for op in sorted({r["op"] for r in played}):
        eng_op = _engine_op(op, engine)
        h = metrics.histogram(f"engine_{eng_op}_wall_s",
                              engine=engine_label)
        s = metrics.histogram("replay_sojourn_s", op=op).snapshot()
        per_op[op] = {
            "p50_s": h.quantile(0.5), "p99_s": h.quantile(0.99),
            "sojourn_p50_s": s["p50"], "sojourn_p99_s": s["p99"],
            "count": s["count"],
        }
    viol_frac = slo_total / slo_checked if slo_checked else math.nan
    metrics.counter("replay_slo_violations_total").inc(slo_total)
    metrics.gauge("replay_slo_violation_frac").set(viol_frac)
    metrics.gauge("replay_wall_s").set(wall)
    metrics.gauge("replay_steps_per_s").set(
        steps_total / wall if wall > 0 else math.nan)
    metrics.gauge("replay_ticks_total").set(ticks_total)
    metrics.gauge("replay_queue_depth_max").set(
        qhist.max if qhist.count else 0.0)
    report = {
        "engine": engine,
        "tenants": S,
        "capacity": cap,
        "window": window,
        "ops_replayed": len(played),
        "ops_skipped": len(all_recs) - len(played),
        "ticks": ticks_total,
        "session_steps": steps_total,
        "wall_s": wall,
        "steps_per_s": steps_total / wall if wall > 0 else math.nan,
        "speedup": speedup,
        "chunk": chunk,
        "slo_s": slo_s,
        "slo_violation_frac": viol_frac,
        "queue_depth_max": float(qhist.max) if qhist.count else 0.0,
        "per_op": per_op,
        "shards": shards,
        "per_shard": per_shard,
        "shed_depth": shed_depth,
        "shed_ops": shed_total,
        "deferred_observes": deferred_total,
        "duplicates_dropped": n_dups,
    }
    if guard:
        gtot: dict[str, Any] = {"rejected": {}, "quarantines": 0,
                                "restores": 0, "quarantined_lanes": []}
        for si, g in enumerate(drivers):
            states[si] = g.finalize(states[si])  # flush deferred sweep
            d = g.drain()
            for kind, v in d["rejected"].items():
                gtot["rejected"][kind] = gtot["rejected"].get(kind, 0) + v
            gtot["quarantines"] += d["quarantines"]
            gtot["restores"] += d["restores"]
            gtot["quarantined_lanes"] += [
                cuts[si] + lane for lane in d["quarantined_lanes"]]
        report["guard"] = gtot
    if shards == 1:
        state, eng_out = states[0], engs[0]
    else:
        import jax as _jax
        import jax.numpy as _jnp

        state = _jax.tree_util.tree_map(
            lambda *ls: _jnp.concatenate(ls, axis=0), *states)
        eng_out = engs
    return ReplayResult(report, state, eng_out, metrics)


def _engine_op(trace_op: str, engine: str) -> str:
    """The engine op a trace op lands on (reads are remapped)."""
    if trace_op in _DRIVE_OPS:
        return "observe_many"
    return "intervals" if engine == "regression" else "predict"


def _corrupt_batch(xs, ys, taus, recs: list[dict[str, Any]], kind: str,
                   n_labels: int) -> None:
    """Apply each record's stamped traffic value fault (schema v3
    ``fault`` field) to its rows of the stacked tick arrays, in place."""
    if not any("fault" in r for r in recs):
        return
    from repro.robustness.faults import VALUE_FAULTS, poisoned_values

    mode = "regression" if kind == "regression" else "classification"
    off = 0
    for r in recs:
        T = r.get("ticks", 1)
        f = r.get("fault")
        if f and f.get("kind") in VALUE_FAULTS:
            lane = int(f.get("tenant", 0)) % xs.shape[1]
            xv, yv, tv = poisoned_values(f["kind"], mode=mode,
                                         n_labels=n_labels)
            for t in range(off, off + T):
                if xv is not None:
                    xs[t, lane, 0] = xv
                if yv is not None:
                    ys[t, lane] = yv
                if tv is not None:
                    taus[t, lane] = tv
        off += T


def _stack_ticks(keys: list[tuple[int, int]], seed: int, S: int, dim: int,
                 kind: str):
    cols = [_tick_traffic(seed, sq, j, S, dim, kind) for sq, j in keys]
    xs = np.stack([c[0] for c in cols])
    ys = np.stack([c[1] for c in cols])
    taus = np.stack([c[2] for c in cols])
    return xs, ys, taus


def _stack_active(recs: list[dict[str, Any]], S: int) -> np.ndarray:
    rows = []
    for rec in recs:
        T = rec.get("ticks", 1)
        if "active" in rec:
            row = np.zeros(S, bool)
            row[[s for s in rec["active"] if s < S]] = True
        else:
            row = np.ones(S, bool)
        rows.extend([row] * T)
    return np.stack(rows)


def _read(eng, state, kind: str, seed: int, seq: int, dim: int,
          eps: float, m: int = 4):
    rng = np.random.default_rng((seed, seq))
    xq = rng.standard_normal((m, dim)).astype(np.float32)
    if kind == "regression":
        return eng.intervals(state, xq, eps)
    return eng.predict(state, xq)


def calibrate_engine(engine: str = "classification", *, tenants: int = 8,
                     capacity: int = 128, window: int | None = None,
                     dim: int = 8, k: int = 7, n_labels: int = 2,
                     chunks: tuple[int, ...] = (1, 4, 16, 64),
                     reps: int = 3, seed: int = 0) -> list[dict[str, Any]]:
    """Probe observe_many at several chunk lengths; return the trace.

    The quick way to get timing data when the input trace has none (a
    loadgen trace records arrivals, not costs): a few synchronized
    dispatches per chunk length, recorded through the ordinary tracer,
    ready for ``costmodel.CostModel.fit``. Compile dispatches are
    flagged as such and excluded by the fit.
    """
    import json as _json

    buf = io.StringIO()
    tr = Tracer(buf)
    window = window if window is not None else max(k, capacity // 2)
    eng = _make_engine(engine, tenants=tenants, capacity=capacity,
                       window=window, dim=dim, k=k, n_labels=n_labels,
                       metrics=MetricsRegistry(), tracer=tr)
    state = eng.init_state()
    for ci, T in enumerate(sorted(set(chunks))):
        for r in range(reps + 1):  # +1: the compile rep, flagged
            xs, ys, taus = _stack_ticks(
                [(ci * (reps + 1) + r, j) for j in range(T)],
                seed, tenants, dim, engine)
            state, _ = eng.observe_many(state, xs, ys, taus)
    tr.close()
    return [_json.loads(line) for line in buf.getvalue().splitlines()]


__all__ = ["ReplayResult", "replay", "calibrate_engine"]
