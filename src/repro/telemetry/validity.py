"""Online validity monitors: the CP correctness signals, as metrics.

Under exchangeability, online conformal prediction guarantees two
observable invariants (Vovk et al.; Zeni et al., "Conformal Prediction:
a Unified Review"): the smoothed p-value of the *observed* label is
uniform on [0, 1], and consequently the eps-level prediction set covers
the observed label with probability 1 - eps. The test suite asserts
both offline; these monitors track them *in serving*, per tenant, over
a rolling window, so drift/miscoverage is a dashboard line instead of a
post-mortem:

* ``CoverageMonitor``   — rolling empirical coverage vs the 1 - eps
  target: the observed label is in the eps-level set iff its smoothed
  p-value exceeds eps.
* ``UniformityMonitor`` — rolling two-sided Kolmogorov-Smirnov distance
  sup_u |ECDF(u) - u| of the p-value stream, vectorized across tenants
  (large KS at stable coverage = the sets are mis-sized, not just
  mis-centered).
* ``DriftMonitor``      — the simple-mixture exchangeability martingale
  (``core.online.simple_mixture_log_martingale``) maintained
  *incrementally* per tenant: log M grows past the threshold only under
  non-exchangeable traffic (valid by Ville's inequality).

All monitors are host-side numpy over the p-values the engines already
return — they add nothing to the device graph. NaN p-values (inactive
lanes / warmup) are skipped per tenant, so tenants advance on their own
clocks. ``export(metrics)`` publishes aggregate gauges; per-tenant
series are available from the arrays directly.
"""
from __future__ import annotations

import math

import numpy as np

_EPS_GRID = np.linspace(0.05, 0.95, 19)  # == simple_mixture_log_martingale
_P_FLOOR = 1e-12


class _RollingBuffer:
    """Per-tenant rolling window over an unevenly advancing stream."""

    def __init__(self, n_tenants: int, window: int):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.n_tenants = n_tenants
        self.window = window
        self.buf = np.full((n_tenants, window), np.nan)
        self.count = np.zeros(n_tenants, dtype=np.int64)  # total stored

    def push(self, values: np.ndarray) -> None:
        """Store ``values[s]`` for every tenant where it is finite."""
        v = np.asarray(values, dtype=float).reshape(-1)
        if v.shape[0] != self.n_tenants:
            raise ValueError(
                f"got {v.shape[0]} values for {self.n_tenants} tenants")
        valid = np.isfinite(v)
        idx = self.count[valid] % self.window
        self.buf[np.flatnonzero(valid), idx] = v[valid]
        self.count[valid] += 1

    def filled(self) -> np.ndarray:
        """(S,) number of live entries per tenant."""
        return np.minimum(self.count, self.window)


class CoverageMonitor:
    """Rolling empirical coverage of the eps-level prediction set.

    ``update`` takes one tick's per-tenant observed-label smoothed
    p-values ((S,) — or a (T, S) block); coverage counts ``p > eps``.
    ``coverage()`` is exactly the mean of the last ``window`` stored
    indicators per tenant (bitwise the same as an offline recomputation
    over the kept suffix — tested).
    """

    def __init__(self, epsilon: float, n_tenants: int, *,
                 window: int = 256):
        if not 0.0 < epsilon < 1.0:
            raise ValueError(f"epsilon {epsilon} outside (0, 1)")
        self.epsilon = float(epsilon)
        self.target = 1.0 - float(epsilon)
        self._buf = _RollingBuffer(n_tenants, window)

    def update(self, pvals) -> None:
        p = np.asarray(pvals, dtype=float)
        if p.ndim == 2:
            for row in p:
                self._buf.push(row)
        else:
            self._buf.push(p)

    def counts(self) -> np.ndarray:
        return self._buf.filled()

    def coverage(self) -> np.ndarray:
        """(S,) rolling empirical coverage; NaN before any observation."""
        m = self._buf.filled()
        hits = np.nansum(self._buf.buf > self.epsilon, axis=1)
        with np.errstate(invalid="ignore", divide="ignore"):
            out = hits / m
        return np.where(m > 0, out, np.nan)

    def export(self, metrics, *, engine: str = "classification") -> None:
        cov = self.coverage()
        seen = cov[np.isfinite(cov)]
        g = lambda name: metrics.gauge(name, engine=engine)  # noqa: E731
        g("validity_coverage_target").set(self.target)
        if seen.size:
            g("validity_coverage_mean").set(float(seen.mean()))
            g("validity_coverage_min").set(float(seen.min()))
            # binomial 3-sigma tolerance at the rolling window size: a
            # tenant below it is miscovering beyond sampling noise
            w = max(int(self._buf.filled().max()), 1)
            tol = 3.0 * math.sqrt(self.target * self.epsilon / w)
            g("validity_coverage_tolerance").set(tol)
            g("validity_tenants_below_target").set(
                int((seen < self.target - tol).sum()))


class UniformityMonitor:
    """Rolling KS distance of the p-value stream from Uniform[0, 1]."""

    def __init__(self, n_tenants: int, *, window: int = 256):
        self._buf = _RollingBuffer(n_tenants, window)

    def update(self, pvals) -> None:
        p = np.asarray(pvals, dtype=float)
        if p.ndim == 2:
            for row in p:
                self._buf.push(row)
        else:
            self._buf.push(p)

    def ks(self) -> np.ndarray:
        """(S,) sup_u |ECDF(u) - u| per tenant; NaN when empty."""
        m = self._buf.filled().astype(float)
        u = np.sort(self._buf.buf, axis=1)  # NaNs sort to the end
        i = np.arange(self._buf.window, dtype=float)[None, :]
        live = i < m[:, None]
        with np.errstate(invalid="ignore", divide="ignore"):
            d_plus = (i + 1.0) / m[:, None] - u
            d_minus = u - i / m[:, None]
        d = np.maximum(np.where(live, d_plus, -np.inf),
                       np.where(live, d_minus, -np.inf)).max(axis=1)
        return np.where(m > 0, d, np.nan)

    def export(self, metrics, *, engine: str = "classification") -> None:
        ks = self.ks()
        seen = ks[np.isfinite(ks)]
        if seen.size:
            metrics.gauge("validity_ks_max", engine=engine).set(
                float(seen.max()))
            metrics.gauge("validity_ks_mean", engine=engine).set(
                float(seen.mean()))


class DriftMonitor:
    """Per-tenant simple-mixture exchangeability martingale, incremental.

    Maintains the per-epsilon log power-martingale sums so each tick is
    an O(S * E) vector add; ``log_m()`` equals
    ``core.online.simple_mixture_log_martingale`` evaluated on the full
    per-tenant p-value history (same mixture grid; float64 here vs the
    device's float32 — equal to numerical tolerance, tested).
    ``flagged()`` applies the Ville threshold to the *running max* of
    log M, the read-out that also catches fast-re-conforming measures.
    """

    def __init__(self, n_tenants: int, *, threshold: float = 2.0):
        self.n_tenants = n_tenants
        self.threshold = float(threshold)
        self._logm = np.zeros((n_tenants, _EPS_GRID.size))
        self.max_log_m = np.full(n_tenants, -np.inf)
        self.ticks = np.zeros(n_tenants, dtype=np.int64)

    def update(self, pvals) -> None:
        p = np.asarray(pvals, dtype=float)
        if p.ndim == 2:
            for row in p:
                self.update(row)
            return
        valid = np.isfinite(p)
        if not valid.any():
            return
        lp = np.log(np.maximum(p[valid], _P_FLOOR))
        inc = np.log(_EPS_GRID)[None, :] + lp[:, None] * (_EPS_GRID - 1.0)
        self._logm[valid] += inc
        self.ticks[valid] += 1
        lm = self._mix(self._logm[valid])
        self.max_log_m[valid] = np.maximum(self.max_log_m[valid], lm)

    @staticmethod
    def _mix(logm_rows: np.ndarray) -> np.ndarray:
        mx = logm_rows.max(axis=1, keepdims=True)
        return (mx[:, 0] + np.log(np.exp(logm_rows - mx).sum(axis=1))
                - np.log(_EPS_GRID.size))

    def log_m(self) -> np.ndarray:
        """(S,) current log mixture martingale (0 before any tick)."""
        out = self._mix(self._logm)
        return np.where(self.ticks > 0, out, 0.0)

    def flagged(self, *, use_max: bool = True) -> np.ndarray:
        stat = self.max_log_m if use_max else self.log_m()
        return stat > self.threshold

    def export(self, metrics, *, engine: str = "classification",
               use_max: bool = True) -> None:
        lm = self.log_m()
        mx = (float(np.max(self.max_log_m)) if (self.ticks > 0).any()
              else 0.0)
        metrics.gauge("drift_log_m_max", engine=engine).set(mx)
        metrics.gauge("drift_log_m_mean", engine=engine).set(
            float(np.mean(lm)))
        metrics.gauge("drift_threshold", engine=engine).set(self.threshold)
        metrics.gauge("drift_tenants_flagged", engine=engine).set(
            int(self.flagged(use_max=use_max).sum()))


__all__ = ["CoverageMonitor", "UniformityMonitor", "DriftMonitor"]
