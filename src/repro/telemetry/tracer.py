"""JSONL per-op trace recorder for the serving hot path.

One JSON object per line, one line per engine-level operation. The file
is the recording half of the ROADMAP's trace-driven benchmark: a replay
harness can re-drive the engines from the ``op``/``ticks``/``tenants``
sequence, and the timing fields calibrate per-bucket cost models.

Schema (``TRACE_SCHEMA``) — every record carries the required fields;
optional fields appear when the recorder knows them:

required
    schema      int   trace format version (== SCHEMA_VERSION)
    seq         int   per-tracer monotone record index
    t           float seconds since tracer start (host clock)
    op          str   one of OP_KINDS
    wall_s      float host wall time around the dispatch. JAX dispatch
                      is async: unless the caller synchronized, this is
                      enqueue + host-side time, not device time (the
                      per-op histogram of synchronized loops — e.g. the
                      launcher's per-tick loop, which fetches p-values
                      every tick — is device-true).
optional
    compile     bool  first call at this (op, shape signature): wall_s
                      includes XLA compile ("compile-vs-steady" flag)
    tenants     int   session slots in the dispatch
    ticks       int   ticks advanced (observe_many chunk length)
    capacity    int   per-session padded capacity
    cap_bucket  int   next_pow2(capacity) — the retrace bucket
    engine      str   "classification" | "regression" | "registry"
    dispatch_s  float device-synchronized time, when the caller timed a
                      ``block_until_ready`` explicitly
    extra: any remaining keys are recorder-specific (e.g. drained device
    counters on a flush record) and must be JSON-serializable.

``Tracer(path, annotate=True)`` additionally wraps each recorded op in a
``jax.profiler.TraceAnnotation`` so records line up with device traces
captured via ``jax.profiler.trace()``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, IO

SCHEMA_VERSION = 1

OP_KINDS = (
    "observe", "observe_many", "predict", "intervals", "pvalues",
    "evict", "grow", "snapshot_save", "snapshot_restore", "fit",
)

_REQUIRED = {"schema": int, "seq": int, "t": float, "op": str,
             "wall_s": float}
_OPTIONAL = {"compile": bool, "tenants": int, "ticks": int,
             "capacity": int, "cap_bucket": int, "engine": str,
             "dispatch_s": float}

TRACE_SCHEMA = {"version": SCHEMA_VERSION, "required": _REQUIRED,
                "optional": _OPTIONAL, "op_kinds": OP_KINDS}


def capacity_bucket(capacity: int) -> int:
    """The engine retrace bucket: smallest power of two >= capacity."""
    return 1 << max(int(capacity) - 1, 0).bit_length()


def validate_record(rec: dict[str, Any]) -> None:
    """Raise ValueError if ``rec`` does not satisfy TRACE_SCHEMA."""
    for k, ty in _REQUIRED.items():
        if k not in rec:
            raise ValueError(f"trace record missing required field {k!r}: "
                             f"{rec}")
        v = rec[k]
        ok = isinstance(v, ty) or (ty is float and isinstance(v, int)
                                   and not isinstance(v, bool))
        if not ok or (ty is int and isinstance(v, bool)):
            raise ValueError(
                f"trace field {k!r} has type {type(v).__name__}, "
                f"expected {ty.__name__}: {rec}")
    if rec["schema"] != SCHEMA_VERSION:
        raise ValueError(f"trace schema {rec['schema']} != "
                         f"{SCHEMA_VERSION}")
    if rec["op"] not in OP_KINDS:
        raise ValueError(f"unknown trace op {rec['op']!r} "
                         f"(known: {OP_KINDS})")
    for k, ty in _OPTIONAL.items():
        if k in rec:
            v = rec[k]
            ok = isinstance(v, ty) or (ty is float and isinstance(v, int)
                                       and not isinstance(v, bool))
            if not ok or (ty is int and isinstance(v, bool)):
                raise ValueError(
                    f"trace field {k!r} has type {type(v).__name__}, "
                    f"expected {ty.__name__}: {rec}")


def read_trace(path: str) -> list[dict[str, Any]]:
    """Load a JSONL trace file (no validation; see validate_trace_file)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_trace_file(path: str) -> list[dict[str, Any]]:
    """Read + schema-validate every record; returns the records."""
    recs = read_trace(path)
    seq = -1
    for rec in recs:
        validate_record(rec)
        if rec["seq"] <= seq:
            raise ValueError(f"trace seq not monotone at {rec['seq']}")
        seq = rec["seq"]
    return recs


class Tracer:
    """Append-only JSONL trace writer.

    Records are flushed per line (the file is valid mid-run; a crash
    loses at most the current line). ``annotate=True`` wraps ``op()``
    bodies in ``jax.profiler.TraceAnnotation(op)`` so host records can
    be joined against an XLA profiler trace of the same run.
    """

    def __init__(self, path_or_file: str | IO[str], *,
                 annotate: bool = False):
        if isinstance(path_or_file, str):
            d = os.path.dirname(path_or_file)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f: IO[str] = open(path_or_file, "w")
            self._owns = True
            self.path: str | None = path_or_file
        else:
            self._f = path_or_file
            self._owns = False
            self.path = getattr(path_or_file, "name", None)
        self.annotate = annotate
        self._t0 = time.perf_counter()
        self._seq = 0
        self._seen: set = set()
        self._closed = False

    # -- compile-vs-steady ---------------------------------------------------

    def first_call(self, op: str, signature: Any = None) -> bool:
        """True exactly once per (op, signature): the compile call."""
        key = (op, signature)
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    # -- recording -----------------------------------------------------------

    def record(self, op: str, wall_s: float, **fields) -> dict[str, Any]:
        if self._closed:
            return {}
        rec: dict[str, Any] = {
            "schema": SCHEMA_VERSION,
            "seq": self._seq,
            "t": time.perf_counter() - self._t0,
            "op": op,
            "wall_s": float(wall_s),
        }
        for k, v in fields.items():
            if v is None:
                continue
            if k == "capacity":
                rec["capacity"] = int(v)
                rec["cap_bucket"] = capacity_bucket(int(v))
                continue
            if k in ("tenants", "ticks", "cap_bucket"):
                v = int(v)
            elif k in ("dispatch_s",):
                v = float(v)
            elif k == "compile":
                v = bool(v)
            rec[k] = v
        validate_record(rec)
        self._seq += 1
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def op(self, op: str, *, signature: Any = None, **fields):
        """Context manager: times the body and records one line.

        ``signature`` feeds the compile-vs-steady flag (first call at a
        given (op, signature) is the compiling one). Extra ``fields``
        land in the record. The open record dict is yielded so the body
        can attach late fields (e.g. ``dispatch_s``).
        """
        return _OpContext(self, op, signature, fields)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._owns:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _OpContext:
    def __init__(self, tracer: Tracer, op: str, signature, fields):
        self._tracer = tracer
        self._op = op
        self._sig = signature
        self._fields = dict(fields)
        self._ann = None
        self.late: dict[str, Any] = {}

    def __enter__(self):
        self._fields.setdefault(
            "compile", self._tracer.first_call(self._op, self._sig))
        if self._tracer.annotate:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(f"repro.{self._op}")
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        wall = time.perf_counter() - self._t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        if exc[0] is None:
            self._tracer.record(self._op, wall,
                                **{**self._fields, **self.late})
        return False


__all__ = ["SCHEMA_VERSION", "OP_KINDS", "TRACE_SCHEMA", "Tracer",
           "capacity_bucket", "validate_record", "read_trace",
           "validate_trace_file"]
