"""JSONL per-op trace recorder for the serving hot path.

One JSON object per line, one line per engine-level operation. The file
is the recording half of the ROADMAP's trace-driven benchmark: a replay
harness can re-drive the engines from the ``op``/``ticks``/``tenants``
sequence, and the timing fields calibrate per-bucket cost models.

Schema (``TRACE_SCHEMA``) — every record carries the required fields;
optional fields appear when the recorder knows them:

required
    schema      int   trace format version (<= SCHEMA_VERSION; v1 files
                      stay readable — v2 only *adds* optional fields)
    seq         int   per-tracer monotone record index
    t           float seconds since tracer start (host clock). Replay
                      treats this as the op's arrival time.
    op          str   one of OP_KINDS
    wall_s      float host wall time around the dispatch. JAX dispatch
                      is async: unless the caller synchronized, this is
                      enqueue + host-side time, not device time (the
                      per-op histogram of synchronized loops — e.g. the
                      launcher's per-tick loop, which fetches p-values
                      every tick — is device-true). Generated (loadgen)
                      traces write 0.0 — no timing was observed.
optional
    compile     bool  first call at this (op, shape signature): wall_s
                      includes XLA compile ("compile-vs-steady" flag)
    tenants     int   session slots in the dispatch
    ticks       int   ticks advanced (observe_many chunk length)
    capacity    int   per-session padded capacity
    cap_bucket  int   next_pow2(capacity) — the retrace bucket
    engine      str   "classification" | "regression" | "registry"
    dispatch_s  float device-synchronized time, when the caller timed a
                      ``block_until_ready`` explicitly (the engines set
                      it under ``sync_timing=True``)
optional, schema v2 (replay/loadgen)
    workload    str   synthetic-trace generator kind (telemetry.loadgen)
    active      list  tenant slots active on this tick (ints); absent
                      means all ``tenants`` slots are active
    slo_s       float per-op latency objective; replay counts a
                      violation when sojourn (completion - arrival)
                      exceeds it
    seed        int   generator seed (synthetic traces)
optional, schema v3 (fault schedule — robustness.faults)
    fault       dict  the fault stamped onto this record by the chaos
                      harness: ``{"kind": <fault kind>, ...}`` —
                      traffic value faults add ``tenant``;
                      ``duplicate_arrival`` adds ``of_seq`` (the seq of
                      the earlier observe this record re-delivers).
                      Replay honors it (corrupts the tick's inputs /
                      dedups); fault-unaware readers ignore it. v2
                      files (no fault fields) validate unchanged.
    delay_s     float injected dispatch delay: replay treats arrival as
                      ``t + delay_s``
    extra: any remaining keys are recorder-specific (e.g. drained device
    counters on a flush record) and must be JSON-serializable.

``Tracer(path, annotate=True)`` additionally wraps each recorded op in a
``jax.profiler.TraceAnnotation`` so records line up with device traces
captured via ``jax.profiler.trace()``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, IO

SCHEMA_VERSION = 3

OP_KINDS = (
    "observe", "observe_many", "predict", "intervals", "pvalues",
    "evict", "grow", "snapshot_save", "snapshot_restore", "fit",
)

_REQUIRED = {"schema": int, "seq": int, "t": float, "op": str,
             "wall_s": float}
_OPTIONAL = {"compile": bool, "tenants": int, "ticks": int,
             "capacity": int, "cap_bucket": int, "engine": str,
             "dispatch_s": float,
             # v2 (replay/loadgen) fields — all optional, so v1 readers
             # that ignore unknown keys keep working and v1 files
             # validate unchanged
             "workload": str, "active": list, "slo_s": float,
             "seed": int,
             # v3 (fault schedule) fields — same optional-only rule, so
             # v2 files validate unchanged
             "fault": dict, "delay_s": float}

TRACE_SCHEMA = {"version": SCHEMA_VERSION, "required": _REQUIRED,
                "optional": _OPTIONAL, "op_kinds": OP_KINDS}


def capacity_bucket(capacity: int) -> int:
    """The engine retrace bucket: smallest power of two >= capacity."""
    return 1 << max(int(capacity) - 1, 0).bit_length()


def validate_record(rec: dict[str, Any]) -> None:
    """Raise ValueError if ``rec`` does not satisfy TRACE_SCHEMA."""
    for k, ty in _REQUIRED.items():
        if k not in rec:
            raise ValueError(f"trace record missing required field {k!r}: "
                             f"{rec}")
        v = rec[k]
        ok = isinstance(v, ty) or (ty is float and isinstance(v, int)
                                   and not isinstance(v, bool))
        if not ok or (ty is int and isinstance(v, bool)):
            raise ValueError(
                f"trace field {k!r} has type {type(v).__name__}, "
                f"expected {ty.__name__}: {rec}")
    if not 1 <= rec["schema"] <= SCHEMA_VERSION:
        raise ValueError(f"trace schema {rec['schema']} not in "
                         f"1..{SCHEMA_VERSION}")
    if rec["op"] not in OP_KINDS:
        raise ValueError(f"unknown trace op {rec['op']!r} "
                         f"(known: {OP_KINDS})")
    for k, ty in _OPTIONAL.items():
        if k in rec:
            v = rec[k]
            ok = isinstance(v, ty) or (ty is float and isinstance(v, int)
                                       and not isinstance(v, bool))
            if not ok or (ty is int and isinstance(v, bool)):
                raise ValueError(
                    f"trace field {k!r} has type {type(v).__name__}, "
                    f"expected {ty.__name__}: {rec}")
    if "active" in rec and not all(
            isinstance(s, int) and not isinstance(s, bool) and s >= 0
            for s in rec["active"]):
        raise ValueError(f"trace field 'active' must hold non-negative "
                         f"tenant indices: {rec['active']}")
    if "fault" in rec and not isinstance(rec["fault"].get("kind"), str):
        # lenient on purpose (no robustness import): the kind must be a
        # string; harness-specific fields ride along untyped
        raise ValueError(f"trace field 'fault' must carry a string "
                         f"'kind': {rec['fault']}")


def iter_trace(path: str, *, validate: bool = True):
    """Stream a JSONL trace file one record at a time.

    A generator, so replaying a multi-GB trace never loads the whole
    file into memory. ``validate=True`` (default) applies the same
    per-record schema check as ``validate_trace_file`` plus the seq
    monotonicity invariant; ``validate=False`` is the raw parse.
    """
    seq = -1
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if validate:
                validate_record(rec)
                if rec["seq"] <= seq:
                    raise ValueError(
                        f"trace seq not monotone at {rec['seq']}")
                seq = rec["seq"]
            yield rec


def read_trace(path: str) -> list[dict[str, Any]]:
    """Load a JSONL trace file (no validation; see validate_trace_file)."""
    return list(iter_trace(path, validate=False))


def validate_trace_file(path: str) -> list[dict[str, Any]]:
    """Read + schema-validate every record; returns the records."""
    return list(iter_trace(path, validate=True))


def write_trace(path_or_file: str | IO[str],
                records: "list[dict[str, Any]]") -> int:
    """Write pre-built records (e.g. a loadgen trace) as JSONL.

    Unlike ``Tracer.record`` the records' ``t``/``seq`` are taken as
    given — synthetic traces carry *arrival* times, not recording
    times. Every record is schema-validated; returns the record count.
    """
    seq = -1
    f: IO[str]
    if isinstance(path_or_file, str):
        d = os.path.dirname(path_or_file)
        if d:
            os.makedirs(d, exist_ok=True)
        f = open(path_or_file, "w")
        owns = True
    else:
        f, owns = path_or_file, False
    try:
        n = 0
        for rec in records:
            validate_record(rec)
            if rec["seq"] <= seq:
                raise ValueError(f"trace seq not monotone at {rec['seq']}")
            seq = rec["seq"]
            f.write(json.dumps(rec) + "\n")
            n += 1
        return n
    finally:
        if owns:
            f.close()


class Tracer:
    """Append-only JSONL trace writer.

    Records are flushed per line (the file is valid mid-run; a crash
    loses at most the current line). ``annotate=True`` wraps ``op()``
    bodies in ``jax.profiler.TraceAnnotation(op)`` so host records can
    be joined against an XLA profiler trace of the same run.
    """

    def __init__(self, path_or_file: str | IO[str], *,
                 annotate: bool = False):
        if isinstance(path_or_file, str):
            d = os.path.dirname(path_or_file)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f: IO[str] = open(path_or_file, "w")
            self._owns = True
            self.path: str | None = path_or_file
        else:
            self._f = path_or_file
            self._owns = False
            self.path = getattr(path_or_file, "name", None)
        self.annotate = annotate
        self._t0 = time.perf_counter()
        self._seq = 0
        self._seen: set = set()
        self._closed = False

    # -- compile-vs-steady ---------------------------------------------------

    def first_call(self, op: str, signature: Any = None) -> bool:
        """True exactly once per (op, signature): the compile call."""
        key = (op, signature)
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    # -- recording -----------------------------------------------------------

    def record(self, op: str, wall_s: float, **fields) -> dict[str, Any]:
        if self._closed:
            return {}
        rec: dict[str, Any] = {
            "schema": SCHEMA_VERSION,
            "seq": self._seq,
            "t": time.perf_counter() - self._t0,
            "op": op,
            "wall_s": float(wall_s),
        }
        for k, v in fields.items():
            if v is None:
                continue
            if k == "capacity":
                rec["capacity"] = int(v)
                rec["cap_bucket"] = capacity_bucket(int(v))
                continue
            if k in ("tenants", "ticks", "cap_bucket"):
                v = int(v)
            elif k in ("dispatch_s",):
                v = float(v)
            elif k == "compile":
                v = bool(v)
            rec[k] = v
        validate_record(rec)
        self._seq += 1
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def op(self, op: str, *, signature: Any = None, **fields):
        """Context manager: times the body and records one line.

        ``signature`` feeds the compile-vs-steady flag (first call at a
        given (op, signature) is the compiling one). Extra ``fields``
        land in the record. The open record dict is yielded so the body
        can attach late fields (e.g. ``dispatch_s``).
        """
        return _OpContext(self, op, signature, fields)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._owns:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _OpContext:
    def __init__(self, tracer: Tracer, op: str, signature, fields):
        self._tracer = tracer
        self._op = op
        self._sig = signature
        self._fields = dict(fields)
        self._ann = None
        self.late: dict[str, Any] = {}

    def __enter__(self):
        self._fields.setdefault(
            "compile", self._tracer.first_call(self._op, self._sig))
        if self._tracer.annotate:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(f"repro.{self._op}")
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        wall = time.perf_counter() - self._t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        if exc[0] is None:
            self._tracer.record(self._op, wall,
                                **{**self._fields, **self.late})
        return False


__all__ = ["SCHEMA_VERSION", "OP_KINDS", "TRACE_SCHEMA", "Tracer",
           "capacity_bucket", "validate_record", "iter_trace",
           "read_trace", "validate_trace_file", "write_trace"]
